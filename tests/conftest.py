"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose - tests see the
real device count; multi-device tests spawn subprocesses with
--xla_force_host_platform_device_count set explicitly."""

import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_with_devices(code: str, devices: int = 8, timeout: int = 600):
    """Run a python snippet in a subprocess with N fake host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:{r.stdout[-3000:]}\n"
            f"STDERR:{r.stderr[-3000:]}")
    return r.stdout


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
