"""Graph engine vs networkx (independent second opinion on the legacy
wrappers), single partition in-process and 8 partitions via subprocess.

The systematic equality gate is tests/test_oracle_conformance.py — every
registered program x parts x graph family against the pure-NumPy
references in tests/oracle.py (shared here instead of ad-hoc per-test
reimplementations)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
import networkx as nx

import oracle
from repro.core import GraphEngine, partition_graph
from repro.graphs import generate_edges, rmat_edges, urand_edges
from repro.launch.mesh import make_graph_mesh

INT_INF = 2 ** 30


@pytest.fixture(scope="module")
def small_graph():
    n, e = 1500, 12000
    edges = urand_edges(n, e, seed=11)
    g = partition_graph(edges, n, parts=1)
    eng = GraphEngine(g, make_graph_mesh(1))
    G = nx.DiGraph()
    G.add_nodes_from(range(n))
    G.add_edges_from(edges.tolist())
    return n, edges, eng, eng.device_graph(), G


@pytest.mark.parametrize("mode", ["bsp", "fast"])
def test_bfs_vs_networkx(small_graph, mode):
    n, edges, eng, garr, G = small_graph
    root = 5
    dist = nx.single_source_shortest_path_length(G, root)
    parents, levels = eng.bfs(mode=mode)(garr, jnp.int32(root))
    par = eng.gather_vertex_field(parents)
    reached = {v for v in range(n) if par[v] < INT_INF}
    assert reached == set(dist)
    # every parent sits exactly one level above its child
    for v in list(reached)[:400]:
        if v != root:
            assert dist[int(par[v])] == dist[v] - 1


@pytest.mark.parametrize("mode,compress", [("bsp", False), ("fast", False),
                                           ("fast", True)])
def test_pagerank_vs_power_iteration(small_graph, mode, compress):
    n, edges, eng, garr, G = small_graph
    ref = oracle.pagerank(edges, n, iters=100)
    rank, err, it = eng.pagerank(mode=mode, iters=100, tol=1e-10,
                                 compress=compress)(garr)
    r = eng.gather_vertex_field(rank)
    rel = np.abs(r - ref).max() / ref.max()
    assert rel < (5e-3 if compress else 1e-5), rel


def test_pagerank_mass_conservation(small_graph):
    """No-dangling graph conserves total rank mass = 1."""
    n = 1024
    rng = np.random.default_rng(3)
    # ensure every vertex has >= 1 out-edge
    src = np.repeat(np.arange(n), 4)
    dst = rng.integers(0, n, src.size)
    edges = np.stack([src, dst], 1)
    g = partition_graph(edges, n, parts=1)
    eng = GraphEngine(g, make_graph_mesh(1))
    rank, _, _ = eng.pagerank(mode="fast", iters=60, tol=1e-12,
                              compress=False)(eng.device_graph())
    total = float(eng.gather_vertex_field(rank).sum())
    assert abs(total - 1.0) < 1e-3, total


def test_sssp_vs_dijkstra(small_graph):
    n, edges, eng, garr, G = small_graph
    dist, rounds = eng.sssp()(garr, jnp.int32(5))
    d = eng.gather_vertex_field(dist)
    w = oracle.edge_weights(edges)
    Gw = nx.DiGraph()
    Gw.add_nodes_from(range(n))
    Gw.add_weighted_edges_from(
        [(int(a), int(b), float(ww)) for (a, b), ww in zip(edges, w)])
    dref = nx.single_source_dijkstra_path_length(Gw, 5)
    for v, dv in list(dref.items())[:500]:
        assert abs(d[v] - dv) < 1e-3


def test_cc_vs_networkx(small_graph):
    n, edges, eng, garr, G = small_graph
    labels, _ = eng.cc()(garr)
    lab = eng.gather_vertex_field(labels)
    for comp in nx.weakly_connected_components(G):
        assert len({int(lab[v]) for v in comp}) == 1


def test_rmat_generator_skew():
    edges = rmat_edges(12, 4096 * 8, seed=1)
    deg = np.bincount(edges[:, 0], minlength=1 << 12)
    # rmat should be much more skewed than uniform
    assert deg.max() > 8 * deg.mean()


def test_triangles_vs_networkx(small_graph):
    """Independent second opinion (networkx) on the rotation counter;
    the NumPy-oracle gate covers partition counts."""
    n, edges, eng, garr, G = small_graph
    tri, total, _ = eng.program("triangles")(garr)
    Gu = nx.Graph()
    Gu.add_nodes_from(range(n))
    Gu.add_edges_from((int(a), int(b)) for a, b in edges if a != b)
    ref = nx.triangles(Gu)
    t = eng.gather_vertex_field(tri)
    assert {v: int(t[v]) for v in range(n)} == ref
    assert int(total) == sum(ref.values()) // 3


@pytest.mark.slow
def test_multi_partition_parity(run_with_devices=None):
    from conftest import run_with_devices as rwd
    out = rwd("""
import numpy as np, jax, jax.numpy as jnp
from repro.graphs import urand_edges
from repro.core import GraphEngine, partition_graph
from repro.launch.mesh import make_graph_mesh
n, e = 2048, 16384
edges = urand_edges(n, e, seed=3)
res = {}
for parts in (1, 8):
    g = partition_graph(edges, n, parts=parts)
    eng = GraphEngine(g, make_graph_mesh(parts))
    garr = eng.device_graph()
    parents, _ = eng.bfs(mode='fast')(garr, jnp.int32(1))
    rank, _, _ = eng.pagerank(mode='fast', iters=40, tol=1e-12,
                              compress=False)(garr)
    res[parts] = (eng.gather_vertex_field(parents),
                  eng.gather_vertex_field(rank))
reach1 = res[1][0] < 2**30
reach8 = res[8][0] < 2**30
assert (reach1 == reach8).all()
np.testing.assert_allclose(res[1][1], res[8][1], rtol=1e-5, atol=1e-9)
print('PARITY OK')
""", devices=8)
    assert "PARITY OK" in out
