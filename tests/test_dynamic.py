"""The dynamic-graph subsystem acceptance gate.

Pins the ISSUE's four claims:

  * a non-overflowing mutation batch patches the RESIDENT device
    buffers in place — no ``partition_graph`` re-run, no full shard
    re-upload, same compiled program objects;
  * snapshot epochs: launches in flight at mutation time answer for the
    epoch they were admitted under (the patch is copy-on-write), and
    every ``QueryResult`` carries its epoch;
  * bucket overflow falls back to a full rebuild and stays correct;
  * served results after a mutation batch exactly equal the NumPy
    oracle on the POST-MUTATION edge list for every registered
    incremental program, at parts {1, 2, 4}, on uniform AND rmat
    graphs (the warm seed must buy rounds, never correctness).

The standalone tests use (n=512, e=6100): ``partition_graph`` rounds
the COO shards up to 48*128 = 6144, so 44 insert slots are free even
at parts=1.  The conformance sweep families have e = exact multiples
of 128 (zero initial COO slack at parts=1), so each sweep DELETES
first (freeing slots), then inserts — mirroring how a server that has
been up for a while actually accrues slack.
"""

import numpy as np
import pytest

from collections import Counter

from conftest import run_with_devices

import oracle
from repro.core import GraphEngine, partition_graph
from repro.graphs import urand_edges
from repro.launch.mesh import make_graph_mesh
from repro.serve import GraphServer, MutationBatch, mutation_stream, query

TESTS_DIR = __file__.rsplit("/", 1)[0]


def _edge_counter(edges):
    return Counter(map(tuple, np.asarray(edges, np.int64).tolist()))


def _apply_host(edges, inserts=None, deletes=None):
    """The referee's own edge-list mutation (multiset semantics)."""
    edges = np.asarray(edges, np.int64)
    if deletes is not None and len(deletes):
        cd = Counter(map(tuple, np.asarray(deletes, np.int64).tolist()))
        keep = np.ones(len(edges), bool)
        for i, uv in enumerate(map(tuple, edges.tolist())):
            if cd.get(uv, 0):
                cd[uv] -= 1
                keep[i] = False
        assert not +cd, f"deletes not present in edge list: {+cd}"
        edges = edges[keep]
    if inserts is not None and len(inserts):
        edges = np.concatenate([edges, np.asarray(inserts, np.int64)])
    return edges


@pytest.fixture()
def slack_server():
    n, e = 512, 6100
    edges = urand_edges(n, e, seed=7)
    g = partition_graph(edges, n, parts=1)
    eng = GraphEngine(g, make_graph_mesh(1))
    return n, edges, eng, GraphServer(eng, buckets=(4,))


# -- in-place patching ---------------------------------------------------


def test_patch_applies_in_place_without_rebuild(slack_server, monkeypatch):
    """The headline acceptance assert: a fitting batch must never
    re-partition or re-upload — partition_graph is rigged to explode,
    the compiled programs must survive as the SAME objects, and the
    patched device buffers must equal the host mirrors exactly."""
    n, edges, eng, server = slack_server
    server.serve([query("cc")])
    prog_before = eng.program("cc")
    garr_ids = {k: id(v) for k, v in server.garr.items()}

    import repro.serve.dynamic.mutation as mutation_mod
    monkeypatch.setattr(
        mutation_mod, "partition_graph",
        lambda *a, **k: pytest.fail("in-place path called partition_graph"))

    dyn = server.dynamic_graph()
    rng = np.random.default_rng(0)
    dels = dyn.sample_deletable(30, rng)
    ins = dyn.sample_insertable(30, rng)
    stats = server.mutate(inserts=ins, deletes=dels)
    assert not stats.rebuild
    assert stats.epoch == 1 and server.epoch == 1
    assert stats.slots_patched > 0 and stats.arrays_patched > 0

    # same compiled object: the cache key (incl. layout signature) holds
    assert eng.program("cc") is prog_before
    # no full re-upload: only patched arrays changed identity
    changed = {k for k, v in server.garr.items() if id(v) != garr_ids[k]}
    assert changed and changed != set(garr_ids), \
        "either nothing was patched or everything was re-uploaded"
    # patched device buffers == host mirrors, bit for bit
    for k in ("out_src_local", "out_dst_global", "in_src_global",
              "in_dst_local", "out_degree", "in_degree"):
        np.testing.assert_array_equal(
            np.asarray(server.garr[k]), getattr(eng.g, k), err_msg=k)
    # and the live edge multiset is exactly the mutated one
    want = _edge_counter(_apply_host(edges, inserts=ins, deletes=dels))
    assert _edge_counter(dyn.current_edges()) == want

    # served answer on the patched graph is oracle-exact
    res = server.serve([query("cc")])[0]
    edges1 = _apply_host(edges, inserts=ins, deletes=dels)
    np.testing.assert_array_equal(res["labels"], oracle.cc_labels(edges1, n))
    assert res.epoch == 1


def test_epoch_snapshot_isolation(slack_server):
    """A launch in flight when mutate() runs answers for ITS epoch: the
    functional patch never donates the buffers an async launch reads."""
    n, edges, eng, server = slack_server
    q_old = query("cc")
    server.submit_query(q_old)
    server.pump()                          # epoch-0 launch now in flight
    dyn = server.dynamic_graph()
    dels = dyn.sample_deletable(40, np.random.default_rng(1))
    server.mutate(deletes=dels)
    q_new = query("cc")
    res_new = server.serve([q_new])[0]
    server.drain()
    res_old = server.results.pop(q_old.qid)

    assert res_old.epoch == 0 and res_new.epoch == 1
    np.testing.assert_array_equal(
        res_old["labels"], oracle.cc_labels(edges, n),
        err_msg="in-flight launch must answer for the pre-mutation epoch")
    np.testing.assert_array_equal(
        res_new["labels"],
        oracle.cc_labels(_apply_host(edges, deletes=dels), n))


def test_pending_queries_flush_before_mutation(slack_server):
    """Queries ADMITTED before mutate() dispatch against their epoch
    even if they were still queued (never launched) when mutate ran."""
    n, edges, eng, server = slack_server
    q_old = query("cc")
    server.submit_query(q_old)             # queued, not pumped
    dyn = server.dynamic_graph()
    dels = dyn.sample_deletable(25, np.random.default_rng(2))
    server.mutate(deletes=dels)
    server.drain()
    res = server.results.pop(q_old.qid)
    assert res.epoch == 0
    np.testing.assert_array_equal(res["labels"], oracle.cc_labels(edges, n))


def test_mutation_epochs_never_coalesce(slack_server):
    """Same-key refreshes from different epochs must not share a
    launch — the coalescer keys pending queues on (key, epoch)."""
    _, _, _, server = slack_server
    a = query("cc")
    server.submit_query(a)
    dyn = server.dynamic_graph()
    server.mutate(deletes=dyn.sample_deletable(5, np.random.default_rng(3)))
    b = query("cc")
    ra = server.serve([b])[0]
    server.drain()
    res_a = server.results.pop(a.qid)
    assert res_a.epoch == 0 and ra.epoch == 1
    assert res_a.fields is not ra.fields


# -- overflow / rebuild fallback -----------------------------------------


def test_overflow_falls_back_to_rebuild(slack_server):
    """Hammering one row past its bucket width must trip the capacity
    dry-run, re-partition, and stay oracle-exact afterwards."""
    n, edges, eng, server = slack_server
    server.serve([query("cc")])
    # same directed edge many times: row 9's ELL width cannot absorb it
    ins = np.tile([[9, 11]], (300, 1))
    stats = server.mutate(inserts=ins)
    assert stats.rebuild and server.epoch == 1
    assert server.mutation_log[-1]["rebuild"]
    edges1 = _apply_host(edges, inserts=ins)
    dyn = server.dynamic_graph()
    assert _edge_counter(dyn.current_edges()) == _edge_counter(edges1)
    res = server.serve([query("cc"), query("kcore")])
    np.testing.assert_array_equal(res[0]["labels"],
                                  oracle.cc_labels(edges1, n))
    np.testing.assert_array_equal(res[1]["core"],
                                  oracle.core_numbers(edges1, n))
    assert all(r.epoch == 1 for r in res)


def test_mutation_validation(slack_server):
    _, _, _, server = slack_server
    with pytest.raises(ValueError, match="delete"):
        server.mutate(deletes=np.array([[0, 600]]))   # out of range
    with pytest.raises(ValueError, match=r"\(k, 2\)"):
        server.mutate(inserts=np.array([1, 2, 3]))
    with pytest.raises(KeyError):                     # not a live instance
        server.mutate(deletes=np.array([[0, 0], [0, 0], [0, 0], [0, 0],
                                        [0, 0], [0, 0], [0, 0], [0, 0]]))


def test_apply_rolls_back_on_midbatch_failure(slack_server, monkeypatch):
    """Failure atomicity: a planner that raises mid-batch must leave
    the host free-slot index, the ELL/COO mirrors, the occupancy
    counters and the resident device graph at the pre-batch epoch —
    and the SAME batch must then apply cleanly and exactly."""
    n, edges, eng, server = slack_server
    server.serve([query("cc")])
    dyn = server.dynamic_graph()
    rng = np.random.default_rng(3)
    ins = dyn.sample_insertable(6, rng)

    g = eng.g
    ell_keys = [f"{nm}_idx" for nm in ("ell_in", "ell_out",
                                       "ell_dst", "ell_src")]
    coo_keys = ("out_src_local", "out_dst_global", "in_src_global",
                "in_dst_local", "out_degree", "in_degree")
    ell0 = {k: g.ell_arrays[k].copy() for k in ell_keys}
    coo0 = {k: getattr(g, k).copy() for k in coo_keys}
    occ0 = {nm: occ.copy() for nm, occ in dyn._occ.items()}
    free0 = ([list(s) for s in dyn._free_out],
             [list(s) for s in dyn._free_in])
    def _pos_index(dicts):              # empty lists == absent keys
        return [{k: list(v) for k, v in d.items() if v} for d in dicts]

    pos0 = (_pos_index(dyn._pos_out), _pos_index(dyn._pos_in))
    garr0 = dict(dyn.garr)
    edges0 = _edge_counter(dyn.current_edges())
    epoch0 = dyn.epoch

    orig_fill = dyn._ell_fill
    calls = {"n": 0}

    def failing(name, p, row, value, touched):
        calls["n"] += 1                 # 4 fills per insert: call 10 is
        if calls["n"] == 10:            # mid-batch, 2 edges committed
            raise RuntimeError("simulated planner crash")
        return orig_fill(name, p, row, value, touched)

    monkeypatch.setattr(dyn, "_ell_fill", failing)
    with pytest.raises(RuntimeError, match="planner crash"):
        dyn.apply(inserts=ins)

    assert dyn.epoch == epoch0
    for k in ell_keys:
        np.testing.assert_array_equal(g.ell_arrays[k], ell0[k], err_msg=k)
    for k in coo_keys:
        np.testing.assert_array_equal(getattr(g, k), coo0[k], err_msg=k)
    for nm in occ0:
        np.testing.assert_array_equal(dyn._occ[nm], occ0[nm], err_msg=nm)
    assert [list(s) for s in dyn._free_out] == free0[0]
    assert [list(s) for s in dyn._free_in] == free0[1]
    assert _pos_index(dyn._pos_out) == pos0[0]
    assert _pos_index(dyn._pos_in) == pos0[1]
    assert dyn.garr.keys() == garr0.keys()
    assert all(dyn.garr[k] is garr0[k] for k in garr0), \
        "device graph must return to the pre-batch buffers"
    assert _edge_counter(dyn.current_edges()) == edges0

    # the same batch now applies cleanly (wrapper stays installed but
    # only call #10 raises) and the result is exact
    stats = server.mutate(inserts=ins)
    assert not stats.rebuild and dyn.epoch == epoch0 + 1
    want = _edge_counter(_apply_host(edges, inserts=ins))
    assert _edge_counter(dyn.current_edges()) == want
    res = server.serve([query("cc")])[0]
    np.testing.assert_array_equal(
        res["labels"], oracle.cc_labels(_apply_host(edges, inserts=ins), n))


# -- warm seeds ----------------------------------------------------------


def test_seed_resolution_follows_mutation_kinds(slack_server):
    """resolve_seed adopts the stored epoch seed only under admissible
    mutation kinds: cc warm needs insert-only history, kcore warm needs
    delete-only, pagerank warm survives anything."""
    _, _, _, server = slack_server
    server.serve([query("cc"), query("kcore"), query("pagerank")])
    dyn = server.dynamic_graph()
    rng = np.random.default_rng(4)
    server.mutate(deletes=dyn.sample_deletable(20, rng))
    assert not server.resolve_seed(query("cc", "incremental").key)[1]
    assert server.resolve_seed(query("kcore", "incremental").key)[1]
    assert server.resolve_seed(query("pagerank", "warm").key)[1]
    # serving the incremental variants stores fresh epoch-1 seeds ...
    server.serve([query("cc", "incremental"), query("kcore", "incremental")])
    server.mutate(inserts=dyn.sample_insertable(20, rng))
    # ... so cc is warm across the insert batch, kcore no longer is
    assert server.resolve_seed(query("cc", "incremental").key)[1]
    assert not server.resolve_seed(query("kcore", "incremental").key)[1]
    assert server.resolve_seed(query("pagerank", "warm").key)[1]


def test_warm_restart_beats_cold_rounds(slack_server):
    """The warm-restart win the bench gates: after a small mutation,
    pagerank/warm from the previous epoch's rank converges in fewer
    rounds than the cold uniform start (identical tolerance)."""
    n, edges, eng, server = slack_server
    server.serve([query("pagerank", iters=300, tol=1e-6)])
    dyn = server.dynamic_graph()
    rng = np.random.default_rng(5)
    server.mutate(deletes=dyn.sample_deletable(15, rng))
    warm = server.serve([query("pagerank", "warm", iters=300, tol=1e-6)])[0]
    cold = server.serve([query("pagerank", iters=300, tol=1e-6)])[0]
    assert 0 < warm.rounds < cold.rounds, (warm.rounds, cold.rounds)


# -- mutation streams ----------------------------------------------------


def test_mutation_stream_shape():
    edges = urand_edges(128, 1000, seed=0)
    ev = mutation_stream(edges, every=0.5, size=10, duration=2.1, seed=1)
    assert [t for t, _ in ev] == [0.5, 1.0, 1.5, 2.0]
    assert ev[0][1].deletes is not None and ev[1][1].inserts is not None
    for _, mb in ev:
        arr = mb.deletes if mb.deletes is not None else mb.inserts
        assert arr.shape == (10, 2)
    # all delete batches draw (without replacement) from the original list
    dels = np.concatenate([mb.deletes for _, mb in ev
                           if mb.deletes is not None])
    assert not +(_edge_counter(dels) - _edge_counter(edges))
    assert mutation_stream(edges, every=0, size=4, duration=1) == []


def test_serve_trace_applies_mutation_events(slack_server):
    """serve_trace interleaves MutationBatch events with query traffic:
    epochs advance mid-trace and later queries answer the mutated
    graph."""
    n, edges, eng, server = slack_server
    dyn = server.dynamic_graph()
    dels = dyn.sample_deletable(20, np.random.default_rng(6))
    trace = [(0.0, query("cc")),
             (0.01, MutationBatch(deletes=dels)),
             (0.02, query("cc"))]
    results = server.serve_trace(trace)
    by_epoch = {r.epoch: r for r in results}
    assert set(by_epoch) == {0, 1}
    np.testing.assert_array_equal(by_epoch[0]["labels"],
                                  oracle.cc_labels(edges, n))
    np.testing.assert_array_equal(
        by_epoch[1]["labels"],
        oracle.cc_labels(_apply_host(edges, deletes=dels), n))
    assert server.mutation_log[-1]["n_delete"] == 20


# -- the served post-mutation conformance sweep --------------------------

_DYNAMIC_SWEEP_CODE = """
import sys
sys.path.insert(0, {tests_dir!r})
from collections import Counter
import numpy as np
import oracle
from repro.core import GraphEngine, partition_graph
from repro.launch.mesh import make_graph_mesh
from repro.serve import GraphServer, query

family, parts_list, n, seed = {family!r}, {parts!r}, {n}, {seed}
edges0, n = oracle.family_edges(family, n, seed)

def apply_host(edges, inserts=None, deletes=None):
    edges = np.asarray(edges, np.int64)
    if deletes is not None and len(deletes):
        cd = Counter(map(tuple, np.asarray(deletes, np.int64).tolist()))
        keep = np.ones(len(edges), bool)
        for i, uv in enumerate(map(tuple, edges.tolist())):
            if cd.get(uv, 0):
                cd[uv] -= 1
                keep[i] = False
        edges = edges[keep]
    if inserts is not None and len(inserts):
        edges = np.concatenate([edges, np.asarray(inserts, np.int64)])
    return edges

for parts in parts_list:
    g = partition_graph(edges0, n, parts)
    eng = GraphEngine(g, make_graph_mesh(parts))
    server = GraphServer(eng, buckets=(4,))
    # epoch 0: serve the static refreshes (also stores the warm seeds)
    server.serve([query("cc"), query("kcore"), query("pagerank")])
    dyn = server.dynamic_graph()
    rng = np.random.default_rng(seed + parts)

    # ---- delete batch: kcore warm, pagerank warm, cc cold-fallback ----
    dels = dyn.sample_deletable(48, rng)
    stats = server.mutate(deletes=dels)
    edges1 = apply_host(edges0, deletes=dels)
    assert (Counter(map(tuple, dyn.current_edges().tolist()))
            == Counter(map(tuple, edges1.tolist()))), "edge multiset drift"
    assert server.resolve_seed(query("kcore", "incremental").key)[1]
    assert not server.resolve_seed(query("cc", "incremental").key)[1]
    for algo, variant in (("cc", "incremental"), ("kcore", "incremental"),
                          ("pagerank", "warm")):
        params = oracle.CONFORMANCE_PARAMS.get((algo, variant), {{}})
        res = server.serve([query(algo, variant, **params)])[0]
        assert res.epoch == 1, (algo, variant, res.epoch)
        oracle.check_conformance(algo, variant, dict(res.fields),
                                 edges1, n, 0)
        print(f"PASS-DELETE {{algo}}/{{variant}} parts={{parts}} "
              f"rebuild={{stats.rebuild}}")

    # ---- insert batch (slots freed above): cc warm, kcore cold --------
    ins = dyn.sample_insertable(48, rng)
    stats = server.mutate(inserts=ins)
    assert not stats.rebuild, "insert batch was sampled to fit"
    edges2 = apply_host(edges1, inserts=ins)
    assert (Counter(map(tuple, dyn.current_edges().tolist()))
            == Counter(map(tuple, edges2.tolist()))), "edge multiset drift"
    assert server.resolve_seed(query("cc", "incremental").key)[1]
    assert not server.resolve_seed(query("kcore", "incremental").key)[1]
    for algo, variant in (("cc", "incremental"), ("kcore", "incremental"),
                          ("pagerank", "warm")):
        params = oracle.CONFORMANCE_PARAMS.get((algo, variant), {{}})
        res = server.serve([query(algo, variant, **params)])[0]
        assert res.epoch == 2, (algo, variant, res.epoch)
        oracle.check_conformance(algo, variant, dict(res.fields),
                                 edges2, n, 0)
        print(f"PASS-INSERT {{algo}}/{{variant}} parts={{parts}}")

    # the static programs answer the mutated graph too
    res = server.serve([query("cc"), query("kcore")])
    oracle.check_conformance("cc", "default", dict(res[0].fields),
                             edges2, n, 0)
    oracle.check_conformance("kcore", "default", dict(res[1].fields),
                             edges2, n, 0)
print("DYNAMIC-CONFORMANCE-OK " + family)
"""

_INCREMENTAL_PAIRS = (("cc", "incremental"), ("kcore", "incremental"),
                      ("pagerank", "warm"))
_DYN_PARTS = (1, 2, 4)


@pytest.mark.parametrize("family", ("urand", "rmat"))
def test_served_mutation_conformance(family):
    """ISSUE acceptance: served results after a mutation batch exactly
    equal the NumPy oracle on the post-mutation edge list for every
    registered incremental program at parts {1, 2, 4} on uniform and
    rmat graphs."""
    out = run_with_devices(
        _DYNAMIC_SWEEP_CODE.format(tests_dir=TESTS_DIR, family=family,
                                   parts=_DYN_PARTS, n=384, seed=11),
        devices=max(_DYN_PARTS), timeout=1800)
    assert f"DYNAMIC-CONFORMANCE-OK {family}" in out
    for parts in _DYN_PARTS:
        for algo, variant in _INCREMENTAL_PAIRS:
            for phase in ("DELETE", "INSERT"):
                assert f"PASS-{phase} {algo}/{variant} parts={parts}" in out, \
                    f"missing {phase} cell {algo}/{variant} parts={parts}"
