"""Async execution mode: rounds accounting, exec_mode resolution and
loop-mode parity.

The conformance gate (test_oracle_conformance.py) already pins async
OUTPUTS against the oracles; this module pins the async-specific
contracts around them:

* rounds-accounting regression — the async variants pay extra rounds
  for overlap (a cross-partition hop still takes one exchange, and the
  two-zero quiescence rule adds a constant tail), but that overhead is
  BOUNDED: async_rounds <= SLACK_FACTOR * bsp_rounds + SLACK_CONST,
  with identical converged outputs.  The same slack constants gate the
  benchmark artifact (benchmarks/compare.py), so a regression here
  fails before it reaches a perf dashboard.
* exec_mode plumbing — ``program(algo, exec_mode=...)`` re-resolves a
  bare algo to its mode variant (same cache entry as naming the
  variant), asserts consistency against an explicit variant, and
  rejects modes/algos without a variant of that mode.
* loop parity — ``static_iters`` swaps the async while loop for a
  fixed-trip scan without changing converged outputs, and batched
  async programs match their single-source runs.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from conftest import run_with_devices

from repro.core import GraphEngine, registry
from repro.core.graph import partition_graph
from repro.core.superstep import (ASYNC_ROUNDS_SLACK_CONST,
                                  ASYNC_ROUNDS_SLACK_FACTOR)
from repro.graphs import urand_edges
from repro.launch.mesh import make_graph_mesh

N, E, SEED, ROOT = 512, 2048, 11, 3

# (algo, params) pairs with BOTH a bsp-mode and an async-mode variant
# whose converged outputs must agree exactly (monotone min-combine)
MONOTONE = (
    ("bfs", {"max_levels": 64}),
    ("cc", {"max_rounds": 64}),
    ("sssp", {"max_rounds": 64}),
)


@pytest.fixture(scope="module")
def engine():
    g = partition_graph(urand_edges(N, E, seed=SEED), N, parts=1)
    return GraphEngine(g, make_graph_mesh(1))


def _run(eng, algo, exec_mode, params, **kw):
    spec = registry.get_spec(algo, registry.mode_variant(algo, exec_mode))
    prog = eng.program(algo, exec_mode=exec_mode, **params, **kw)
    args = (eng.device_graph(),) + (jnp.int32(ROOT),) * len(spec.inputs)
    *outs, rounds = prog(*args)
    return [eng.gather_vertex_field(o) for o, isv in
            zip(outs, prog.program.output_is_vertex) if isv], int(rounds)


@pytest.mark.parametrize("algo,params", MONOTONE)
def test_async_rounds_within_documented_slack(engine, algo, params):
    """Same outputs, bounded extra rounds — parts=1 in-process."""
    bsp_outs, bsp_rounds = _run(engine, algo, "bsp", params)
    async_outs, async_rounds = _run(engine, algo, "async", params)
    for b, a in zip(bsp_outs, async_outs):
        np.testing.assert_array_equal(b, a)
    cap = ASYNC_ROUNDS_SLACK_FACTOR * bsp_rounds + ASYNC_ROUNDS_SLACK_CONST
    assert async_rounds <= cap, \
        f"{algo}: async {async_rounds} rounds vs bsp {bsp_rounds} (cap {cap})"


_MULTIPART_CODE = """
import numpy as np
import jax.numpy as jnp
from repro.core import GraphEngine, registry
from repro.core.graph import partition_graph
from repro.core.superstep import (ASYNC_ROUNDS_SLACK_CONST,
                                  ASYNC_ROUNDS_SLACK_FACTOR)
from repro.graphs import urand_edges
from repro.launch.mesh import make_graph_mesh

n, e, seed, root, parts = {n}, {e}, {seed}, {root}, {parts}
g = partition_graph(urand_edges(n, e, seed=seed), n, parts=parts)
eng = GraphEngine(g, make_graph_mesh(parts))
garr = eng.device_graph()
for algo, params in {monotone!r}:
    spec = registry.get_spec(algo)
    nroot = len(spec.inputs)
    outs = {{}}
    rounds = {{}}
    for mode in ("bsp", "async"):
        prog = eng.program(algo, exec_mode=mode, **params)
        *o, r = prog(garr, *([jnp.int32(root)] * nroot))
        outs[mode] = [eng.gather_vertex_field(x) for x, isv in
                      zip(o, prog.program.output_is_vertex) if isv]
        rounds[mode] = int(r)
    for b, a in zip(outs["bsp"], outs["async"]):
        np.testing.assert_array_equal(b, a)
    cap = (ASYNC_ROUNDS_SLACK_FACTOR * rounds["bsp"]
           + ASYNC_ROUNDS_SLACK_CONST)
    assert rounds["async"] <= cap, (algo, rounds, cap)
    print(f"ROUNDS-OK {{algo}} bsp={{rounds['bsp']}} "
          f"async={{rounds['async']}}")
"""


def test_async_rounds_within_slack_multipart():
    """Same regression under real multi-partition exchange (parts=4):
    the slack must absorb the cross-partition relay latency, not just
    the degenerate single-shard quiescence tail."""
    out = run_with_devices(
        _MULTIPART_CODE.format(n=N, e=E, seed=SEED, root=ROOT, parts=4,
                               monotone=MONOTONE),
        devices=4, timeout=900)
    for algo, _ in MONOTONE:
        assert f"ROUNDS-OK {algo} " in out


def test_exec_mode_resolves_bare_algo(engine):
    """exec_mode='async' on a bare algo is exactly the async variant —
    the SAME cached compile, not a sibling entry."""
    via_mode = engine.program("bfs", exec_mode="async")
    via_name = engine.program("bfs", "async")
    assert via_mode is via_name
    assert via_mode.spec.exec_mode == "async"
    # bsp re-resolution lands on the default variant of that mode
    bsp = engine.program("bfs", exec_mode="bsp")
    assert bsp.spec.exec_mode == "bsp"
    assert bsp is engine.program("bfs", bsp.spec.variant)


def test_exec_mode_conflicts_raise(engine):
    with pytest.raises(ValueError, match="contradicts"):
        engine.program("bfs", "fast", exec_mode="async")
    with pytest.raises(ValueError, match="contradicts"):
        engine.program("pagerank/async", exec_mode="bsp")
    with pytest.raises(ValueError, match="no async variant"):
        engine.program("triangles", exec_mode="async")
    with pytest.raises(ValueError, match="exec_mode"):
        engine.program("bfs", exec_mode="speculative")


def test_exec_mode_in_cache_key(engine):
    """bsp and async compiles of one algo must coexist in the cache."""
    a = engine.program("cc", exec_mode="async")
    b = engine.program("cc", exec_mode="bsp")
    assert a is not b
    assert a is engine.program("cc", exec_mode="async")


def test_async_static_iters_scan_parity(engine):
    """Fixed-trip scan (the dry-run path) runs exactly static_iters
    rounds and still lands on the converged outputs."""
    (dist,), rounds = _run(engine, "sssp", "async", {}, static_iters=24)
    assert rounds == 24
    (dist_dyn,), _ = _run(engine, "sssp", "async", {})
    np.testing.assert_array_equal(dist, dist_dyn)


def test_async_batched_matches_single_source(engine):
    roots = np.asarray([0, 3, 17, 200], np.int32)
    prog = engine.program("bfs", exec_mode="async", batch=len(roots))
    parents, rounds = prog(engine.device_graph(), jnp.asarray(roots))
    batched = engine.gather_batched_vertex_field(parents)
    single = engine.program("bfs", exec_mode="async")
    for i, r in enumerate(roots):
        p, _ = single(engine.device_graph(), jnp.int32(r))
        np.testing.assert_array_equal(batched[i],
                                      engine.gather_vertex_field(p))
