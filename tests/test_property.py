"""Property-based tests (hypothesis) on system invariants.

The async-exchange properties run the REAL partitioned collectives
under ``jax.vmap(..., axis_name=AXIS)``: vmap's batching rules for
``all_to_all``/``psum_scatter``/``psum`` execute the same cross-part
semantics on one device, so hypothesis can drive random (parts,
n_local, payload) cases in-process instead of one subprocess per
example.  Multi-device coverage of the identical code path is gated by
tests/test_oracle_conformance.py and tests/test_async.py.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax
import jax.numpy as jnp

from repro.core.partitioned import AXIS, \
    exchange_min_finish, exchange_min_start, \
    exchange_or_finish, exchange_or_start, \
    exchange_sum_finish, exchange_sum_start, \
    pack_bits as _pack_bits, psum_scalar, \
    test_bit as _test_bits, unpack_bits
from repro.distributed.compression import quantize_int8
from repro.graphs import urand_edges
from repro.core.graph import partition_graph
from repro.models import layers as L

SETTINGS = dict(max_examples=20, deadline=None)


@given(st.integers(1, 200), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_pack_unpack_bits_roundtrip(nwords, seed):
    rng = np.random.default_rng(seed)
    bits = jnp.asarray(rng.integers(0, 2, nwords * 32, dtype=np.int32)
                       .astype(bool))
    packed = _pack_bits(bits)
    idx = jnp.arange(nwords * 32, dtype=jnp.int32)
    recovered = _test_bits(packed, idx) == 1
    np.testing.assert_array_equal(np.asarray(recovered), np.asarray(bits))


@given(st.integers(2, 64), st.integers(1, 6), st.integers(0, 2 ** 20))
@settings(**SETTINGS)
def test_partition_conserves_edges(nv_exp, deg, seed):
    """Sum of valid edges across partitions == |E| for both layouts."""
    n = 32 * nv_exp
    e = n * deg
    edges = urand_edges(n, e, seed=seed)
    for parts in (1, 2, 4):
        g = partition_graph(edges, n, parts=parts)
        out_valid = (g.out_dst_global < g.n).sum()
        in_valid = (g.in_src_global < g.n).sum()
        assert out_valid == e, (parts, out_valid, e)
        assert in_valid == e, (parts, in_valid, e)
        # degree fields consistent
        assert g.out_degree.sum() == e
        assert g.in_degree.sum() == e


@given(st.integers(1, 8), st.integers(4, 32), st.integers(0, 2 ** 20))
@settings(**SETTINGS)
def test_flash_matches_naive_property(heads, seq4, seed):
    s = 4 * seq4
    key = jax.random.key(seed)
    ks = jax.random.split(key, 3)
    q, k, v = [jax.random.normal(kk, (1, s, heads, 8)) for kk in ks]
    o1 = L.flash_attention_xla(q, k, v, True, 0, 0.0, 16, 16)
    o2 = L.attention_naive(q, k, v, q_pos=jnp.arange(s), k_pos=jnp.arange(s),
                           causal=True, window=0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5)


@given(st.integers(0, 2 ** 20))
@settings(**SETTINGS)
def test_softmax_rows_sum_to_one(seed):
    s = 32
    q, k, v = [jax.random.normal(jax.random.key(seed + i), (1, s, 2, 8))
               for i in range(3)]
    # with v = ones, attention output must be exactly ones (row-stochastic)
    ones = jnp.ones_like(v)
    o = L.attention_naive(q, k, ones, q_pos=jnp.arange(s),
                          k_pos=jnp.arange(s), causal=True, window=0)
    np.testing.assert_allclose(np.asarray(o), 1.0, atol=1e-5)


@given(st.integers(0, 2 ** 16), st.floats(0.01, 100.0))
@settings(**SETTINGS)
def test_int8_error_feedback_bounded(seed, scale):
    """Quantization residual is bounded by one quantization step."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=256).astype(np.float32)) * scale
    resid = jnp.zeros_like(x)
    q, s, r = quantize_int8(x, resid)
    assert float(jnp.abs(r).max()) <= float(s) * 0.5 + 1e-6
    # dequantized + residual reconstructs exactly
    np.testing.assert_allclose(
        np.asarray(q.astype(jnp.float32) * s + r), np.asarray(x),
        rtol=1e-5, atol=1e-5)


# -- async double-buffered exchange properties ----------------------------

def _parted(fn, *arrays):
    """Run a partitioned-collective body on one device: vmap over the
    leading parts axis with the partition axis NAME bound, so
    all_to_all/psum_scatter/psum execute their real cross-part
    semantics in-process."""
    return jax.vmap(fn, axis_name=AXIS)(*arrays)


@given(st.sampled_from([2, 3, 4, 8]), st.integers(1, 4),
       st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_double_buffered_exchange_matches_blocking(parts, nw, seed):
    """Splitting an exchange into start (ship) + finish (reduce) must
    deliver EXACTLY what the blocking collective delivers — same rows,
    same reduction, bit for bit — for all three reduction flavors."""
    n_local = 32 * nw
    n = parts * n_local
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(rng.normal(size=(parts, n)).astype(np.float32))
    scal = jnp.asarray(rng.integers(0, 1 << 20, parts).astype(np.float32))
    mask = jnp.asarray(rng.integers(0, 2, size=(parts, n)).astype(bool))
    cnt = jnp.asarray(rng.integers(0, 1 << 20, parts).astype(np.uint32))

    def min_async(v, s):
        return exchange_min_finish(exchange_min_start(v, s))

    def min_blocking(v, s):
        rows = jax.lax.all_to_all(v.reshape(parts, 1, n_local), AXIS,
                                  split_axis=0, concat_axis=1)
        return rows.min(axis=(0, 1)), psum_scalar(s)

    for got, ref in zip(_parted(min_async, vals, scal),
                        _parted(min_blocking, vals, scal)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def sum_async(v, s):
        return exchange_sum_finish(exchange_sum_start(v, s))

    def sum_blocking(v, s):
        acc = jax.lax.psum_scatter(v.reshape(parts, n_local), AXIS,
                                   scatter_dimension=0, tiled=False)
        return acc, psum_scalar(s)

    for got, ref in zip(_parted(sum_async, vals, scal),
                        _parted(sum_blocking, vals, scal)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def or_async(m, c):
        return exchange_or_finish(exchange_or_start(m, c), n_local)

    def or_blocking(m, c):
        rows = jax.lax.all_to_all(
            _pack_bits(m).reshape(parts, 1, -1), AXIS,
            split_axis=0, concat_axis=1).reshape(parts, -1)
        acc = jax.lax.reduce(rows, jnp.uint32(0), jax.lax.bitwise_or, (0,))
        return unpack_bits(acc, n_local), \
            psum_scalar(c.astype(jnp.int32))

    for got, ref in zip(_parted(or_async, mask, cnt),
                        _parted(or_blocking, mask, cnt)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@given(st.sampled_from([2, 4, 8]), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_piggybacked_halt_is_bitexact_psum(parts, seed):
    """The halt count stamped on every outgoing row and summed at the
    receiver must equal a separate psum_scalar BIT FOR BIT: int-valued
    counts are exact in the f32 payload column up to 2^24, and every
    receiver sums the same P stamps in the same order."""
    n_local = 32
    rng = np.random.default_rng(seed)
    # per-part change counts; bound so even parts * max stays < 2^24
    counts = rng.integers(0, 1 << 20, parts).astype(np.int32)
    vals = jnp.asarray(rng.normal(size=(parts, parts * n_local))
                       .astype(np.float32))

    def piggy(v, c):
        _, tot = exchange_min_finish(
            exchange_min_start(v, c.astype(jnp.float32)))
        return tot

    def separate(_, c):
        return psum_scalar(c)

    tot = _parted(piggy, vals, jnp.asarray(counts))
    ref = _parted(separate, vals, jnp.asarray(counts))
    # every partition observes the identical, exactly-integral total
    np.testing.assert_array_equal(np.asarray(tot).astype(np.int64),
                                  np.asarray(ref).astype(np.int64))
    assert int(np.asarray(tot)[0]) == int(counts.sum())


def _stale_pagerank_residuals(edges, n, parts, staleness, rounds,
                              alpha=0.85):
    """NumPy model of pagerank/async's stale recurrence: the push
    matrix splits into a same-partition block D (always fresh) and a
    cross-partition block R whose product is shipped at refresh rounds
    and served stale in between — exactly the program's schedule
    (init ships R@x0; fold refreshes when it % staleness == 0)."""
    n_local = n // parts
    deg = np.bincount(edges[:, 0], minlength=n).astype(np.float64)
    inv = np.where(deg > 0, 1.0 / np.maximum(deg, 1.0), 0.0)
    M = np.zeros((n, n))
    np.add.at(M, (edges[:, 1], edges[:, 0]), inv[edges[:, 0]])
    owner = np.arange(n) // n_local
    same = owner[:, None] == owner[None, :]
    D, R = M * same, M * ~same
    base = (1.0 - alpha) / n
    x = np.full(n, 1.0 / n)
    inflight = R @ x
    remote = np.zeros(n)
    res = []
    for r in range(rounds):
        new_x = base + alpha * (D @ x + remote)
        res.append(np.abs(new_x - x).sum())
        if r % staleness == 0:
            remote, inflight = inflight, R @ x
        x = new_x
    return np.asarray(res)


@given(st.integers(2, 8), st.integers(1, 8), st.sampled_from([2, 4]),
       st.integers(1, 3), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_stale_pagerank_window_max_residual_monotone(
        nb, degree, parts, staleness, seed):
    """Bounded staleness keeps pagerank an alpha-contraction with delay
    bound d = 2*staleness + 1: per-round residual may oscillate, but
    its max over consecutive windows of d + 1 rounds must be monotone
    non-increasing — the convergence claim pagerank/async's docstring
    makes, pinned on the NumPy model of the exact refresh schedule."""
    n = 16 * nb * parts
    rng = np.random.default_rng(seed)
    edges = rng.integers(0, n, size=(n * degree, 2))
    res = _stale_pagerank_residuals(edges, n, parts, staleness, rounds=64)
    w = 2 * staleness + 2
    wm = np.asarray([res[i * w:(i + 1) * w].max()
                     for i in range(len(res) // w)])
    assert np.all(wm[1:] <= wm[:-1] * (1 + 1e-9) + 1e-15), \
        f"window-max residual increased: {wm}"
    # and the tail actually decays (contraction, not mere boundedness)
    assert wm[-1] < wm[0] * 0.9


# -- durability: WAL framing + snapshot envelope properties ---------------
#
# Bytes-level (no files, no jax): the WAL/snapshot modules are jax-free
# so these properties exercise exactly the code the recovery path trusts.

from repro.serve.persist.snapshot import SnapshotCorrupt, \
    pack_snapshot, unpack_snapshot  # noqa: E402
from repro.serve.persist.wal import WalRecord, edge_digest, \
    encode_record, scan_records, update_digest  # noqa: E402


def _random_records(rng, k):
    recs, digest, count = [], 0, 0
    for i in range(1, k + 1):
        ins = rng.integers(0, 1 << 40, size=(int(rng.integers(0, 6)), 2))
        dels = rng.integers(0, 1 << 40, size=(int(rng.integers(0, 6)), 2))
        digest, count = update_digest(digest, count, ins, dels)
        recs.append(WalRecord(i, i, bool(rng.integers(0, 2)),
                              digest, max(count, 0),
                              ins.astype(np.int64), dels.astype(np.int64)))
    return recs


def _same_record(a, b):
    return (a.batch_id == b.batch_id and a.epoch == b.epoch
            and a.rebuild == b.rebuild and a.digest == b.digest
            and a.count == b.count
            and np.array_equal(a.inserts, b.inserts)
            and np.array_equal(a.deletes, b.deletes))


@given(st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_wal_records_roundtrip_through_scan(k, seed):
    """encode -> concatenate -> scan recovers every record exactly and
    consumes the whole buffer (canonical framing, no slack bytes)."""
    recs = _random_records(np.random.default_rng(seed), k)
    data = b"".join(encode_record(r) for r in recs)
    got, end = scan_records(data)
    assert end == len(data)
    assert len(got) == k
    assert all(_same_record(a, b) for a, b in zip(got, recs))


@given(st.integers(1, 6), st.integers(0, 2 ** 31 - 1),
       st.floats(0.0, 1.0))
@settings(**SETTINGS)
def test_wal_truncated_tail_yields_exact_prefix(k, seed, cut_frac):
    """Cutting the log at ANY byte position yields exactly the records
    whose frames lie fully before the cut — the prefix-durability
    contract a torn tail relies on."""
    recs = _random_records(np.random.default_rng(seed), k)
    frames = [encode_record(r) for r in recs]
    data = b"".join(frames)
    cut = int(round(cut_frac * len(data)))
    bounds = np.cumsum([0] + [len(f) for f in frames])
    expect = int(np.searchsorted(bounds, cut, side="right")) - 1
    got, end = scan_records(data[:cut])
    assert len(got) == expect
    assert end == int(bounds[expect])
    assert all(_same_record(a, b) for a, b in zip(got, recs))


@given(st.integers(1, 6), st.integers(0, 2 ** 31 - 1),
       st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_wal_bitflip_stops_scan_at_damaged_record(k, seed, flip_seed):
    """One flipped bit anywhere in frame i: the scan returns exactly
    the i preceding records, bit-identical, and nothing after."""
    recs = _random_records(np.random.default_rng(seed), k)
    frames = [encode_record(r) for r in recs]
    frng = np.random.default_rng(flip_seed)
    i = int(frng.integers(0, k))
    pos = int(frng.integers(0, len(frames[i])))
    bit = 1 << int(frng.integers(0, 8))
    buf = bytearray(frames[i])
    buf[pos] ^= bit
    frames[i] = bytes(buf)
    got, _ = scan_records(b"".join(frames))
    assert len(got) == i
    assert all(_same_record(a, b) for a, b in zip(got, recs))


@given(st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_edge_digest_order_free_and_invertible(seed):
    """The multiset digest is permutation-invariant, and inserting then
    deleting the same edges is the identity — the two algebraic facts
    write-ahead digest computation rests on."""
    rng = np.random.default_rng(seed)
    edges = rng.integers(0, 1 << 40, size=(int(rng.integers(1, 64)), 2))
    d0 = edge_digest(edges)
    assert edge_digest(rng.permutation(edges, axis=0)) == d0
    batch = rng.integers(0, 1 << 40, size=(8, 2))
    d1 = update_digest(*d0, batch, np.zeros((0, 2), np.int64))
    assert update_digest(*d1, np.zeros((0, 2), np.int64), batch) == d0


@given(st.integers(0, 2 ** 31 - 1), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_snapshot_envelope_roundtrip_and_flip_detection(seed, flip_seed):
    """pack/unpack round-trips the state dict; flipping any single bit
    of the envelope raises SnapshotCorrupt (CRC covers epoch+payload)."""
    rng = np.random.default_rng(seed)
    epoch = int(rng.integers(0, 1 << 40))
    state = {"format": 1, "epoch": epoch,
             "arr": rng.integers(0, 100, size=(4, 2)),
             "nested": {"k": list(rng.integers(0, 9, 3))}}
    data = pack_snapshot(epoch, state)
    got_epoch, got = unpack_snapshot(data)
    assert got_epoch == epoch and got["epoch"] == epoch
    np.testing.assert_array_equal(got["arr"], state["arr"])
    frng = np.random.default_rng(flip_seed)
    pos = int(frng.integers(0, len(data)))
    buf = bytearray(data)
    buf[pos] ^= 1 << int(frng.integers(0, 8))
    with pytest.raises(SnapshotCorrupt):
        unpack_snapshot(bytes(buf))
    with pytest.raises(SnapshotCorrupt):
        unpack_snapshot(data[:int(frng.integers(0, len(data)))])


@given(st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_bfs_parents_form_valid_tree(seed):
    """Random small graph: BFS parents always one level apart (oracle-free
    invariant: parent of v was reached before v)."""
    import networkx as nx
    from repro.core import GraphEngine
    from repro.launch.mesh import make_graph_mesh
    n = 256
    edges = urand_edges(n, 1024, seed=seed)
    g = partition_graph(edges, n, parts=1)
    eng = GraphEngine(g, make_graph_mesh(1))
    parents, _ = eng.bfs(mode="fast")(eng.device_graph(), jnp.int32(0))
    par = eng.gather_vertex_field(parents)
    G = nx.DiGraph()
    G.add_nodes_from(range(n))
    G.add_edges_from(edges.tolist())
    dist = nx.single_source_shortest_path_length(G, 0)
    reached = {v for v in range(n) if par[v] < 2 ** 30}
    assert reached == set(dist)
    for v in reached:
        if v != 0:
            assert dist[int(par[v])] == dist[v] - 1
