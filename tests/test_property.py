"""Property-based tests (hypothesis) on system invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax
import jax.numpy as jnp

from repro.core.partitioned import pack_bits as _pack_bits, \
    test_bit as _test_bits
from repro.distributed.compression import quantize_int8
from repro.graphs import urand_edges
from repro.core.graph import partition_graph
from repro.models import layers as L

SETTINGS = dict(max_examples=20, deadline=None)


@given(st.integers(1, 200), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_pack_unpack_bits_roundtrip(nwords, seed):
    rng = np.random.default_rng(seed)
    bits = jnp.asarray(rng.integers(0, 2, nwords * 32, dtype=np.int32)
                       .astype(bool))
    packed = _pack_bits(bits)
    idx = jnp.arange(nwords * 32, dtype=jnp.int32)
    recovered = _test_bits(packed, idx) == 1
    np.testing.assert_array_equal(np.asarray(recovered), np.asarray(bits))


@given(st.integers(2, 64), st.integers(1, 6), st.integers(0, 2 ** 20))
@settings(**SETTINGS)
def test_partition_conserves_edges(nv_exp, deg, seed):
    """Sum of valid edges across partitions == |E| for both layouts."""
    n = 32 * nv_exp
    e = n * deg
    edges = urand_edges(n, e, seed=seed)
    for parts in (1, 2, 4):
        g = partition_graph(edges, n, parts=parts)
        out_valid = (g.out_dst_global < g.n).sum()
        in_valid = (g.in_src_global < g.n).sum()
        assert out_valid == e, (parts, out_valid, e)
        assert in_valid == e, (parts, in_valid, e)
        # degree fields consistent
        assert g.out_degree.sum() == e
        assert g.in_degree.sum() == e


@given(st.integers(1, 8), st.integers(4, 32), st.integers(0, 2 ** 20))
@settings(**SETTINGS)
def test_flash_matches_naive_property(heads, seq4, seed):
    s = 4 * seq4
    key = jax.random.key(seed)
    ks = jax.random.split(key, 3)
    q, k, v = [jax.random.normal(kk, (1, s, heads, 8)) for kk in ks]
    o1 = L.flash_attention_xla(q, k, v, True, 0, 0.0, 16, 16)
    o2 = L.attention_naive(q, k, v, q_pos=jnp.arange(s), k_pos=jnp.arange(s),
                           causal=True, window=0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5)


@given(st.integers(0, 2 ** 20))
@settings(**SETTINGS)
def test_softmax_rows_sum_to_one(seed):
    s = 32
    q, k, v = [jax.random.normal(jax.random.key(seed + i), (1, s, 2, 8))
               for i in range(3)]
    # with v = ones, attention output must be exactly ones (row-stochastic)
    ones = jnp.ones_like(v)
    o = L.attention_naive(q, k, ones, q_pos=jnp.arange(s),
                          k_pos=jnp.arange(s), causal=True, window=0)
    np.testing.assert_allclose(np.asarray(o), 1.0, atol=1e-5)


@given(st.integers(0, 2 ** 16), st.floats(0.01, 100.0))
@settings(**SETTINGS)
def test_int8_error_feedback_bounded(seed, scale):
    """Quantization residual is bounded by one quantization step."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=256).astype(np.float32)) * scale
    resid = jnp.zeros_like(x)
    q, s, r = quantize_int8(x, resid)
    assert float(jnp.abs(r).max()) <= float(s) * 0.5 + 1e-6
    # dequantized + residual reconstructs exactly
    np.testing.assert_allclose(
        np.asarray(q.astype(jnp.float32) * s + r), np.asarray(x),
        rtol=1e-5, atol=1e-5)


@given(st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_bfs_parents_form_valid_tree(seed):
    """Random small graph: BFS parents always one level apart (oracle-free
    invariant: parent of v was reached before v)."""
    import networkx as nx
    from repro.core import GraphEngine
    from repro.launch.mesh import make_graph_mesh
    n = 256
    edges = urand_edges(n, 1024, seed=seed)
    g = partition_graph(edges, n, parts=1)
    eng = GraphEngine(g, make_graph_mesh(1))
    parents, _ = eng.bfs(mode="fast")(eng.device_graph(), jnp.int32(0))
    par = eng.gather_vertex_field(parents)
    G = nx.DiGraph()
    G.add_nodes_from(range(n))
    G.add_edges_from(edges.tolist())
    dist = nx.single_source_shortest_path_length(G, 0)
    reached = {v for v in range(n) if par[v] < 2 ** 30}
    assert reached == set(dist)
    for v in reached:
        if v != 0:
            assert dist[int(par[v])] == dist[v] - 1
