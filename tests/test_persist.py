"""Durable serving state (PR 9): WAL framing, crash-consistent
snapshots, recovery semantics, and the subprocess kill drills.

Tier-1 (in-process, parts=1): record framing + torn-tail/bit-flip
handling, the commutative edge digest, WAL-before-apply ordering (an
apply failure truncates the orphan record; an append failure blocks
the apply), idempotent replay of snapshotted batch ids, rebuild-record
replay, seed-store round-trip, corrupt-snapshot fallback, metrics
observability, and the docs drift guard for the crash-point table.

The `durability` lane (subprocess, parts=2) is the acceptance drill:
for each named crash point a victim server is killed mid-trace at that
exact protocol instruction (``REPRO_CRASH_POINT``), a fresh process
recovers the directory, and the recovered epoch, edge multiset, and
every re-served probe answer must be bit-identical to an uninterrupted
reference server at that epoch — same bar as tests/test_chaos.py.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from conftest import SRC

from repro.core import GraphEngine, partition_graph
from repro.graphs import urand_edges
from repro.launch.mesh import make_graph_mesh
from repro.serve import GraphServer, Persistence, Query, make_key
from repro.serve.dynamic.mutation import DynamicGraph
from repro.serve.persist import CRASH_EXIT_CODE, CRASH_POINTS, \
    crash_points_markdown_table, maybe_crash, reset_counts
from repro.serve.persist.recover import RecoveryFailed, recover_state
from repro.serve.persist.snapshot import SnapshotCorrupt, find_snapshots, \
    load_snapshot, pack_snapshot, unpack_snapshot, write_snapshot
from repro.serve.persist.wal import FILE_MAGIC, WalRecord, WriteAheadLog, \
    edge_digest, encode_record, update_digest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _rec(bid, epoch, ins=(), dels=(), rebuild=False):
    return WalRecord(batch_id=bid, epoch=epoch, rebuild=rebuild,
                     digest=bid * 17, count=bid,
                     inserts=np.asarray(ins, np.int64).reshape(-1, 2),
                     deletes=np.asarray(dels, np.int64).reshape(-1, 2))


def _same(a: WalRecord, b: WalRecord) -> bool:
    return (a.batch_id == b.batch_id and a.epoch == b.epoch
            and a.rebuild == b.rebuild and a.digest == b.digest
            and a.count == b.count
            and np.array_equal(a.inserts, b.inserts)
            and np.array_equal(a.deletes, b.deletes))


def _make_server(pdir=None, *, n=256, e=2048, seed=11, snapshot_every=2,
                 retain=2, **kw):
    edges = urand_edges(n, e, seed=seed)
    g = partition_graph(edges, n, 1)
    eng = GraphEngine(g, make_graph_mesh(1))
    pers = Persistence(dir=str(pdir), snapshot_every=snapshot_every,
                       retain=retain, fsync=False) \
        if pdir is not None else None
    return GraphServer(eng, buckets=(4,), persistence=pers, **kw)


def _run_rounds(server, rounds, rng):
    """The shared deterministic trace: per round one delete batch, one
    insert batch (sampled against live capacity), one served query."""
    dyn = server.dynamic_graph()
    for _ in range(rounds):
        server.mutate(deletes=dyn.sample_deletable(12, rng))
        server.mutate(inserts=dyn.sample_insertable(12, rng))
        server.serve([Query(make_key("bfs"), 3)])


def _sorted_edges(dyn):
    cur = dyn.current_edges()
    return cur[np.lexsort((cur[:, 1], cur[:, 0]))]


# -- WAL framing -------------------------------------------------------------

def test_wal_roundtrip_and_reopen(tmp_path):
    path = tmp_path / "wal.log"
    wal = WriteAheadLog(path, fsync=False)
    recs = [_rec(1, 1, ins=[[0, 1]]),
            _rec(2, 2, dels=[[3, 4], [5, 6]], rebuild=True),
            _rec(3, 3)]
    for r in recs:
        wal.append(r)
    wal.close()
    wal2 = WriteAheadLog(path, fsync=False)
    assert wal2.n_records == 3
    assert all(_same(a, b) for a, b in zip(recs, wal2.records))
    wal2.close()


def test_wal_torn_tail_truncated(tmp_path):
    path = tmp_path / "wal.log"
    wal = WriteAheadLog(path, fsync=False)
    wal.append(_rec(1, 1, ins=[[0, 1]]))
    wal.append(_rec(2, 2, ins=[[2, 3]]))
    wal.close()
    frame = encode_record(_rec(3, 3, ins=[[4, 5]]))
    with open(path, "ab") as f:
        f.write(frame[:len(frame) // 2])      # the crash mid-append
    wal2 = WriteAheadLog(path, fsync=False)
    assert [r.batch_id for r in wal2.records] == [1, 2]
    wal2.close()
    # the torn bytes are gone from disk, not just skipped
    size = os.path.getsize(path)
    assert size == len(FILE_MAGIC) + sum(
        len(encode_record(r)) for r in wal2.records)


def test_wal_bitflip_stops_scan(tmp_path):
    path = tmp_path / "wal.log"
    wal = WriteAheadLog(path, fsync=False)
    for i in (1, 2, 3):
        wal.append(_rec(i, i, ins=[[i, i + 1]]))
    wal.close()
    data = bytearray(open(path, "rb").read())
    flip = len(FILE_MAGIC) + len(encode_record(_rec(1, 1,
                                                    ins=[[1, 2]]))) + 12
    data[flip] ^= 0x10                         # inside record 2
    open(path, "wb").write(bytes(data))
    wal2 = WriteAheadLog(path, fsync=False)
    assert [r.batch_id for r in wal2.records] == [1]
    wal2.close()


def test_wal_truncate_to_drops_appended_record(tmp_path):
    wal = WriteAheadLog(tmp_path / "wal.log", fsync=False)
    wal.append(_rec(1, 1))
    off = wal.append(_rec(2, 2, ins=[[7, 8]]))
    wal.truncate_to(off)
    assert [r.batch_id for r in wal.records] == [1]
    wal.append(_rec(2, 2, ins=[[9, 9]]))       # the log stays appendable
    wal.close()
    wal2 = WriteAheadLog(tmp_path / "wal.log", fsync=False)
    assert [r.batch_id for r in wal2.records] == [1, 2]
    assert wal2.records[1].inserts[0, 0] == 9
    wal2.close()


def test_edge_digest_commutative_update():
    rng = np.random.default_rng(0)
    edges = rng.integers(0, 100, size=(50, 2))
    d, c = edge_digest(edges)
    dp, cp = edge_digest(rng.permutation(edges, axis=0))
    assert (d, c) == (dp, cp)                  # order-independent
    ins, dels = rng.integers(0, 100, size=(7, 2)), edges[:5]
    after = np.concatenate([edges[5:], ins])
    assert update_digest(d, c, ins, dels) == edge_digest(after)
    # multiplicity matters: a duplicated edge is a different multiset
    assert edge_digest(np.concatenate([edges, edges[:1]])) != (d, c)


# -- snapshots ---------------------------------------------------------------

def test_snapshot_envelope_detects_any_flip(tmp_path):
    state = {"x": np.arange(5), "epoch": 7}
    data = pack_snapshot(7, state)
    epoch, loaded = unpack_snapshot(data)
    assert epoch == 7 and np.array_equal(loaded["x"], state["x"])
    for pos in (2, 9, len(data) - 3):          # magic, header, payload
        bad = bytearray(data)
        bad[pos] ^= 1
        with pytest.raises(SnapshotCorrupt):
            unpack_snapshot(bytes(bad))
    with pytest.raises(SnapshotCorrupt):
        unpack_snapshot(data[:-1])             # truncation

    write_snapshot(tmp_path, 3, state, fsync=False)
    write_snapshot(tmp_path, 9, state, fsync=False)
    (tmp_path / ".snapshot-0000000011.tmp").write_bytes(b"torn")
    assert [e for e, _ in find_snapshots(tmp_path)] == [9, 3]
    assert load_snapshot(find_snapshots(tmp_path)[0][1])[0] == 9


def test_persistence_refuses_resumable_dir(tmp_path):
    _make_server(tmp_path)
    with pytest.raises(ValueError, match="already holds durable state"):
        _make_server(tmp_path)


def test_recover_empty_dir_raises(tmp_path):
    with pytest.raises(RecoveryFailed, match="no snapshots"):
        recover_state(str(tmp_path))


# -- recovery semantics ------------------------------------------------------

def test_recover_replay_bit_identical(tmp_path):
    # snapshot_every huge => recovery replays EVERY batch from the base
    # snapshot, the pure-WAL path
    server = _make_server(tmp_path, snapshot_every=100)
    rng = np.random.default_rng(3)
    _run_rounds(server, 2, rng)
    (res,) = server.serve([Query(make_key("bfs"), 3)])
    ref_edges = _sorted_edges(server.dynamic)

    # WAL-before-apply, observable: every applied epoch's batch is in
    # the log (the converse — logged but unapplied — is what replay fixes)
    logged = {r.epoch for r in server.durability.wal.records}
    assert {m["epoch"] for m in server.mutation_log} <= logged

    rec = GraphServer.recover(tmp_path, buckets=(4,))
    rep = rec.recovery_report
    assert (rep.snapshot_epoch, rep.epoch, rep.replayed, rep.skipped) \
        == (0, 4, 4, 0)
    assert rec.epoch == server.epoch == 4
    np.testing.assert_array_equal(ref_edges, _sorted_edges(rec.dynamic))
    (res2,) = rec.serve([Query(make_key("bfs"), 3)])
    np.testing.assert_array_equal(np.asarray(res["parents"]),
                                  np.asarray(res2["parents"]))
    assert res2.rounds == res.rounds
    assert rec.metrics.recoveries == 1


def test_replay_of_snapshotted_batch_is_noop(tmp_path):
    # snapshot_every=1 => the newest snapshot already folds in every
    # batch; replay must SKIP all of them (idempotence on batch id)
    server = _make_server(tmp_path, snapshot_every=1)
    rng = np.random.default_rng(5)
    _run_rounds(server, 2, rng)
    ref_edges = _sorted_edges(server.dynamic)

    rec = GraphServer.recover(tmp_path)
    rep = rec.recovery_report
    assert (rep.replayed, rep.skipped, rep.epoch) == (0, 4, 4)
    np.testing.assert_array_equal(ref_edges, _sorted_edges(rec.dynamic))
    # the recovered server keeps mutating durably on the same WAL
    dyn = rec.dynamic_graph()
    rec.mutate(deletes=dyn.sample_deletable(3, rng))
    assert rec.epoch == 5 and rec.durability.batch_id == 5
    rec2 = GraphServer.recover(tmp_path)
    assert rec2.epoch == 5
    np.testing.assert_array_equal(_sorted_edges(rec.dynamic),
                                  _sorted_edges(rec2.dynamic))


def test_rebuild_record_replays_rebuild_path(tmp_path):
    server = _make_server(tmp_path, snapshot_every=100)
    rng = np.random.default_rng(7)
    dyn = server.dynamic_graph()
    server.mutate(deletes=dyn.sample_deletable(8, rng))
    # overflow the out-COO free pool => the rebuild path, logged as such
    hot = np.tile([[0, 1]], (len(dyn._free_out[0]) + 1, 1))
    stats = server.mutate(inserts=hot)
    assert stats.rebuild
    assert server.durability.wal.records[-1].rebuild
    server.mutate(deletes=dyn.sample_deletable(5, rng))
    ref_edges = _sorted_edges(dyn)

    rec = GraphServer.recover(tmp_path)
    rep = rec.recovery_report
    assert (rep.replayed, rep.rebuilds, rep.epoch) == (3, 1, 3)
    np.testing.assert_array_equal(ref_edges, _sorted_edges(rec.dynamic))


def test_wal_append_failure_blocks_apply(tmp_path, monkeypatch):
    server = _make_server(tmp_path)
    rng = np.random.default_rng(9)
    dyn = server.dynamic_graph()
    before = _sorted_edges(dyn)
    monkeypatch.setattr(WriteAheadLog, "append",
                        lambda self, rec: (_ for _ in ()).throw(
                            OSError("disk full")))
    with pytest.raises(OSError, match="disk full"):
        server.mutate(deletes=dyn.sample_deletable(4, rng))
    # no log record => no applied epoch: the graph never moved
    assert server.epoch == 0 and dyn.epoch == 0
    np.testing.assert_array_equal(before, _sorted_edges(dyn))
    monkeypatch.undo()
    assert server.durability.wal.n_records == 0


def test_apply_failure_truncates_orphan_record(tmp_path, monkeypatch):
    server = _make_server(tmp_path)
    rng = np.random.default_rng(13)
    dyn = server.dynamic_graph()
    before = _sorted_edges(dyn)
    monkeypatch.setattr(DynamicGraph, "_apply_patches",
                        lambda self, touched: (_ for _ in ()).throw(
                            RuntimeError("device fell over")))
    with pytest.raises(RuntimeError, match="device fell over"):
        server.mutate(deletes=dyn.sample_deletable(4, rng))
    monkeypatch.undo()
    # the record logged ahead of the failed apply is truncated away:
    # log and state agree (no batch that neither applied nor replays)
    assert server.durability.wal.n_records == 0
    assert server.epoch == 0
    np.testing.assert_array_equal(before, _sorted_edges(dyn))
    server.mutate(deletes=dyn.sample_deletable(4, rng))   # still durable
    assert server.durability.wal.n_records == 1
    rec = GraphServer.recover(tmp_path)
    assert rec.epoch == 1
    np.testing.assert_array_equal(_sorted_edges(dyn),
                                  _sorted_edges(rec.dynamic))


def test_snapshot_corruption_falls_back_to_previous(tmp_path):
    server = _make_server(tmp_path, snapshot_every=1, retain=3)
    rng = np.random.default_rng(17)
    _run_rounds(server, 2, rng)                # snapshots at 0..4
    ref_edges = _sorted_edges(server.dynamic)
    newest = find_snapshots(tmp_path)[0][1]
    data = bytearray(open(newest, "rb").read())
    data[len(data) // 2] ^= 1                  # flip a payload bit
    open(newest, "wb").write(bytes(data))

    rec = GraphServer.recover(tmp_path)
    rep = rec.recovery_report
    assert rep.snapshots_tried == 2            # newest condemned by CRC
    assert (rep.snapshot_epoch, rep.replayed, rep.epoch) == (3, 1, 4)
    np.testing.assert_array_equal(ref_edges, _sorted_edges(rec.dynamic))


def test_seed_store_roundtrip(tmp_path):
    server = _make_server(tmp_path)
    server.serve([Query(make_key("pagerank"), None)])   # harvests the seed
    assert ("pagerank", "rank") in server._seeds
    server.durability.snapshot_now(server)
    rec = GraphServer.recover(tmp_path)
    assert set(rec._seeds) == set(server._seeds)
    ep0, arr0 = server._seeds[("pagerank", "rank")]
    ep1, arr1 = rec._seeds[("pagerank", "rank")]
    assert ep0 == ep1
    np.testing.assert_array_equal(np.asarray(arr0), np.asarray(arr1))


# -- observability / machinery ----------------------------------------------

def test_metrics_snapshot_fields(tmp_path):
    from repro.serve.metrics import ServeMetrics
    snap = ServeMetrics().snapshot()
    assert (snap["epoch"], snap["recoveries"], snap["wal_records"]) \
        == (0, 0, 0)
    assert set(snap) == {"window_s", "epoch", "recoveries", "wal_records",
                         "counts", "rows"}
    server = _make_server(tmp_path)
    rng = np.random.default_rng(1)
    dyn = server.dynamic_graph()
    server.mutate(deletes=dyn.sample_deletable(2, rng))
    snap = server.metrics.snapshot()
    assert snap["epoch"] == 1 and snap["wal_records"] == 1
    rec = GraphServer.recover(tmp_path)
    snap = rec.metrics.snapshot()
    assert snap["recoveries"] == 1 and snap["epoch"] == 1 \
        and snap["wal_records"] == 1


def test_crash_point_machinery(monkeypatch):
    fired = []
    monkeypatch.setattr(os, "_exit",
                        lambda code: fired.append(code) or (_ for _ in ())
                        .throw(SystemExit(code)))
    monkeypatch.setenv("REPRO_CRASH_POINT", "between-batches:2")
    reset_counts()
    maybe_crash("between-batches")             # occurrence 1: survives
    maybe_crash("after-wal-append")            # other points don't count
    assert not fired
    with pytest.raises(SystemExit):
        maybe_crash("between-batches")         # occurrence 2: dies
    assert fired == [CRASH_EXIT_CODE]
    reset_counts()
    with pytest.raises(ValueError, match="unknown crash point"):
        maybe_crash("not-a-point")


def test_docs_crash_point_table_in_sync():
    content = open(os.path.join(REPO, "docs", "API.md")).read()
    table = crash_points_markdown_table()
    assert table in content, (
        "docs/API.md 'Durability & crash recovery' crash-point table is "
        "out of sync; paste this:\n\n" + table)


# -- the kill drills ---------------------------------------------------------

_DRILL_SETUP = r"""
import hashlib, json, os
import numpy as np
from repro.core import GraphEngine, partition_graph
from repro.graphs import urand_edges
from repro.launch.mesh import make_graph_mesh
from repro.serve import GraphServer, Persistence, Query, make_key

N, PARTS, E, ROUNDS = 512, 2, 4096, 3
PROBES = (("bfs", 3), ("pagerank", None), ("cc", None))

def hsh(a):
    return hashlib.sha256(
        np.ascontiguousarray(np.asarray(a)).tobytes()).hexdigest()

def probe(server):
    out = {}
    for algo, root in PROBES:
        (res,) = server.serve([Query(make_key(algo), root)])
        out[algo] = {"rounds": int(res.rounds),
                     "fields": {k: hsh(v)
                                for k, v in sorted(res.fields.items())}}
    return out

def build(persistence=None):
    edges = urand_edges(N, E, seed=11)
    g = partition_graph(edges, N, PARTS)
    eng = GraphEngine(g, make_graph_mesh(PARTS))
    return GraphServer(eng, buckets=(4,), persistence=persistence)

def edges_hash(dyn):
    cur = dyn.current_edges()
    return hsh(cur[np.lexsort((cur[:, 1], cur[:, 0]))])
"""

_VICTIM_CODE = _DRILL_SETUP + r"""
server = build(Persistence(dir=os.environ["DRILL_DIR"], snapshot_every=2))
rng = np.random.default_rng(3)
dyn = server.dynamic_graph()
for k in range(ROUNDS):
    server.mutate(deletes=dyn.sample_deletable(12, rng))
    server.mutate(inserts=dyn.sample_insertable(12, rng))
    server.serve([Query(make_key("bfs"), 3)])
print("VICTIM-SURVIVED")
"""

_REFERENCE_CODE = _DRILL_SETUP + r"""
server = build()
rng = np.random.default_rng(3)
dyn = server.dynamic_graph()
report = {}
for k in range(ROUNDS):
    server.mutate(deletes=dyn.sample_deletable(12, rng))
    report[str(server.epoch)] = {"edges": edges_hash(dyn),
                                 "answers": probe(server)}
    server.mutate(inserts=dyn.sample_insertable(12, rng))
    report[str(server.epoch)] = {"edges": edges_hash(dyn),
                                 "answers": probe(server)}
    server.serve([Query(make_key("bfs"), 3)])
print("REF " + json.dumps(report))
"""

_RECOVER_CODE = _DRILL_SETUP + r"""
server = GraphServer.recover(os.environ["DRILL_DIR"], buckets=(4,))
rep = server.recovery_report
print("RECOVERED " + json.dumps({
    "epoch": server.epoch, "snapshot_epoch": rep.snapshot_epoch,
    "replayed": rep.replayed, "skipped": rep.skipped,
    "recoveries": server.metrics.recoveries,
    "wal_records": server.metrics.wal_records,
    "edges": edges_hash(server.dynamic_graph()),
    "answers": probe(server)}))
"""

# crash spec -> what recovery must land on.  The victim trace is 6
# mutate() calls (epochs 1..6) with snapshots at epochs 0/2/4/6; the
# occurrence counter picks the exact protocol instruction to die at.
_DRILLS = [
    # 5th WAL append: batch 5 logged + fsynced, never applied — replay
    # redoes it from snapshot 4
    ("after-wal-append:5",
     dict(epoch=5, snapshot_epoch=4, replayed=1, skipped=4)),
    # top of mutate 5: nothing of batch 5 exists — clean resume at 4
    ("between-batches:5",
     dict(epoch=4, snapshot_epoch=4, replayed=0, skipped=4)),
    # 3rd snapshot write (epoch 4) torn mid-temp-file: recovery ignores
    # the temp and replays batches 3..4 over snapshot 2
    ("mid-snapshot-temp-write:3",
     dict(epoch=4, snapshot_epoch=2, replayed=2, skipped=2)),
    # crash right after snapshot 4's atomic rename: the new snapshot IS
    # durable, every logged batch idempotently skips
    ("post-rename:3",
     dict(epoch=4, snapshot_epoch=4, replayed=0, skipped=4)),
]


def _run_drill_proc(code, *, expect_rc=0, extra_env=None, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = SRC
    env.update(extra_env or {})
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == expect_rc, (
        f"rc={r.returncode} (expected {expect_rc})\n"
        f"STDOUT:{r.stdout[-3000:]}\nSTDERR:{r.stderr[-3000:]}")
    return r.stdout


@pytest.fixture(scope="module")
def reference_report():
    """One uninterrupted run of the drill trace, probed at EVERY epoch:
    the oracle the recovered servers must match bit-for-bit."""
    out = _run_drill_proc(_REFERENCE_CODE)
    for line in out.splitlines():
        if line.startswith("REF "):
            return json.loads(line[len("REF "):])
    raise AssertionError(f"no REF line in reference output:\n{out[-2000:]}")


@pytest.mark.durability
@pytest.mark.slow
@pytest.mark.parametrize("crash_spec,expect",
                         _DRILLS, ids=[d[0] for d in _DRILLS])
def test_crash_drill(crash_spec, expect, reference_report, tmp_path):
    pdir = str(tmp_path / "store")
    out = _run_drill_proc(_VICTIM_CODE,
                          expect_rc=CRASH_EXIT_CODE,
                          extra_env={"REPRO_CRASH_POINT": crash_spec,
                                     "DRILL_DIR": pdir})
    assert "VICTIM-SURVIVED" not in out, \
        f"{crash_spec}: the crash point never fired"

    out = _run_drill_proc(_RECOVER_CODE, extra_env={"DRILL_DIR": pdir})
    rec = next(json.loads(line[len("RECOVERED "):])
               for line in out.splitlines()
               if line.startswith("RECOVERED "))
    for k in ("epoch", "snapshot_epoch", "replayed", "skipped"):
        assert rec[k] == expect[k], \
            f"{crash_spec}: {k}={rec[k]}, expected {expect[k]}"
    assert rec["recoveries"] == 1
    ref = reference_report[str(expect["epoch"])]
    assert rec["edges"] == ref["edges"], \
        f"{crash_spec}: recovered edge multiset differs from reference"
    assert rec["answers"] == ref["answers"], \
        f"{crash_spec}: recovered answers not bit-identical to reference"


@pytest.mark.durability
@pytest.mark.slow
def test_drill_crash_points_are_exhaustive():
    """Every registered crash point has a drill (and vice versa)."""
    drilled = {spec.split(":")[0] for spec, _ in _DRILLS}
    assert drilled == set(CRASH_POINTS)
