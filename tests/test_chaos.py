"""The fault-tolerance gate: deterministic fault injection, guard
detection, superstep checkpointing, and rollback recovery.

In-process tier-1 coverage runs at parts=1: schedule parsing, the
``guard=True`` engine path (bit-identity, detection, the two channels),
and the :class:`CheckpointRunner` contracts (checkpoint/resume
bit-identity, recovery, the ``max_recoveries`` bound).

The CHAOS LANE (``-m chaos``, subprocess with forced host devices) is
the acceptance sweep: EVERY registered (algo, variant) pair at parts
{2, 4} runs under a seeded schedule carrying at least one drop, one
corruption and one stall; each run must detect the faults, recover from
the last checkpoint, produce outputs BIT-IDENTICAL to an uninterrupted
direct ``engine.program()`` call, and pass the NumPy oracle
(``tests/oracle.py``; pagerank within its documented tolerance).  The
same sweep pins checkpoint/resume bit-identity for every pair.
"""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import run_with_devices

import oracle  # noqa: F401  (fail fast if the oracle module breaks)
from repro.core import CheckpointRunner, GraphEngine, RecoveryError, \
    partition_graph, registry
from repro.core.faults import FaultEvent, FaultSchedule, as_schedule
from repro.graphs import urand_edges
from repro.launch.mesh import make_graph_mesh

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))

N = 256
ROOT = 3


@pytest.fixture(scope="module")
def eng():
    edges = urand_edges(N, 2048, seed=11)
    g = partition_graph(edges, N, parts=1)
    return GraphEngine(g, make_graph_mesh(1))


# -- schedule plumbing ---------------------------------------------------


def test_fault_event_validation():
    ev = FaultEvent(round=3, part=1, kind="stall", op="min", rounds=2)
    assert ev.spec() == "stall@r3p1:minx2"
    with pytest.raises(ValueError):
        FaultEvent(round=1, part=0, kind="fizzle")
    with pytest.raises(ValueError):
        FaultEvent(round=1, part=0, kind="drop", op="gossip")
    with pytest.raises(ValueError):
        FaultEvent(round=-1, part=0, kind="drop")
    with pytest.raises(ValueError):
        FaultEvent(round=1, part=0, kind="stall", rounds=0)


def test_fault_schedule_parse_roundtrip():
    text = "drop@r1p0 corrupt@r2p1:min stall@r3p0x2 seed=7"
    sched = FaultSchedule.parse(text)
    assert sched.seed == 7 and len(sched.events) == 3
    assert sched.spec() == text
    assert FaultSchedule.parse(sched.spec()) == sched
    assert hash(sched) == hash(FaultSchedule.parse(text))  # cache-keyable
    with pytest.raises(ValueError):
        FaultSchedule.parse("drop@round1part0")


def test_as_schedule_coercion():
    assert as_schedule(None) is None
    sched = FaultSchedule.parse("dup@r0p0 seed=1")
    assert as_schedule(sched) is sched
    assert as_schedule("dup@r0p0 seed=1") == sched
    with pytest.raises(TypeError):
        as_schedule(42)


# -- the guarded engine path ---------------------------------------------


def test_guarded_run_is_bit_identical_and_ok(eng):
    garr = eng.device_graph()
    plain = eng.program("bfs", "fast")
    parents, rounds = plain(garr, jnp.int32(ROOT))
    guarded = eng.program("bfs", "fast", guard=True)
    gparents, grounds, ok = guarded(garr, jnp.int32(ROOT))
    assert int(ok) == 1 and int(grounds) == int(rounds)
    np.testing.assert_array_equal(np.asarray(parents),
                                  np.asarray(gparents))
    # cache identity: (guard, faults) are part of the compile-cache key
    assert eng.program("bfs", "fast", guard=True) is guarded
    assert eng.program("bfs", "fast") is plain and guarded is not plain


@pytest.mark.parametrize("spec", ["corrupt@r1p0:min seed=3",
                                  "drop@r1p0 seed=3",
                                  "stall@r1p0x2 seed=3",
                                  "dup@r1p0 seed=3"])
def test_engine_flags_stamped_faults(eng, spec):
    """Every stamped fault kind lands in the trailing ``ok`` scalar."""
    garr = eng.device_graph()
    prog = eng.program("bfs", "fast", guard=True, faults=spec)
    *_, ok = prog(garr, jnp.int32(ROOT))
    assert int(ok) == 0


def test_clean_schedule_rounds_beyond_halt_stay_ok(eng):
    """An event addressed past the program's last executed round never
    fires and never taints the verdict."""
    garr = eng.device_graph()
    prog = eng.program("bfs", "fast", guard=True,
                       faults="corrupt@r500p0 seed=3")
    *_, ok = prog(garr, jnp.int32(ROOT))
    assert int(ok) == 1


def test_stale_is_transport_silent_on_async(eng):
    """``stale`` (partial delivery) is deliberately NOT stamped: the
    stale-tolerant async variants absorb it — same fixed point, clean
    verdict — which is exactly the fault class they exist for."""
    garr = eng.device_graph()
    clean = eng.program("bfs", "async")
    parents, _ = clean(garr, jnp.int32(ROOT))
    prog = eng.program("bfs", "async", guard=True,
                       faults="stale@r1p0 seed=5")
    sparents, _, ok = prog(garr, jnp.int32(ROOT))
    assert int(ok) == 1
    np.testing.assert_array_equal(np.asarray(parents),
                                  np.asarray(sparents))


def test_value_guard_catches_nan_without_fault_harness():
    """The second detection channel is independent of the fault taps: a
    program whose OWN step writes NaN into float state trips the default
    finite-state screen with no schedule armed at all."""
    from repro.core.compat import shard_map
    from repro.core.superstep import SuperstepProgram, run_program

    P = jax.sharding.PartitionSpec
    mesh = make_graph_mesh(1)

    def make(poison_round):
        return SuperstepProgram(
            name="probe", variant="nan", inputs=(),
            init=lambda g: (jnp.zeros(8, jnp.float32), jnp.int32(0)),
            step=lambda g, s: (
                jnp.where(s[1] + 1 == poison_round,
                          jnp.full(8, jnp.nan, jnp.float32), s[0] + 1.0),
                s[1] + 1),
            halt=lambda s: s[1] >= 6,
            outputs=lambda s: (s[0],),
            output_names=("x",), output_is_vertex=(True,),
            max_rounds=8)

    def run(prog):
        fn = shard_map(lambda: run_program(prog, {}, guard=True),
                       mesh=mesh, in_specs=(),
                       out_specs=((P("parts"),), P(), P()),
                       check_vma=False)
        (x,), rounds, ok = jax.jit(fn)()
        return np.asarray(x), int(rounds), int(ok)

    _, rounds, ok = run(make(poison_round=99))       # never fires
    assert ok == 1 and rounds == 6
    _, rounds, ok = run(make(poison_round=3))
    assert ok == 0 and rounds == 3                   # stopped at detection


def test_guard_and_faults_validation(eng):
    with pytest.raises(ValueError):
        eng.program("pagerank", "bsp", guard=True, static_iters=4)
    with pytest.raises(ValueError):
        eng.program("bfs", "fast", guard=True, batch=4)
    with pytest.raises(ValueError):
        eng.program("bfs", "fast", faults="drop@r1p0", batch=4)


# -- checkpoint / resume / recovery (parts=1 fast path) ------------------


def _fields(eng, prog, outs):
    names = prog.output_names
    isv = prog.output_is_vertex
    return {n: (eng.gather_vertex_field(o) if v else np.asarray(o))
            for n, o, v in zip(names, outs, isv)}


def test_checkpoint_runner_bit_identity_and_resume(eng):
    garr = eng.device_graph()
    direct = eng.program("bfs", "fast")
    parents, rounds = direct(garr, jnp.int32(ROOT))
    runner = CheckpointRunner(eng, "bfs", "fast", checkpoint_every=2,
                              keep_history=True)
    rep = runner.run(garr, jnp.int32(ROOT))
    assert rep.recoveries == 0 and rep.rounds == int(rounds)
    assert rep.checkpoints == len(rep.history) >= 2
    np.testing.assert_array_equal(
        eng.gather_vertex_field(rep.outputs[0]),
        eng.gather_vertex_field(np.asarray(parents)))
    # resume from a mid-run snapshot: same bits as the full run
    mid = rep.history[len(rep.history) // 2]
    rep2 = runner.run(garr, jnp.int32(ROOT), resume_from=mid)
    assert rep2.recoveries == 0
    np.testing.assert_array_equal(rep.outputs[0], rep2.outputs[0])


def test_checkpoint_runner_recovers_to_clean_bits(eng):
    garr = eng.device_graph()
    direct = eng.program("bfs", "fast")
    parents, _ = direct(garr, jnp.int32(ROOT))
    runner = CheckpointRunner(eng, "bfs", "fast", checkpoint_every=2,
                              faults="corrupt@r2p0:min seed=7")
    rep = runner.run(garr, jnp.int32(ROOT))
    assert rep.recoveries >= 1 and len(rep.detections) >= 1
    np.testing.assert_array_equal(
        eng.gather_vertex_field(rep.outputs[0]),
        eng.gather_vertex_field(np.asarray(parents)))


def test_max_recoveries_bounds_the_rollback_loop(eng):
    garr = eng.device_graph()
    runner = CheckpointRunner(eng, "bfs", "fast", checkpoint_every=2,
                              faults="drop@r1p0 seed=1", max_recoveries=0)
    with pytest.raises(RecoveryError):
        runner.run(garr, jnp.int32(ROOT))


def test_checkpoint_every_validation(eng):
    with pytest.raises(ValueError):
        CheckpointRunner(eng, "bfs", "fast", checkpoint_every=0)


# -- the chaos acceptance sweep (multi-partition, subprocess) ------------

_CHAOS_SWEEP_CODE = """
import sys
sys.path.insert(0, {tests_dir!r})
import numpy as np
import jax.numpy as jnp
import oracle
from repro.core import CheckpointRunner, GraphEngine, incremental, \\
    partition_graph, registry
from repro.launch.mesh import make_graph_mesh

parts, n, seed, root = {parts}, {n}, {seed}, {root}
edges, n = oracle.family_edges("urand", n, seed)
g = partition_graph(edges, n, parts)
eng = GraphEngine(g, make_graph_mesh(parts))
garr = eng.device_graph()
for algo, variant in registry.available():
    spec = registry.get_spec(algo, variant)
    params = oracle.CONFORMANCE_PARAMS.get((algo, variant), {{}})
    if any(k != "scalar" for k in spec.input_kinds):
        (seed_arr,) = incremental.cold_seed(spec, g)
        ins = (eng.scatter_vertex_field(
            seed_arr, incremental.KIND_DTYPES[spec.input_kinds[0]]),)
    else:
        ins = (jnp.int32(root),) * len(spec.inputs)
    # 1) the uninterrupted reference: a direct engine.program() call
    prog = eng.program(algo, variant, **params)
    *outs, rounds = prog(garr, *ins)
    p = prog.program
    ref = [np.asarray(o) for o in outs]

    def check(tag, outputs):
        for name, r, o, isv in zip(p.output_names, ref, outputs,
                                   p.output_is_vertex):
            a = eng.gather_vertex_field(r) if isv else np.asarray(r)[()]
            b = eng.gather_vertex_field(o) if isv else np.asarray(o)[()]
            assert np.array_equal(a, b), (
                f"{{algo}}/{{variant}} parts={{parts}} {{tag}}: output "
                f"{{name}} diverged from the uninterrupted run")

    # 2) checkpointed execution is bit-identical, and so is a resume
    #    from a mid-run snapshot
    runner = CheckpointRunner(eng, algo, variant, checkpoint_every=2,
                              keep_history=True, **params)
    rep = runner.run(garr, *ins)
    assert rep.recoveries == 0, (algo, variant)
    check("checkpointed", rep.outputs)
    mid = rep.history[len(rep.history) // 2]
    rep2 = runner.run(garr, *ins, resume_from=mid)
    check("resumed", rep2.outputs)

    # 3) chaos: >=1 drop + >=1 corruption + >=1 stall inside the
    #    executed-round window; the run must detect, recover from the
    #    last checkpoint, and still produce the uninterrupted bits
    R = max(int(rep.rounds), 1)
    r1, r2, r3 = min(1, R - 1), min(2, R - 1), min(3, R - 1)
    sched = (f"drop@r{{r1}}p0 corrupt@r{{r2}}p{{min(1, parts - 1)}} "
             f"stall@r{{r3}}p0x2 seed=7")
    chaos = CheckpointRunner(eng, algo, variant, checkpoint_every=2,
                             faults=sched, **params)
    rep3 = chaos.run(garr, *ins)
    assert rep3.recoveries >= 1 and rep3.detections, (
        f"{{algo}}/{{variant}} parts={{parts}}: schedule {{sched!r}} "
        f"was never detected")
    check("recovered", rep3.outputs)
    fields = {{name: (eng.gather_vertex_field(o) if isv
                      else np.asarray(o)[()])
               for name, o, isv in zip(p.output_names, rep3.outputs,
                                       p.output_is_vertex)}}
    oracle.check_conformance(algo, variant, fields, edges, n, root)
    print(f"PASS {{algo}}/{{variant}} parts={{parts}} "
          f"recoveries={{rep3.recoveries}}")
print("CHAOS-OK parts=%d" % parts)
"""


@pytest.mark.chaos
@pytest.mark.slow
@pytest.mark.parametrize("parts", [2, 4])
def test_chaos_conformance_sweep(parts):
    """Acceptance: every registered pair, seeded drop+corrupt+stall,
    detect -> rollback -> bit-identical outputs -> oracle-exact."""
    out = run_with_devices(
        _CHAOS_SWEEP_CODE.format(tests_dir=TESTS_DIR, parts=parts,
                                 n=N, seed=5, root=ROOT),
        devices=parts, timeout=1200)
    for algo, variant in registry.available():
        assert f"PASS {algo}/{variant} parts={parts}" in out, (
            f"chaos cell missing: {algo}/{variant} parts={parts}\n{out}")
    assert f"CHAOS-OK parts={parts}" in out
