"""Config registry: param counts vs published sizes, shape assignment."""

import pytest

from repro.configs.base import LM_SHAPES, shapes_for
from repro.configs.registry import ARCHS, all_cells, skipped_cells, \
    smoke_config

PUBLISHED_B = {
    "dbrx-132b": (132, 0.05), "phi3.5-moe-42b-a6.6b": (41.9, 0.05),
    "mamba2-1.3b": (1.3, 0.1), "h2o-danube-3-4b": (4.0, 0.1),
    "gemma3-27b": (27.0, 0.10), "qwen2.5-32b": (32.5, 0.05),
    "tinyllama-1.1b": (1.1, 0.05), "whisper-small": (0.244, 0.25),
    "internvl2-1b": (0.5, 0.25), "zamba2-7b": (7.0, 0.10),
}

ACTIVE_B = {"dbrx-132b": (36, 0.10), "phi3.5-moe-42b-a6.6b": (6.6, 0.05)}


@pytest.mark.parametrize("name", list(ARCHS))
def test_param_counts_match_published(name):
    cfg = ARCHS[name]
    target, tol = PUBLISHED_B[name]
    total = cfg.params_total() / 1e9
    assert abs(total - target) / target < tol, (name, total, target)


@pytest.mark.parametrize("name", list(ACTIVE_B))
def test_active_params_moe(name):
    cfg = ARCHS[name]
    target, tol = ACTIVE_B[name]
    active = cfg.params_active() / 1e9
    assert abs(active - target) / target < tol, (name, active, target)


def test_cell_assignment_covers_40():
    assert len(all_cells()) + len(skipped_cells()) == 10 * len(LM_SHAPES)
    # only long_500k may be skipped, only for full-attention archs
    for arch, shape, reason in skipped_cells():
        assert shape == "long_500k"
        assert not ARCHS[arch].supports_long_context


def test_long_context_archs_run_long_500k():
    for name in ("mamba2-1.3b", "zamba2-7b", "gemma3-27b", "h2o-danube-3-4b"):
        assert "long_500k" in {s.name for s in shapes_for(ARCHS[name])}


@pytest.mark.parametrize("name", list(ARCHS))
def test_smoke_configs_are_small(name):
    cfg = smoke_config(name)
    assert cfg.params_total() < 5e6
    assert cfg.family == ARCHS[name].family
