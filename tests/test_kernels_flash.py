"""Flash attention Pallas kernel: shape/dtype/mask sweep vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.models.layers import attention_naive


@pytest.mark.parametrize("bh,s,d,bq,bk", [
    (2, 256, 128, 128, 128), (4, 512, 128, 256, 128), (1, 128, 256, 64, 64),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_kernel_sweep(bh, s, d, bq, bk, causal, window):
    q, k, v = [jax.random.normal(jax.random.key(i), (bh, s, d), jnp.float32)
               for i in range(3)]
    got = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              block_q=bq, block_k=bk, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_flash_kernel_dtypes(dtype, tol):
    q, k, v = [jax.random.normal(jax.random.key(i), (2, 256, 128),
                                 jnp.float32).astype(dtype)
               for i in range(3)]
    got = flash_attention_fwd(q, k, v, causal=True, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_kernel_cross_lengths():
    """Sq != Sk (cross-attention shape)."""
    q = jax.random.normal(jax.random.key(0), (2, 128, 128))
    k = jax.random.normal(jax.random.key(1), (2, 512, 128))
    v = jax.random.normal(jax.random.key(2), (2, 512, 128))
    got = flash_attention_fwd(q, k, v, causal=False, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_ops_wrapper_pads_odd_head_dim():
    """danube3's head_dim=120 path: pad to 128 + scale correction."""
    q, k, v = [jax.random.normal(jax.random.key(i), (2, 128, 4, 120))
               for i in range(3)]
    got = flash_attention(q, k, v, causal=True, force_kernel=True,
                          interpret=True)
    ref = attention_naive(q, k, v, q_pos=jnp.arange(128),
                          k_pos=jnp.arange(128), causal=True, window=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=5e-5)


def test_flash_softcap():
    q, k, v = [jax.random.normal(jax.random.key(i), (1, 128, 128))
               for i in range(3)]
    got = flash_attention_fwd(q, k, v, causal=True, softcap=20.0,
                              interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, softcap=20.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)
