"""The observability subsystem (``repro/obs/``): span recorder + ring
semantics, engine telemetry series/wire accounting, Chrome trace export
and its schema validator, the ServeMetrics reconciliation contract, the
docs-drift gates for the registry tables, and the multi-device
acceptance drills (traced serve session with a schema-valid export;
telemetry-ON programs through the NumPy-oracle gate at parts {1,2,4}).

The in-process tests ride tier-1; the subprocess acceptance drills are
marked ``obs`` (their own lane in scripts/ci.sh).
"""

import json
import os
import sys
import time

import numpy as np
import pytest

import jax.numpy as jnp

from conftest import REPO, run_with_devices
from repro.core import CheckpointRunner, GraphEngine, partition_graph
from repro.graphs import urand_edges
from repro.launch.mesh import make_graph_mesh
from repro.obs import (
    NULL_RECORDER,
    Event,
    PhaseSeries,
    Registry,
    RunTelemetry,
    Span,
    SpanRecorder,
    WireRecord,
    chrome_trace,
    derive_latency_cells,
    instruments_markdown_table,
    rollup,
    spans_markdown_table,
    trace_summary,
    validate_chrome_trace,
    write_trace,
)
from repro.obs import telemetry as obs_tel
from repro.serve import GraphServer
from repro.serve.metrics import ServeMetrics, percentiles

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))

N, E, ROOT = 256, 2048, 3


@pytest.fixture(scope="module")
def eng():
    edges = urand_edges(N, E, seed=11)
    g = partition_graph(edges, N, parts=1)
    return GraphEngine(g, make_graph_mesh(1))


# -- percentile semantics (serve/metrics.py) -----------------------------


def test_percentiles_empty_cell_is_zero_not_nan():
    assert percentiles([]) == (0.0, 0.0, 0.0)


def test_percentiles_single_sample_is_that_sample():
    assert percentiles([0.25]) == (0.25, 0.25, 0.25)


def test_percentiles_two_samples_interpolate():
    p50, p95, p99 = percentiles([0.1, 0.3])
    assert p50 == pytest.approx(0.2)        # midpoint, by construction
    assert p95 == pytest.approx(0.1 + 0.95 * 0.2)
    assert p99 == pytest.approx(0.1 + 0.99 * 0.2)
    assert p50 < p95 < p99 <= 0.3


def test_metrics_rows_small_sample_cells():
    m = ServeMetrics()
    assert m.rows() == []                   # no cells -> no rows
    m.record("bfs_fast", 4, 0.010)
    (row,) = m.rows()
    assert row["count"] == 1
    assert row["p50_ms"] == row["p95_ms"] == row["p99_ms"] == 10.0
    m.record("bfs_fast", 4, 0.030)
    (row,) = m.rows()
    assert row["count"] == 2 and row["p50_ms"] == 20.0
    assert row["p50_ms"] < row["p95_ms"] < row["p99_ms"] <= 30.0


# -- span recorder -------------------------------------------------------


def test_span_recorder_ring_bounds_and_drop_counts():
    rec = SpanRecorder(maxlen=4)
    for i in range(6):
        rec.add_span("admission", "server", float(i), float(i) + 0.5, i=i)
        rec.event("shed", "server", i=i)
    assert len(rec.spans()) == 4 and rec.dropped_spans == 2
    assert len(rec.events()) == 4 and rec.dropped_events == 2
    assert [s.args["i"] for s in rec.spans()] == [2, 3, 4, 5]  # newest win
    rec.clear()
    assert rec.spans() == [] and rec.events() == []
    assert rec.dropped_spans == 0 and rec.dropped_events == 0


def test_span_context_manager_closes_and_stamps_errors():
    rec = SpanRecorder()
    with rec.span("validate", "server", qid=7) as sp:
        sp.args["extra"] = 1
    with pytest.raises(RuntimeError):
        with rec.span("dispatch", "executor"):
            raise RuntimeError("boom")
    s_ok, s_err = rec.spans()
    assert s_ok.kind == "validate" and s_ok.args == {"qid": 7, "extra": 1}
    assert s_ok.t1 >= s_ok.t0 and s_ok.dur >= 0.0
    assert s_err.args["error"] == "RuntimeError"
    # seq is recorder-global and monotone in start order
    assert s_err.seq > s_ok.seq


def test_null_recorder_is_inert():
    with NULL_RECORDER.span("admission", "server") as sp:
        sp.args["x"] = 1                    # body still works
    NULL_RECORDER.add_span("query", "server", 0.0, 1.0)
    NULL_RECORDER.event("shed", "server")
    assert not NULL_RECORDER.enabled
    assert NULL_RECORDER.spans() == [] and NULL_RECORDER.events() == []


# -- telemetry series + wire accounting ----------------------------------


def test_phase_series_trims_on_done_column():
    arr = np.zeros((6, 3), np.float32)      # 2 fixed cols + 1 probe
    arr[:4, 0] = 1.0                        # 4 rows actually written
    arr[3, 1] = 1.0                         # halted on the last one
    arr[:4, 2] = [5, 9, 2, 0]
    ps = PhaseSeries.from_array(arr, ("frontier",))
    assert ps.rounds == 4
    assert list(ps.halt()) == [0.0, 0.0, 0.0, 1.0]
    assert list(ps.probe("frontier")) == [5.0, 9.0, 2.0, 0.0]
    summ = ps.summary()
    assert summ["rounds"] == 4 and summ["halt_last"] == 1.0
    assert summ["frontier_max"] == 9.0
    assert summ["frontier_mean"] == pytest.approx(4.0)


def test_phase_series_width_mismatch_raises():
    with pytest.raises(ValueError):
        PhaseSeries.from_array(np.zeros((3, 3), np.float32),
                               ("a", "b"))  # expects 2 + 2 columns
    with pytest.raises(ValueError):
        PhaseSeries.from_array(np.zeros(6, np.float32), ())


def test_wire_record_phases_and_recording_context():
    rec = WireRecord()
    rec.add("stale", "junk", 999)           # recording() must clear this
    with obs_tel.recording(rec):
        obs_tel.phase("init")
        obs_tel.tap_wire("all_gather", np.zeros((4, 8), np.float32))
        obs_tel.phase("round")
        obs_tel.tap_wire("all_to_all", np.zeros(16, np.int32))
        obs_tel.tap_wire("all_to_all", np.zeros(16, np.int32))
    snap = rec.snapshot()
    assert snap == {
        "init/all_gather": {"bytes": 4 * 8 * 4, "taps": 1},
        "round/all_to_all": {"bytes": 2 * 16 * 4, "taps": 2},
    }
    assert rec.bytes_per_round() == 4 * 8 * 4 + 2 * 16 * 4
    # outside a recording context taps are no-ops (the off path)
    obs_tel.tap_wire("all_to_all", np.zeros(16, np.int32))
    assert rec.snapshot() == snap


def test_run_telemetry_summary_math():
    arr = np.zeros((3, 2), np.float32)
    arr[:, 0] = 1.0
    tel = RunTelemetry(
        series=PhaseSeries.from_array(arr),
        wire={"round/all_to_all": {"bytes": 100, "taps": 2},
              "init/all_gather": {"bytes": 7, "taps": 1}},
        wall_s=0.03)
    assert tel.wire_bytes_by_op() == {"all_to_all": 100}
    assert tel.wire_bytes_by_op(loop_only=False) == {
        "all_to_all": 100, "all_gather": 7}
    summ = tel.summary()
    assert summ["wire_bytes_per_round"] == {"all_to_all": 100}
    assert summ["wire_bytes_total"] == 100 * 3 + 7
    assert summ["round_ms_mean"] == pytest.approx(10.0)


# -- instrument registry + roll-up ---------------------------------------


def test_registry_refuses_undeclared_instruments():
    reg = Registry()
    reg.count("queries_submitted", 3)
    reg.gauge("epoch", 2)
    reg.observe("query_latency_ms", 12.5)
    with pytest.raises(KeyError):
        reg.count("made_up_counter")
    with pytest.raises(KeyError):
        reg.gauge("queries_submitted", 1)   # declared, but not a gauge
    snap = reg.snapshot()
    assert snap["counters"]["queries_submitted"] == 3
    assert snap["histograms"]["query_latency_ms"]["count"] == 1


def test_rollup_smoke():
    reg = Registry()
    reg.count("wal_appends", 2)
    rec = SpanRecorder()
    rec.add_span("admission", "server", 0.0, 0.001)
    text = rollup(reg, rec)
    assert "== obs roll-up ==" in text
    assert "wal_appends" in text and "server" in text


# -- Chrome trace export + schema validator ------------------------------


def _spanset():
    """admission(validate nested) + overlapping async queries + event."""
    spans = [
        Span("admission", "server", 0.000, 0.010, 1, {"qid": 0}),
        Span("validate", "server", 0.001, 0.002, 2, {}),
        Span("query", "server", 0.000, 0.050, 3,
             {"qid": 0, "status": "ok", "latency_s": 0.05}),
        Span("query", "server", 0.005, 0.040, 4,
             {"qid": 1, "status": "ok", "latency_s": 0.035}),
        Span("device", "device", 0.010, 0.030, 5, {"n": 2}),
    ]
    events = [Event("shed", "server", 0.020, 6, {"qid": 2})]
    return spans, events


def test_chrome_trace_export_shapes():
    spans, events = _spanset()
    trace = chrome_trace(spans, events)
    counts = validate_chrome_trace(trace)
    # 2 complete spans, 3 async spans (query x2 overlap + device), 1 inst
    assert counts["X"] == 2
    assert counts["b"] == counts["e"] == 3
    assert counts["i"] == 1
    assert counts["M"] >= 1
    evs = trace["traceEvents"]
    assert all(e["ts"] >= 0 for e in evs)   # relative to earliest stamp
    b_ids = {e["id"] for e in evs if e["ph"] == "b"}
    assert b_ids == {3, 4, 5}               # async pairs keyed by seq


def test_chrome_trace_engine_tracks():
    arr = np.zeros((3, 3), np.float32)
    arr[:, 0] = 1.0
    arr[2, 1] = 1.0
    arr[:, 2] = [4, 2, 0]
    tel = RunTelemetry(series=PhaseSeries.from_array(arr, ("frontier",)),
                       wall_s=0.012)
    trace = chrome_trace(engine=[("bfs_fast", tel, 2)])
    counts = validate_chrome_trace(trace)
    assert counts["X"] == 3 * 2             # rounds x parts
    rounds = [e for e in trace["traceEvents"]
              if e.get("name") == "engine_round"]
    assert {e["pid"] for e in rounds} == {2}
    assert {e["tid"] for e in rounds} == {0, 1}
    assert rounds[0]["args"]["frontier"] == 4.0


def test_validator_rejects_malformed_traces():
    def bad(evs):
        with pytest.raises(ValueError):
            validate_chrome_trace({"traceEvents": evs})

    bad([{"ph": "X", "pid": 1, "tid": 0, "name": "a", "dur": 1.0}])
    bad([{"ph": "X", "pid": 1, "tid": 0, "name": "a", "ts": 0.0}])
    # partial overlap on one track (nesting would be fine)
    bad([{"ph": "X", "pid": 1, "tid": 0, "name": "a", "ts": 0.0,
          "dur": 10.0},
         {"ph": "X", "pid": 1, "tid": 0, "name": "b", "ts": 5.0,
          "dur": 10.0}])
    # unmatched / inverted async pairs
    bad([{"ph": "b", "pid": 1, "tid": 0, "name": "q", "cat": "server",
          "id": 1, "ts": 0.0}])
    bad([{"ph": "e", "pid": 1, "tid": 0, "name": "q", "cat": "server",
          "id": 1, "ts": 0.0}])
    # decreasing timestamps on one track
    bad([{"ph": "X", "pid": 1, "tid": 0, "name": "a", "ts": 10.0,
          "dur": 1.0},
         {"ph": "X", "pid": 1, "tid": 0, "name": "b", "ts": 5.0,
          "dur": 1.0}])
    with pytest.raises(ValueError):
        validate_chrome_trace({"nope": []})
    # proper nesting on one track is NOT an error
    validate_chrome_trace({"traceEvents": [
        {"ph": "X", "pid": 1, "tid": 0, "name": "a", "ts": 0.0,
         "dur": 10.0},
        {"ph": "X", "pid": 1, "tid": 0, "name": "b", "ts": 2.0,
         "dur": 3.0}]})


def test_write_trace_round_trip(tmp_path):
    spans, events = _spanset()
    trace = chrome_trace(spans, events)
    path = tmp_path / "sub" / "trace.json"
    counts = write_trace(path, trace)
    assert counts == validate_chrome_trace(trace)
    on_disk = json.loads(path.read_text())
    assert on_disk == json.loads(json.dumps(trace))
    assert len(on_disk["traceEvents"]) == sum(counts.values())


# -- report: trace_summary + metrics reconciliation ----------------------


def test_trace_summary_counts_and_ranking():
    rec = SpanRecorder()
    rec.add_span("admission", "server", 0.0, 0.001)
    rec.add_span("admission", "server", 0.0, 0.002)
    rec.add_span("device", "device", 0.0, 0.5)
    rec.event("shed", "server")
    summ = trace_summary(rec, top=2)
    assert summ["spans_total"] == 3 and summ["events_total"] == 1
    assert summ["spans_per_kind"] == {"admission": 2, "device": 1}
    assert summ["spans_per_component"] == {"device": 1, "server": 2}
    assert summ["events_per_kind"] == {"shed": 1}
    assert summ["top_p99_ms"][0]["kind"] == "device"
    assert summ["top_p99_ms"][0]["p99_ms"] == pytest.approx(500.0)
    assert summ["dropped_spans"] == 0


def test_derive_latency_cells_counts_only_ok_queries():
    rec = SpanRecorder()
    rec.add_span("query", "server", 0.0, 0.1, label="bfs_fast", bucket=4,
                 status="ok", latency_s=0.125)
    rec.add_span("query", "server", 0.0, 0.1, label="bfs_fast", bucket=4,
                 status="timed_out", latency_s=9.0)
    rec.add_span("query", "server", 0.0, 0.1, label="pagerank_fast",
                 bucket=0, status="ok", latency_s=0.5)
    rec.add_span("admission", "server", 0.0, 0.1)
    assert derive_latency_cells(rec) == {
        ("bfs_fast", 4): [0.125],
        ("pagerank_fast", 0): [0.5],
    }


# -- docs drift: the registry tables in docs/API.md ----------------------


def test_docs_observability_span_table_is_current():
    content = open(os.path.join(REPO, "docs", "API.md")).read()
    assert spans_markdown_table() in content, (
        "docs/API.md observability span/event table drifted from "
        "obs.registry; regenerate it with "
        "repro.obs.spans_markdown_table()")


def test_docs_observability_instrument_table_is_current():
    content = open(os.path.join(REPO, "docs", "API.md")).read()
    assert instruments_markdown_table() in content, (
        "docs/API.md instrument table drifted from obs.registry; "
        "regenerate it with repro.obs.instruments_markdown_table()")


# -- compare.py never gates on observability blocks ----------------------


def test_compare_ignores_telemetry_and_trace_summary():
    sys.path.insert(0, REPO)
    try:
        from benchmarks import compare as cmp
    finally:
        sys.path.remove(REPO)
    row = {"algo": "bfs", "variant": "fast", "graph": "urand12",
           "parts": 2, "ms": 100.0, "rounds_to_converge": 8,
           "wire_mb_per_part": 0.5}
    new_row = dict(row, ms=104.0,
                   telemetry={"rounds": 8, "wire_bytes_total": 12345})
    old = {cmp._graph_key(row): row}
    new = {cmp._graph_key(new_row): new_row}
    lines, regressions = cmp.compare(old, new, threshold=1.25)
    assert regressions == [] and len(lines) == 2
    # ... and in the other direction (baseline has it, fresh doesn't)
    lines, regressions = cmp.compare(new, old, threshold=1.25)
    assert regressions == []
    # a serve meta gaining trace_summary is NOT config drift
    meta = {"localops": "auto", "mode": "fast", "launches": 16,
            "graph": "urand12", "parts": 2, "jax": "0.4.37",
            "device": "cpu"}
    assert not cmp.config_changed(meta, {**meta, "trace_summary": {}})


# -- engine telemetry end to end (parts=1, in-process) -------------------


def test_telemetry_on_is_bit_identical_to_off(eng):
    garr = eng.device_graph()
    off = eng.program("bfs", "fast")
    *outs, rounds = off(garr, jnp.int32(ROOT))
    on = eng.program("bfs", "fast", telemetry=True)
    tout = on(garr, jnp.int32(ROOT))
    assert len(tout) == len(outs) + 2       # trailing series output
    for a, b in zip((*outs, rounds), tout[:-1]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    tel = on.run_telemetry(tout[-1])
    assert tel.series.rounds == int(rounds) > 0
    assert tel.series.halt()[-1] == 1.0     # converged, not round-capped
    assert np.all(tel.series.halt()[:-1] == 0.0)
    assert "frontier" in tel.series.probe_names
    assert tel.series.probe("frontier")[0] >= 1.0
    assert tel.series.probe("frontier")[-1] == 0.0
    assert on.last_wall_s > 0.0 and tel.wall_s > 0.0
    summ = tel.summary()
    assert summ["rounds"] == int(rounds)
    assert "wire_bytes_total" in summ and "wall_ms" in summ


def test_telemetry_is_a_compile_cache_dimension(eng):
    off = eng.program("bfs", "fast")
    on = eng.program("bfs", "fast", telemetry=True)
    assert on is not off and on.telemetry and not off.telemetry
    assert eng.program("bfs", "fast", telemetry=True) is on
    assert eng.program("bfs", "fast") is off


def test_telemetry_composition_rules(eng):
    with pytest.raises(ValueError):
        eng.program("pagerank", "bsp", telemetry=True, static_iters=4)
    with pytest.raises(ValueError):
        eng.program("bfs", "fast", telemetry=True, batch=4)
    with pytest.raises(ValueError):
        eng.program("bfs", "fast").run_telemetry(None)


def test_checkpoint_runner_obs_events_and_telemetry(eng):
    garr = eng.device_graph()
    direct = eng.program("bfs", "fast")
    parents, rounds = direct(garr, jnp.int32(ROOT))
    rec = SpanRecorder()
    runner = CheckpointRunner(eng, "bfs", "fast", checkpoint_every=2,
                              faults="corrupt@r2p0:min seed=7",
                              telemetry=True, obs=rec)
    rep = runner.run(garr, jnp.int32(ROOT))
    assert rep.recoveries >= 1
    kinds = {e.kind for e in rec.events()}
    assert {"checkpoint", "fault_detection", "rollback"} <= kinds
    chunk_spans = [s for s in rec.spans() if s.kind == "chunk"]
    assert chunk_spans and all(s.component == "recovery"
                               for s in chunk_spans)
    # telemetry rolled back with the carry: no rows from discarded
    # chunks, and the recovered output is still the clean bits
    assert rep.telemetry is not None
    assert rep.telemetry["rounds"] == rep.rounds == int(rounds)
    np.testing.assert_array_equal(
        eng.gather_vertex_field(rep.outputs[0]),
        eng.gather_vertex_field(np.asarray(parents)))


# -- traced serving path (parts=1, in-process) ---------------------------


def test_traced_serve_spans_reconcile_with_metrics(eng):
    rec = SpanRecorder()
    server = GraphServer(eng, buckets=(4,), obs=rec)
    qids = [server.submit("bfs", root=r) for r in range(5)]
    qids.append(server.submit("pagerank"))
    server.drain()
    results = [server.results.pop(q) for q in qids]
    assert all(r.status == "ok" for r in results)

    spans = rec.spans()
    kinds = {s.kind for s in spans}
    assert {"admission", "validate", "coalesce_wait", "dispatch",
            "device", "demux", "query"} <= kinds
    # one query span per resolved query, one admission per submit
    assert sum(s.kind == "query" for s in spans) == len(qids)
    assert sum(s.kind == "admission" for s in spans) == len(qids)
    # THE reconciliation contract: latency cells derived from query
    # spans equal ServeMetrics' cells exactly (same floats, same order)
    assert derive_latency_cells(rec) == server.metrics.latencies()

    # a mutation records its span with the new epoch
    dels = server.dynamic_graph().sample_deletable(
        8, np.random.default_rng(0))
    stats = server.mutate(deletes=dels)
    (msp,) = [s for s in rec.spans() if s.kind == "mutation"]
    assert msp.args["epoch"] == server.epoch == 1
    assert msp.args["n_delete"] == stats.n_delete >= 1

    # a rejected admission leaves an event, not a span
    with pytest.raises(ValueError):
        server.submit("bfs", root=10 ** 9)
    assert any(e.kind == "rejected" for e in rec.events())

    # the recorder exports to a schema-valid trace round-trip
    trace = chrome_trace(rec.spans(), rec.events())
    counts = validate_chrome_trace(trace)
    assert counts["b"] == counts["e"] >= len(qids)
    summ = trace_summary(rec)
    assert summ["spans_per_kind"]["query"] == len(qids)
    assert summ["dropped_spans"] == 0 and summ["dropped_events"] == 0
    assert summ["top_p99_ms"]


def test_untraced_server_records_nothing(eng):
    server = GraphServer(eng, buckets=(4,))
    assert server.obs is NULL_RECORDER
    qid = server.submit("bfs", root=1)
    server.drain()
    assert server.results.pop(qid).status == "ok"
    assert NULL_RECORDER.spans() == [] and NULL_RECORDER.events() == []


def test_durability_and_recovery_spans(tmp_path):
    edges = urand_edges(128, 512, seed=3)
    g = partition_graph(edges, 128, parts=1)
    eng2 = GraphEngine(g, make_graph_mesh(1))
    rec = SpanRecorder()
    server = GraphServer(eng2, buckets=(4,), persistence=str(tmp_path),
                         obs=rec)
    dels = server.dynamic_graph().sample_deletable(
        4, np.random.default_rng(2))
    server.mutate(deletes=dels)
    server.durability.snapshot_now(server)
    kinds = {s.kind for s in rec.spans()}
    assert {"mutation", "wal_append", "snapshot"} <= kinds
    (wsp,) = [s for s in rec.spans() if s.kind == "wal_append"]
    assert wsp.component == "durability" and wsp.args["epoch"] == 1

    rec2 = SpanRecorder()
    srv2 = GraphServer.recover(tmp_path, buckets=(4,), obs=rec2)
    (rsp,) = [s for s in rec2.spans() if s.kind == "recovery"]
    assert rsp.args["epoch"] == srv2.epoch == 1
    # the recovered server's durability path stays instrumented
    dels2 = srv2.dynamic_graph().sample_deletable(
        4, np.random.default_rng(3))
    srv2.mutate(deletes=dels2)
    assert any(s.kind == "wal_append" for s in rec2.spans())


# -- multi-device acceptance drills (subprocess, obs lane) ---------------

_TRACED_SERVE_CODE = """
import sys
sys.path.insert(0, {tests_dir!r})
import json
import numpy as np
import oracle
from repro.core import GraphEngine, partition_graph
from repro.launch.mesh import make_graph_mesh
from repro.obs import (SpanRecorder, chrome_trace, derive_latency_cells,
                       trace_summary, validate_chrome_trace)
from repro.serve import GraphServer

parts = 2
edges, n = oracle.family_edges("urand", 384, 5)
g = partition_graph(edges, n, parts)
eng = GraphEngine(g, make_graph_mesh(parts))
rec = SpanRecorder()
server = GraphServer(eng, buckets=(8,), obs=rec)
qids = [server.submit("bfs", root=r) for r in range(12)]
qids.append(server.submit("pagerank"))
server.drain()
results = [server.results.pop(q) for q in qids]
assert all(r.status == "ok" for r in results), [r.status for r in results]
# served answers stay oracle-correct under tracing
oracle.check_conformance("bfs", "fast", dict(results[0].fields),
                         edges, n, 0)
# mutation under tracing
dels = server.dynamic_graph().sample_deletable(
    8, np.random.default_rng(1))
server.mutate(deletes=dels)

spans = rec.spans()
q_spans = [s for s in spans if s.kind == "query"]
assert len(q_spans) == len(qids), (len(q_spans), len(qids))
assert derive_latency_cells(rec) == server.metrics.latencies()
kinds = {{s.kind for s in spans}}
assert {{"admission", "validate", "coalesce_wait", "dispatch", "device",
         "demux", "mutation"}} <= kinds, kinds
counts = validate_chrome_trace(chrome_trace(spans, rec.events()))
assert counts["b"] == counts["e"] >= len(qids)
summ = trace_summary(rec)
assert summ["spans_per_kind"]["query"] == len(qids)
assert summ["dropped_spans"] == 0
print("TRACED-SERVE-OK", json.dumps(counts))
"""


@pytest.mark.obs
@pytest.mark.slow
def test_obs_traced_serve_acceptance():
    """A parts=2 traced serve session: answers stay correct, every
    pipeline stage leaves spans, the latency cells reconcile exactly,
    and the Chrome export passes the schema validator."""
    out = run_with_devices(_TRACED_SERVE_CODE.format(tests_dir=TESTS_DIR))
    assert "TRACED-SERVE-OK" in out


_TELEMETRY_SWEEP_CODE = """
import sys
sys.path.insert(0, {tests_dir!r})
import numpy as np
import jax.numpy as jnp
import oracle
from repro.core import GraphEngine, partition_graph, registry
from repro.launch.mesh import make_graph_mesh

n, seed, root = 384, 5, 3
edges, n = oracle.family_edges("urand", n, seed)
pairs = {{}}
for algo, variant in sorted(registry.available()):
    spec = registry.get_spec(algo, variant)
    if all(k == "scalar" for k in spec.input_kinds):
        pairs.setdefault(algo, (algo, variant))
pairs = list(pairs.values())
assert len(pairs) >= 3, pairs
for parts in (1, 2, 4):
    g = partition_graph(edges, n, parts)
    eng = GraphEngine(g, make_graph_mesh(parts))
    garr = eng.device_graph()
    for algo, variant in pairs:
        spec = registry.get_spec(algo, variant)
        params = oracle.CONFORMANCE_PARAMS.get((algo, variant), {{}})
        ins = (jnp.int32(root),) * len(spec.inputs)
        prog = eng.program(algo, variant, **params)
        *outs, rounds = prog(garr, *ins)
        tprog = eng.program(algo, variant, telemetry=True, **params)
        tout = tprog(garr, *ins)
        # telemetry-ON output bits == telemetry-OFF (the seed path)
        for a, b in zip((*outs, rounds), tout[:-1]):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (
                f"{{algo}}/{{variant}} parts={{parts}}: telemetry build "
                "diverged from the plain build")
        tel = tprog.run_telemetry(tout[-1])
        assert tel.series.rounds == int(rounds), (algo, variant, parts)
        if parts > 1:
            # multi-part runs exchange every round; the trace-time tap
            # accounting must see it
            assert sum(tel.wire_bytes_by_op().values()) > 0, (
                algo, variant, parts)
        # ... and the telemetry run still passes the oracle gate
        p = prog.program
        fields = {{name: (eng.gather_vertex_field(o) if isv
                          else np.asarray(o)[()])
                   for name, o, isv in zip(p.output_names, tout[:-2],
                                           p.output_is_vertex)}}
        oracle.check_conformance(algo, variant, fields, edges, n, root)
        print(f"PASS {{algo}}/{{variant}} parts={{parts}} "
              f"rounds={{int(rounds)}}")
print("TELEMETRY-OK")
"""


@pytest.mark.obs
@pytest.mark.slow
def test_obs_telemetry_conformance_across_parts():
    """Telemetry-ON builds at parts {1,2,4}: bit-identical outputs to
    the plain builds, per-round series lengths matching the driver's
    round count, non-zero wire accounting on multi-part meshes, and
    NumPy-oracle conformance of the telemetry run itself."""
    out = run_with_devices(
        _TELEMETRY_SWEEP_CODE.format(tests_dir=TESTS_DIR))
    assert "TELEMETRY-OK" in out
