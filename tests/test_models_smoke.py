"""Per-architecture smoke tests (assignment requirement): reduced config
of the same family, one forward/train step on CPU, output shapes + no
NaNs; plus decode-path and grad-accumulation consistency checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.configs.registry import ARCHS, SMOKE_SHAPE, smoke_config
from repro.launch.steps import make_train_step
from repro.models import (
    forward_decode,
    forward_prefill,
    forward_train,
    init_cache,
    init_params,
    param_spec,
)
from repro.optim import init_opt_state


def _batch(cfg, B=2, S=64, key=1):
    b = {"tokens": jax.random.randint(jax.random.key(key), (B, S), 0,
                                      cfg.vocab_size)}
    if cfg.family == "audio":
        b["enc_embeds"] = 0.1 * jax.random.normal(
            jax.random.key(2), (B, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        b["vis_embeds"] = 0.02 * jax.random.normal(
            jax.random.key(2), (B, cfg.vision_tokens, cfg.d_model))
    return b


@pytest.mark.parametrize("name", list(ARCHS))
def test_train_step_smoke(name):
    cfg = smoke_config(name)
    params = init_params(param_spec(cfg), jax.random.key(0))
    opt = init_opt_state(params)
    tc = TrainConfig(total_steps=10, warmup_steps=2)
    step = jax.jit(make_train_step(cfg, tc))
    b = _batch(cfg, SMOKE_SHAPE.global_batch, SMOKE_SHAPE.seq_len)
    params2, opt2, metrics = step(params, opt, b)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and 1.0 < loss < 20.0, (name, loss)
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l2 = jax.tree.leaves(params2)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l2))
    # all outputs finite
    for leaf in jax.tree.leaves(params2):
        assert np.isfinite(np.asarray(leaf)).all(), name


@pytest.mark.parametrize("name", ["tinyllama-1.1b", "gemma3-27b",
                                  "h2o-danube-3-4b", "zamba2-7b",
                                  "mamba2-1.3b", "qwen2.5-32b"])
def test_prefill_decode_equivalence(name):
    """Decode step-by-step reproduces prefill logits at the last position."""
    cfg = smoke_config(name)
    params = init_params(param_spec(cfg), jax.random.key(0))
    S = 16
    toks = jax.random.randint(jax.random.key(1), (2, S), 0, cfg.vocab_size)
    lg_p, _ = jax.jit(lambda p, b: forward_prefill(p, cfg, b))(
        params, {"tokens": toks})
    cache = init_cache(cfg, 2, S)
    dec = jax.jit(lambda p, t, c: forward_decode(p, cfg, t, c))
    for t in range(S):
        lg_d, cache = dec(params, toks[:, t:t + 1], cache)
    np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_d),
                               atol=0.05, rtol=0.05)


def test_grad_accum_equivalence():
    """accum=2 matches accum=1 on the same global batch (same grads)."""
    cfg = smoke_config("tinyllama-1.1b")
    params = init_params(param_spec(cfg), jax.random.key(0))
    b = _batch(cfg, B=4, S=32)

    outs = {}
    for accum in (1, 2):
        tc = TrainConfig(total_steps=10, warmup_steps=2, grad_accum=accum)
        opt = init_opt_state(params)
        step = jax.jit(make_train_step(cfg, tc))
        p2, _, m = step(params, opt, b)
        outs[accum] = (p2, float(m["loss"]))
    assert abs(outs[1][1] - outs[2][1]) < 1e-3
    for a, b_ in zip(jax.tree.leaves(outs[1][0]),
                     jax.tree.leaves(outs[2][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=2e-4, rtol=2e-3)


def test_loss_decreases_over_steps():
    cfg = smoke_config("tinyllama-1.1b")
    params = init_params(param_spec(cfg), jax.random.key(0))
    opt = init_opt_state(params)
    tc = TrainConfig(learning_rate=3e-3, total_steps=60, warmup_steps=5)
    step = jax.jit(make_train_step(cfg, tc))
    from repro.data import TokenStream
    stream = TokenStream(global_batch=4, seq_len=64,
                         vocab_size=cfg.vocab_size)
    losses = []
    for i in range(60):
        params, opt, m = step(params, opt, stream.next())
        losses.append(float(m["loss"]))
    first = sum(losses[:8]) / 8
    last = sum(losses[-8:]) / 8
    assert last < first - 0.1, (first, last)
