"""The superstep-program API: registry coverage, compile-cache behaviour,
batched multi-source traversal vs per-root single-source runs, and the
registry-generated docs table.

Coverage tests ENUMERATE the registry (no hard-coded program list), so
newly registered programs are picked up without edits; CORE_PAIRS /
NEW_PAIRS only assert that expected programs exist, never that the set
is exactly them.
"""

import os

import numpy as np
import pytest

import jax.numpy as jnp

from conftest import run_with_devices
from repro.core import GraphEngine, incremental, partition_graph, registry
from repro.core.registry import ProgramSpec
from repro.graphs import urand_edges
from repro.launch.mesh import make_graph_mesh

INT_INF = 2 ** 30

CORE_PAIRS = {("bfs", "bsp"), ("bfs", "fast"), ("pagerank", "bsp"),
              ("pagerank", "fast"), ("sssp", "default"), ("cc", "default")}
NEW_PAIRS = {("triangles", "default"), ("kcore", "default"),
             ("betweenness", "default")}
SEEDED_PAIRS = {("pagerank", "warm"), ("cc", "incremental"),
                ("kcore", "incremental")}

# snapshot for parametrization (registry is append-only at runtime)
ALL_PAIRS = sorted(registry.available())


@pytest.fixture(scope="module")
def tiny_engine():
    n, e = 512, 4096
    edges = urand_edges(n, e, seed=7)
    g = partition_graph(edges, n, parts=1)
    eng = GraphEngine(g, make_graph_mesh(1))
    return n, edges, eng, eng.device_graph()


def test_all_programs_registered():
    got = set(registry.available())
    assert got >= CORE_PAIRS
    assert got >= NEW_PAIRS
    assert got >= SEEDED_PAIRS
    assert len(got) >= 12


# light per-algorithm output sanity; deep equality lives in the oracle
# conformance suite (tests/test_oracle_conformance.py)
_SANITY = {
    "bfs": lambda f, root: f[root] == root,       # root is its own parent
    "sssp": lambda f, root: f[root] == 0.0,
    "cc": lambda f, root: f.min() >= 0,
    "pagerank": lambda f, root: abs(f.sum() - 1.0) < 0.2,
    "triangles": lambda f, root: (f >= 0).all(),
    "kcore": lambda f, root: (f >= 0).all(),
    "betweenness": lambda f, root: f[root] == 0.0,  # delta_s(s) == 0
}


@pytest.mark.parametrize("algo,variant", ALL_PAIRS)
def test_every_program_runs(tiny_engine, algo, variant):
    n, edges, eng, garr = tiny_engine
    spec = registry.get_spec(algo, variant)
    prog = eng.program(algo, variant)
    if any(k != "scalar" for k in spec.input_kinds):
        (seed_arr,) = incremental.cold_seed(spec, eng.g)
        args = (garr, eng.scatter_vertex_field(
            seed_arr, incremental.KIND_DTYPES[spec.input_kinds[0]]))
    else:
        args = (garr,) + (jnp.int32(3),) * len(spec.inputs)
    *outs, rounds = prog(*args)
    assert int(rounds) > 0
    field = eng.gather_vertex_field(outs[0])
    assert field.shape == (n,)
    assert _SANITY[algo](field, 3), f"{algo}/{variant} output sanity"


def test_shorthand_and_default_variants(tiny_engine):
    _, _, eng, _ = tiny_engine
    assert registry.get_spec("bfs").variant == "fast"
    assert registry.get_spec("pagerank").variant == "fast"
    assert registry.get_spec("bfs/bsp").variant == "bsp"
    for algo in ("triangles", "kcore", "betweenness"):
        assert registry.get_spec(algo).variant == "default"
    with pytest.raises(KeyError):
        registry.get_spec("bfs", "nope")
    with pytest.raises(KeyError):
        registry.get_spec("nope")
    with pytest.raises(TypeError):
        eng.program("bfs", "fast", bogus_param=1)


def test_unknown_program_error_lists_registered_keys():
    """An unknown algo/variant must raise naming the registered keys
    (at least bfs and pagerank), not a bare KeyError."""
    for bad in ("nope", ("bfs", "nope"), "bfs/nope", "pagerank/nope"):
        with pytest.raises(KeyError) as ei:
            if isinstance(bad, tuple):
                registry.get_spec(*bad)
            else:
                registry.get_spec(bad)
        msg = str(ei.value)
        assert "bfs" in msg and "pagerank" in msg, msg
        assert "registered programs" in msg, msg


def test_register_default_claims():
    """The implicit default is the FIRST registered variant; an explicit
    default=True overrides it; a SECOND explicit claim for the same algo
    raises instead of being silently resolved by registration order."""
    def spec(variant):
        return ProgramSpec(algo="zz_test_algo", variant=variant,
                           make=lambda g: None, inputs=())
    try:
        registry.register(spec("a"))
        assert registry.default_variant("zz_test_algo") == "a"   # implicit
        registry.register(spec("b"), default=True)
        assert registry.default_variant("zz_test_algo") == "b"   # explicit
        with pytest.raises(ValueError, match="already claimed"):
            registry.register(spec("c"), default=True)
        assert ("zz_test_algo", "c") not in registry.available()
        with pytest.raises(ValueError, match="duplicate"):
            registry.register(spec("a"))
    finally:
        for v in ("a", "b", "c"):
            registry._REGISTRY.pop(("zz_test_algo", v), None)
        registry._DEFAULT_VARIANT.pop("zz_test_algo", None)
        registry._EXPLICIT_DEFAULT.discard("zz_test_algo")


def test_builtin_defaults_are_explicit():
    """Every built-in algorithm's default is an explicit claim — the
    old silent first-wins behaviour can't decide a shipped default."""
    for algo in {a for a, _ in registry.available()}:
        assert algo in registry._EXPLICIT_DEFAULT, \
            f"{algo}: default variant relies on registration order"


def test_program_compile_cache(tiny_engine):
    _, _, eng, garr = tiny_engine
    p1 = eng.program("bfs", "fast", max_levels=32)
    p2 = eng.program("bfs", "fast", max_levels=32)
    assert p1 is p2                               # same cached object
    p1(garr, jnp.int32(0))
    p1(garr, jnp.int32(1))
    assert p1.trace_cache_size() == 1             # no re-trace across calls
    # different params / loop modes are distinct cache entries
    assert eng.program("bfs", "fast", max_levels=16) is not p1
    assert eng.program("bfs", "fast", max_levels=32,
                       static_iters=4) is not p1
    assert p1.aot() is p1.aot()                   # AOT executable cached too
    # phased programs ride the same cache
    b1 = eng.program("betweenness")
    assert eng.program("betweenness") is b1


def test_batched_multi_source_bfs_matches_single(tiny_engine):
    n, _, eng, garr = tiny_engine
    roots = [0, 3, 250, 499]
    batched = eng.program("bfs", "fast", batch=len(roots))
    parents_b, levels_b = batched(garr, jnp.asarray(roots, jnp.int32))
    single = eng.program("bfs", "fast")
    all_parents = eng.gather_batched_vertex_field(parents_b)
    assert all_parents.shape == (len(roots), n)
    for i, r in enumerate(roots):
        p, lv = single(garr, jnp.int32(r))
        np.testing.assert_array_equal(all_parents[i],
                                      eng.gather_vertex_field(p))
        assert int(levels_b[i]) == int(lv)


def test_batched_multi_source_sssp_matches_single(tiny_engine):
    n, _, eng, garr = tiny_engine
    roots = [0, 77]
    dist_b, _ = eng.program("sssp", batch=len(roots))(
        garr, jnp.asarray(roots, jnp.int32))
    for i, r in enumerate(roots):
        d, _ = eng.program("sssp")(garr, jnp.int32(r))
        np.testing.assert_allclose(eng.gather_batched_vertex_field(dist_b)[i],
                                   eng.gather_vertex_field(d))


def test_batched_betweenness_matches_single(tiny_engine):
    """The phased program under run_program_batched: B forward sweeps +
    B backward sweeps vmapped as one launch must be bit-identical to
    per-source runs (forward sigma/dist AND backward bc)."""
    n, _, eng, garr = tiny_engine
    roots = [0, 3, 250]
    bc_b, sg_b, d_b, rounds_b = eng.program("betweenness", batch=len(roots))(
        garr, jnp.asarray(roots, jnp.int32))
    single = eng.program("betweenness")
    for i, r in enumerate(roots):
        bc, sg, d, rounds = single(garr, jnp.int32(r))
        np.testing.assert_array_equal(
            eng.gather_batched_vertex_field(d_b)[i],
            eng.gather_vertex_field(d))
        np.testing.assert_array_equal(
            eng.gather_batched_vertex_field(sg_b)[i],
            eng.gather_vertex_field(sg))
        np.testing.assert_array_equal(
            eng.gather_batched_vertex_field(bc_b)[i],
            eng.gather_vertex_field(bc))
        assert int(rounds_b[i]) == int(rounds)


def test_batch_rejected_for_inputless_programs(tiny_engine):
    _, _, eng, _ = tiny_engine
    with pytest.raises(ValueError):
        eng.program("pagerank", "fast", batch=4)
    with pytest.raises(ValueError):
        eng.program("triangles", batch=4)
    # seeded (vertex-input) programs can't ride root batches either
    with pytest.raises(ValueError):
        eng.program("pagerank", "warm", batch=4)
    with pytest.raises(ValueError):
        eng.program("cc", "incremental", batch=4)


def test_static_iters_matches_early_exit(tiny_engine):
    """Fixed-trip scans converge to the same fixed point as the
    early-exit while loop (rounds past convergence are no-ops) — for
    the fixpoint programs AND the new gated-rotation/peeling/phased
    ones."""
    _, _, eng, garr = tiny_engine
    d0, _ = eng.program("sssp")(garr, jnp.int32(0))
    d1, rs = eng.program("sssp", static_iters=24)(garr, jnp.int32(0))
    assert int(rs) == 24
    np.testing.assert_allclose(eng.gather_vertex_field(d1),
                               eng.gather_vertex_field(d0))
    c0, _ = eng.program("cc")(garr)
    c1, _ = eng.program("cc", static_iters=16)(garr)
    np.testing.assert_array_equal(eng.gather_vertex_field(c1),
                                  eng.gather_vertex_field(c0))
    t0, tot0, _ = eng.program("triangles")(garr)
    t1, tot1, rt = eng.program("triangles", static_iters=5)(garr)
    assert int(rt) == 5 and int(tot1) == int(tot0)  # rounds past P gated
    np.testing.assert_array_equal(eng.gather_vertex_field(t1),
                                  eng.gather_vertex_field(t0))
    k0, km0, _ = eng.program("kcore")(garr)
    k1, km1, _ = eng.program("kcore", static_iters=48)(garr)
    assert int(km1) == int(km0)
    np.testing.assert_array_equal(eng.gather_vertex_field(k1),
                                  eng.gather_vertex_field(k0))
    b0, _, _, _ = eng.program("betweenness")(garr, jnp.int32(0))
    b1, _, _, rb = eng.program("betweenness", static_iters=14)(
        garr, jnp.int32(0))
    assert int(rb) == 28                          # per-phase static count
    np.testing.assert_array_equal(eng.gather_vertex_field(b1),
                                  eng.gather_vertex_field(b0))


@pytest.mark.slow
def test_rounds_accounting_partition_invariant():
    """The driver's returned round count is a property of the algorithm
    on the graph, not of the partitioning — for every program whose
    round structure is integer-combined (min/or/count exchanges are
    order-exact).  The triangle rotation is the documented exception:
    it runs exactly P supersteps by construction."""
    out = run_with_devices("""
import jax.numpy as jnp
from repro.graphs import urand_edges
from repro.core import GraphEngine, partition_graph, registry
from repro.launch.mesh import make_graph_mesh

n, e = 1024, 8192
edges = urand_edges(n, e, seed=3)
invariant = ["bfs/bsp", "bfs/fast", "sssp", "cc", "kcore", "betweenness"]
rounds = {}
for parts in (1, 2, 4):
    g = partition_graph(edges, n, parts)
    eng = GraphEngine(g, make_graph_mesh(parts))
    garr = eng.device_graph()
    for key in invariant:
        spec = registry.get_spec(key)
        prog = eng.program(key)
        args = (garr,) + (jnp.int32(1),) * len(spec.inputs)
        *_, r = prog(*args)
        rounds.setdefault(key, []).append(int(r))
    *_, rt = eng.program("triangles")(garr)
    assert int(rt) == parts, f"triangle rotation must run P={parts} rounds"
for key, rs in rounds.items():
    assert len(set(rs)) == 1, f"{key}: rounds vary across parts: {rs}"
    assert rs[0] > 0, key
print("ROUNDS-INVARIANT OK", rounds)
""", devices=4)
    assert "ROUNDS-INVARIANT OK" in out


def test_docs_table_matches_registry():
    """docs/API.md embeds registry.algorithms_markdown_table() AND
    registry.incremental_markdown_table() verbatim, so neither table can
    drift from the registry."""
    api_md = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "docs", "API.md")
    with open(api_md) as f:
        content = f.read()
    assert registry.algorithms_markdown_table() in content, (
        "docs/API.md algorithms table is stale — regenerate with:\n"
        "  PYTHONPATH=src python -c 'from repro.core import registry; "
        "print(registry.algorithms_markdown_table())'")
    assert registry.incremental_markdown_table() in content, (
        "docs/API.md incremental-programs table is stale — regenerate "
        "with:\n  PYTHONPATH=src python -c 'from repro.core import "
        "registry; print(registry.incremental_markdown_table())'")
    assert registry.guards_markdown_table() in content, (
        "docs/API.md fault-guard table is stale — regenerate with:\n"
        "  PYTHONPATH=src python -c 'from repro.core import registry; "
        "print(registry.guards_markdown_table())'")
