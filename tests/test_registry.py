"""The superstep-program API: registry coverage, compile-cache behaviour,
and batched multi-source traversal vs per-root single-source runs."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import GraphEngine, partition_graph, registry
from repro.graphs import urand_edges
from repro.launch.mesh import make_graph_mesh

INT_INF = 2 ** 30

EXPECTED = {("bfs", "bsp"), ("bfs", "fast"), ("pagerank", "bsp"),
            ("pagerank", "fast"), ("sssp", "default"), ("cc", "default")}


@pytest.fixture(scope="module")
def tiny_engine():
    n, e = 512, 4096
    edges = urand_edges(n, e, seed=7)
    g = partition_graph(edges, n, parts=1)
    eng = GraphEngine(g, make_graph_mesh(1))
    return n, edges, eng, eng.device_graph()


def test_all_programs_registered():
    assert set(registry.available()) == EXPECTED


@pytest.mark.parametrize("algo,variant", sorted(EXPECTED))
def test_every_program_runs(tiny_engine, algo, variant):
    n, edges, eng, garr = tiny_engine
    spec = registry.get_spec(algo, variant)
    prog = eng.program(algo, variant)
    args = (garr,) + (jnp.int32(3),) * len(spec.inputs)
    *outs, rounds = prog(*args)
    assert int(rounds) > 0
    field = eng.gather_vertex_field(outs[0])
    assert field.shape == (n,)
    if algo == "bfs":
        assert field[3] == 3                      # root is its own parent
    elif algo == "sssp":
        assert field[3] == 0.0
    elif algo == "cc":
        assert field.min() >= 0
    elif algo == "pagerank":
        assert abs(field.sum() - 1.0) < 0.2       # rank mass ~conserved


def test_shorthand_and_default_variants(tiny_engine):
    _, _, eng, _ = tiny_engine
    assert registry.get_spec("bfs").variant == "fast"
    assert registry.get_spec("pagerank").variant == "fast"
    assert registry.get_spec("bfs/bsp").variant == "bsp"
    with pytest.raises(KeyError):
        registry.get_spec("bfs", "nope")
    with pytest.raises(KeyError):
        registry.get_spec("nope")
    with pytest.raises(TypeError):
        eng.program("bfs", "fast", bogus_param=1)


def test_program_compile_cache(tiny_engine):
    _, _, eng, garr = tiny_engine
    p1 = eng.program("bfs", "fast", max_levels=32)
    p2 = eng.program("bfs", "fast", max_levels=32)
    assert p1 is p2                               # same cached object
    p1(garr, jnp.int32(0))
    p1(garr, jnp.int32(1))
    assert p1.trace_cache_size() == 1             # no re-trace across calls
    # different params / loop modes are distinct cache entries
    assert eng.program("bfs", "fast", max_levels=16) is not p1
    assert eng.program("bfs", "fast", max_levels=32,
                       static_iters=4) is not p1
    assert p1.aot() is p1.aot()                   # AOT executable cached too


def test_batched_multi_source_bfs_matches_single(tiny_engine):
    n, _, eng, garr = tiny_engine
    roots = [0, 3, 250, 499]
    batched = eng.program("bfs", "fast", batch=len(roots))
    parents_b, levels_b = batched(garr, jnp.asarray(roots, jnp.int32))
    single = eng.program("bfs", "fast")
    all_parents = eng.gather_batched_vertex_field(parents_b)
    assert all_parents.shape == (len(roots), n)
    for i, r in enumerate(roots):
        p, lv = single(garr, jnp.int32(r))
        np.testing.assert_array_equal(all_parents[i],
                                      eng.gather_vertex_field(p))
        assert int(levels_b[i]) == int(lv)


def test_batched_multi_source_sssp_matches_single(tiny_engine):
    n, _, eng, garr = tiny_engine
    roots = [0, 77]
    dist_b, _ = eng.program("sssp", batch=len(roots))(
        garr, jnp.asarray(roots, jnp.int32))
    for i, r in enumerate(roots):
        d, _ = eng.program("sssp")(garr, jnp.int32(r))
        np.testing.assert_allclose(eng.gather_batched_vertex_field(dist_b)[i],
                                   eng.gather_vertex_field(d))


def test_batch_rejected_for_inputless_programs(tiny_engine):
    _, _, eng, _ = tiny_engine
    with pytest.raises(ValueError):
        eng.program("pagerank", "fast", batch=4)


def test_static_iters_matches_early_exit(tiny_engine):
    """SSSP/CC under the driver's fixed-trip scan converge to the same
    fixed point as the early-exit while loop (rounds past convergence
    are no-ops)."""
    _, _, eng, garr = tiny_engine
    d0, _ = eng.program("sssp")(garr, jnp.int32(0))
    d1, rs = eng.program("sssp", static_iters=24)(garr, jnp.int32(0))
    assert int(rs) == 24
    np.testing.assert_allclose(eng.gather_vertex_field(d1),
                               eng.gather_vertex_field(d0))
    c0, _ = eng.program("cc")(garr)
    c1, _ = eng.program("cc", static_iters=16)(garr)
    np.testing.assert_array_equal(eng.gather_vertex_field(c1),
                                  eng.gather_vertex_field(c0))
