"""Pure-NumPy reference implementations for EVERY registered graph
program, plus the conformance checks that pin engine outputs to them.

This module is the single source of algorithmic truth for the test
suite: ``test_oracle_conformance.py`` runs every registered (algo,
variant) pair x parts in {1, 2, 4} x two graph families against these
oracles, and future programs inherit the gate by adding one entry to
``CHECKS``.  It is imported both in-process (pytest puts tests/ on
sys.path) and inside multi-device subprocesses (the conformance test
inserts this directory explicitly).

Semantics notes (each oracle mirrors its engine program's documented
convention — see the module docstrings in repro/core/*.py):

  * bfs / sssp / betweenness: DIRECTED multigraph, parallel edges are
    parallel paths; sssp weights reproduce ``repro.core.sssp.edge_weight``.
  * cc: weakly-connected components labeled by their minimum vertex id
    (the exact fixed point of min-label propagation).
  * triangles: SIMPLE UNDIRECTED graph (dedup, no self-loops).
  * kcore: UNDIRECTED MULTIGRAPH (parallel edges count, no self-loops).
"""

from __future__ import annotations

import numpy as np

INT_INF = 2 ** 30


# ---------------------------------------------------------------------------
# graph families for the conformance gate
# ---------------------------------------------------------------------------

def family_edges(family: str, n: int, seed: int):
    """Deterministic (edges, n) for a named conformance family."""
    # imported lazily so this module stays importable without jax deps
    from repro.graphs import rmat_edges, smallworld_edges, urand_edges
    if family == "urand":
        return urand_edges(n, 8 * n, seed=seed), n
    if family == "smallworld":
        return smallworld_edges(n, k=8, p=0.2, seed=seed), n
    if family == "rmat":
        # Graph500-style power-law graph; rmat_edges needs a pow2 vertex
        # count, so round n up — skewed degrees stress the blocked-ELL
        # bucket ladder in a way the uniform families cannot.
        scale = max(1, int(np.ceil(np.log2(n))))
        n2 = 1 << scale
        return rmat_edges(scale, 8 * n2, seed=seed), n2
    raise ValueError(family)


# ---------------------------------------------------------------------------
# oracles
# ---------------------------------------------------------------------------

def bfs_levels(edges, n, root):
    """Hop distances; -1 for unreachable."""
    dist = np.full(n, -1, np.int64)
    dist[root] = 0
    frontier = np.zeros(n, bool)
    frontier[root] = True
    src, dst = edges[:, 0], edges[:, 1]
    level = 0
    while frontier.any():
        level += 1
        hit = frontier[src]
        nxt = np.zeros(n, bool)
        nxt[dst[hit]] = True
        nxt &= dist < 0
        dist[nxt] = level
        frontier = nxt
    return dist


def edge_weights(edges):
    """The engine's deterministic pseudo-random weights in [1, 2)."""
    su = edges[:, 0].astype(np.uint32)
    du = edges[:, 1].astype(np.uint32)
    h = su * np.uint32(2654435761) ^ du * np.uint32(40503)
    return 1.0 + (h % np.uint32(1 << 16)).astype(np.float64) / (1 << 16)


def sssp_dist(edges, n, root):
    """Bellman-Ford distances with the engine's weights; inf unreachable."""
    w = edge_weights(edges)
    dist = np.full(n, np.inf)
    dist[root] = 0.0
    src, dst = edges[:, 0], edges[:, 1]
    for _ in range(n):
        new = dist.copy()
        np.minimum.at(new, dst, dist[src] + w)
        if np.array_equal(new, dist):
            break
        dist = new
    return dist


def pagerank(edges, n, iters=50, alpha=0.85):
    """Power iteration matching the engine (dangling mass is dropped)."""
    outdeg = np.bincount(edges[:, 0], minlength=n).astype(np.float64)
    r = np.full(n, 1.0 / n)
    for _ in range(iters):
        contrib = np.where(outdeg > 0, r / np.maximum(outdeg, 1), 0.0)
        z = np.zeros(n)
        np.add.at(z, edges[:, 1], contrib[edges[:, 0]])
        r = (1 - alpha) / n + alpha * z
    return r


def cc_labels(edges, n):
    """Weak-component labels: min vertex id in each component (the exact
    fixed point the engine's min-label propagation converges to)."""
    labels = np.arange(n)
    changed = True
    while changed:
        new = labels.copy()
        np.minimum.at(new, edges[:, 1], labels[edges[:, 0]])
        np.minimum.at(new, edges[:, 0], new[edges[:, 1]])
        changed = (new != labels).any()
        labels = new
    return labels


def triangles(edges, n):
    """(per-vertex, total) triangle counts of the simple undirected graph."""
    A = np.zeros((n, n), bool)
    A[edges[:, 0], edges[:, 1]] = True
    A |= A.T
    np.fill_diagonal(A, False)
    Af = A.astype(np.float64)
    per_vertex = (np.einsum("ij,ij->i", Af, Af @ Af) / 2).astype(np.int64)
    return per_vertex, int(per_vertex.sum()) // 3


def core_numbers(edges, n):
    """Core numbers of the undirected multigraph (threshold peeling)."""
    src, dst = edges[:, 0], edges[:, 1]
    ns = src != dst
    deg = (np.bincount(src[ns], minlength=n)
           + np.bincount(dst[ns], minlength=n)).astype(np.int64)
    alive = np.ones(n, bool)
    core = np.zeros(n, np.int64)
    k = 0
    while alive.any():
        kills = alive & (deg <= k)
        if kills.any():
            core[kills] = k
            alive[kills] = False
            dec = np.zeros(n, np.int64)
            m = kills[src] & ns
            np.add.at(dec, dst[m], 1)
            m = kills[dst] & ns
            np.add.at(dec, src[m], 1)
            deg = deg - dec
        else:
            k += 1
    return core


def betweenness_deps(edges, n, root):
    """Brandes single-source dependencies delta_s(v) on the directed
    multigraph, unweighted, delta_s(s) = 0."""
    M = np.zeros((n, n))
    np.add.at(M, (edges[:, 0], edges[:, 1]), 1.0)
    dist = np.full(n, INT_INF, np.int64)
    dist[root] = 0
    sigma = np.zeros(n)
    sigma[root] = 1.0
    level = 0
    while True:
        fr = dist == level
        if not fr.any():
            break
        pushed = M.T @ (sigma * fr)
        newly = (pushed > 0) & (dist == INT_INF)
        dist[newly] = level + 1
        sigma[newly] = pushed[newly]
        level += 1
    delta = np.zeros(n)
    for lvl in range(level - 1, -1, -1):
        coef = np.where(sigma > 0, (1 + delta) / np.maximum(sigma, 1), 0.0)
        coef *= dist == lvl + 1
        relaxed = sigma * (M @ coef)
        delta[dist == lvl] = relaxed[dist == lvl]
    delta[root] = 0.0
    return delta, sigma, dist


# ---------------------------------------------------------------------------
# conformance checks: one per ALGORITHM; every variant of the algorithm
# must pass it.  ``fields`` maps the program's output_names to gathered
# (n_orig,) numpy arrays (scalars stay scalars).
# ---------------------------------------------------------------------------

def _check_bfs(fields, edges, n, root):
    parents = fields["parents"]
    dist = bfs_levels(edges, n, root)
    reached = parents < INT_INF
    assert (reached == (dist >= 0)).all(), "BFS reachability mismatch"
    assert parents[root] == root, "root must be its own parent"
    # every parent is a true in-neighbor exactly one level up
    has_edge = np.zeros((n, n), bool)
    has_edge[edges[:, 0], edges[:, 1]] = True
    for v in np.flatnonzero(reached):
        if v == root:
            continue
        p = int(parents[v])
        assert has_edge[p, v], f"parent {p} of {v} is not an in-neighbor"
        assert dist[p] == dist[v] - 1, f"parent {p} of {v} level mismatch"


def _check_sssp(fields, edges, n, root):
    ref = sssp_dist(edges, n, root)
    got = np.where(fields["dist"] >= 1e29, np.inf, fields["dist"])
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-4)


def _check_pagerank(fields, edges, n, root):
    ref = pagerank(edges, n, iters=CONFORMANCE_PR_ITERS)
    rel = np.abs(fields["rank"] - ref).max() / ref.max()
    assert rel < 1e-4, f"pagerank max rel err {rel:.2e}"


def _check_cc(fields, edges, n, root):
    np.testing.assert_array_equal(fields["labels"], cc_labels(edges, n))


def _check_triangles(fields, edges, n, root):
    per_vertex, total = triangles(edges, n)
    np.testing.assert_array_equal(fields["triangles"], per_vertex)
    assert int(fields["total"]) == total, \
        f"global triangle count {int(fields['total'])} != {total}"


def _check_kcore(fields, edges, n, root):
    ref = core_numbers(edges, n)
    np.testing.assert_array_equal(fields["core"], ref)
    assert int(fields["kmax"]) == int(ref.max()), "degeneracy mismatch"


def _check_betweenness(fields, edges, n, root):
    delta, sigma, dist = betweenness_deps(edges, n, root)
    np.testing.assert_array_equal(fields["dist"], dist)
    np.testing.assert_allclose(fields["sigma"], sigma, rtol=1e-6)
    np.testing.assert_allclose(fields["bc"], delta, rtol=1e-4, atol=1e-4)


def _check_pagerank_converged(fields, edges, n, root):
    """Variant check for ``pagerank/warm``: the warm restart iterates to
    ITS OWN fixed point, not along the cold 40-iteration trajectory, so
    the peer is a CONVERGED oracle (300 rounds is far past the 1e-9
    conformance tol at alpha=0.85)."""
    ref = pagerank(edges, n, iters=300)
    rel = np.abs(fields["rank"] - ref).max() / ref.max()
    assert rel < 1e-4, f"pagerank(converged) max rel err {rel:.2e}"


# conformance settings for the async lane: pagerank/async sweeps with a
# non-default staleness so the knob is exercised, and its remote term is
# then provably at most 2*staleness + 1 rounds old (shipped at most
# staleness rounds after its source ranks were computed, then served for
# at most staleness rounds) — the program reports the realized maximum
# as ``max_age`` and the check asserts the bound.
ASYNC_PR_STALENESS = 2
ASYNC_PR_AGE_BOUND = 2 * ASYNC_PR_STALENESS + 1
# documented staleness tolerance: the bounded-staleness iteration is
# still an alpha-contraction to the same fixed point, so after a
# converged run the rank must match the converged oracle to the SAME
# 1e-4 relative bound the warm variant meets (measured headroom at
# parts {1,2,4}: worst rel ~1e-5).
ASYNC_PR_REL_TOL = 1e-4


def _check_pagerank_async(fields, edges, n, root):
    """Variant check for ``pagerank/async``: converged-oracle match
    within the documented staleness tolerance, PLUS the staleness bound
    itself — a run whose remote term aged beyond 2*staleness + 1 rounds
    would be unbounded staleness, which is a different (and unchecked)
    convergence claim."""
    ref = pagerank(edges, n, iters=300)
    rel = np.abs(fields["rank"] - ref).max() / ref.max()
    assert rel < ASYNC_PR_REL_TOL, \
        f"pagerank/async max rel err {rel:.2e} (tol {ASYNC_PR_REL_TOL})"
    assert int(fields["max_age"]) <= ASYNC_PR_AGE_BOUND, \
        (f"staleness bound violated: max_age {int(fields['max_age'])} > "
         f"2*{ASYNC_PR_STALENESS}+1")


CHECKS = {
    "bfs": _check_bfs,
    "sssp": _check_sssp,
    "pagerank": _check_pagerank,
    "cc": _check_cc,
    "triangles": _check_triangles,
    "kcore": _check_kcore,
    "betweenness": _check_betweenness,
}

# per-(algo, variant) check overrides, consulted before CHECKS: variants
# whose contract differs from the default trajectory (e.g. seeded warm
# restarts that converge to the fixed point instead of replaying the
# cold iteration count) pin against their own oracle form.
VARIANT_CHECKS = {
    ("pagerank", "warm"): _check_pagerank_converged,
    ("pagerank", "async"): _check_pagerank_async,
}

# conformance-run parameter overrides: pagerank runs a fixed iteration
# budget (tol below reach) so the oracle's power iteration is an exact
# peer; the fast variant's bf16 compression is off for a tight bound.
# pagerank/warm instead runs TO CONVERGENCE (300-round cap, tight tol)
# because its check compares against the converged oracle —
# pagerank/async likewise (a stale trajectory can't replay the cold
# iteration count, but the fixed point is shared).  The monotone async
# variants (bfs/cc/sssp) run their defaults: staleness never changes
# their answer, so the base algorithm checks apply EXACTLY.
CONFORMANCE_PR_ITERS = 40
CONFORMANCE_PARAMS = {
    ("pagerank", "bsp"): {"iters": CONFORMANCE_PR_ITERS, "tol": 1e-12},
    ("pagerank", "fast"): {"iters": CONFORMANCE_PR_ITERS, "tol": 1e-12,
                           "compress": False},
    ("pagerank", "warm"): {"iters": 300, "tol": 1e-9},
    ("pagerank", "async"): {"iters": 300, "tol": 1e-9,
                            "staleness": ASYNC_PR_STALENESS},
    ("cc", "default"): {"max_rounds": 128},
    ("cc", "incremental"): {"max_rounds": 128},
    ("cc", "async"): {"max_rounds": 128},
}


def check_conformance(algo, variant, fields, edges, n, root):
    """Dispatch to the algorithm's oracle check; unknown algorithms fail
    loudly so a new program MUST ship an oracle entry."""
    if (algo, variant) in VARIANT_CHECKS:
        VARIANT_CHECKS[(algo, variant)](fields, edges, n, root)
        return
    if algo not in CHECKS:
        raise AssertionError(
            f"no oracle registered for algorithm {algo!r} — add a "
            "reference implementation and a CHECKS entry in tests/oracle.py")
    CHECKS[algo](fields, edges, n, root)
