"""Mamba2 SSD: chunked scan vs naive recurrence; decode-step consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import smoke_config
from repro.models import mamba2 as M2
from repro.models.params import init_params


def naive_ssd(x, dt, A, Bc, Cc, D):
    """Reference: literal recurrence h_t = exp(dt A) h_{t-1} + dt B x."""
    Bsz, S, H, P = x.shape
    G, N = Bc.shape[2], Bc.shape[3]
    rep = H // G
    h = np.zeros((Bsz, H, P, N))
    ys = np.zeros((Bsz, S, H, P))
    x, dt, A = np.asarray(x, np.float64), np.asarray(dt, np.float64), \
        np.asarray(A, np.float64)
    Bc, Cc, D = np.asarray(Bc, np.float64), np.asarray(Cc, np.float64), \
        np.asarray(D, np.float64)
    for t in range(S):
        for hh in range(H):
            g = hh // rep
            decay = np.exp(dt[:, t, hh] * A[hh])              # (B,)
            inp = (dt[:, t, hh, None, None]
                   * np.einsum("bn,bp->bpn", Bc[:, t, g], x[:, t, hh]))
            h[:, hh] = decay[:, None, None] * h[:, hh] + inp
            ys[:, t, hh] = np.einsum("bpn,bn->bp", h[:, hh], Cc[:, t, g]) \
                + D[hh] * x[:, t, hh]
    return ys, h


def _rand_inputs(key, B=2, S=32, H=4, P=8, G=1, N=16):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bc = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    Cc = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    D = jnp.ones((H,))
    return x, dt, A, Bc, Cc, D


def test_ssd_chunked_matches_naive_recurrence():
    x, dt, A, Bc, Cc, D = _rand_inputs(jax.random.key(0))
    y, h = M2.ssd_chunked(x, dt, A, Bc, Cc, D, chunk=8)
    y_ref, h_ref = naive_ssd(x, dt, A, Bc, Cc, D)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=2e-3, atol=2e-3)


def test_ssd_chunk_size_invariance():
    x, dt, A, Bc, Cc, D = _rand_inputs(jax.random.key(1))
    y8, h8 = M2.ssd_chunked(x, dt, A, Bc, Cc, D, chunk=8)
    y16, h16 = M2.ssd_chunked(x, dt, A, Bc, Cc, D, chunk=16)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y16),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h8), np.asarray(h16),
                               rtol=1e-4, atol=1e-4)


def test_ssd_initial_state_continuation():
    """Running [first half] then [second half with h0] == full run."""
    x, dt, A, Bc, Cc, D = _rand_inputs(jax.random.key(2), S=32)
    y_full, h_full = M2.ssd_chunked(x, dt, A, Bc, Cc, D, chunk=8)
    y1, h1 = M2.ssd_chunked(x[:, :16], dt[:, :16], A, Bc[:, :16],
                            Cc[:, :16], D, chunk=8)
    y2, h2 = M2.ssd_chunked(x[:, 16:], dt[:, 16:], A, Bc[:, 16:],
                            Cc[:, 16:], D, chunk=8, h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-3, atol=1e-3)


def test_mamba_block_decode_matches_full_forward():
    cfg = smoke_config("mamba2-1.3b")
    p = init_params(M2.mamba2_spec(cfg), jax.random.key(0))
    B, S = 2, 16
    x = 0.1 * jax.random.normal(jax.random.key(1), (B, S, cfg.d_model),
                                dtype=jnp.float32)
    y_full = M2.mamba2_block(p, x, cfg)
    state = M2.init_ssm_state(cfg, B)
    ys = []
    for t in range(S):
        yt, state = M2.mamba2_decode(p, x[:, t:t + 1], cfg, state)
        ys.append(yt)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               rtol=3e-2, atol=3e-3)
