"""The query-serving subsystem: coalescer/executor unit behaviour, the
no-retrace guarantee the bucket ladder relies on, and the end-to-end
conformance gate — served answers must be bit-identical to direct
``GraphEngine.program()`` calls for EVERY query type the server accepts
(every registered program: source queries and refresh queries alike).
"""

import time

import numpy as np
import pytest

import jax.numpy as jnp

from conftest import run_with_devices
from repro.core import GraphEngine, incremental, partition_graph, registry
from repro.graphs import urand_edges
from repro.launch.mesh import make_graph_mesh
from repro.serve import (
    BucketLadder,
    Coalescer,
    DoubleBufferedExecutor,
    GraphServer,
    Query,
    make_key,
    parse_mix,
    query,
    synthetic_trace,
    validate_query,
    zipf_root_sampler,
)

ALL_PAIRS = sorted(registry.available())


@pytest.fixture(scope="module")
def served():
    n, e = 768, 6144
    edges = urand_edges(n, e, seed=13)
    g = partition_graph(edges, n, parts=1)
    eng = GraphEngine(g, make_graph_mesh(1))
    server = GraphServer(eng, buckets=(4,))
    return n, eng, eng.device_graph(), server


# -- coalescer -----------------------------------------------------------


def test_bucket_ladder_pick():
    ladder = BucketLadder((1, 8, 32, 128))
    assert [ladder.pick(k) for k in (1, 2, 8, 9, 32, 129, 500)] == \
        [1, 8, 8, 32, 32, 128, 128]
    with pytest.raises(ValueError):
        BucketLadder(())
    with pytest.raises(ValueError):
        BucketLadder((0, 8))


def test_coalescer_packs_and_pads():
    co = Coalescer(BucketLadder((1, 4)))
    for root in (5, 6, 7):
        co.admit(Query(make_key("bfs"), root))
    co.admit(Query(make_key("pagerank")))
    co.admit(Query(make_key("pagerank")))
    assert co.pending_count() == 5
    b1 = co.next_batch()                   # bfs queries are oldest
    assert b1.key.label == "bfs_fast" and b1.bucket == 4
    assert b1.n_real == 3 and b1.roots == [5, 6, 7, 7]   # dup-root padding
    b2 = co.next_batch()                   # both refreshes share one launch
    assert b2.key.label == "pagerank_fast" and b2.bucket == 0
    assert b2.n_real == 2 and b2.roots == []
    assert co.next_batch() is None and not co.has_pending()


def test_coalescer_overflow_chunks_at_top_bucket():
    co = Coalescer(BucketLadder((1, 4)))
    for root in range(11):
        co.admit(Query(make_key("sssp"), root))
    sizes = []
    while co.has_pending():
        b = co.next_batch()
        sizes.append((b.bucket, b.n_real))
    assert sizes == [(4, 4), (4, 4), (4, 3)]


def test_query_validation():
    with pytest.raises(ValueError, match="needs root"):
        query("bfs")
    with pytest.raises(ValueError, match="no per-query inputs"):
        query("pagerank", root=3)
    with pytest.raises(KeyError, match="registered programs"):
        query("nope", root=3)
    with pytest.raises(TypeError, match="unknown params"):
        query("bfs", root=3, bogus=1)


# -- executor ------------------------------------------------------------


def test_executor_depth_and_order():
    ex = DoubleBufferedExecutor(depth=2)
    assert ex.push("a", jnp.zeros(4)) == []
    assert ex.push("b", jnp.zeros(4)) == []          # 2 in flight: no block
    done = ex.push("c", jnp.zeros(4))                # full: retires oldest
    assert [l.payload for l in done] == ["a"]
    assert [l.payload for l in ex.drain()] == ["b", "c"]
    assert len(ex) == 0 and ex.complete_one() is None
    with pytest.raises(ValueError):
        DoubleBufferedExecutor(depth=0)


def test_executor_depth_one_is_synchronous():
    """depth=1 degenerates to a one-slot pipeline: every push retires
    the previous launch, drain retires exactly the last one, and no
    launch is ever dangling."""
    ex = DoubleBufferedExecutor(depth=1)
    assert ex.push("a", jnp.zeros(2)) == []          # first fills the slot
    assert [l.payload for l in ex.push("b", jnp.zeros(2))] == ["a"]
    assert [l.payload for l in ex.push("c", jnp.zeros(2))] == ["b"]
    assert len(ex) == 1
    assert [l.payload for l in ex.drain()] == ["c"]
    assert len(ex) == 0 and ex.drain() == []


def test_pump_on_empty_queue_is_a_noop(served):
    """pump() with nothing admitted must not launch, block, or record."""
    _, eng, _, _ = served
    server = GraphServer(eng, buckets=(4,))
    assert server.pump() == []
    assert not server.results and len(server.executor) == 0
    assert server.metrics.rows() == []


def test_drain_after_mixed_submit_pump_interleave(served):
    """Interleaved submit/pump/submit/drain resolves every qid in
    submission order with no in-flight launch left behind."""
    _, eng, _, _ = served
    server = GraphServer(eng, buckets=(4,), depth=2)
    q1 = server.submit("bfs", root=1)
    q2 = server.submit("cc")
    server.pump()                          # launches something
    q3 = server.submit("sssp", root=2)
    q4 = server.submit("bfs", root=5)
    server.drain()
    assert sorted(server.results) == sorted([q1, q2, q3, q4])
    assert len(server.executor) == 0, "dangling in-flight launch"
    assert not server.coalescer.has_pending()
    # demux preserves per-query identity across the interleave
    assert server.results[q1].key.label == "bfs_fast"
    assert server.results[q3].key.label == "sssp"
    for qid in (q1, q2, q3, q4):
        server.results.pop(qid)


def test_metrics_window_opens_at_admission(served):
    """The qps window must include the first query's queue wait:
    submit (admission) opens the window, so time spent queued before
    the first pump is inside window_s."""
    import time as _time
    _, eng, _, _ = served
    server = GraphServer(eng, buckets=(4,))
    server.submit("cc")
    _time.sleep(0.05)                      # queued, nothing launched yet
    server.drain()
    assert server.metrics.window_s >= 0.05, \
        "metrics window missed the pre-launch queue wait"
    server.results.clear()
    # standalone ServeMetrics still self-opens on a bare record()
    from repro.serve import ServeMetrics
    m = ServeMetrics()
    m.record("x", 0, 0.001)
    assert 0 < m.window_s < 10


# -- the no-retrace guarantee the ladder relies on -----------------------


def test_batch_defaults_pin_vmap_friendly_params(served):
    """Batched builds merge ProgramSpec.batch_defaults (bfs/fast pins
    direction='pull' so the per-lane push/pull cond doesn't run both
    branches under vmap); an explicit caller param resolves to the SAME
    cache entry, and overriding it back to adaptive is a distinct one."""
    _, eng, garr, _ = served
    auto = eng.program("bfs", "fast", batch=4)
    assert eng.program("bfs", "fast", batch=4, direction="pull") is auto
    adaptive = eng.program("bfs", "fast", batch=4, direction="adaptive")
    assert adaptive is not auto
    # both directions produce bit-identical parents
    roots = jnp.asarray([1, 5, 9, 700], jnp.int32)
    np.testing.assert_array_equal(np.asarray(auto(garr, roots)[0]),
                                  np.asarray(adaptive(garr, roots)[0]))
    # single-source builds keep the adaptive default (no batch merge)
    single = eng.program("bfs", "fast")
    assert single is eng.program("bfs", "fast", direction="adaptive")


def test_bucket_ladder_no_retrace(served):
    """After warmup, every ladder rung resolves to the SAME cached
    CompiledProgram on every launch and jit holds exactly one trace —
    the property that makes coalesced serving free of re-tracing."""
    _, eng, garr, _ = served
    for bucket in (1, 4, 8):
        prog = eng.program("bfs", "fast", batch=bucket)
        roots = jnp.arange(bucket, dtype=jnp.int32)
        prog(garr, roots)
        prog(garr, roots + 1)              # fresh operands, same trace
        assert eng.program("bfs", "fast", batch=bucket) is prog
        assert prog.trace_cache_size() == 1, \
            f"bucket {bucket} re-traced across launches"


# -- end-to-end conformance ----------------------------------------------


@pytest.mark.parametrize("algo,variant", ALL_PAIRS)
def test_served_matches_direct(served, algo, variant):
    """The acceptance gate: a served query's fields are bit-identical to
    a direct engine.program() call, for every registered query type.
    Source queries ride a padded batch=4 launch; refresh and seeded
    queries ride unbatched bucket-0 launches.  Seeded variants pass an
    EXPLICIT cold seed so served and direct use identical inputs no
    matter what the module-scoped server's seed store holds."""
    _, eng, garr, server = served
    spec = registry.get_spec(algo, variant)
    key = make_key(f"{algo}/{variant}")
    if key.seeded:
        (seed_arr,) = incremental.cold_seed(spec, eng.g)
        q = Query(key, seed=(seed_arr,))
        direct_extra = (eng.scatter_vertex_field(
            seed_arr, incremental.KIND_DTYPES[spec.input_kinds[0]]),)
    else:
        root = 7 if spec.inputs else None
        q = Query(key, root)
        direct_extra = (jnp.int32(root),) if spec.inputs else ()
    res = server.serve([q])[0]
    assert res.bucket == (4 if key.rooted else 0)
    assert res.rounds > 0

    *outs, rounds = eng.program(algo, variant)(garr, *direct_extra)
    assert res.rounds == int(rounds)
    prog = eng.program(algo, variant)
    for name, is_v, out in zip(prog.program.output_names,
                               prog.program.output_is_vertex, outs):
        want = (eng.gather_vertex_field(out) if is_v
                else np.asarray(out)[()])
        np.testing.assert_array_equal(
            res[name], want,
            err_msg=f"{algo}/{variant} field {name!r}: served != direct")


def test_refresh_queries_share_one_launch(served):
    """Concurrent refresh queries of one key are deduplicated into a
    single launch whose result every query shares."""
    _, _, _, server = served
    a, b = server.serve([query("cc"), query("cc")])
    assert a.bucket == b.bucket == 0
    assert a.fields is b.fields            # same launch, shared demux


def test_resubmitting_a_stamped_query_is_rejected(served):
    """submit stamps the Query object in place; submitting the same
    object twice would re-stamp it and orphan the first result."""
    _, _, _, server = served
    q = query("bfs", root=2)
    with pytest.raises(ValueError, match="already admitted"):
        server.serve([q, q])
    server.drain()                         # flush the first admission
    server.results.pop(q.qid, None)


def test_serve_collects_results_from_mailbox(served):
    """serve() pops what it returns: a long-running server must not
    accumulate every (n_orig,)-field result forever."""
    _, _, _, server = served
    res = server.serve([query("bfs", root=2), query("cc")])
    assert all(r.qid not in server.results for r in res)


def test_warmup_mid_traffic_demuxes_inflight(served):
    """Warming a new program while real launches are in flight must
    demux the launches it retires, not drop them."""
    _, eng, _, _ = served
    server = GraphServer(eng, buckets=(4,), depth=1)
    qid = server.submit("bfs", root=3)
    server.pump()                          # real launch now in flight
    server.warmup(["kcore"])               # retires it to free the slot
    assert qid in server.results, "in-flight result dropped by warmup"
    assert server.results.pop(qid).key.label == "bfs_fast"


def test_mixed_stream_all_answered(served):
    """A mixed closed-loop stream resolves every qid, in submission
    order, and per-(algo, bucket) metrics cover the traffic."""
    _, _, _, server = served
    qs = [query("bfs", root=1), query("sssp", root=2), query("cc"),
          query("bfs", root=3), query("bfs", root=9), query("sssp", root=4)]
    results = server.serve(qs)
    assert [r.qid for r in results] == [q.qid for q in qs]
    assert all(r.latency_s > 0 for r in results)
    cells = {(r["algo"], r["bucket"]) for r in server.metrics.rows()}
    assert ("bfs_fast", 4) in cells and ("cc", 0) in cells


def test_async_served_matches_direct_depth2(served):
    """Async-mode programs under the serving stack: rooted async
    queries coalesce onto the padded batch launch, async refreshes ride
    bucket 0, and with depth=2 two async launches are genuinely in
    flight together in the executor — every served field must still be
    bit-identical to the direct async engine call.  (The ALL_PAIRS
    parametrization above covers each async pair alone; this pins the
    interleaved, overlapped stream.)"""
    _, eng, garr, _ = served
    server = GraphServer(eng, buckets=(4,), depth=2)
    qs = [query("bfs/async", root=5), query("cc/async"),
          query("sssp/async", root=9), query("pagerank/async"),
          query("bfs/async", root=31)]
    results = server.serve(qs)
    assert [r.qid for r in results] == [q.qid for q in qs]
    assert [r.bucket for r in results] == [4, 0, 4, 0, 4]
    for q, r in zip(qs, results):
        prog = eng.program(r.key.algo, r.key.variant)
        assert prog.spec.exec_mode == "async"
        extra = (jnp.int32(q.root),) if q.root is not None else ()
        *outs, rounds = prog(garr, *extra)
        assert r.rounds == int(rounds)
        for name, isv, o in zip(prog.program.output_names,
                                prog.program.output_is_vertex, outs):
            want = (eng.gather_vertex_field(o) if isv
                    else np.asarray(o)[()])
            np.testing.assert_array_equal(
                r[name], want,
                err_msg=f"{r.key.label} field {name!r}: served != direct")


def test_async_epoch_snapshot_isolation():
    """An ASYNC launch in flight when mutate() runs answers for the
    pre-mutation epoch: the double-buffered exchange loop reads the
    graph buffers captured at dispatch for its whole lifetime, so the
    copy-on-write patch must never swap them out from under it (the
    BSP twin of this test lives in test_dynamic.py)."""
    import oracle
    from test_dynamic import _apply_host
    n, e = 512, 6100
    edges = urand_edges(n, e, seed=7)
    g = partition_graph(edges, n, parts=1)
    eng = GraphEngine(g, make_graph_mesh(1))
    server = GraphServer(eng, buckets=(4,))
    q_old = query("cc/async")
    server.submit_query(q_old)
    server.pump()                      # epoch-0 async launch in flight
    dyn = server.dynamic_graph()
    dels = dyn.sample_deletable(40, np.random.default_rng(1))
    server.mutate(deletes=dels)
    res_new = server.serve([query("cc/async")])[0]
    server.drain()
    res_old = server.results.pop(q_old.qid)

    assert res_old.epoch == 0 and res_new.epoch == 1
    np.testing.assert_array_equal(
        res_old["labels"], oracle.cc_labels(edges, n),
        err_msg="in-flight async launch must answer pre-mutation epoch")
    np.testing.assert_array_equal(
        res_new["labels"],
        oracle.cc_labels(_apply_host(edges, deletes=dels), n))


# -- workload generator --------------------------------------------------


def test_workload_generator():
    mix = parse_mix("bfs:8, sssp:4 ,cc:1")
    assert [(k.label, w) for k, w in mix] == \
        [("bfs_fast", 8.0), ("sssp", 4.0), ("cc", 1.0)]
    trace = synthetic_trace(1 << 10, "bfs:8,sssp:4,cc:1", rate=500,
                            duration=1.0, seed=3)
    assert trace and all(0 <= t < 1.0 for t, _ in trace)
    assert [t for t, _ in trace] == sorted(t for t, _ in trace)
    for _, q in trace:
        assert (q.root is not None) == q.key.rooted
        if q.root is not None:
            assert 0 <= q.root < (1 << 10)
    # same seed -> same trace; zipf skew -> repeated hot roots
    trace2 = synthetic_trace(1 << 10, "bfs:8,sssp:4,cc:1", rate=500,
                             duration=1.0, seed=3)
    assert [(t, q.key, q.root) for t, q in trace] == \
        [(t, q.key, q.root) for t, q in trace2]
    sample = zipf_root_sampler(1 << 16, s=1.1, seed=0)
    roots = sample(size=4096)
    top_share = np.bincount(roots).max() / 4096
    assert top_share > 0.01                # a hot vertex exists


@pytest.mark.slow
def test_served_parity_multi_partition():
    """Served-vs-direct parity holds at parts=2 too (the server demuxes
    (P, B, n_local) outputs across real partitions)."""
    out = run_with_devices("""
import numpy as np, jax.numpy as jnp
from repro.core import GraphEngine, partition_graph
from repro.graphs import urand_edges
from repro.launch.mesh import make_graph_mesh
from repro.serve import GraphServer, query

n, e = 1024, 8192
edges = urand_edges(n, e, seed=5)
g = partition_graph(edges, n, parts=2)
eng = GraphEngine(g, make_graph_mesh(2))
garr = eng.device_graph()
server = GraphServer(eng, buckets=(1, 4))
res = server.serve([query("bfs", root=3), query("bfs", root=700),
                    query("sssp", root=3), query("pagerank")])
p, _ = eng.program("bfs", "fast")(garr, jnp.int32(700))
np.testing.assert_array_equal(res[1]["parents"], eng.gather_vertex_field(p))
d, _ = eng.program("sssp")(garr, jnp.int32(3))
np.testing.assert_array_equal(res[2]["dist"], eng.gather_vertex_field(d))
r, _, _ = eng.program("pagerank")(garr)
np.testing.assert_array_equal(res[3]["rank"], eng.gather_vertex_field(r))
print("SERVE-PARITY OK")
""", devices=2)
    assert "SERVE-PARITY OK" in out


# -- resilience: validation, deadlines, shedding, retry/quarantine -------


def test_validate_query_rejects_bad_inputs(served):
    """Admission-time validation: out-of-range roots, non-finite float
    params, malformed seed vectors and non-positive deadlines are all
    rejected before they can reach a compiled program."""
    n, eng, _, _ = served
    validate_query(query("bfs", root=5), n)              # clean passes
    with pytest.raises(ValueError, match="root"):
        validate_query(query("bfs", root=n), n)
    with pytest.raises(ValueError, match="root"):
        validate_query(query("bfs", root=-1), n)
    with pytest.raises(ValueError, match="finite"):
        validate_query(
            query("sssp", root=1, weight_scale=float("inf")), n)
    bad_rank = np.full(n, 1.0 / n, np.float32)
    bad_rank[7] = np.nan
    with pytest.raises(ValueError, match="finite"):
        validate_query(query("pagerank", "warm", seed=(bad_rank,)), n)
    bad_labels = np.arange(n, dtype=np.int32)
    bad_labels[3] = n                                    # out of range
    with pytest.raises(ValueError, match="outside"):
        validate_query(query("cc", "incremental", seed=(bad_labels,)), n)
    with pytest.raises(ValueError, match="shape"):
        validate_query(
            query("cc", "incremental",
                  seed=(np.zeros(n - 1, np.int32),)), n)
    with pytest.raises(ValueError, match="deadline"):
        validate_query(query("bfs", root=1, deadline_s=0.0), n)


def test_server_rejects_invalid_at_admission(served):
    """submit() raises on an invalid query, counts it, and leaves the
    admission queue untouched (no poison enters the pipeline)."""
    n, eng, _, _ = served
    server = GraphServer(eng, buckets=(4,))
    with pytest.raises(ValueError, match="root"):
        server.submit("bfs", root=n + 7)
    assert server.metrics.counts["rejected"] == 1
    assert not server.coalescer.has_pending()
    assert server.pump() == []


def test_deadline_expired_in_queue_times_out(served):
    """A query whose deadline lapses while queued gets a typed
    ``timed_out`` result and is dropped from the batch pre-launch; its
    live batchmates are still answered, bit-identical to direct."""
    _, eng, garr, _ = served
    server = GraphServer(eng, buckets=(4,))
    qid_live = server.submit("bfs", root=5)
    qid_dead = server.submit("bfs", root=6, deadline_s=1e-6)
    time.sleep(0.01)                       # lapse the tiny deadline
    res = {r.qid: r for r in server.drain()}
    dead = res[qid_dead]
    assert dead.status == "timed_out" and not dead.ok
    assert dead.fields == {} and dead.rounds == -1
    with pytest.raises(KeyError, match="timed_out"):
        dead["parents"]
    live = res[qid_live]
    assert live.ok and live.status == "ok"
    p, _ = eng.program("bfs", "fast")(garr, jnp.int32(5))
    np.testing.assert_array_equal(live["parents"],
                                  eng.gather_vertex_field(p))
    assert server.metrics.counts["timed_out"] == 1


def test_default_deadline_is_inherited(served):
    """``default_deadline_s`` applies to queries submitted without an
    explicit deadline."""
    _, eng, _, _ = served
    server = GraphServer(eng, buckets=(4,), default_deadline_s=1e-6)
    qid = server.submit("cc")
    time.sleep(0.01)
    res = server.drain()
    assert [r.status for r in res] == ["timed_out"]
    assert server.results[qid].status == "timed_out"


def test_load_shedding_evicts_oldest_deadline_first(served):
    """With ``max_queued=2`` the coalescer sheds on overflow, evicting
    the pending query with the soonest deadline; shed queries resolve
    as ``shed`` and the survivors are still answered."""
    _, eng, garr, _ = served
    server = GraphServer(eng, buckets=(4,), max_queued=2)
    q1 = server.submit("bfs", root=1, deadline_s=0.5)
    q2 = server.submit("bfs", root=2, deadline_s=30.0)
    q3 = server.submit("bfs", root=3)              # sheds q1 (soonest)
    q4 = server.submit("bfs", root=4, deadline_s=5.0)   # sheds q4 itself
    assert server.results[q1].status == "shed"
    assert server.results[q4].status == "shed"
    res = {r.qid: r for r in server.drain()}
    assert sorted(res) == sorted([q1, q2, q3, q4])  # shed results surface
    assert res[q1].status == "shed" and res[q4].status == "shed"
    assert res[q2].ok and res[q3].ok
    p, _ = eng.program("bfs", "fast")(garr, jnp.int32(2))
    np.testing.assert_array_equal(res[q2]["parents"],
                                  eng.gather_vertex_field(p))
    assert server.metrics.counts["shed"] == 2


def test_transient_launch_failure_is_retried(served, monkeypatch):
    """A dispatch that fails once then succeeds yields an ok answer
    after one backoff retry — the failure is invisible to the caller
    beyond the retry counter."""
    _, eng, garr, _ = served
    server = GraphServer(eng, buckets=(4,), retry_backoff_s=0.0)
    orig = server._dispatch
    calls = {"n": 0}

    def flaky(batch):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient launch failure")
        return orig(batch)

    monkeypatch.setattr(server, "_dispatch", flaky)
    res = server.serve([query("bfs", root=7)])
    assert [r.status for r in res] == ["ok"]
    assert server.metrics.counts["retries"] == 1
    p, _ = eng.program("bfs", "fast")(garr, jnp.int32(7))
    np.testing.assert_array_equal(res[0]["parents"],
                                  eng.gather_vertex_field(p))


def test_poison_query_is_bisected_and_quarantined(served, monkeypatch):
    """A poison query that makes every containing launch raise is
    isolated by bisection: its batchmates are answered bit-identical,
    the poison member exhausts its retries, lands in
    ``server.quarantined`` with the causal error, and the server stays
    fully usable afterwards."""
    _, eng, garr, _ = served
    server = GraphServer(eng, buckets=(4,), max_retries=1,
                         retry_backoff_s=0.0)
    orig = server._dispatch

    def poisoned(batch):
        if any(q.root == 13 for q in batch.queries):
            raise RuntimeError("poison root")
        return orig(batch)

    monkeypatch.setattr(server, "_dispatch", poisoned)
    res = server.serve([query("bfs", root=5), query("bfs", root=13),
                        query("bfs", root=9)])
    assert [r.status for r in res] == ["ok", "failed", "ok"]
    bad = res[1]
    assert isinstance(bad.error, RuntimeError) and not bad.ok
    assert [r.qid for r in server.quarantined] == [bad.qid]
    assert server.metrics.counts["quarantined"] == 1
    assert server.metrics.counts["retries"] == 1    # singleton retried once
    prog = eng.program("bfs", "fast")
    for r, root in ((res[0], 5), (res[2], 9)):
        p, _ = prog(garr, jnp.int32(root))
        np.testing.assert_array_equal(r["parents"],
                                      eng.gather_vertex_field(p))
    after = server.serve([query("bfs", root=2)])    # still healthy
    assert after[0].ok


def test_executor_failed_block_is_contained(monkeypatch):
    """Satellite 3 (unit): a launch whose block raises is returned with
    ``error`` set; its in-flight peer is untouched, drain returns every
    remaining launch, and the executor stays usable."""
    import repro.serve.executor as executor_mod
    ex = DoubleBufferedExecutor(depth=2)
    orig = executor_mod.jax.block_until_ready

    def boom(out):
        if isinstance(out, str):
            raise RuntimeError("device error")
        return orig(out)

    monkeypatch.setattr(executor_mod.jax, "block_until_ready", boom)
    ex.push("a", "BOOM")
    ex.push("b", jnp.zeros(2))
    done = ex.drain()                               # never raises
    assert [l.payload for l in done] == ["a", "b"]
    assert isinstance(done[0].error, RuntimeError)
    assert done[1].error is None
    assert len(ex) == 0
    assert [l.payload for l in ex.drain()] == []    # not wedged
    ex.push("c", jnp.zeros(2))
    done = ex.drain()
    assert [l.payload for l in done] == ["c"] and done[0].error is None


def test_async_launch_failure_does_not_orphan_peers(served, monkeypatch):
    """Satellite 3 (server): a failure surfacing at block time (async
    dispatch) with depth=2 in flight routes through the retry path
    without orphaning the concurrent launch — both queries end ok."""
    import repro.serve.executor as executor_mod
    _, eng, garr, _ = served
    server = GraphServer(eng, buckets=(4,), depth=2, retry_backoff_s=0.0)
    poison_ids = set()
    armed = {"on": True}
    orig_dispatch = server._dispatch

    def marked(batch):
        out = orig_dispatch(batch)
        if armed["on"] and any(q.root == 13 for q in batch.queries):
            armed["on"] = False                     # fail only the first
            poison_ids.add(id(out))
        return out

    orig_block = executor_mod.jax.block_until_ready

    def boom(out):
        if id(out) in poison_ids:
            poison_ids.discard(id(out))
            raise RuntimeError("async failure surfaced at block")
        return orig_block(out)

    monkeypatch.setattr(server, "_dispatch", marked)
    monkeypatch.setattr(executor_mod.jax, "block_until_ready", boom)
    res = server.serve([query("bfs", root=13), query("sssp", root=7)])
    assert [r.status for r in res] == ["ok", "ok"]
    assert server.metrics.counts["retries"] == 1
    assert len(server.executor) == 0
    p, _ = eng.program("bfs", "fast")(garr, jnp.int32(13))
    np.testing.assert_array_equal(res[0]["parents"],
                                  eng.gather_vertex_field(p))
    d, _ = eng.program("sssp")(garr, jnp.int32(7))
    np.testing.assert_array_equal(res[1]["dist"],
                                  eng.gather_vertex_field(d))


def test_overload_sheds_but_never_corrupts(served, monkeypatch):
    """Overload acceptance: a trace far beyond capacity through a
    bounded queue sheds/times out part of the load, but every answer
    that does come back ok is bit-identical to a direct program()
    call, and recorded latency (ok answers only) respects the
    deadline."""
    n, eng, garr, _ = served
    server = GraphServer(eng, buckets=(1, 4), max_queued=8,
                         default_deadline_s=2.0)
    server.serve([query("bfs", root=0)])            # warm the compile
    orig = server._dispatch

    def slow(batch):                # pin capacity below the trace rate
        time.sleep(0.005)
        return orig(batch)

    monkeypatch.setattr(server, "_dispatch", slow)
    trace = synthetic_trace(n, "bfs", rate=2000, duration=0.2, seed=4)
    res = server.serve_trace(trace)
    assert len(res) == len(trace)
    statuses = {r.status for r in res}
    assert "ok" in statuses
    shed = server.metrics.counts["shed"]
    timed_out = server.metrics.counts["timed_out"]
    assert shed + timed_out > 0                     # overload was real
    prog = eng.program("bfs", "fast")
    by_qid = {q.qid: q for _, q in trace}
    checked = 0
    for r in res:
        if not r.ok or checked >= 8:
            continue
        p, _ = prog(garr, jnp.int32(by_qid[r.qid].root))
        np.testing.assert_array_equal(r["parents"],
                                      eng.gather_vertex_field(p))
        checked += 1
    assert checked > 0
    for row in server.metrics.rows():
        assert row["p99_ms"] <= 2.0 * 1e3
