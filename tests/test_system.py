"""End-to-end behaviour: training with checkpoint/restart, serving
round-trip, distributed train-step parity, graph analytics driver."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import run_with_devices
from repro.configs.base import TrainConfig
from repro.configs.registry import smoke_config
from repro.launch.serve import serve
from repro.launch.train import train


def test_train_checkpoint_resume_bitexact(tmp_path):
    """Interrupt at step 12, resume from checkpoint at 10 -> same state as
    an uninterrupted run (deterministic data + optimizer)."""
    cfg = smoke_config("tinyllama-1.1b")
    tc = TrainConfig(learning_rate=1e-3, total_steps=20, warmup_steps=2,
                     checkpoint_dir=str(tmp_path / "a"),
                     checkpoint_every=10)
    p_full, _, _ = train(cfg, tc, batch=2, seq=32, steps=20, resume=False,
                         log_every=100)

    tc2 = TrainConfig(learning_rate=1e-3, total_steps=20, warmup_steps=2,
                      checkpoint_dir=str(tmp_path / "b"),
                      checkpoint_every=10)
    train(cfg, tc2, batch=2, seq=32, steps=12, resume=False, log_every=100)
    # "crash" after step 12; resume trains 10 -> 20 from the checkpoint
    p_res, _, _ = train(cfg, tc2, batch=2, seq=32, steps=20, resume=True,
                        log_every=100)
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)


def test_serve_generates_tokens():
    cfg = smoke_config("tinyllama-1.1b")
    toks, stats = serve(cfg, batch=2, prompt_len=16, gen=8)
    assert toks.shape == (2, 8)
    assert int(toks.max()) < cfg.vocab_size
    assert stats["tok_per_s"] > 0


def test_serve_ssm_arch():
    cfg = smoke_config("mamba2-1.3b")
    toks, _ = serve(cfg, batch=2, prompt_len=16, gen=8)
    assert toks.shape == (2, 8)


@pytest.mark.slow
def test_distributed_train_parity_with_single_device():
    """Same tiny model, same data: (2 data x 2 model) mesh step == single
    device step (up to bf16 noise). Proves the sharding rules preserve
    semantics."""
    out = run_with_devices("""
import dataclasses, numpy as np, jax, jax.numpy as jnp
from repro.configs.registry import smoke_config
from repro.configs.base import TrainConfig
from repro.launch.mesh import make_local_mesh, batch_axes
from repro.launch.steps import make_train_step
from repro.distributed import actctx
from repro.models import param_spec, init_params, param_shardings
from repro.models.params import abstract_params
from repro.optim import init_opt_state

cfg = smoke_config('tinyllama-1.1b')
tc = TrainConfig(total_steps=10, warmup_steps=2)
spec = param_spec(cfg)
params = init_params(spec, jax.random.key(0))
opt = init_opt_state(params)
batch = {'tokens': jax.random.randint(jax.random.key(1), (4, 64), 0,
                                      cfg.vocab_size)}
step = make_train_step(cfg, tc)

# single device
p1, o1, m1 = jax.jit(step)(params, opt, batch)

# 2x2 mesh
mesh = make_local_mesh(2, 2)
sh = param_shardings(spec, mesh)
params_d = jax.tree.map(jax.device_put, params, sh)
opt_d = init_opt_state(params_d)
ba = batch_axes(mesh, 4)
with actctx.policy(actctx.make_train_policy(mesh, batch_axes=ba)):
    step_d = jax.jit(step, in_shardings=(sh,
        type(opt_d)(m=sh, v=sh, step=jax.sharding.NamedSharding(mesh,
            jax.sharding.PartitionSpec())), None))
    p2, o2, m2 = step_d(params_d, opt_d, batch)

assert abs(float(m1['loss']) - float(m2['loss'])) < 1e-3
for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=3e-3, atol=3e-4)
print('DIST PARITY OK')
""", devices=4)
    assert "DIST PARITY OK" in out


@pytest.mark.slow
def test_elastic_restore_across_meshes():
    """Checkpoint written on a (4,1) mesh restores onto (2,2)."""
    out = run_with_devices("""
import numpy as np, jax, jax.numpy as jnp, tempfile
from repro.configs.registry import smoke_config
from repro import checkpoint as ckpt
from repro.launch.mesh import make_local_mesh
from repro.models import param_spec, init_params, param_shardings

cfg = smoke_config('tinyllama-1.1b')
spec = param_spec(cfg)
params = init_params(spec, jax.random.key(0))
mesh_a = make_local_mesh(4, 1)
sh_a = param_shardings(spec, mesh_a)
params_a = jax.tree.map(jax.device_put, params, sh_a)
d = tempfile.mkdtemp()
ckpt.save(d, 1, params_a)

mesh_b = make_local_mesh(2, 2)
sh_b = param_shardings(spec, mesh_b)
restored = ckpt.restore(d, 1, params, sh_b)
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print('ELASTIC OK')
""", devices=4)
    assert "ELASTIC OK" in out


def test_graph_analytics_driver_runs():
    from repro.launch.graph_analytics import run
    results = run("urand16", parts=1, pr_iters=20)
    assert set(results) >= {"bfs_bsp", "bfs_fast", "pagerank_bsp",
                            "pagerank_fast", "sssp", "cc", "kcore",
                            "betweenness"}
    # triangles' O(n^2/P) bitmap exceeds its n_budget on urand16: skipped
    assert "triangles" not in results


def test_graph_analytics_driver_within_triangle_budget():
    """On a graph inside every n_budget the driver runs the FULL suite."""
    from repro.launch.graph_analytics import run
    results = run("urand12", parts=1, pr_iters=10)
    assert "triangles" in results
