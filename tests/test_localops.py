"""Local-ops dispatch layer: parity of every primitive across its three
implementations (COO-scatter ref, blocked-ELL gather, Pallas kernel in
interpret mode), plus layout/property guards:

  * the blocked-ELL structures round-trip the EXACT edge multiset of the
    COO shards (both conformance graph families, property-tested over
    random graphs when hypothesis is installed);
  * whole programs produce identical results under ``layout="ell"`` and
    ``layout="coo"`` (the escape-hatch path compiles the same math);
  * REPRO_LOCALOPS mode resolution and the set_mode override.

The primitives are pure per-partition compute (no collectives), so they
are exercised here directly on per-partition graph dicts - the
multi-partition exchange behaviour is covered by the oracle-conformance
gate, which runs the ELL path by default.
"""

import numpy as np
import pytest

import jax.numpy as jnp

import oracle
from repro.core import GraphEngine, localops, partition_graph
from repro.core.graph import ELL_BLOCK, ELL_LANE, ell_entries
from repro.launch.mesh import make_graph_mesh

INT_INF = 2 ** 30
MODES = ("ref", "auto", "kernel")


def _shard_dicts(g):
    """Per-partition graph dicts (what step() sees inside shard_map)."""
    arrs = g.device_arrays()
    return [{k: v[p] for k, v in arrs.items()} for p in range(g.parts)]


@pytest.fixture(scope="module", params=["urand", "smallworld"])
def graph(request):
    edges, n = oracle.family_edges(request.param, 384, 5)
    return request.param, edges, n, partition_graph(edges, n, parts=2)


# ---------------------------------------------------------------------------
# primitive parity: ref == ell == pallas-interpret (per partition)
# ---------------------------------------------------------------------------

def test_spmv_pull_parity(graph, rng):
    _, edges, n, g = graph
    x = rng.normal(size=g.n).astype(np.float32)
    want = np.zeros(g.n)
    np.add.at(want, edges[:, 1], x[edges[:, 0]].astype(np.float64))
    for p, garr in enumerate(_shard_dicts(g)):
        lo = p * g.n_local
        for mode in MODES:
            got = np.asarray(localops.spmv_pull(
                garr, g.ell_meta["ell_in"], jnp.asarray(x), mode=mode))
            np.testing.assert_allclose(got, want[lo:lo + g.n_local],
                                       rtol=1e-4, atol=1e-4,
                                       err_msg=f"p={p} mode={mode}")


def test_frontier_pull_parity(graph, rng):
    _, edges, n, g = graph
    bits = rng.integers(0, 2 ** 32, g.n // 32, dtype=np.uint32)
    unv = rng.integers(0, 2, g.n).astype(bool)

    def in_frontier(v):
        return (bits[v >> 5] >> (v & 31)) & 1

    want = np.full(g.n, INT_INF, np.int64)
    for s, d in edges:
        if in_frontier(s) and unv[d]:
            want[d] = min(want[d], s)
    for p, garr in enumerate(_shard_dicts(g)):
        lo = p * g.n_local
        unv_p = jnp.asarray(unv[lo:lo + g.n_local])
        for mode in MODES:
            got = np.asarray(localops.frontier_pull(
                garr, g.ell_meta["ell_in"], jnp.asarray(bits), unv_p,
                mode=mode))
            np.testing.assert_array_equal(got, want[lo:lo + g.n_local],
                                          err_msg=f"p={p} mode={mode}")


@pytest.mark.parametrize("which,op", [
    ("ell_dst", "add"), ("ell_dst", "min"), ("ell_dst", "max"),
    ("ell_dst", "or"), ("ell_src", "min"), ("ell_src", "add"),
])
def test_scatter_combine_parity(graph, rng, which, op):
    _, edges, n, g = graph
    key_name = {"ell_dst": "out_dst_global", "ell_src": "in_src_global"}
    combine = {"add": np.add, "min": np.minimum, "max": np.maximum,
               "or": np.maximum}
    for p, garr in enumerate(_shard_dicts(g)):
        key = np.asarray(garr[key_name[which]])
        valid = key < g.n
        if op == "add":
            identity, vals = 0.0, np.where(
                valid, rng.normal(size=g.e_max), 0.0).astype(np.float32)
        elif op == "or":
            identity = False
            vals = valid & (rng.integers(0, 2, g.e_max) > 0)
        else:
            identity = INT_INF if op == "min" else 0
            vals = np.where(valid, rng.integers(0, 10 ** 6, g.e_max),
                            identity).astype(np.int32)
        want = np.full(g.n, identity,
                       np.float64 if op == "add" else np.int64)
        combine[op].at(want, key[valid], vals[valid])
        if op == "or":
            want = want > 0
        for mode in MODES:
            got = np.asarray(localops.scatter_combine(
                garr, g.ell_meta[which], jnp.asarray(vals), op,
                identity=identity, mode=mode))
            if op == "add":
                np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4,
                                           err_msg=f"p={p} mode={mode}")
            else:
                np.testing.assert_array_equal(got, want,
                                              err_msg=f"p={p} mode={mode}")


def test_scatter_combine_out_rows(graph, rng):
    """The per-local-source structure (ell_out) combines into n_local."""
    _, edges, n, g = graph
    for p, garr in enumerate(_shard_dicts(g)):
        dst = np.asarray(garr["out_dst_global"])
        srcl = np.asarray(garr["out_src_local"])
        valid = dst < g.n
        vals = np.where(valid, rng.normal(size=g.e_max), 0.0) \
            .astype(np.float32)
        want = np.zeros(g.n_local)
        np.add.at(want, srcl[valid], vals[valid].astype(np.float64))
        for mode in MODES:
            got = np.asarray(localops.scatter_combine(
                garr, g.ell_meta["ell_out"], jnp.asarray(vals), "add",
                identity=0.0, mode=mode))
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4,
                                       err_msg=f"p={p} mode={mode}")


# ---------------------------------------------------------------------------
# blocked-ELL layout properties
# ---------------------------------------------------------------------------

def _check_ell_roundtrip(edges, n, parts):
    """Every ELL structure must hold EXACTLY the COO edge multiset."""
    g = partition_graph(edges, n, parts)
    in_valid = g.in_src_global < g.n
    out_valid = g.out_dst_global < g.n
    for name in ("ell_in", "ell_out", "ell_dst", "ell_src"):
        meta = g.ell_meta[name]
        # structural invariants of the bucketed layout
        assert sum(r for r, _ in meta.buckets) == meta.n_rows
        assert all(r % ELL_BLOCK == 0 for r, _ in meta.buckets)
        assert all(k % ELL_LANE == 0 for _, k in meta.buckets)
        widths = [k for _, k in meta.buckets]
        assert widths == sorted(widths, reverse=True), \
            f"{name}: degree buckets must be width-sorted"
        assert meta.slots == sum(r * k for r, k in meta.buckets)
        for p in range(parts):
            pairs = ell_entries(meta, g.ell_arrays[f"{name}_idx"][p],
                                g.ell_arrays[f"{name}_inv"][p])
            if name == "ell_in":    # (local dst row, global src id)
                ref = list(zip(g.in_dst_local[p][in_valid[p]].tolist(),
                               g.in_src_global[p][in_valid[p]].tolist()))
            elif name == "ell_out":  # (local src row, out-edge position)
                pos = np.flatnonzero(out_valid[p])
                ref = list(zip(g.out_src_local[p][pos].tolist(),
                               pos.tolist()))
            elif name == "ell_dst":  # (global dst row, out-edge position)
                pos = np.flatnonzero(out_valid[p])
                ref = list(zip(g.out_dst_global[p][pos].tolist(),
                               pos.tolist()))
            else:                    # (global src row, in-edge position)
                pos = np.flatnonzero(in_valid[p])
                ref = list(zip(g.in_src_global[p][pos].tolist(),
                               pos.tolist()))
            assert sorted(pairs) == sorted(ref), \
                f"{name} p={p}: edge multiset mismatch"


@pytest.mark.parametrize("family", ["urand", "smallworld"])
@pytest.mark.parametrize("parts", [1, 2, 4])
def test_ell_roundtrips_edge_multiset(family, parts):
    edges, n = oracle.family_edges(family, 384, 5)
    _check_ell_roundtrip(edges, n, parts)


try:
    from hypothesis import given, settings, strategies as st

    @given(st.integers(2, 8), st.integers(1, 6), st.integers(0, 2 ** 20),
           st.sampled_from([1, 2, 4]))
    @settings(max_examples=15, deadline=None)
    def test_ell_roundtrip_property(nv, deg, seed, parts):
        """Random urand graphs: the blocked-ELL layout is a lossless
        re-grouping of the COO shards for ANY degree distribution."""
        from repro.graphs import urand_edges
        n = 32 * nv
        edges = urand_edges(n, n * deg, seed=seed)
        _check_ell_roundtrip(edges, n, parts)
except ImportError:  # pragma: no cover - hypothesis is optional
    pass


# ---------------------------------------------------------------------------
# whole-program layout parity + mode resolution
# ---------------------------------------------------------------------------

def test_programs_match_across_layouts(graph):
    """layout="ell" and layout="coo" compile the same math."""
    _, edges, n, g = graph
    g1 = partition_graph(edges, n, parts=1)
    mesh = make_graph_mesh(1)
    eng_ell = GraphEngine(g1, mesh, layout="ell")
    eng_coo = GraphEngine(g1, mesh, layout="coo")
    for algo, variant, exact in (("bfs", "fast", True), ("cc", None, True),
                                 ("kcore", None, True),
                                 ("pagerank", "fast", False)):
        params = oracle.CONFORMANCE_PARAMS.get(
            (algo, variant or "default"), {})
        a = eng_ell.program(algo, variant, **params)(
            eng_ell.device_graph(),
            *([jnp.int32(3)] if algo == "bfs" else []))
        b = eng_coo.program(algo, variant, **params)(
            eng_coo.device_graph(),
            *([jnp.int32(3)] if algo == "bfs" else []))
        va = eng_ell.gather_vertex_field(a[0])
        vb = eng_coo.gather_vertex_field(b[0])
        if exact:
            np.testing.assert_array_equal(va, vb, err_msg=f"{algo}")
        else:
            np.testing.assert_allclose(va, vb, rtol=1e-5, atol=1e-9,
                                       err_msg=f"{algo}")


def test_programs_run_without_ell_build(graph):
    """partition_graph(build_ell_layout=False) must still serve every
    program: shards.ell() hands factories zero-slot placeholder metas
    and localops falls back to the COO scatter reference path."""
    _, edges, n, _ = graph
    g_no = partition_graph(edges, n, parts=1, build_ell_layout=False)
    assert not g_no.ell_meta and not g_no.ell_arrays
    g_full = partition_graph(edges, n, parts=1)
    mesh = make_graph_mesh(1)
    eng_no = GraphEngine(g_no, mesh)
    eng_full = GraphEngine(g_full, mesh)
    a, _ = eng_no.program("bfs", "fast")(eng_no.device_graph(),
                                         jnp.int32(3))
    b, _ = eng_full.program("bfs", "fast")(eng_full.device_graph(),
                                           jnp.int32(3))
    np.testing.assert_array_equal(eng_no.gather_vertex_field(a),
                                  eng_full.gather_vertex_field(b))


def test_mode_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_LOCALOPS", raising=False)
    localops.set_mode(None)
    assert localops.get_mode() == "auto"
    monkeypatch.setenv("REPRO_LOCALOPS", "ref")
    assert localops.get_mode() == "ref"
    localops.set_mode("kernel")         # override beats the env var
    assert localops.get_mode() == "kernel"
    localops.set_mode(None)
    assert localops.get_mode() == "ref"
    monkeypatch.setenv("REPRO_LOCALOPS", "bogus")
    with pytest.raises(ValueError):
        localops.get_mode()
    with pytest.raises(ValueError):
        localops.set_mode("bogus")
    monkeypatch.delenv("REPRO_LOCALOPS")
    assert localops.resolve(mode="ref") == "ref"
    assert localops.resolve(mode="kernel") == "pallas"
    assert localops.resolve(mode="auto", backend="tpu") == "pallas"
    assert localops.resolve(mode="auto", backend="cpu") == "ell"
