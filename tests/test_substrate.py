"""Substrate: optimizer, data pipeline, checkpoint, fault tolerance,
compression."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.configs.base import TrainConfig
from repro.data import TokenStream, batch_at
from repro.distributed.compression import (
    compress_tree,
    decompress_tree,
    init_ef_state,
)
from repro.distributed.fault_tolerance import StepWatchdog, plan_remesh
from repro.optim import (
    adamw_update,
    clip_by_global_norm,
    init_opt_state,
    lr_schedule,
)


# ---------------- optimizer ----------------
def test_adamw_reduces_quadratic():
    tc = TrainConfig(learning_rate=0.1, weight_decay=0.0, warmup_steps=1,
                     total_steps=200, grad_clip=1e9)
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    opt = init_opt_state(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(params, grads, opt, tc)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_weight_decay_only_on_matrices():
    tc = TrainConfig(learning_rate=0.1, weight_decay=0.5, warmup_steps=1,
                     total_steps=10)
    params = {"m": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    grads = jax.tree.map(jnp.zeros_like, params)
    opt = init_opt_state(params)
    p2, _, _ = adamw_update(params, grads, opt, tc)
    assert float(p2["m"][0, 0]) < 1.0       # decayed
    assert float(p2["b"][0]) == 1.0         # not decayed


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.sqrt((clipped["a"] ** 2).sum())) - 1.0) < 1e-5
    assert float(norm) > 100


def test_lr_schedule_warmup_and_decay():
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=100, total_steps=1000)
    lr0 = float(lr_schedule(jnp.int32(0), tc))
    lr_mid = float(lr_schedule(jnp.int32(100), tc))
    lr_end = float(lr_schedule(jnp.int32(999), tc))
    assert lr0 < lr_mid
    assert abs(lr_mid - 1e-3) < 2e-5
    assert lr_end < 0.2 * lr_mid


# ---------------- data ----------------
def test_data_deterministic_and_restartable():
    s1 = TokenStream(global_batch=4, seq_len=32, vocab_size=1000)
    batches = [s1.next()["tokens"] for _ in range(5)]
    s2 = TokenStream(global_batch=4, seq_len=32, vocab_size=1000)
    s2.restore(3)
    np.testing.assert_array_equal(np.asarray(s2.next()["tokens"]),
                                  np.asarray(batches[3]))
    b = batch_at(7, global_batch=4, seq_len=32, vocab_size=1000)
    b2 = batch_at(7, global_batch=4, seq_len=32, vocab_size=1000)
    np.testing.assert_array_equal(np.asarray(b), np.asarray(b2))
    assert int(b.max()) < 1000 and int(b.min()) >= 0


def test_data_nonuniform():
    b = np.asarray(batch_at(0, global_batch=8, seq_len=256,
                            vocab_size=100))
    counts = np.bincount(b.reshape(-1), minlength=100)
    assert counts.max() > 3 * counts.mean()  # zipf shaping


# ---------------- checkpoint ----------------
def test_checkpoint_roundtrip_and_retention(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 3))}}
    for step in (10, 20, 30, 40):
        ckpt.save(tmp_path, step, tree, keep=2)
    assert ckpt.latest_step(tmp_path) == 40
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [30, 40]
    restored = ckpt.restore(tmp_path, 40, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_checkpoint_atomicity(tmp_path):
    tree = {"a": jnp.zeros(4)}
    ckpt.save(tmp_path, 1, tree)
    # a stale tmp dir from a crashed writer must not break LATEST
    (tmp_path / ".tmp_step_2").mkdir()
    assert ckpt.latest_step(tmp_path) == 1


# ---------------- fault tolerance ----------------
def test_plan_remesh_preserves_model_axis():
    plan = plan_remesh(512, 256, model_parallel=16)
    assert plan.mesh_shape[-1] == 16
    assert plan.devices_used <= 256
    assert plan.devices_used % 16 == 0
    plan2 = plan_remesh(512, 0, model_parallel=16)
    assert plan2.devices_used == 512
    assert plan2.mesh_shape == (2, 16, 16)


def test_watchdog_flags_stragglers():
    import time
    wd = StepWatchdog(factor=3.0, window=16)
    for i in range(10):
        wd.start()
        time.sleep(0.002)
        assert not wd.stop(i)
    wd.start()
    time.sleep(0.05)
    assert wd.stop(99)
    assert wd.flagged and wd.flagged[0][0] == 99


# ---------------- compression ----------------
def test_compress_decompress_tree():
    grads = {"w": jax.random.normal(jax.random.key(0), (64,)),
             "b": jax.random.normal(jax.random.key(1), (8,)) * 10}
    ef = init_ef_state(grads)
    qs, scales, resid = compress_tree(grads, ef)
    deq = decompress_tree(qs, scales)
    for k in grads:
        err = float(jnp.abs(deq[k] - grads[k]).max())
        step = float(scales[k])
        assert err <= step * 0.5 + 1e-6


def test_error_feedback_unbiased_over_time():
    """With EF, the accumulated transmitted signal tracks the true sum."""
    rng = jax.random.split(jax.random.key(0), 50)
    true_sum = jnp.zeros(32)
    sent_sum = jnp.zeros(32)
    ef = jnp.zeros(32)
    from repro.distributed.compression import quantize_int8
    for k in rng:
        g = jax.random.normal(k, (32,))
        true_sum = true_sum + g
        q, s, ef = quantize_int8(g, ef)
        sent_sum = sent_sum + q.astype(jnp.float32) * s
    # residual never accumulates beyond one quantization step
    gap = float(jnp.abs(true_sum - sent_sum).max())
    assert gap < 0.1, gap
