"""THE correctness gate: every registered program x parts in {1, 2, 4}
x three graph families must match its pure-NumPy oracle (tests/oracle.py).

This replaces ad-hoc per-algorithm equality checks: a new program only
passes the suite once it has an oracle entry, and it is exercised under
real multi-partition exchange (2 and 4 parts run in a subprocess with
forced host devices), not just the degenerate single-shard case.

One subprocess per family runs the full program x parts sweep (the
per-case PASS lines are asserted host-side so a failure names its
cell).  Seeded variants (``pagerank/warm``, ``cc/incremental``,
``kcore/incremental``) run from their COLD seeds here — the static
gate pins that the seeded program is exact from ANY admissible start;
the warm-seed path on mutated graphs is gated by test_dynamic.py.

The ASYNC lane rides the same sweep: ``registry.available()``
enumerates the ``*/async`` pairs, so every async variant runs at parts
{1, 2, 4} on all three families against the SAME oracles as its BSP
counterpart — exactly, for the monotone min-combine trio (bfs/cc/sssp:
staleness never changes a min-combine fixed point), and within the
documented staleness tolerance for ``pagerank/async`` (whose variant
check also asserts the realized ``max_age`` against the 2s+1 bound).
``test_every_async_variant_has_an_oracle`` makes a missing entry a
HARD registration-time failure, not a silently skipped cell.
"""

import os

import pytest

from conftest import run_with_devices

import oracle  # noqa: F401  (fail fast if the oracle module breaks)
from repro.core import registry

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))

FAMILIES = ("urand", "smallworld", "rmat")
PARTS = (1, 2, 4)
N = 384          # pads to 512 at parts=4 (n_local multiples of 128)
SEED = 5
ROOT = 3

_SWEEP_CODE = """
import sys
sys.path.insert(0, {tests_dir!r})
import numpy as np
import jax.numpy as jnp
import oracle
from repro.core import GraphEngine, incremental, partition_graph, registry
from repro.launch.mesh import make_graph_mesh

family, parts_list, n, seed, root = {family!r}, {parts!r}, {n}, {seed}, {root}
edges, n = oracle.family_edges(family, n, seed)
for parts in parts_list:
    g = partition_graph(edges, n, parts)
    eng = GraphEngine(g, make_graph_mesh(parts))
    garr = eng.device_graph()
    for algo, variant in registry.available():
        spec = registry.get_spec(algo, variant)
        params = oracle.CONFORMANCE_PARAMS.get((algo, variant), {{}})
        prog = eng.program(algo, variant, **params)
        if any(k != "scalar" for k in spec.input_kinds):
            (seed_arr,) = incremental.cold_seed(spec, g)
            args = (garr, eng.scatter_vertex_field(
                seed_arr, incremental.KIND_DTYPES[spec.input_kinds[0]]))
        else:
            args = (garr,) + (jnp.int32(root),) * len(spec.inputs)
        *outs, rounds = prog(*args)
        p = prog.program
        fields = {{name: (eng.gather_vertex_field(o) if isv
                          else np.asarray(o))
                   for name, o, isv in zip(p.output_names, outs,
                                           p.output_is_vertex)}}
        assert int(rounds) > 0, (algo, variant)
        try:
            oracle.check_conformance(algo, variant, fields, edges, n, root)
        except AssertionError as e:
            raise AssertionError(
                f"conformance FAILED: {{algo}}/{{variant}} parts={{parts}} "
                f"family={{family}}: {{e}}") from e
        print(f"PASS {{algo}}/{{variant}} parts={{parts}}")
print("CONFORMANCE-OK " + family)
"""


@pytest.mark.parametrize("family", FAMILIES)
def test_every_program_matches_oracle(family):
    out = run_with_devices(
        _SWEEP_CODE.format(tests_dir=TESTS_DIR, family=family,
                           parts=PARTS, n=N, seed=SEED, root=ROOT),
        devices=max(PARTS), timeout=1800)
    assert f"CONFORMANCE-OK {family}" in out
    for parts in PARTS:
        for algo, variant in registry.available():
            assert f"PASS {algo}/{variant} parts={parts}" in out, \
                f"missing conformance cell {algo}/{variant} parts={parts}"


def test_every_algorithm_has_an_oracle():
    """A registered algorithm without an oracle entry is a gap in the
    gate — fail at registration time, not first conformance run."""
    algos = {a for a, _ in registry.available()}
    missing = algos - set(oracle.CHECKS)
    assert not missing, f"algorithms without oracles: {sorted(missing)}"


def test_every_async_variant_has_an_oracle():
    """HARD failure: a registered async variant with neither a base
    algorithm oracle nor a variant-check override would register into
    the sweep but assert nothing meaningful about staleness."""
    pairs = registry.async_pairs()
    assert pairs, "no async variants registered"
    missing = [f"{a}/{v}" for a, v in pairs
               if a not in oracle.CHECKS
               and (a, v) not in oracle.VARIANT_CHECKS]
    assert not missing, f"async variants without oracles: {missing}"


def test_async_lane_shape():
    """The async lane must cover the four stale-tolerant algorithms,
    and pagerank/async must run its OWN check: the base pagerank oracle
    replays a fixed iteration count, which a stale trajectory cannot
    match — it needs the converged-fixed-point + staleness-bound form."""
    pairs = registry.async_pairs()
    assert {a for a, _ in pairs} >= {"bfs", "pagerank", "cc", "sssp"}
    for algo, variant in pairs:
        assert registry.get_spec(algo, variant).exec_mode == "async"
    assert ("pagerank", "async") in oracle.VARIANT_CHECKS
    # the sweep must run pagerank/async to convergence with the
    # non-default staleness the check's age bound is stated for
    params = oracle.CONFORMANCE_PARAMS[("pagerank", "async")]
    assert params["staleness"] == oracle.ASYNC_PR_STALENESS
