"""SpMV Pallas kernel: shape/dtype sweep vs pure-jnp oracle (interpret).

tier1: the localops dispatch layer (core/localops.py) routes the
PageRank/additive-combine hot loops through this kernel on TPU, so its
interpret-mode parity belongs in the conformance lane of
``scripts/ci.sh --markers``, never the slow tier."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.spmv.kernel import spmv_ell
from repro.kernels.spmv.ref import spmv_ell_ref

pytestmark = pytest.mark.tier1


@pytest.mark.parametrize("n_rows,k,n_cols,row_block", [
    (256, 8, 512, 128), (512, 16, 1024, 256), (1024, 4, 256, 512),
    (256, 32, 2048, 64), (128, 1, 128, 128),
])
def test_spmv_shapes(n_rows, k, n_cols, row_block):
    idx = jax.random.randint(jax.random.key(1), (n_rows, k), 0, n_cols)
    val = jax.random.normal(jax.random.key(2), (n_rows, k))
    x = jax.random.normal(jax.random.key(3), (n_cols,))
    got = spmv_ell(idx, val, x, row_block=row_block, interpret=True)
    ref = spmv_ell_ref(idx, val, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmv_dtypes(dtype):
    idx = jax.random.randint(jax.random.key(1), (256, 8), 0, 512)
    val = jax.random.normal(jax.random.key(2), (256, 8)).astype(dtype)
    x = jax.random.normal(jax.random.key(3), (512,)).astype(dtype)
    got = spmv_ell(idx, val.astype(jnp.float32), x, row_block=128,
                   interpret=True)
    ref = spmv_ell_ref(idx, val.astype(jnp.float32), x)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=tol, atol=tol)


def test_spmv_padding_zero_val_ignored():
    """Sentinel-padded slots (val=0) contribute nothing."""
    idx = jnp.zeros((128, 4), jnp.int32)
    val = jnp.zeros((128, 4), jnp.float32)
    x = jax.random.normal(jax.random.key(0), (128,))
    got = spmv_ell(idx, val, x, row_block=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), 0.0)


def test_spmv_matches_scatter_formulation():
    """ELL pull == COO scatter-add (the core/pagerank formulation)."""
    rng = np.random.default_rng(0)
    n = 256
    deg = 6
    idx = rng.integers(0, n, (n, deg))
    val = rng.normal(size=(n, deg)).astype(np.float32)
    x = rng.normal(size=(n,)).astype(np.float32)
    got = spmv_ell(jnp.asarray(idx), jnp.asarray(val), jnp.asarray(x),
                   row_block=128, interpret=True)
    ref = np.zeros(n, np.float32)
    for r in range(n):
        ref[r] = (val[r] * x[idx[r]]).sum()
    np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-4, atol=1e-4)
