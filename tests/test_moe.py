"""MoE dispatch: capacity semantics, combine weights, dense equivalence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import smoke_config
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models.params import init_params


def _cfg(**kw):
    base = smoke_config("dbrx-132b")
    return dataclasses.replace(base, **kw)


def test_single_expert_topk1_equals_dense_mlp():
    cfg = _cfg(num_experts=1, num_experts_per_tok=1, capacity_factor=4.0)
    p = init_params(MOE.moe_spec(cfg), jax.random.key(0))
    x = 0.1 * jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y, aux = MOE.apply_moe(p, x, cfg)
    dense_p = {"wi": p["wi"][0], "wg": p["wg"][0], "wo": p["wo"][0]}
    y_ref = L.apply_mlp(dense_p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)
    assert float(aux["moe_drop_frac"]) < 1e-6


def test_capacity_drops_overflow_tokens():
    # force capacity 1 with many tokens -> most tokens dropped
    cfg = _cfg(num_experts=2, num_experts_per_tok=1, capacity_factor=1e-6)
    p = init_params(MOE.moe_spec(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (1, 32, cfg.d_model))
    y, aux = MOE.apply_moe(p, x, cfg, group_size=32)
    assert float(aux["moe_drop_frac"]) > 0.8


def test_lb_loss_minimal_when_balanced():
    cfg = _cfg(num_experts=4, num_experts_per_tok=1)
    E = cfg.num_experts
    # perfectly balanced probs -> lb_loss == 1.0 (its minimum)
    probs = jnp.full((8, E), 1.0 / E)
    me = probs.mean(axis=0)
    ce = jnp.full((E,), 1.0 / E)
    lb = E * jnp.sum(me * ce)
    assert abs(float(lb) - 1.0) < 1e-6


def test_moe_grads_flow_to_all_parts():
    cfg = _cfg(num_experts=4, num_experts_per_tok=2, capacity_factor=2.0)
    p = init_params(MOE.moe_spec(cfg), jax.random.key(0))
    x = 0.1 * jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))

    def loss(p):
        y, aux = MOE.apply_moe(p, x, cfg)
        return (y ** 2).mean() + 0.01 * aux["moe_lb_loss"]

    g = jax.grad(loss)(p)
    for k, v in g.items():
        assert float(jnp.abs(v).max()) > 0, f"zero grad for {k}"
