"""Layer-level unit tests: norms, rope, flash attention vs naive oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


def test_rmsnorm_matches_manual():
    x = jax.random.normal(jax.random.key(0), (2, 5, 16))
    p = {"scale": jnp.full((16,), 2.0)}
    got = L.apply_norm(p, x, "rmsnorm")
    ref = x / np.sqrt((np.asarray(x) ** 2).mean(-1, keepdims=True) + 1e-6) * 2
    np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-5)


def test_layernorm_zero_mean_unit_var():
    x = jax.random.normal(jax.random.key(1), (3, 7, 32)) * 5 + 3
    p = {"scale": jnp.ones((32,)), "bias": jnp.zeros((32,))}
    y = np.asarray(L.apply_norm(p, x, "layernorm"))
    np.testing.assert_allclose(y.mean(-1), 0, atol=1e-4)
    np.testing.assert_allclose(y.var(-1), 1, atol=1e-3)


def test_rope_preserves_norm_and_relative_phase():
    d = 32
    x = jax.random.normal(jax.random.key(2), (1, 8, 2, d))
    pos = jnp.arange(8)
    y = L.apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.key(3), (1, 1, 1, d))
    k = jax.random.normal(jax.random.key(4), (1, 1, 1, d))
    def dot_at(i, j):
        qi = L.apply_rope(q, jnp.array([i]), 1e4)
        kj = L.apply_rope(k, jnp.array([j]), 1e4)
        return float((qi * kj).sum())
    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-4


@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (True, 16, 0.0), (False, 0, 0.0), (True, 0, 20.0)])
def test_flash_xla_matches_naive(causal, window, softcap):
    B, S, H, D = 2, 64, 2, 16
    q, k, v = [jax.random.normal(jax.random.key(i), (B, S, H, D))
               for i in range(3)]
    o1 = L.flash_attention_xla(q, k, v, causal, window, softcap, 32, 32)
    o2 = L.attention_naive(q, k, v, q_pos=jnp.arange(S), k_pos=jnp.arange(S),
                           causal=causal, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_flash_xla_grads_match_naive():
    B, S, H, D = 1, 32, 2, 8
    q, k, v = [jax.random.normal(jax.random.key(i), (B, S, H, D))
               for i in range(3)]
    f1 = lambda q, k, v: L.flash_attention_xla(
        q, k, v, True, 0, 0.0, 16, 16).sum()
    f2 = lambda q, k, v: L.attention_naive(
        q, k, v, q_pos=jnp.arange(S), k_pos=jnp.arange(S), causal=True,
        window=0).astype(jnp.float32).sum()
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


def test_sliding_window_masks_far_past():
    S = 32
    m = L.attn_mask(jnp.arange(S), jnp.arange(S), causal=True, window=4)
    m = np.asarray(m)
    assert m[10, 10] and m[10, 7] and not m[10, 6] and not m[5, 9]


def test_repeat_kv():
    k = jnp.arange(2 * 3 * 2 * 4).reshape(2, 3, 2, 4)
    r = L.repeat_kv(k, 2)
    assert r.shape == (2, 3, 4, 4)
    np.testing.assert_array_equal(np.asarray(r[:, :, 0]),
                                  np.asarray(r[:, :, 1]))
