"""BFS frontier Pallas kernel: sweep vs oracle (interpret mode).

tier1: the localops dispatch layer (core/localops.py) routes the BFS
pull hot loop through this kernel on TPU, so its interpret-mode parity
belongs in the conformance lane of ``scripts/ci.sh --markers``, never
the slow tier."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.frontier.kernel import INT_INF, bfs_pull
from repro.kernels.frontier.ref import bfs_pull_ref

pytestmark = pytest.mark.tier1


def _inputs(n_rows, k, n_cols, seed=0):
    rng = np.random.default_rng(seed)
    nbr = jnp.asarray(rng.integers(0, n_cols, (n_rows, k), dtype=np.int32))
    bits = jnp.asarray(rng.integers(0, 2 ** 32, n_cols // 32,
                                    dtype=np.uint32))
    unv = jnp.asarray(rng.integers(0, 2, n_rows, dtype=np.int32))
    return nbr, bits, unv


@pytest.mark.parametrize("n_rows,k,n_cols,rb", [
    (256, 8, 512, 128), (512, 16, 1024, 256), (128, 4, 4096, 128),
    (1024, 2, 128, 512),
])
def test_frontier_sweep(n_rows, k, n_cols, rb):
    nbr, bits, unv = _inputs(n_rows, k, n_cols)
    got = bfs_pull(nbr, bits, unv, row_block=rb, interpret=True)
    ref = bfs_pull_ref(nbr, bits, unv)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_frontier_visited_rows_inf():
    nbr, bits, _ = _inputs(128, 4, 256)
    unv = jnp.zeros((128,), jnp.int32)
    got = bfs_pull(nbr, bits, unv, row_block=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), INT_INF)


def test_frontier_min_parent_selection():
    """When several in-neighbors are in the frontier, min id wins."""
    n_cols = 64
    bits = np.zeros(2, np.uint32)
    for v in (5, 9, 40):
        bits[v // 32] |= np.uint32(1 << (v % 32))
    nbr = jnp.asarray([[40, 9, 5, 63]] * 128, jnp.int32)
    unv = jnp.ones((128,), jnp.int32)
    got = bfs_pull(nbr, jnp.asarray(bits), unv, row_block=128,
                   interpret=True)
    np.testing.assert_array_equal(np.asarray(got), 5)
