"""Dynamic-graph benchmark: in-place mutation throughput and the
incremental-recompute win on the resident server; writes
``BENCH_mutate.json`` at the repo root.

  PYTHONPATH=src python -m benchmarks.bench_mutate [--fast]

One subprocess (so ``XLA_FLAGS=--xla_force_host_platform_device_count``
binds the partition count before jax imports) builds a server, serves a
PageRank refresh at epoch 0, applies a K-edge delete batch then a
K-edge insert batch through ``GraphServer.mutate`` (both must take the
in-place slot-patch path — a rebuild fails the run), and re-serves
PageRank both ways on the mutated graph:

  * ``mutate/apply``    — batched patch wall time; the summary reports
    edges/sec applied and asserts ``rebuild`` never fired;
  * ``pagerank/warm``   — warm restart from the epoch-0 served rank;
  * ``pagerank/cold``   — the cold uniform start, same tolerance.

The summary records ``rounds_warm``/``rounds_cold`` and their ratio,
plus the warm program's wire MB per part from its AOT collectives
(``repro.roofline.analysis.parse_collectives``).  The run FAILS (exit
3) unless the warm restart converges in strictly fewer rounds than
cold — the dynamic-subsystem acceptance floor.  ``benchmarks/
compare.py`` gates the committed rows per (algo, variant) cell with
the same threshold/jitter-floor/cross-config rules as BENCH_graph.json.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
SRC = str(REPO_ROOT / "src")

_CELL_CODE = r"""
import json, time
import numpy as np
from repro.configs import graph_workloads
from repro.core import GraphEngine, localops, partition_graph
from repro.core.compat import runtime_fingerprint
from repro.graphs import generate_edges
from repro.launch.mesh import make_graph_mesh
from repro.roofline import analysis as RA
from repro.serve import GraphServer, query

graph, parts, k_edges = {graph!r}, {parts}, {k_edges}
PR = dict(iters=300, tol=1e-6)          # identical tolerance both ways
gcfg = graph_workloads.ALL[graph]
edges = generate_edges(gcfg, seed=42)
g = partition_graph(edges, gcfg.num_vertices, parts)
eng = GraphEngine(g, make_graph_mesh(parts))
server = GraphServer(eng, buckets=(1,))
server.warmup([query("pagerank", **PR).key,
               query("pagerank", "warm", **PR).key])
print("META " + json.dumps({{
    "localops": localops.get_mode(), **runtime_fingerprint()}}))

# epoch 0: the refresh whose served rank becomes the warm seed
server.serve([query("pagerank", **PR)])

# delete K live edges, then insert K fresh ones (the freed slots
# guarantee insert capacity, so neither batch may fall back to rebuild)
dyn = server.dynamic_graph()
rng = np.random.default_rng(7)
s_del = server.mutate(deletes=dyn.sample_deletable(k_edges, rng))
s_ins = server.mutate(inserts=dyn.sample_insertable(k_edges, rng))
assert not (s_del.rebuild or s_ins.rebuild), "mutation fell back to rebuild"
apply_s = s_del.apply_s + s_ins.apply_s
print("RESULT " + json.dumps({{
    "algo": "mutate", "variant": "apply", "graph": graph, "parts": parts,
    "ms": apply_s * 1e3, "edges": 2 * k_edges,
    "edges_per_s": 2 * k_edges / apply_s,
    "slots_patched": s_del.slots_patched + s_ins.slots_patched}}))

# epoch 2: recompute on the mutated graph, warm then cold.  The warm
# query must run FIRST - serving it updates the stored seed, so a
# second warm launch would trivially converge in one round.
for variant, label in ((("pagerank", "warm"), "warm"),
                       (("pagerank",), "cold")):
    (res,) = server.serve([query(*variant, **PR)])
    print("RESULT " + json.dumps({{
        "algo": "pagerank", "variant": label, "graph": graph,
        "parts": parts, "ms": res.latency_s * 1e3,
        "rounds": int(res.rounds), "epoch": res.epoch}}))

stats = RA.parse_collectives(
    eng.program("pagerank", "warm", **PR).aot().as_text())
print("WIRE " + json.dumps(
    {{"wire_mb_per_part": stats.total_wire_bytes / parts / 1e6}}))
"""


def run_cells(graph: str, parts: int, k_edges: int):
    code = _CELL_CODE.format(graph=graph, parts=parts, k_edges=k_edges)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={parts} "
                        + env.get("XLA_FLAGS", "")).strip()
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(
            f"mutate bench subprocess failed:\n{proc.stdout[-2000:]}\n"
            f"{proc.stderr[-4000:]}")
    rows, meta, wire = [], {}, {}
    for line in proc.stdout.splitlines():
        if line.startswith("META "):
            meta = json.loads(line[len("META "):])
        elif line.startswith("RESULT "):
            rows.append(json.loads(line[len("RESULT "):]))
        elif line.startswith("WIRE "):
            wire = json.loads(line[len("WIRE "):])
    return rows, meta, wire


def summary_section(rows: list[dict], wire: dict) -> dict:
    by = {(r["algo"], r["variant"]): r for r in rows}
    apply_row = by[("mutate", "apply")]
    warm, cold = by[("pagerank", "warm")], by[("pagerank", "cold")]
    return {
        "edges_applied": apply_row["edges"],
        "edges_per_s": round(apply_row["edges_per_s"], 1),
        "rounds_warm": warm["rounds"], "rounds_cold": cold["rounds"],
        "speedup_rounds": round(cold["rounds"] / max(warm["rounds"], 1), 2),
        "wire_mb_per_part": round(wire.get("wire_mb_per_part", 0.0), 3),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller graph / smaller batches (CI mode)")
    ap.add_argument("--graph", default=None,
                    help="override the suite's graph config")
    ap.add_argument("--parts", type=int, default=2)
    ap.add_argument("--edges", type=int, default=None,
                    help="edges per mutation batch (delete and insert)")
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_mutate.json"))
    args = ap.parse_args(argv)

    graph = args.graph or ("urand12" if args.fast else "urand16")
    k_edges = args.edges or (256 if args.fast else 1024)

    print(f"[bench_mutate] {graph} parts={args.parts} "
          f"batch={k_edges} edges (delete + insert)")
    rows, sub_meta, wire = run_cells(graph, args.parts, k_edges)
    for r in rows:
        extra = (f"{r['edges_per_s']:10.0f} edges/s"
                 if r["algo"] == "mutate" else f"{r['rounds']:6d} rounds")
        print(f"[bench_mutate] {r['algo'] + '/' + r['variant']:16s} "
              f"{r['ms']:9.1f} ms  {extra}")

    summary = summary_section(rows, wire)
    print(f"[bench_mutate] warm restart: {summary['rounds_warm']} rounds "
          f"vs cold {summary['rounds_cold']} "
          f"({summary['speedup_rounds']:.2f}x fewer); "
          f"wire {summary['wire_mb_per_part']:.3f} MB/part")

    meta = {"graph": graph, "parts": args.parts, "launches": k_edges,
            "mode": "fast" if args.fast else "full", "layout": "ell",
            "localops": sub_meta.get(
                "localops", os.environ.get("REPRO_LOCALOPS", "auto")),
            "jax": sub_meta.get("jax"), "device": sub_meta.get("device")}
    payload = {"meta": meta, "rows": rows, "summary": summary}
    pathlib.Path(args.out).write_text(
        json.dumps(payload, indent=2) + "\n")
    print(f"[bench_mutate] wrote {args.out} ({len(rows)} rows)")
    if summary["rounds_warm"] >= summary["rounds_cold"]:
        print(f"[bench_mutate] FAIL: warm restart took "
              f"{summary['rounds_warm']} rounds, not fewer than cold's "
              f"{summary['rounds_cold']}", file=sys.stderr)
        return 3
    return 0


if __name__ == "__main__":
    sys.exit(main())
