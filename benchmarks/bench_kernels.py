"""Kernel micro-benchmarks: jnp-oracle wall time on this host (CPU) plus
derived TPU-roofline projections for the Pallas kernels.

On-CPU wall time exercises the oracle path only (kernels are TPU-target;
interpret mode is a correctness tool, not a perf path).  The projection
derives bytes/flops per call from shapes and reports the v5e roofline
bound per kernel - the number the Pallas implementation is written to
approach.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.roofline.analysis import HBM_BW, PEAK_FLOPS_BF16


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return min(ts)


def bench_spmv():
    from repro.kernels.spmv.ref import spmv_ell_ref
    n_rows, k, n_cols = 65536, 16, 65536
    idx = jax.random.randint(jax.random.key(1), (n_rows, k), 0, n_cols)
    val = jax.random.normal(jax.random.key(2), (n_rows, k))
    x = jax.random.normal(jax.random.key(3), (n_cols,))
    f = jax.jit(spmv_ell_ref)
    dt = _time(f, idx, val, x)
    bytes_moved = (idx.size * 4 + val.size * 4 + n_rows * 4
                   + n_rows * k * 4)  # gather traffic ~ 1 read per edge
    flops = 2 * n_rows * k
    bound = max(bytes_moved / HBM_BW, flops / PEAK_FLOPS_BF16)
    print(f"spmv_ell,{dt*1e6:.0f}us_cpu_oracle,"
          f"tpu_roofline_bound={bound*1e6:.1f}us,"
          f"intensity={flops/bytes_moved:.3f}flop/B")


def bench_frontier():
    from repro.kernels.frontier.ref import bfs_pull_ref
    import numpy as np
    n_rows, k, n_cols = 65536, 16, 1 << 20
    rng = np.random.default_rng(0)
    nbr = jnp.asarray(rng.integers(0, n_cols, (n_rows, k), dtype=np.int32))
    bits = jnp.asarray(rng.integers(0, 2 ** 32, n_cols // 32,
                                    dtype=np.uint32))
    unv = jnp.asarray(rng.integers(0, 2, n_rows, dtype=np.int32))
    f = jax.jit(bfs_pull_ref)
    dt = _time(f, nbr, bits, unv)
    bytes_moved = nbr.size * 4 + nbr.size * 4 + n_rows * 8
    bound = bytes_moved / HBM_BW
    print(f"bfs_pull,{dt*1e6:.0f}us_cpu_oracle,"
          f"tpu_roofline_bound={bound*1e6:.1f}us,memory_bound")


def bench_flash():
    from repro.models.layers import flash_attention_xla
    B, S, H, D = 1, 2048, 8, 128
    q, k, v = [jax.random.normal(jax.random.key(i), (B, S, H, D),
                                 jnp.bfloat16) for i in range(3)]
    f = jax.jit(lambda q, k, v: flash_attention_xla(
        q, k, v, True, 0, 0.0, 512, 512))
    dt = _time(f, q, k, v)
    flops = 4 * B * H * S * S * D  # qk + pv
    bytes_moved = 4 * B * S * H * D * 2
    bound = max(flops / PEAK_FLOPS_BF16, bytes_moved / HBM_BW)
    print(f"flash_attention,{dt*1e6:.0f}us_cpu_oracle,"
          f"tpu_roofline_bound={bound*1e6:.1f}us,"
          f"intensity={flops/bytes_moved:.0f}flop/B,compute_bound")


def main():
    print("name,cpu_oracle_time,tpu_projection,notes")
    bench_spmv()
    bench_frontier()
    bench_flash()


if __name__ == "__main__":
    main()
