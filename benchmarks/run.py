"""Benchmark harness: one entry per paper table/figure + roofline table.

  PYTHONPATH=src python -m benchmarks.run [--fast]

Outputs CSV-ish lines per benchmark and writes JSON artifacts under
artifacts/.
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller graphs / fewer reps (CI mode)")
    ap.add_argument("--skip-scaling", action="store_true",
                    help="skip the multi-process scaling figures")
    args = ap.parse_args()

    graph = "urand16"
    parts = (1, 2, 4) if args.fast else (1, 2, 4, 8)
    reps = 2 if args.fast else 3

    print("=" * 72)
    print("Figure 1: distributed BFS, BSP(Boost-like) vs HPX-adapted")
    print("=" * 72)
    if not args.skip_scaling:
        from benchmarks.bench_bfs import main as bfs_main
        bfs_main(graph=graph, parts=parts, reps=reps)

    print("=" * 72)
    print("Figure 2: distributed PageRank, BSP(Boost-like) vs HPX-adapted")
    print("=" * 72)
    if not args.skip_scaling:
        from benchmarks.bench_pagerank import main as pr_main
        pr_main(graph=graph, parts=parts, reps=reps)

    print("=" * 72)
    print("Kernel micro-benchmarks (CPU oracle time + TPU roofline bound)")
    print("=" * 72)
    from benchmarks.bench_kernels import main as k_main
    k_main()

    print("=" * 72)
    print("Roofline table (from dry-run artifacts; see EXPERIMENTS.md)")
    print("=" * 72)
    try:
        from benchmarks.roofline_table import main as r_main
        r_main()
    except Exception as e:  # noqa: BLE001 - artifacts may not exist yet
        print(f"(roofline table unavailable: {e!r}; "
              "run python -m repro.launch.dryrun first)")


if __name__ == "__main__":
    main()
