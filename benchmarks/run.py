"""Benchmark harness: one entry per paper table/figure + roofline table.

  PYTHONPATH=src python -m benchmarks.run [--fast]

Outputs CSV-ish lines per benchmark, writes JSON artifacts under
artifacts/, and writes a machine-readable ``BENCH_graph.json`` at the
repo root (one row per algorithm x variant x partition count with the
measured ms) so the perf trajectory is tracked across PRs.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def runtime_meta() -> dict:
    """jax version + device kind, read in a SUBPROCESS — the harness
    itself never imports jax (each bench point is a subprocess that
    must set XLA_FLAGS before its first jax import).  Recorded in the
    bench meta so benchmarks/compare.py can tell environment drift
    (jax upgrade, CPU-vs-TPU move) from real regressions."""
    code = ("import json; from repro.core.compat import "
            "runtime_fingerprint; print(json.dumps(runtime_fingerprint()))")
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src")
    try:
        proc = subprocess.run([sys.executable, "-c", code], env=env,
                              capture_output=True, text=True, timeout=120)
        return json.loads(proc.stdout.strip().splitlines()[-1])
    except Exception:  # noqa: BLE001 - meta is best-effort
        return {"jax": None, "device": None}


def write_bench_artifact(rows: list[dict], meta: dict,
                         path=None) -> pathlib.Path:
    """Write BENCH_graph.json: {meta, rows: [{algo, variant, graph,
    parts, ms, wire_mb, rounds_to_converge}]}.  ``meta`` records
    graphs/reps/mode — and each row carries its own graph — so cross-PR
    comparisons never silently mix measurement configurations.
    ``rounds_to_converge`` is the driver's actual round count (early
    exit for convergent programs, the fixed budget for iteration-capped
    ones): deterministic per configuration, so compare.py gates it
    exactly — an async variant silently paying extra rounds is an
    algorithmic regression wall-time jitter could hide."""
    out = path or (REPO_ROOT / "BENCH_graph.json")
    slim = [{
        "algo": r["algo"],
        "variant": r["mode"],
        "graph": r["graph"],
        "parts": r["parts"],
        "ms": round(r["ms"], 2),
        "wire_mb_per_part": round(r["wire_bytes_per_part"] / 1e6, 3),
        "rounds_to_converge": r["rounds"],
        # per-row engine-telemetry summary (per-round probe series +
        # tap-level wire bytes) — INFORMATIONAL ONLY: compare.py never
        # gates on it and tolerates rows without it (older baselines)
        **({"telemetry": r["telemetry"]} if "telemetry" in r else {}),
    } for r in rows]
    pathlib.Path(out).write_text(
        json.dumps({"meta": meta, "rows": slim}, indent=2) + "\n")
    print(f"[bench] wrote {out} ({len(slim)} rows)")
    return pathlib.Path(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller graphs / fewer reps (CI mode)")
    ap.add_argument("--skip-scaling", action="store_true",
                    help="skip the multi-process scaling figures")
    args = ap.parse_args()

    graph = "urand16"
    parts = (1, 2) if args.fast else (1, 2, 4, 8)
    reps = 2 if args.fast else 3

    graph_rows: list[dict] = []

    print("=" * 72)
    print("Figure 1: distributed BFS, BSP(Boost-like) vs HPX-adapted")
    print("=" * 72)
    if not args.skip_scaling:
        from benchmarks.bench_bfs import main as bfs_main
        graph_rows += bfs_main(graph=graph, parts=parts, reps=reps)

    print("=" * 72)
    print("Figure 2: distributed PageRank, BSP(Boost-like) vs HPX-adapted")
    print("=" * 72)
    if not args.skip_scaling:
        from benchmarks.bench_pagerank import main as pr_main
        graph_rows += pr_main(graph=graph, parts=parts, reps=reps)

    # the registry's post-paper programs (ROADMAP: "full NWGraph set").
    # Benchmarked on urand12: triangle counting's rotation exchange is
    # O(n^2/P) memory/compute, so its bench point is a graph inside its
    # n_budget; kcore/betweenness ride the same graph for comparability.
    graph_extra = "urand12"
    print("=" * 72)
    print(f"New algorithms: triangles / kcore / betweenness ({graph_extra})")
    print("=" * 72)
    if not args.skip_scaling:
        from benchmarks.graph_scaling import scaling_table
        for algo in ("triangles", "kcore", "betweenness"):
            graph_rows += scaling_table(graph_extra, algo,
                                        parts_list=parts, reps=reps)

    if graph_rows:
        # the localops mode/layout steer which hot-loop implementation
        # was measured; recorded so cross-PR comparisons (compare.py)
        # never silently mix dispatch configurations.  Read from the env
        # (not repro.core.localops): each bench point is a subprocess
        # inheriting this env, and the harness never imports jax.
        write_bench_artifact(graph_rows, {
            "graph": graph, "graph_new_algos": graph_extra,
            "parts": list(parts), "reps": reps,
            "mode": "fast" if args.fast else "full",
            "localops": os.environ.get("REPRO_LOCALOPS", "auto"),
            "layout": "ell", **runtime_meta()})

    print("=" * 72)
    print("Kernel micro-benchmarks (CPU oracle time + TPU roofline bound)")
    print("=" * 72)
    from benchmarks.bench_kernels import main as k_main
    k_main()

    print("=" * 72)
    print("Roofline table (from dry-run artifacts; see EXPERIMENTS.md)")
    print("=" * 72)
    try:
        from benchmarks.roofline_table import main as r_main
        r_main()
    except Exception as e:  # noqa: BLE001 - artifacts may not exist yet
        print(f"(roofline table unavailable: {e!r}; "
              "run python -m repro.launch.dryrun first)")


if __name__ == "__main__":
    main()
