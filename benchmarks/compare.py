"""Perf-regression gate: diff freshly written bench artifacts against
the committed baselines (``git show HEAD:<artifact>`` by default).

  PYTHONPATH=src python -m benchmarks.compare [--threshold 1.25]

Three artifacts are gated:

  * ``BENCH_graph.json`` — direct program launches; rows join per
    (algo, variant, graph, parts) and fail when new/old wall-time
    exceeds the threshold.  Graph rows additionally gate their
    DETERMINISTIC fields — ``rounds_to_converge`` (the superstep
    driver's round count; an async variant quietly paying extra rounds
    is an algorithmic regression wall-time jitter could hide) and
    ``wire_mb_per_part`` (parsed from the compiled HLO) — growth past
    the threshold plus a small absolute slack fails regardless of the
    wall-time jitter floor, because these numbers have no jitter.
  * ``BENCH_serve.json`` — the query-serving path; rows join per
    (algo, bucket) and fail when queries/sec DROPS by more than the
    threshold (old/new qps ratio).
  * ``BENCH_mutate.json`` — the dynamic-graph path (batched mutation
    apply + warm-vs-cold PageRank recompute); graph-shaped rows, same
    wall-time rule as BENCH_graph.json.

All share the guards against false alarms:

  * rows measured under DIFFERENT configurations are never
    hard-compared — the meta records dispatch (``localops`` /
    ``layout``), measurement setup (mode / reps or launches), and the
    environment (``jax`` version, ``device`` kind), so a REPRO_LOCALOPS
    override, a jax upgrade, or a CPU-vs-TPU move reads as config
    drift, not a regression (the table still prints, the gate is
    skipped) — but a field recorded on only ONE side (a baseline from
    before the field existed) is a wildcard, so introducing a new meta
    field never hands that PR a gate holiday;
  * cells where both sides are under ``--min-ms`` (wall time for graph
    rows, p50 latency for serve rows) are jitter on emulated devices,
    not signal, and never fail the gate;
  * rows present on only one side (new algorithms, new bucket rungs,
    dropped bench points) are reported but never fail;
  * a missing baseline (fresh clone, artifact not committed yet) is a
    skip, not a failure;
  * observability blocks are INFORMATIONAL, never gated: a row's
    ``telemetry`` dict (per-round probe series + tap-level wire bytes,
    from ``repro.obs``) and a serve artifact's ``trace_summary`` are
    ignored by the join and by every gate rule above — rows or
    baselines without them compare exactly as before, so enabling or
    refreshing telemetry can never flip this gate.

``scripts/ci.sh`` runs this right after the fast benches.  The
committed artifacts are the baselines, so land refreshed rows in the
same PR as an intentional perf change.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

GRAPH_ARTIFACT = "BENCH_graph.json"
SERVE_ARTIFACT = "BENCH_serve.json"
MUTATE_ARTIFACT = "BENCH_mutate.json"


def _graph_key(r: dict) -> tuple:
    return (r["algo"], r["variant"], r.get("graph", "?"), r["parts"])


def _serve_key(r: dict) -> tuple:
    return (r["algo"], r["bucket"])


def load_bench(source: str, name: str = GRAPH_ARTIFACT, key=_graph_key):
    """(meta, {key: row}) from a path or ``git:REV``; None if unavailable.

    A plain-path ``source`` may be a directory (the artifact name is
    appended) or a file (used as-is — its SIBLING is used for the other
    artifact via the directory form).
    """
    if source.startswith("git:"):
        rev = source[len("git:"):]
        proc = subprocess.run(
            ["git", "show", f"{rev}:{name}"], cwd=REPO_ROOT,
            capture_output=True, text=True)
        if proc.returncode != 0:
            return None
        text = proc.stdout
    else:
        path = pathlib.Path(source)
        if path.is_dir():
            path = path / name
        if not path.exists():
            return None
        text = path.read_text()
    data = json.loads(text)
    return data.get("meta", {}), {key(r): r for r in data.get("rows", [])}


def dispatch_config(meta: dict) -> tuple:
    """The configuration a row set was measured under: dispatch
    (localops/layout), measurement setup (fast-vs-full mode, reps or
    launches per cell, and — for serve rows, whose (algo, bucket) key
    does not carry them — the graph and partition count), and the
    environment (jax version, device kind)."""
    parts = meta.get("parts")
    return (meta.get("localops"), meta.get("layout"), meta.get("mode"),
            meta.get("reps", meta.get("launches")),
            meta.get("graph"), tuple(parts) if isinstance(parts, list)
            else parts,
            meta.get("jax"), meta.get("device"))


def config_changed(old_meta: dict, new_meta: dict) -> bool:
    """True when the two row sets were measured under DIFFERENT
    configurations — numbers are then not comparable and the hard gate
    is skipped (the table still prints).  A field recorded on only ONE
    side (None on the other — e.g. the baseline predates jax/device
    recording) is a wildcard, NOT drift: introducing a new meta field
    must not hand the PR that introduces it a gate holiday."""
    return any(o != n for o, n in zip(dispatch_config(old_meta),
                                      dispatch_config(new_meta))
               if o is not None and n is not None)


# deterministic per-row fields gated WITHOUT the jitter floor: (field,
# short label, absolute slack added on top of the ratio threshold).
# Absent on either side (baseline predates the field) -> not compared.
# A row's "telemetry" block is deliberately NOT here: its figures
# (probe means, tap-level bytes, wall_ms) are informational context,
# and the gated wire number stays the HLO-parsed wire_mb_per_part.
DETERMINISTIC_FIELDS = (
    ("rounds_to_converge", "rounds", 2),
    ("wire_mb_per_part", "wire_mb", 0.01),
)


def _fmt_graph(key) -> str:
    algo, variant, graph, parts = key
    return f"{algo + '/' + variant:22s} {graph:10s} {parts:5d}"


def _fmt_serve(key) -> str:
    algo, bucket = key
    return f"{algo:22s} {'shared' if bucket == 0 else bucket:>10} {'':5s}"


def _sort_key(key) -> tuple:
    """Serve keys mix int buckets with str rungs ("overload",
    "recovery") — stringify so sorted() never compares across types."""
    return tuple(str(part) for part in key)


def compare(old: dict, new: dict, threshold: float, min_ms: float = 0.0, *,
            serve: bool = False) -> tuple[list, list]:
    """(table_lines, regression_keys) for the joined row sets.

    Graph rows regress when wall time GROWS (new/old ms > threshold);
    serve rows regress when throughput DROPS (old/new qps > threshold).
    The jitter floor reads ms for graph rows, p50_ms for serve rows.
    """
    metric, fmt = ("qps", _fmt_serve) if serve else ("ms", _fmt_graph)
    head = (f"{'algo':22s} {'bucket':>10s} {'':5s}" if serve
            else f"{'algo/variant':22s} {'graph':10s} {'parts':>5s}")
    lines = [f"{head} {'old':>9s} {'new':>9s} {'ratio':>6s}  ({metric})"]
    regressions = []
    for key in sorted(set(old) & set(new), key=_sort_key):
        o, n = old[key][metric], new[key][metric]
        ratio = (o / max(n, 1e-9)) if serve else (n / max(o, 1e-9))
        floor_vals = ((old[key].get("p50_ms", 0.0),
                       new[key].get("p50_ms", 0.0)) if serve else (o, n))
        flag = ""
        if ratio > threshold and max(floor_vals) >= min_ms:
            flag = "  <-- REGRESSION"
            regressions.append(key)
        elif ratio > threshold:
            flag = f"  (worse, under the {min_ms:.0f}ms jitter floor)"
        elif ratio < 1.0 / threshold:
            flag = "  (better)"
        lines.append(f"{fmt(key)} {o:9.1f} {n:9.1f} {ratio:6.2f}{flag}")
        if serve:
            continue
        for field, label, slack in DETERMINISTIC_FIELDS:
            ov, nv = old[key].get(field), new[key].get(field)
            if ov is None or nv is None:
                continue
            if nv > ov * threshold and nv - ov > slack:
                regressions.append(key + (label,))
                lines.append(
                    f"{fmt(key)} {ov:9.1f} {nv:9.1f} "
                    f"{nv / max(ov, 1e-9):6.2f}  <-- REGRESSION "
                    f"({label}: deterministic, no jitter floor)")
    for key in sorted(set(new) - set(old), key=_sort_key):
        lines.append(f"{fmt(key)} {'-':>9s} {new[key][metric]:9.1f}   "
                     "new row")
    for key in sorted(set(old) - set(new), key=_sort_key):
        lines.append(f"{fmt(key)} {old[key][metric]:9.1f} {'-':>9s}   "
                     "row dropped")
    return lines, regressions


def _sibling_source(source: str, name: str) -> str:
    """The other artifact next to ``source``: same git rev, or the
    file's directory, or the directory itself."""
    if source.startswith("git:"):
        return source
    path = pathlib.Path(source)
    return str(path if path.is_dir() else path.parent)


def gate_artifact(name: str, baseline: str, current: str, threshold: float,
                  min_ms: float, *, serve: bool, required: bool) -> int:
    """Run one artifact's gate; returns an exit code (0 ok/skip)."""
    key = _serve_key if serve else _graph_key
    loaded_old = load_bench(baseline, name, key)
    loaded_new = load_bench(current, name, key)
    if loaded_old is None:
        print(f"[compare] baseline {baseline} has no {name}; skipping "
              "its regression gate")
        return 0
    if loaded_new is None:
        if not required:
            print(f"[compare] current {name} missing; run its bench "
                  "to gate it")
            return 0
        print(f"[compare] current rows for {name} missing; run "
              "benchmarks first", file=sys.stderr)
        return 2
    old_meta, old = loaded_old
    new_meta, new = loaded_new

    lines, regressions = compare(old, new, threshold, min_ms, serve=serve)
    print(f"[compare] {name}: current vs {baseline} "
          f"(threshold {threshold:.2f}x, floor {min_ms:.0f}ms)")
    print("\n".join(lines))
    if config_changed(old_meta, new_meta):
        print("[compare] measurement config changed (localops, layout, "
              "mode, reps/launches, graph, parts, jax, device): "
              f"{dispatch_config(old_meta)} -> "
              f"{dispatch_config(new_meta)}; ratios are "
              "cross-configuration — regression gate skipped")
        return 0
    if regressions:
        print(f"[compare] {name}: {len(regressions)} regression(s) over "
              f"{threshold:.2f}x: "
              + ", ".join("/".join(map(str, k)) for k in regressions),
              file=sys.stderr)
        return 1
    print(f"[compare] {name}: no regressions")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="git:HEAD",
                    help="committed rows: 'git:REV', a directory, or a "
                         "BENCH_graph.json path (default git:HEAD)")
    ap.add_argument("--current", default=str(REPO_ROOT),
                    help="freshly written rows: a directory or a "
                         "BENCH_graph.json path (default repo root)")
    ap.add_argument("--threshold", type=float, default=1.25,
                    help="fail when the ratio (ms growth / qps drop) "
                         "exceeds this")
    ap.add_argument("--min-ms", type=float, default=10.0,
                    help="cells where BOTH sides are under this never "
                         "fail (emulated-device jitter floor)")
    args = ap.parse_args(argv)

    rc = gate_artifact(GRAPH_ARTIFACT, args.baseline, args.current,
                       args.threshold, args.min_ms, serve=False,
                       required=True)
    rc_serve = gate_artifact(
        SERVE_ARTIFACT, _sibling_source(args.baseline, SERVE_ARTIFACT),
        _sibling_source(args.current, SERVE_ARTIFACT),
        args.threshold, args.min_ms, serve=True, required=False)
    rc_mutate = gate_artifact(
        MUTATE_ARTIFACT, _sibling_source(args.baseline, MUTATE_ARTIFACT),
        _sibling_source(args.current, MUTATE_ARTIFACT),
        args.threshold, args.min_ms, serve=False, required=False)
    return rc or rc_serve or rc_mutate


if __name__ == "__main__":
    sys.exit(main())
