"""Perf-regression gate: diff a freshly written BENCH_graph.json against
the committed baseline (``git show HEAD:BENCH_graph.json`` by default).

  PYTHONPATH=src python -m benchmarks.compare [--threshold 1.25]

Rows are joined per (algo, variant, graph, parts); a ratio table prints
for every matched cell, and the process exits non-zero when any cell's
new/old wall-time ratio exceeds the threshold.  Guards against false
alarms:

  * rows measured under DIFFERENT dispatch configurations (the
    ``localops`` / ``layout`` fields benchmarks/run.py records in meta)
    are never hard-compared — a REPRO_LOCALOPS=ref run vs an ELL-path
    baseline is a config change, not a regression (the table still
    prints, the gate is skipped);
  * cells where both sides are under ``--min-ms`` are jitter on
    emulated devices, not signal, and never fail the gate;
  * rows present on only one side (new algorithms, dropped bench
    points) are reported but never fail;
  * a missing baseline (fresh clone, no git) is a skip, not a failure.

``scripts/ci.sh`` runs this right after the fast bench.  The committed
BENCH_graph.json is the baseline, so land refreshed rows in the same PR
as an intentional perf change.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _row_key(r: dict) -> tuple:
    return (r["algo"], r["variant"], r.get("graph", "?"), r["parts"])


def load_bench(source: str) -> tuple[dict, dict] | None:
    """(meta, {key: row}) from a path or ``git:REV``; None if unavailable."""
    if source.startswith("git:"):
        rev = source[len("git:"):]
        proc = subprocess.run(
            ["git", "show", f"{rev}:BENCH_graph.json"], cwd=REPO_ROOT,
            capture_output=True, text=True)
        if proc.returncode != 0:
            return None
        text = proc.stdout
    else:
        path = pathlib.Path(source)
        if not path.exists():
            return None
        text = path.read_text()
    data = json.loads(text)
    return data.get("meta", {}), {_row_key(r): r for r in data.get("rows", [])}


def dispatch_config(meta: dict) -> tuple:
    """The measurement configuration a row set was taken under:
    dispatch (localops/layout) AND measurement setup (fast-vs-full mode,
    rep count) - ms from different configs are not comparable, so any
    mismatch skips the hard gate (the table still prints).  Artifacts
    from before the localops layer read as (None, None, ...)."""
    return (meta.get("localops"), meta.get("layout"),
            meta.get("mode"), meta.get("reps"))


def compare(old: dict, new: dict, threshold: float,
            min_ms: float = 0.0) -> tuple[list, list]:
    """(table_lines, regression_keys) for the joined row sets."""
    lines = [f"{'algo/variant':22s} {'graph':10s} {'parts':>5s} "
             f"{'old_ms':>9s} {'new_ms':>9s} {'ratio':>6s}"]
    regressions = []
    for key in sorted(set(old) & set(new)):
        algo, variant, graph, parts = key
        o, n = old[key]["ms"], new[key]["ms"]
        ratio = n / max(o, 1e-9)
        flag = ""
        if ratio > threshold and max(o, n) >= min_ms:
            flag = "  <-- REGRESSION"
            regressions.append(key)
        elif ratio > threshold:
            flag = f"  (slower, under the {min_ms:.0f}ms jitter floor)"
        elif ratio < 1.0 / threshold:
            flag = "  (faster)"
        lines.append(f"{algo + '/' + variant:22s} {graph:10s} {parts:5d} "
                     f"{o:9.1f} {n:9.1f} {ratio:6.2f}{flag}")
    for key in sorted(set(new) - set(old)):
        lines.append(f"{key[0] + '/' + key[1]:22s} {key[2]:10s} "
                     f"{key[3]:5d} {'-':>9s} {new[key]['ms']:9.1f}   new row")
    for key in sorted(set(old) - set(new)):
        lines.append(f"{key[0] + '/' + key[1]:22s} {key[2]:10s} "
                     f"{key[3]:5d} {old[key]['ms']:9.1f} {'-':>9s}   "
                     "row dropped")
    return lines, regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="git:HEAD",
                    help="committed rows: 'git:REV' or a file path "
                         "(default git:HEAD)")
    ap.add_argument("--current", default=str(REPO_ROOT / "BENCH_graph.json"),
                    help="freshly written rows (default repo root)")
    ap.add_argument("--threshold", type=float, default=1.25,
                    help="fail when new/old ms exceeds this ratio")
    ap.add_argument("--min-ms", type=float, default=10.0,
                    help="cells where BOTH sides are under this never "
                         "fail (emulated-device jitter floor)")
    args = ap.parse_args(argv)

    loaded_old = load_bench(args.baseline)
    loaded_new = load_bench(args.current)
    if loaded_old is None:
        print(f"[compare] baseline {args.baseline} unavailable; skipping "
              "regression gate")
        return 0
    if loaded_new is None:
        print(f"[compare] current rows {args.current} missing; run "
              "benchmarks.run first", file=sys.stderr)
        return 2
    old_meta, old = loaded_old
    new_meta, new = loaded_new

    cfg_old, cfg_new = dispatch_config(old_meta), dispatch_config(new_meta)
    lines, regressions = compare(old, new, args.threshold, args.min_ms)
    print(f"[compare] {args.current} vs {args.baseline} "
          f"(threshold {args.threshold:.2f}x, floor {args.min_ms:.0f}ms)")
    print("\n".join(lines))
    if cfg_old != cfg_new:
        print("[compare] measurement config changed (localops, layout, "
              f"mode, reps): {cfg_old} -> {cfg_new}; ratios are "
              "cross-configuration — regression gate skipped")
        return 0
    if regressions:
        print(f"[compare] {len(regressions)} regression(s) over "
              f"{args.threshold:.2f}x: "
              + ", ".join("/".join(map(str, k)) for k in regressions),
              file=sys.stderr)
        return 1
    print("[compare] no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
