"""Render the roofline table from dry-run artifacts (EXPERIMENTS.md
SRoofline source of truth)."""

from __future__ import annotations

import json
import pathlib


def load(art_dir="artifacts/dryrun"):
    rows = []
    for p in sorted(pathlib.Path(art_dir).glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("status") == "ok":
            rows.append(rec)
    return rows


def render(rows, mesh="pod", include_graph=True):
    out = []
    hdr = ("| arch | shape | c (ms) | m (ms) | x (ms) | bottleneck | "
           "MODEL/HLO | HBM GB/dev |")
    out.append(hdr)
    out.append("|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["arch"].startswith("graph-") and not include_graph:
            continue
        # donated outputs alias inputs: HBM = args + temps
        hbm = (r.get("arg_bytes_per_device", 0)
               + r.get("temp_bytes_per_device", 0)) / 1e9
        out.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} "
            f"| {r['collective_s']*1e3:.1f} | {r['bottleneck']} "
            f"| {r.get('useful_flops_ratio', 0):.2f} | {hbm:.1f} |")
    return "\n".join(out)


def summarize(rows, mesh="pod"):
    """Per-cell roofline fraction = dominant-term share of an ideal
    perfectly-overlapped step: step_time >= max(c,m,x); fraction =
    max-term / sum-terms proxies how balanced the cell is."""
    worst = []
    for r in rows:
        if r["mesh"] != mesh or r["arch"].startswith("graph-"):
            continue
        c, m, x = r["compute_s"], r["memory_s"], r["collective_s"]
        tot = c + m + x
        dom = max(c, m, x)
        frac = c / dom  # compute share of the critical term
        worst.append((frac, r["arch"], r["shape"], r["bottleneck"]))
    worst.sort()
    return worst


def main():
    rows = load()
    print(render(rows))
    print("\nmost-skewed cells (lowest compute share of dominant term):")
    for frac, arch, shape, b in summarize(rows)[:6]:
        print(f"  {arch} x {shape}: compute/dominant = {frac:.3f} ({b})")


if __name__ == "__main__":
    main()
