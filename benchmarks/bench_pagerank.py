"""Figure 2: distributed PageRank runtime, BSP baseline (Boost-like) vs
the HPX-adapted implementation, across partition counts on urand graphs.
Variants are enumerated from the algorithm registry."""

from __future__ import annotations

import json
import pathlib

from benchmarks.bench_bfs import print_speedup_table
from benchmarks.graph_scaling import scaling_table


def main(graph: str = "urand16", parts=(1, 2, 4, 8), reps: int = 3,
         out: str = "artifacts/bench_pagerank.json"):
    print(f"[bench_pagerank] Figure 2 analogue on {graph}")
    rows = scaling_table(graph, "pagerank", parts_list=parts, reps=reps)
    pathlib.Path(out).parent.mkdir(parents=True, exist_ok=True)
    pathlib.Path(out).write_text(json.dumps(rows, indent=2))
    print_speedup_table(rows, parts)
    return rows


if __name__ == "__main__":
    main()
