"""Figure 1: distributed BFS runtime, BSP baseline (Boost-like) vs the
HPX-adapted implementation, across partition counts on urand graphs.
Variants are enumerated from the algorithm registry."""

from __future__ import annotations

import json
import pathlib

from benchmarks.graph_scaling import scaling_table


def print_speedup_table(rows, parts, baseline="bsp", fast="fast"):
    """Paper-style summary: speedup of fast over bsp per partition count."""
    by = {(r["mode"], r["parts"]): r for r in rows}
    if not all(((baseline, p) in by and (fast, p) in by) for p in parts):
        return
    print("parts,bsp_ms,fast_ms,speedup,wire_ratio")
    for p in parts:
        b, f = by[(baseline, p)], by[(fast, p)]
        wr = b["wire_bytes_per_part"] / max(f["wire_bytes_per_part"], 1)
        print(f"{p},{b['ms']:.1f},{f['ms']:.1f},"
              f"{b['ms']/f['ms']:.2f},{wr:.1f}x")


def main(graph: str = "urand16", parts=(1, 2, 4, 8), reps: int = 3,
         out: str = "artifacts/bench_bfs.json"):
    print(f"[bench_bfs] Figure 1 analogue on {graph}")
    rows = scaling_table(graph, "bfs", parts_list=parts, reps=reps)
    pathlib.Path(out).parent.mkdir(parents=True, exist_ok=True)
    pathlib.Path(out).write_text(json.dumps(rows, indent=2))
    print_speedup_table(rows, parts)
    return rows


if __name__ == "__main__":
    main()
