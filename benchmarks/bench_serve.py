"""Serve-path benchmark: closed-loop (program x bucket) cells through
the resident-engine GraphServer; writes ``BENCH_serve.json`` at the
repo root.

  PYTHONPATH=src python -m benchmarks.bench_serve [--fast]

Each cell floods one server (ladder pinned to a single bucket) with
``launches x bucket`` source queries and records queries/sec and
p50/p95/p99 admission-to-demux latency.  The ``bucket=1`` cell IS the
one-query-per-launch baseline, so ``qps(bucket=B) / qps(bucket=1)``
measures the coalescing win directly — the fast suite asserts the
batched-BFS ratio (recorded in the artifact's ``speedup`` section)
stays >= 3x.  Refresh programs (``cc``) bench as sequential shared
launches (``bucket=0``).

A final ``bucket="overload"`` row replays a bfs trace at 2x the
measured closed-loop capacity through a bounded-queue, deadlined
server: it records admitted qps / p99 plus ``shed`` and ``timed_out``
counts, and the subprocess asserts in-line that p99 of admitted
answers holds the deadline and that ok answers under overload stay
bit-identical to direct ``program()`` calls.

A ``bucket="recovery"`` row times restart recovery: a durable server
(WAL + snapshots, ``repro.serve.persist``) runs a short mutation trace,
is abandoned, and ``GraphServer.recover()`` rebuilds it from the
directory — the row records ``ttfok_ms`` (recover start to first ok
answer), epochs replayed from the WAL, and the snapshot epoch resumed
from, with in-line asserts that the recovered server lands on the exact
killed epoch and serves bit-identical bfs parents.

Like ``benchmarks/graph_scaling.py``, the measurement runs in ONE
subprocess so ``XLA_FLAGS=--xla_force_host_platform_device_count`` can
force the partition count before jax imports; the harness process never
imports jax.  ``benchmarks/compare.py`` gates the committed rows per
(algo, bucket) cell with the same threshold/jitter-floor/cross-config
rules as BENCH_graph.json.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
SRC = str(REPO_ROOT / "src")

# (algo, bucket) cells; bucket 0 = sequential shared refresh launches.
# 3 rooted algorithms x >= 2 bucket sizes + the bucket=1 baselines.
FAST_CELLS = [
    ("bfs", (1, 8, 32)),
    ("sssp", (1, 8, 32)),
    ("betweenness", (1, 8)),
    ("cc", (0,)),
]
FULL_CELLS = [
    ("bfs", (1, 8, 32, 128)),
    ("sssp", (1, 8, 32, 128)),
    ("betweenness", (1, 8, 32)),
    ("cc", (0,)),
    ("pagerank", (0,)),
]

_CELL_CODE = r"""
import json
from repro.configs import graph_workloads
from repro.core import GraphEngine, localops, partition_graph
from repro.core.compat import runtime_fingerprint
from repro.graphs import generate_edges
from repro.launch.mesh import make_graph_mesh
from repro.serve import GraphServer, Query, make_key

graph, parts, cells, launches = {graph!r}, {parts}, {cells!r}, {launches}
gcfg = graph_workloads.ALL[graph]
edges = generate_edges(gcfg, seed=42)
g = partition_graph(edges, gcfg.num_vertices, parts)
eng = GraphEngine(g, make_graph_mesh(parts))
print("META " + json.dumps({{
    "localops": localops.get_mode(), **runtime_fingerprint()}}))
rows_all = []
for algo, bucket in cells:
    key = make_key(algo)
    server = GraphServer(eng, buckets=(max(bucket, 1),))
    server.warmup([key])
    # small buckets run MORE launches so every cell carries similar
    # measurement mass (the bucket=1 baseline would otherwise be a
    # handful of ms of wall time - pure scheduler jitter)
    n_launch = launches if bucket == 0 else max(launches, 32 // bucket)
    if bucket == 0:
        for _ in range(n_launch):           # sequential shared refreshes
            server.serve([Query(key, None)])
    else:
        roots = [(7 * i) % gcfg.num_vertices
                 for i in range(n_launch * bucket)]
        server.serve([Query(key, r) for r in roots])
    (row,) = server.metrics.rows()
    rows_all.append(row)
    print("RESULT " + json.dumps(row))

# -- overload cell: a 2x-capacity bfs trace through a bounded-queue,
# deadlined server.  Offered rate = 2x the measured closed-loop qps of
# the same bucket, so the cell tracks "how gracefully does the server
# degrade": p99 of ADMITTED answers must hold the deadline (lapsed ones
# resolve timed_out, never recorded), the bounded queue sheds the rest,
# and every ok answer stays bit-identical to a direct program() call.
import numpy as np
import jax.numpy as jnp
from repro.serve import synthetic_trace

ob, deadline = {overload_bucket}, {deadline_s}
cap_qps = max(r["qps"] for r in rows_all
              if r["algo"].startswith("bfs") and r["bucket"] == ob)
server = GraphServer(eng, buckets=(ob,), max_queued=4 * ob,
                     default_deadline_s=deadline)
server.warmup([make_key("bfs")])
trace = synthetic_trace(gcfg.num_vertices, "bfs", rate=2.0 * cap_qps,
                        duration={overload_duration}, seed=99)
res = server.serve_trace(trace)
by_qid = {{q.qid: q for _, q in trace}}
garr, prog, checked = eng.device_graph(), eng.program("bfs"), 0
for r in res:
    if r.ok and checked < 8:
        p, _ = prog(garr, jnp.int32(by_qid[r.qid].root))
        assert (np.asarray(r["parents"])
                == eng.gather_vertex_field(p)).all(), \
            "overload ok answer differs from direct program() call"
        checked += 1
assert checked > 0, "overload trace produced no ok answers"
(orow,) = server.metrics.rows()
assert orow["p99_ms"] <= deadline * 1e3, \
    "p99 of admitted answers exceeds the deadline"
orow = dict(orow, bucket="overload",
            offered_qps=round(2.0 * cap_qps, 1),
            shed=server.metrics.counts["shed"],
            timed_out=server.metrics.counts["timed_out"],
            deadline_s=deadline)
print("RESULT " + json.dumps(orow))

# -- recovery cell: a durable server runs a short mutation trace, is
# abandoned mid-flight (the live object stands in for a killed
# process - the on-disk WAL/snapshot state is identical either way),
# and GraphServer.recover() restarts from the directory.  ttfok =
# recover() start to the first ok answer off the recovered server,
# asserted in-line to land on the exact killed epoch with the bfs
# parents bit-identical to the pre-kill server's.
import tempfile, time
from repro.serve import Persistence

pdir = tempfile.mkdtemp(prefix="bench-recovery-")
dserver = GraphServer(eng, buckets=(8,), persistence=Persistence(
    dir=pdir, snapshot_every=4, fsync=False))
dserver.warmup([make_key("bfs")])
rng = np.random.default_rng(7)
for _ in range(3):
    dserver.mutate(deletes=dserver.dynamic_graph()
                   .sample_deletable(16, rng))
    dserver.mutate(inserts=dserver.dynamic_graph()
                   .sample_insertable(16, rng))
    live = dserver.serve([Query(make_key("bfs"), 3)])
killed_epoch = dserver.epoch
t0 = time.perf_counter()
rec = GraphServer.recover(pdir, buckets=(8,))
res = rec.serve([Query(make_key("bfs"), 3)])
ttfok = time.perf_counter() - t0
assert rec.epoch == killed_epoch and res[0].ok, \
    (rec.epoch, killed_epoch, res[0].status)
assert (np.asarray(res[0]["parents"])
        == np.asarray(live[0]["parents"])).all(), \
    "recovered answer differs from the pre-kill server's"
rep = rec.recovery_report
ms = round(ttfok * 1e3, 1)
print("RESULT " + json.dumps({{
    "algo": "bfs_fast", "bucket": "recovery", "count": 1,
    "qps": round(1.0 / ttfok, 3),
    "p50_ms": ms, "p95_ms": ms, "p99_ms": ms, "ttfok_ms": ms,
    "epochs_replayed": rep.replayed, "wal_records": rep.wal_records,
    "snapshot_epoch": rep.snapshot_epoch}}))

# -- obs session: a SHORT traced replay on its OWN server, so every
# gated cell above ran un-instrumented (tracing on the timed path
# would be a confound).  Its span summary rides the artifact as
# informational context; compare.py never gates on it.
from repro.obs import SpanRecorder, trace_summary
orec = SpanRecorder()
oserver = GraphServer(eng, buckets=(8,), obs=orec)
oserver.warmup([make_key("bfs")])
oserver.serve([Query(make_key("bfs"), (13 * i) % gcfg.num_vertices)
               for i in range(16)])
print("TRACE " + json.dumps(trace_summary(orec)))
"""


def run_cells(graph: str, parts: int, cells, launches: int,
              overload_duration: float = 0.5):
    flat = [(a, b) for a, bs in cells for b in bs]
    code = _CELL_CODE.format(graph=graph, parts=parts, cells=flat,
                             launches=launches, overload_bucket=8,
                             deadline_s=0.25,
                             overload_duration=overload_duration)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={parts} "
                        + env.get("XLA_FLAGS", "")).strip()
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(
            f"serve bench subprocess failed:\n{proc.stdout[-2000:]}\n"
            f"{proc.stderr[-4000:]}")
    rows, meta, trace_sum = [], {}, None
    for line in proc.stdout.splitlines():
        if line.startswith("META "):
            meta = json.loads(line[len("META "):])
        elif line.startswith("RESULT "):
            rows.append(json.loads(line[len("RESULT "):]))
        elif line.startswith("TRACE "):
            trace_sum = json.loads(line[len("TRACE "):])
    return rows, meta, trace_sum


def speedup_section(rows: list[dict], algo_label: str = "bfs_fast") -> dict:
    """Coalesced-vs-single throughput for one program's ladder."""
    cells = {r["bucket"]: r["qps"] for r in rows
             if r["algo"] == algo_label and isinstance(r["bucket"], int)}
    if 1 not in cells or len(cells) < 2:
        return {}
    top = max(b for b in cells if b != 1)
    return {"algo": algo_label, "bucket": top,
            "single_qps": cells[1], "coalesced_qps": cells[top],
            "speedup": round(cells[top] / max(cells[1], 1e-9), 2)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller graph / fewer launches (CI mode)")
    ap.add_argument("--graph", default=None,
                    help="override the suite's graph config")
    ap.add_argument("--parts", type=int, default=2)
    ap.add_argument("--launches", type=int, default=None,
                    help="coalesced launches per cell")
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_serve.json"))
    ap.add_argument("--speedup-floor", type=float, default=3.0,
                    help="exit non-zero when the coalesced-vs-single "
                         "bfs qps ratio falls below this (the PR-5 "
                         "acceptance floor; 0 disables)")
    args = ap.parse_args(argv)

    graph = args.graph or ("urand12" if args.fast else "urand16")
    launches = args.launches or (3 if args.fast else 6)
    cells = FAST_CELLS if args.fast else FULL_CELLS

    print(f"[bench_serve] {graph} parts={args.parts} "
          f"launches/cell={launches} "
          f"cells={[(a, list(b)) for a, b in cells]}")
    rows, sub_meta, trace_sum = run_cells(
        graph, args.parts, cells, launches,
        overload_duration=0.5 if args.fast else 1.0)
    for r in rows:
        b = str(r["bucket"]) if r["bucket"] else "shared"
        if r["bucket"] == "overload":
            extra = (f" shed={r['shed']} timed_out={r['timed_out']} "
                     f"offered={r['offered_qps']:.0f}q/s")
        elif r["bucket"] == "recovery":
            extra = (f" ttfok={r['ttfok_ms']:.0f}ms "
                     f"replayed={r['epochs_replayed']} "
                     f"snapshot_epoch={r['snapshot_epoch']}")
        else:
            extra = ""
        print(f"[bench_serve] {r['algo']:16s} bucket={b:>8s} "
              f"qps={r['qps']:8.1f} p50={r['p50_ms']:7.1f}ms "
              f"p99={r['p99_ms']:7.1f}ms" + extra)

    speedup = speedup_section(rows)
    below_floor = (speedup and args.speedup_floor
                   and speedup["speedup"] < args.speedup_floor)
    if speedup:
        print(f"[bench_serve] coalescing win ({speedup['algo']} bucket "
              f"{speedup['bucket']} vs 1): {speedup['speedup']:.1f}x "
              f"({speedup['coalesced_qps']:.1f} vs "
              f"{speedup['single_qps']:.1f} q/s)"
              + (f"  <-- BELOW the {args.speedup_floor:.0f}x acceptance "
                 "floor" if below_floor else ""))

    meta = {"graph": graph, "parts": args.parts, "launches": launches,
            "mode": "fast" if args.fast else "full", "layout": "ell",
            "localops": sub_meta.get(
                "localops", os.environ.get("REPRO_LOCALOPS", "auto")),
            "jax": sub_meta.get("jax"), "device": sub_meta.get("device")}
    payload = {"meta": meta, "rows": rows, "speedup": speedup}
    if trace_sum is not None:
        # span summary of the short traced session (separate server —
        # the gated cells ran un-instrumented); informational only,
        # compare.py ignores it
        payload["trace_summary"] = trace_sum
        print(f"[bench_serve] obs session: {trace_sum['spans_total']} "
              f"spans, top p99: "
              + ", ".join(f"{r['kind']}={r['p99_ms']:.2f}ms"
                          for r in trace_sum["top_p99_ms"]))
    pathlib.Path(args.out).write_text(
        json.dumps(payload, indent=2) + "\n")
    print(f"[bench_serve] wrote {args.out} ({len(rows)} rows)")
    if below_floor:
        print(f"[bench_serve] FAIL: coalescing speedup "
              f"{speedup['speedup']:.2f}x < floor "
              f"{args.speedup_floor:.1f}x", file=sys.stderr)
        return 3
    return 0


if __name__ == "__main__":
    sys.exit(main())
