"""Shared machinery for the paper's two figures (BFS / PageRank scaling).

Each (algorithm, variant, partitions) point runs in a subprocess with
that many forced host devices, times the jitted program (median of
reps), and reports the per-partition collective wire bytes parsed from
the compiled HLO - wall time on emulated devices is indicative; wire
bytes are exact.  Programs are resolved through the algorithm registry
(``repro.core.registry``) so new variants show up in the figures without
editing the harness.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# per-(algo, variant) parameter overrides for the bench points
_BENCH_PARAMS = {
    # fast mode benches the steady-state compressed exchange ("always");
    # the adaptive variant's HLO contains both branches and the parser
    # prices the worst one, hiding the bf16 win
    ("pagerank", "fast"): {"iters": 30, "tol": 1e-12, "compress": "always"},
    ("pagerank", "bsp"): {"iters": 30, "tol": 1e-12},
    # async rides the same fixed 30-round budget (tol below reach) so
    # its bsp-vs-async row pair differs only in the superstep driver
    ("pagerank", "async"): {"iters": 30, "tol": 1e-12},
}

_POINT_CODE = r"""
import json, time
import jax, jax.numpy as jnp
from repro.graphs import generate_edges
from repro.configs import graph_workloads
from repro.core import GraphEngine, partition_graph, registry
from repro.launch.mesh import make_graph_mesh
from repro.roofline import analysis as RA

graph, algo, variant, parts, reps = {graph!r}, {algo!r}, {variant!r}, {parts}, {reps}
params = {params!r}
gcfg = graph_workloads.ALL[graph]
edges = generate_edges(gcfg, seed=42)
g = partition_graph(edges, gcfg.num_vertices, parts)
eng = GraphEngine(g, make_graph_mesh(parts))
garr = eng.device_graph()
spec = registry.get_spec(algo, variant)
prog = eng.program(algo, variant, **params)
args = (garr,) + (jnp.int32(0),) * len(spec.inputs)
compiled = prog.aot()
stats = RA.parse_collectives(compiled.as_text())
wire = stats.total_wire_bytes
if (algo, variant) == ("pagerank", "fast"):
    # bf16 payload promoted to f32 by the host backend (see DESIGN S7)
    rs = stats.wire_bytes.get("reduce-scatter", 0.0)
    wire -= rs / 2.0
out = prog(*args); jax.block_until_ready(out)   # warm
times = []
for _ in range(reps):
    t0 = time.perf_counter()
    out = prog(*args); jax.block_until_ready(out)
    times.append(time.perf_counter() - t0)
times.sort()
# separate telemetry build AFTER the timed reps (its own compile-cache
# entry; the headline ms stays the un-instrumented number).  The summary
# rides the row as INFORMATIONAL context — compare.py never gates on it.
tprog = eng.program(algo, variant, telemetry=True, **params)
tout = tprog(*args)
telemetry = tprog.run_telemetry(tout[-1]).summary()
print("RESULT " + json.dumps({{
    "graph": graph, "algo": algo, "mode": variant, "parts": parts,
    "ms": times[len(times)//2] * 1e3,
    "wire_bytes_per_part": wire,
    "rounds": int(out[-1]),
    "collective_counts": stats.counts,
    "telemetry": telemetry,
}}))
"""


def algo_variants(algo: str) -> list[str]:
    """Registered variants of ``algo`` whose inputs are all scalar, read
    in a subprocess so the harness process never imports jax (each bench
    point must set its own XLA_FLAGS device count before first jax
    import).  Seeded incremental variants are excluded — their bench
    lives in bench_mutate.py where a previous epoch exists to seed from;
    a cold-seeded run here would just re-measure the static variant."""
    code = ("import json\nfrom repro.core import registry\n"
            f"print(json.dumps([v for v in registry.variants({algo!r}) "
            f"if all(k == 'scalar' for k in "
            f"registry.get_spec({algo!r}, v).input_kinds)]))")
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=120)
    if r.returncode != 0:
        raise RuntimeError(f"registry peek failed:\n{r.stderr[-2000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def run_point(graph: str, algo: str, variant: str, parts: int,
              reps: int = 3, timeout: int = 900) -> dict:
    params = _BENCH_PARAMS.get((algo, variant), {})
    code = _POINT_CODE.format(graph=graph, algo=algo, variant=variant,
                              parts=parts, reps=reps, params=params)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={parts}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    for line in r.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(
        f"bench point failed ({graph},{algo},{variant},{parts}):\n"
        f"{r.stdout[-2000:]}\n{r.stderr[-2000:]}")


def scaling_table(graph: str, algo: str, parts_list=(1, 2, 4, 8),
                  reps: int = 3, variants=None) -> list[dict]:
    rows = []
    for variant in (variants or algo_variants(algo)):
        for p in parts_list:
            rows.append(run_point(graph, algo, variant, p, reps=reps))
            r = rows[-1]
            print(f"  {algo}/{variant:4s} parts={p:2d} {r['ms']:9.1f} ms  "
                  f"wire/part {r['wire_bytes_per_part']/1e6:8.2f} MB  "
                  f"rounds {r['rounds']:3d}")
    return rows
