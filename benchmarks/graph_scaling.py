"""Shared machinery for the paper's two figures (BFS / PageRank scaling).

Each (algorithm, partitions) point runs in a subprocess with that many
forced host devices, times the jitted program (median of reps), and
reports the per-partition collective wire bytes parsed from the compiled
HLO - wall time on emulated devices is indicative; wire bytes are exact.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

_POINT_CODE = r"""
import json, time
import jax, jax.numpy as jnp
from repro.graphs import generate_edges
from repro.configs import graph_workloads
from repro.core import GraphEngine, partition_graph
from repro.launch.mesh import make_graph_mesh
from repro.roofline import analysis as RA

graph, algo, mode, parts, reps = {graph!r}, {algo!r}, {mode!r}, {parts}, {reps}
gcfg = graph_workloads.ALL[graph]
edges = generate_edges(gcfg, seed=42)
g = partition_graph(edges, gcfg.num_vertices, parts)
eng = GraphEngine(g, make_graph_mesh(parts))
garr = eng.device_graph()
if algo == "bfs":
    fn = eng.bfs(mode=mode)
    args = (garr, jnp.int32(0))
else:
    # fast mode benches the steady-state compressed exchange ("always");
    # the adaptive variant's HLO contains both branches and the parser
    # prices the worst one, hiding the bf16 win
    fn = eng.pagerank(mode=mode, iters=30, tol=1e-12,
                      compress=("always" if mode == "fast" else False))
    args = (garr,)
lowered = fn.lower(*args)
compiled = lowered.compile()
stats = RA.parse_collectives(compiled.as_text())
wire = stats.total_wire_bytes
if algo == "pagerank" and mode == "fast":
    # bf16 payload promoted to f32 by the host backend (see DESIGN S7)
    rs = stats.wire_bytes.get("reduce-scatter", 0.0)
    wire -= rs / 2.0
out = fn(*args); jax.block_until_ready(out)   # warm
times = []
for _ in range(reps):
    t0 = time.perf_counter()
    out = fn(*args); jax.block_until_ready(out)
    times.append(time.perf_counter() - t0)
times.sort()
print("RESULT " + json.dumps({{
    "graph": graph, "algo": algo, "mode": mode, "parts": parts,
    "ms": times[len(times)//2] * 1e3,
    "wire_bytes_per_part": wire,
    "collective_counts": stats.counts,
}}))
"""


def run_point(graph: str, algo: str, mode: str, parts: int,
              reps: int = 3, timeout: int = 900) -> dict:
    code = _POINT_CODE.format(graph=graph, algo=algo, mode=mode,
                              parts=parts, reps=reps)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={parts}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    for line in r.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(
        f"bench point failed ({graph},{algo},{mode},{parts}):\n"
        f"{r.stdout[-2000:]}\n{r.stderr[-2000:]}")


def scaling_table(graph: str, algo: str, parts_list=(1, 2, 4, 8),
                  reps: int = 3) -> list[dict]:
    rows = []
    for mode in ("bsp", "fast"):
        for p in parts_list:
            rows.append(run_point(graph, algo, mode, p, reps=reps))
            r = rows[-1]
            print(f"  {algo}/{mode:4s} parts={p:2d} {r['ms']:9.1f} ms  "
                  f"wire/part {r['wire_bytes_per_part']/1e6:8.2f} MB")
    return rows
