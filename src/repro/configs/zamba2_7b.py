"""Zamba2-7B: hybrid Mamba2 backbone + shared attention blocks.

Adaptation note (DESIGN.md §Arch-applicability): Zamba2 interleaves two
shared transformer blocks with per-invocation LoRA deltas; we model a
single shared attention+MLP block applied every ``hybrid_attn_every``
SSM layers, which preserves the parameter-sharing structure and the
compute/communication shape.

[arXiv:2411.15242; unverified]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,             # mamba2 blocks
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,           # shared block is MHA
    head_dim=112,              # 3584 / 32
    d_ff=14336,                # shared block MLP
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    ssm_conv=4,
    ssm_groups=1,
    hybrid_attn_every=6,       # shared attn block before every 6th mamba layer
    tie_embeddings=True,
    norm="rmsnorm",
    act="swiglu",
    supports_long_context=True,   # SSM-dominated -> run long_500k
    notes="Mamba2 + shared attn blocks",
    source="arXiv:2411.15242",
)
