"""Mamba2-1.3B: attention-free SSD (state-space duality).

[arXiv:2405.21060; unverified]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    ssm_conv=4,
    ssm_groups=1,
    tie_embeddings=True,
    norm="rmsnorm",
    supports_long_context=True,    # O(1)-state decode -> run long_500k
    notes="SSD (state-space duality); attention-free",
    source="arXiv:2405.21060",
)
