"""InternVL2-1B: InternViT frontend (STUB) + InternLM2 LM backbone.

The vision frontend is a stub: ``input_specs()`` provides precomputed
patch embeddings of shape (batch, vision_tokens, d_model) which the model
prepends to the token embeddings.

[arXiv:2404.16821; hf]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    vision_tokens=256,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    norm="rmsnorm",
    act="swiglu",
    supports_long_context=False,   # full attention -> skip long_500k
    notes="InternViT stub + InternLM2 backbone",
    source="arXiv:2404.16821",
)
