"""Gemma3-27B: dense, 5:1 local:global attention, 128k context, 262k vocab.

[hf:google/gemma-3-1b-pt; unverified]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    sliding_window=1024,       # local layers
    global_every=6,            # every 6th layer is global (5:1 local:global)
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    act="swiglu",               # gemma uses gelu-glu; swiglu is the same cost/shape
    max_seq_len=131_072,
    supports_long_context=True,   # 5:1 local:global -> decode cache mostly O(window)
    notes="5:1 local:global, 128k context",
    source="hf:google/gemma-3-1b-pt",
)
