"""Whisper-small: encoder-decoder, conv audio frontend (STUB per assignment).

The modality frontend is a stub: ``input_specs()`` provides precomputed
frame embeddings of shape (batch, encoder_seq, d_model).

[arXiv:2212.04356; unverified]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,             # decoder layers
    encoder_layers=12,
    encoder_seq=1500,          # 30s of audio at 50 frames/s (stub embeddings)
    cross_attention=True,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,           # MHA
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    rope_theta=10_000.0,       # positions: we use RoPE in place of learned-abs (noted in DESIGN.md)
    norm="layernorm",
    act="gelu",
    supports_long_context=False,   # full attention -> skip long_500k
    notes="enc-dec, conv frontend stubbed to precomputed frame embeddings",
    source="arXiv:2212.04356",
)
