from repro.configs.base import (
    GraphConfig,
    LM_SHAPES,
    ModelConfig,
    ShapeConfig,
    TrainConfig,
    shapes_for,
)

__all__ = [
    "GraphConfig",
    "LM_SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "TrainConfig",
    "shapes_for",
]
