"""H2O-Danube3-4B: dense llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; unverified]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    head_dim=120,              # 3840 / 32 (not MXU-aligned; padded in kernels)
    d_ff=10240,
    vocab_size=32000,
    sliding_window=4096,       # mistral-style SWA
    rope_theta=10_000.0,
    norm="rmsnorm",
    act="swiglu",
    supports_long_context=True,   # SWA => O(window) decode cache -> run long_500k
    notes="llama+mistral mix, SWA",
    source="arXiv:2401.16818",
)
