"""Configuration dataclasses for models, shapes, and graph workloads.

Every assigned architecture is expressed as a ``ModelConfig``.  The same
config drives model construction, parameter sharding, the multi-pod
dry-run, and the roofline analysis, so it must be complete enough to
derive parameter counts and FLOP estimates without instantiating weights.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence


@dataclass(frozen=True)
class ModelConfig:
    """Unified architecture description covering all assigned families."""

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention flavour ---
    attn_bias: bool = False          # qwen-style QKV bias
    rope_theta: float = 10_000.0
    sliding_window: int = 0          # 0 = full attention
    global_every: int = 0            # gemma3: every Nth layer is global, rest local
    attn_logit_softcap: float = 0.0

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_groups: int = 1

    # --- hybrid (zamba2): shared attention block applied every N SSM layers ---
    hybrid_attn_every: int = 0

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0             # precomputed frame embeddings (frontend stub)
    cross_attention: bool = False

    # --- VLM (internvl): patch embeddings prepended (frontend stub) ---
    vision_tokens: int = 0

    # --- misc ---
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "swiglu"              # swiglu | gelu  (gelu => single up proj)
    tie_embeddings: bool = False
    max_seq_len: int = 131_072
    dtype: str = "bfloat16"

    # which assigned shapes the arch supports (skips recorded in DESIGN.md)
    supports_long_context: bool = False   # sub-quadratic / SWA / SSM only
    supports_decode: bool = True

    notes: str = ""
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ------------------------------------------------------------------
    # Derived quantities (used by roofline + memory budgeting)
    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    def attn_params_per_layer(self) -> int:
        d, hd = self.d_model, self.head_dim
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        bias = (self.num_heads + 2 * self.num_kv_heads) * hd if self.attn_bias else 0
        return q + kv + o + bias

    def mlp_params(self, d_ff: int) -> int:
        n_in = 2 if self.act == "swiglu" else 1
        return (n_in + 1) * self.d_model * d_ff

    def ssm_params_per_layer(self) -> int:
        d, di, st = self.d_model, self.d_inner, self.ssm_state
        nh = self.ssm_nheads
        # in_proj -> [z, x, B, C, dt], conv on (x,B,C), out_proj, A/D/dt_bias/norm
        in_proj = d * (2 * di + 2 * self.ssm_groups * st + nh)
        conv = self.ssm_conv * (di + 2 * self.ssm_groups * st)
        out_proj = di * d
        extras = 3 * nh + di
        return in_proj + conv + out_proj + extras

    def params_total(self) -> int:
        """Total parameter count (embedding + all blocks + final norm/head)."""
        d = self.d_model
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        total = emb + head + d  # final norm
        norm_per_block = 2 * d

        if self.family in ("dense", "vlm"):
            per = self.attn_params_per_layer() + self.mlp_params(self.d_ff) + norm_per_block
            total += self.num_layers * per
        elif self.family == "moe":
            moe = self.num_experts * self.mlp_params(self.d_ff) + d * self.num_experts
            per = self.attn_params_per_layer() + moe + norm_per_block
            total += self.num_layers * per
        elif self.family == "ssm":
            total += self.num_layers * (self.ssm_params_per_layer() + d)
        elif self.family == "hybrid":
            total += self.num_layers * (self.ssm_params_per_layer() + d)
            # one shared attention+MLP block (parameters counted once)
            total += self.attn_params_per_layer() + self.mlp_params(self.d_ff) + norm_per_block
        elif self.family == "audio":
            enc = self.encoder_layers * (
                self.attn_params_per_layer() + self.mlp_params(self.d_ff) + norm_per_block
            )
            dec_per = (
                2 * self.attn_params_per_layer()  # self + cross
                + self.mlp_params(self.d_ff)
                + 3 * d
            )
            total += enc + self.num_layers * dec_per
        else:
            raise ValueError(f"unknown family {self.family}")
        return total

    def params_active(self) -> int:
        """Active parameters per token (MoE: only routed experts count)."""
        if self.family != "moe":
            return self.params_total()
        d = self.d_model
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        per = (
            self.attn_params_per_layer()
            + self.num_experts_per_tok * self.mlp_params(self.d_ff)
            + d * self.num_experts
            + 2 * d
        )
        return emb + head + d + self.num_layers * per


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell: what program to lower and at what size."""

    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


# The four assigned LM shapes (identical across architectures).
TRAIN_4K = ShapeConfig("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)

LM_SHAPES: Sequence[ShapeConfig] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ModelConfig) -> list[ShapeConfig]:
    """Shapes applicable to an architecture (skips per the assignment rules)."""
    out = []
    for s in LM_SHAPES:
        if s.name == "long_500k" and not cfg.supports_long_context:
            continue  # pure full-attention arch: noted in DESIGN.md
        if s.kind == "decode" and not cfg.supports_decode:
            continue
        out.append(s)
    return out


@dataclass(frozen=True)
class GraphConfig:
    """Paper-side workload: an Erdos-Renyi ('urand') or RMAT graph."""

    name: str
    scale: int                # 2**scale vertices
    avg_degree: int = 16
    generator: str = "urand"  # urand | rmat
    directed: bool = True

    @property
    def num_vertices(self) -> int:
        return 1 << self.scale

    @property
    def num_edges(self) -> int:
        return self.num_vertices * self.avg_degree


@dataclass(frozen=True)
class TrainConfig:
    """Training-loop hyperparameters (optimizer, schedule, fault tolerance)."""

    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    seed: int = 0
    remat: bool = True
    grad_accum: int = 1              # microbatches per step (activation memory / N)
    grad_compression: str = "none"   # none | int8_ef
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3


def scaled(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Produce a reduced config of the same family (used by smoke tests)."""
    return dataclasses.replace(cfg, **overrides)
