"""Paper-side workload configs: urand (Erdos-Renyi) graphs as in §5.

The paper evaluates BFS and PageRank on 'urand' graphs of varying scale
(urand25 has 2^25 vertices) on up to 32 nodes.  These configs drive the
benchmark harness (Figures 1 and 2) and the graph-engine dry-run.
"""

from repro.configs.base import GraphConfig

# Benchmark-scale graphs (runnable on this container).  urand12 is the
# bench point for the dense-bitmap algorithms (triangle counting is
# O(n^2/P) memory; see ProgramSpec.n_budget).
URAND12 = GraphConfig("urand12", scale=12)
URAND16 = GraphConfig("urand16", scale=16)
URAND18 = GraphConfig("urand18", scale=18)
URAND20 = GraphConfig("urand20", scale=20)

# Small-world (Watts-Strogatz): the high-clustering family of the
# oracle-conformance gate, at benchmark scale for the launcher.
SW12 = GraphConfig("sw12", scale=12, generator="smallworld")
SW16 = GraphConfig("sw16", scale=16, generator="smallworld")

# Paper-scale graphs (dry-run / production targets)
URAND22 = GraphConfig("urand22", scale=22)
URAND25 = GraphConfig("urand25", scale=25)
URAND28 = GraphConfig("urand28", scale=28)

# RMAT (GAP 'kron'-style) for skewed-degree stress.  rmat12/rmat16 are
# the benchmark-scale points (runnable here, same rungs as urand12/16);
# the skewed tail is what stresses the blocked-ELL bucket ladder and
# the dynamic-graph free-slot pools.
RMAT12 = GraphConfig("rmat12", scale=12, generator="rmat")
RMAT16 = GraphConfig("rmat16", scale=16, generator="rmat")
RMAT18 = GraphConfig("rmat18", scale=18, generator="rmat")
RMAT20 = GraphConfig("rmat20", scale=20, generator="rmat")

ALL = {
    g.name: g
    for g in (URAND12, URAND16, URAND18, URAND20, URAND22, URAND25,
              URAND28, RMAT12, RMAT16, RMAT18, RMAT20, SW12, SW16)
}
