"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""

from __future__ import annotations

import dataclasses

from repro.configs import (
    dbrx_132b,
    gemma3_27b,
    h2o_danube3_4b,
    internvl2_1b,
    mamba2_1_3b,
    phi35_moe_42b,
    qwen2_5_32b,
    tinyllama_1_1b,
    whisper_small,
    zamba2_7b,
)
from repro.configs.base import LM_SHAPES, ModelConfig, ShapeConfig, shapes_for

_MODULES = (
    dbrx_132b,
    phi35_moe_42b,
    mamba2_1_3b,
    h2o_danube3_4b,
    gemma3_27b,
    qwen2_5_32b,
    tinyllama_1_1b,
    whisper_small,
    internvl2_1b,
    zamba2_7b,
)

ARCHS: dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}

SHAPES: dict[str, ShapeConfig] = {s.name: s for s in LM_SHAPES}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


def all_cells() -> list[tuple[ModelConfig, ShapeConfig]]:
    """Every applicable (architecture x shape) pair."""
    cells = []
    for cfg in ARCHS.values():
        for s in shapes_for(cfg):
            cells.append((cfg, s))
    return cells


def skipped_cells() -> list[tuple[str, str, str]]:
    """(arch, shape, reason) for assignment cells skipped per the rules."""
    out = []
    for cfg in ARCHS.values():
        valid = {s.name for s in shapes_for(cfg)}
        for s in LM_SHAPES:
            if s.name not in valid:
                reason = (
                    "pure full-attention arch: long_500k needs sub-quadratic attention"
                    if s.name == "long_500k"
                    else "arch has no decode step"
                )
                out.append((cfg.name, s.name, reason))
    return out


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests: same family/topology, tiny sizes.
# ---------------------------------------------------------------------------
_SMOKE_OVERRIDES: dict[str, dict] = {
    "dbrx-132b": dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      head_dim=16, d_ff=96, vocab_size=256, num_experts=4,
                      num_experts_per_tok=2),
    "phi3.5-moe-42b-a6.6b": dict(num_layers=2, d_model=64, num_heads=4,
                                 num_kv_heads=2, head_dim=16, d_ff=96,
                                 vocab_size=256, num_experts=4,
                                 num_experts_per_tok=2),
    "mamba2-1.3b": dict(num_layers=2, d_model=64, vocab_size=256, ssm_state=16,
                        ssm_head_dim=16, ssm_chunk=32),
    "h2o-danube-3-4b": dict(num_layers=2, d_model=64, num_heads=4,
                            num_kv_heads=2, head_dim=16, d_ff=128,
                            vocab_size=256, sliding_window=32),
    "gemma3-27b": dict(num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
                       head_dim=16, d_ff=128, vocab_size=512,
                       sliding_window=16, global_every=2),
    "qwen2.5-32b": dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                        head_dim=16, d_ff=128, vocab_size=256),
    "tinyllama-1.1b": dict(num_layers=2, d_model=64, num_heads=4,
                           num_kv_heads=2, head_dim=16, d_ff=128,
                           vocab_size=256),
    "whisper-small": dict(num_layers=2, encoder_layers=2, encoder_seq=24,
                          d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
                          d_ff=128, vocab_size=256),
    "internvl2-1b": dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                         head_dim=16, d_ff=128, vocab_size=256,
                         vision_tokens=8),
    "zamba2-7b": dict(num_layers=6, d_model=64, num_heads=4, num_kv_heads=4,
                      head_dim=16, d_ff=128, vocab_size=256, ssm_state=16,
                      ssm_head_dim=16, ssm_chunk=32, hybrid_attn_every=3),
}


def smoke_config(name: str) -> ModelConfig:
    cfg = get_arch(name)
    return dataclasses.replace(cfg, **_SMOKE_OVERRIDES[name])


SMOKE_SHAPE = ShapeConfig("smoke", "train", seq_len=64, global_batch=2)
