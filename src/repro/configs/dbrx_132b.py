"""DBRX-132B: fine-grained MoE, 16 experts top-4, GQA kv=8.

[hf:databricks/dbrx-base; unverified]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,                # per-expert FFN width
    vocab_size=100352,
    num_experts=16,
    num_experts_per_tok=4,
    rope_theta=500_000.0,
    norm="layernorm",
    act="swiglu",
    supports_long_context=False,   # pure full attention -> skip long_500k
    notes="16 experts top-4, fine-grained MoE; every layer is MoE",
    source="hf:databricks/dbrx-base",
)
