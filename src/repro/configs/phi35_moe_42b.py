"""Phi-3.5-MoE 42B (6.6B active): 16 experts top-2, GQA kv=8.

[hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,                 # per-expert FFN width
    vocab_size=32064,
    num_experts=16,
    num_experts_per_tok=2,
    rope_theta=10_000.0,
    norm="layernorm",
    act="swiglu",
    supports_long_context=False,   # full attention -> skip long_500k
    notes="16 experts top-2",
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
