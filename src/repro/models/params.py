"""Parameter-spec system: declare shapes + logical axes once, derive
materialized params, ShapeDtypeStructs (dry-run) and NamedShardings from
the same tree.

A model definition builds a pytree of ``ParamSpec`` leaves.  From it we
can (a) initialize real weights, (b) produce ShapeDtypeStruct stand-ins
for AOT lowering without touching device memory, and (c) resolve logical
axes to mesh axes for pjit in_shardings.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]          # logical axis name per dim (or None)
    init: str = "normal"                     # normal | zeros | ones | small_normal
    scale: float = 0.02
    dtype: jnp.dtype = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def spec(shape, axes, init="normal", scale=0.02, dtype=jnp.float32) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), init, scale, dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def init_params(spec_tree, key):
    """Materialize a spec tree into real fp32 parameters."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))

    def make(s: ParamSpec, k):
        if s.init == "zeros":
            return jnp.zeros(s.shape, s.dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, s.dtype)
        if s.init == "arange_neg":  # mamba A_log init: log(arange(1, n+1))
            n = s.shape[-1]
            base = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
            return jnp.broadcast_to(base, s.shape).astype(s.dtype)
        std = s.scale
        return (jax.random.normal(k, s.shape, jnp.float32) * std).astype(s.dtype)

    return jax.tree.unflatten(treedef, [make(s, k) for s, k in zip(leaves, keys)])


def abstract_params(spec_tree):
    """ShapeDtypeStruct tree for AOT lowering (no allocation)."""
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree
    )


def param_count(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))


# ---------------------------------------------------------------------------
# Logical-axis -> mesh-axis resolution
# ---------------------------------------------------------------------------

# Default rules for the production 2D/3D mesh.  "fsdp" (embed dim) shards
# parameters over the data axis (ZeRO-3 style); "tp" dims shard over model.
DEFAULT_RULES: dict[str, str] = {
    "embed": "data",        # FSDP axis (ZeRO-3)
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ffn": "model",
    "moe_ffn": "data",      # 2-D expert sharding: no weight gathers
    "experts": "model",     # expert parallelism
    "ssm_inner": "model",
    "ssm_heads": "model",
    "ssm_state": None,
    "conv": None,
    "enc_seq": None,
}


def resolve_axes(s: ParamSpec, rules: dict, mesh: jax.sharding.Mesh):
    """Map logical axes to mesh axes.

    Argument shardings must divide evenly (jit in_shardings rejects
    padding), so a dim that is not a multiple of the mesh-axis size is
    replicated instead - e.g. qwen2.5's 40 heads or odd vocab sizes on a
    16-wide model axis.  The padded-sharding variant for such dims is a
    recorded perf iteration (EXPERIMENTS.md SPerf).
    """
    out = []
    for dim, name in zip(s.shape, s.axes):
        mesh_axis = rules.get(name) if name else None
        if mesh_axis is None or mesh_axis not in mesh.shape:
            out.append(None)
            continue
        size = mesh.shape[mesh_axis]
        if dim % size == 0:
            out.append(mesh_axis)
        else:
            out.append(None)
    # A mesh axis may appear at most once in a partition spec.
    seen = set()
    dedup = []
    for a in out:
        if a is not None and a in seen:
            dedup.append(None)
        else:
            dedup.append(a)
            if a is not None:
                seen.add(a)
    return tuple(dedup)


def param_shardings(spec_tree, mesh: jax.sharding.Mesh, rules: Optional[dict] = None):
    """NamedSharding tree matching the spec tree."""
    rules = dict(DEFAULT_RULES if rules is None else rules)
    P = jax.sharding.PartitionSpec

    def one(s: ParamSpec):
        return jax.sharding.NamedSharding(mesh, P(*resolve_axes(s, rules, mesh)))

    return tree_map_specs(one, spec_tree)


def replicated_sharding(mesh: jax.sharding.Mesh):
    return jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
