"""Unified model assembly for all assigned architectures.

A config is compiled into a *layer plan*: an ordered list of homogeneous
segments, each scanned with ``jax.lax.scan`` over stacked parameters
(keeping HLO size O(#segments), not O(#layers)).  Segment kinds:

  attn        -- GQA attention + MLP block   (dense / vlm; window per segment)
  moe         -- GQA attention + MoE block
  mamba       -- Mamba2 (SSD) block
  shared_attn -- zamba2's parameter-shared attention+MLP block
  enc_attn    -- bidirectional encoder block (whisper)
  xattn       -- decoder block with self + cross attention (whisper)

Three entry points (used by train/prefill/decode steps and the dry-run):
  forward_train   full-sequence causal LM loss
  forward_prefill full-sequence forward that also builds the KV/SSM cache
  forward_decode  single-token step against the cache
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.actctx import constrain
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models.params import spec, tree_map_specs


# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Segment:
    kind: str
    count: int
    window: int = 0          # 0 = full attention
    causal: bool = True
    shared_index: int = -1   # invocation index for shared_attn


def build_plan(cfg: ModelConfig) -> list[Segment]:
    fam = cfg.family
    if fam in ("dense", "vlm"):
        if cfg.global_every > 0:
            # gemma3-style local:global pattern -> runs of equal window
            segs: list[Segment] = []
            run_w, run_n = None, 0
            for i in range(cfg.num_layers):
                w = 0 if (i + 1) % cfg.global_every == 0 else cfg.sliding_window
                if w == run_w:
                    run_n += 1
                else:
                    if run_n:
                        segs.append(Segment("attn", run_n, window=run_w))
                    run_w, run_n = w, 1
            if run_n:
                segs.append(Segment("attn", run_n, window=run_w))
            return segs
        return [Segment("attn", cfg.num_layers, window=cfg.sliding_window)]
    if fam == "moe":
        return [Segment("moe", cfg.num_layers, window=cfg.sliding_window)]
    if fam == "ssm":
        return [Segment("mamba", cfg.num_layers)]
    if fam == "hybrid":
        segs = []
        remaining = cfg.num_layers
        idx = 0
        every = cfg.hybrid_attn_every
        while remaining > 0:
            segs.append(Segment("shared_attn", 1, shared_index=idx))
            idx += 1
            n = min(every, remaining)
            segs.append(Segment("mamba", n))
            remaining -= n
        return segs
    if fam == "audio":
        return [Segment("xattn", cfg.num_layers)]
    raise ValueError(fam)


def num_shared_invocations(cfg) -> int:
    return sum(1 for s in build_plan(cfg) if s.kind == "shared_attn")


# ---------------------------------------------------------------------------
# Per-block param specs
# ---------------------------------------------------------------------------
def _block_spec(cfg: ModelConfig, kind: str):
    if kind in ("attn", "enc_attn"):
        return {"ln1": L.norm_spec(cfg.norm, cfg.d_model),
                "attn": L.attn_spec(cfg),
                "ln2": L.norm_spec(cfg.norm, cfg.d_model),
                "mlp": L.mlp_spec(cfg)}
    if kind == "moe":
        return {"ln1": L.norm_spec(cfg.norm, cfg.d_model),
                "attn": L.attn_spec(cfg),
                "ln2": L.norm_spec(cfg.norm, cfg.d_model),
                "moe": MOE.moe_spec(cfg)}
    if kind == "mamba":
        return {"ln": L.norm_spec("rmsnorm", cfg.d_model),
                "mixer": M2.mamba2_spec(cfg)}
    if kind == "xattn":
        return {"ln1": L.norm_spec(cfg.norm, cfg.d_model),
                "attn": L.attn_spec(cfg),
                "lnx": L.norm_spec(cfg.norm, cfg.d_model),
                "xattn": L.attn_spec(cfg),
                "ln2": L.norm_spec(cfg.norm, cfg.d_model),
                "mlp": L.mlp_spec(cfg)}
    raise ValueError(kind)


def _stack_spec(tree, n: int):
    return tree_map_specs(
        lambda s: dataclasses.replace(s, shape=(n,) + s.shape,
                                      axes=(None,) + s.axes), tree)


def param_spec(cfg: ModelConfig):
    """Full parameter spec tree for an architecture."""
    d = cfg.d_model
    p: dict[str, Any] = {
        "embed": spec((cfg.vocab_size, d), ("vocab", "embed"), scale=0.02),
        "final_norm": L.norm_spec(cfg.norm, d),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = spec((d, cfg.vocab_size), ("embed", "vocab"))
    segs = []
    for s in build_plan(cfg):
        if s.kind == "shared_attn":
            segs.append({})
        else:
            segs.append(_stack_spec(_block_spec(cfg, s.kind), s.count))
    p["segments"] = segs
    if cfg.family == "hybrid":
        p["shared"] = _block_spec(cfg, "attn")
    if cfg.family == "audio":
        p["encoder"] = {
            "segments": [_stack_spec(_block_spec(cfg, "enc_attn"),
                                     cfg.encoder_layers)],
            "final_norm": L.norm_spec(cfg.norm, d),
        }
    return p


# ---------------------------------------------------------------------------
# Block bodies (full-sequence mode)
# ---------------------------------------------------------------------------
def _attn_body(bp, x, cfg, seg: Segment, positions, impl, memory=None):
    h = L.apply_norm(bp["ln1"], x, cfg.norm)
    a, kv = L.attention_block(bp["attn"], h, cfg, positions=positions,
                              causal=seg.causal, window=seg.window, impl=impl)
    x = x + a
    extras = {"k": kv[0], "v": kv[1]}
    if seg.kind == "xattn":
        h = L.apply_norm(bp["lnx"], x, cfg.norm)
        a, xkv = L.attention_block(bp["xattn"], h, cfg, positions=positions,
                                   impl=impl, kv=memory)
        x = x + a
        extras.update({"xk": xkv[0], "xv": xkv[1]})
    h = L.apply_norm(bp["ln2"], x, cfg.norm)
    aux = {}
    if seg.kind == "moe":
        m, aux = MOE.apply_moe(bp["moe"], h, cfg)
    else:
        m = L.apply_mlp(bp["mlp"], h, cfg)
    return x + m, extras, aux


def _mamba_body(bp, x, cfg):
    h = L.apply_norm(bp["ln"], x, "rmsnorm")
    out, (h_last, conv) = M2.mamba2_block(bp["mixer"], h, cfg,
                                          return_state=True)
    return x + out, {"h": h_last, "conv": conv}, {}


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------
def _zero_aux(cfg):
    if cfg.family == "moe":
        return {"moe_lb_loss": jnp.float32(0), "moe_z_loss": jnp.float32(0),
                "moe_drop_frac": jnp.float32(0)}
    return {}


def _run_segments(params, cfg, x, positions, *, impl, remat, want_cache,
                  cache_window, memory=None):
    """Run the layer plan over full-sequence x. Returns x, caches, aux."""
    plan = build_plan(cfg)
    caches = []
    aux_tot = _zero_aux(cfg)

    for si, seg in enumerate(plan):
        if seg.kind == "shared_attn":
            x, extras, _ = _attn_body(params["shared"], x, cfg, seg,
                                      positions, impl)
            caches.append(_clip_cache(extras, seg, cfg, cache_window)
                          if want_cache else {})
            continue

        seg_params = params["segments"][si]

        def inner(carry, lp, seg=seg):
            x, aux = carry
            if seg.kind == "mamba":
                x, extras, a = _mamba_body(lp, x, cfg)
            else:
                x, extras, a = _attn_body(lp, x, cfg, seg, positions, impl,
                                          memory=memory)
            for k in aux:
                aux = dict(aux)
                aux[k] = aux[k] + a.get(k, 0.0)
            if not want_cache:
                extras = {}
            else:
                extras = _clip_cache(extras, seg, cfg, cache_window)
            return (x, aux), extras

        if remat:
            inner = jax.checkpoint(inner, prevent_cse=False)

        def body(carry, lp):
            # constraints OUTSIDE the remat boundary: the value autodiff
            # saves per layer is this constrained tensor, so the stacked
            # residual buffer inherits batch+seq (SP) sharding.
            x, aux = carry
            x = constrain(x, "resid")
            (x, aux), extras = inner((x, aux), lp)
            x = constrain(x, "resid")
            return (x, aux), extras

        (x, aux_tot), seg_cache = jax.lax.scan(body, (x, aux_tot), seg_params)
        caches.append(seg_cache if want_cache else {})

    return x, caches, aux_tot


def _clip_cache(extras, seg: Segment, cfg, cache_window: bool):
    """Keep only the window-relevant tail of k/v for SWA segments."""
    out = {}
    for name, t in extras.items():
        if name in ("k", "v", "xk", "xv") and cache_window and seg.window > 0 \
                and seg.kind != "xattn" and t.shape[1] > seg.window:
            t = t[:, -seg.window:]
        out[name] = t
    return out


def _embed(params, cfg, tokens, extras=None):
    x = params["embed"].astype(jnp.bfloat16)[tokens]
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype) if cfg.family == "dense" \
        and cfg.global_every > 0 else x  # gemma-style embed scaling
    if cfg.family == "vlm" and extras is not None and "vis_embeds" in extras:
        x = jnp.concatenate([extras["vis_embeds"].astype(x.dtype), x], axis=1)
    return x


def _encode_audio(params, cfg, enc_embeds, impl, remat):
    x = enc_embeds.astype(jnp.bfloat16)
    pos = jnp.arange(x.shape[1])
    enc = params["encoder"]
    seg = Segment("enc_attn", cfg.encoder_layers, causal=False)

    def body(carry, lp):
        h, _e, _a = _attn_body(lp, carry, cfg, seg, pos, impl)
        return h, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=True)
    x, _ = jax.lax.scan(body, x, enc["segments"][0])
    return L.apply_norm(enc["final_norm"], x, cfg.norm)


def _logits(params, cfg, x):
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        w = params["embed"].astype(x.dtype)          # (V, d)
        return jnp.einsum("bsd,vd->bsv", x, w)
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))


def _chunked_ce(params, cfg, x, tokens, vis: int, chunk: int = 512):
    """Next-token CE with the vocab projection scanned over seq chunks.

    Avoids materializing the full (B, S, V) fp32 logits tensor (gemma3's
    262k vocab would otherwise need ~8 GB/device at the loss).  The seq
    length is kept at S (targets rolled, last position masked) so the
    chunk reshape never crosses shard boundaries, and each chunk body is
    rematted so backward recomputes its logits instead of stacking them.
    """
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    xt = x[:, vis:, :]                               # (B, S, d)
    tgt = jnp.roll(tokens, -1, axis=1)               # (B, S); last is garbage
    B, S, d = xt.shape
    c = L._pick_chunk(S, chunk)
    n = S // c
    xc = xt.reshape(B, n, c, d).transpose(1, 0, 2, 3)
    tc = tgt.reshape(B, n, c).transpose(1, 0, 2)
    wc = (jnp.arange(S) < S - 1).astype(jnp.float32).reshape(n, c)
    if cfg.tie_embeddings:
        w = params["embed"]
        proj = lambda h: jnp.einsum("bsd,vd->bsv", h, w.astype(h.dtype))
    else:
        w = params["lm_head"]
        proj = lambda h: jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype))

    def body(acc, inp):
        xx, tt, ww = inp
        lg = proj(xx).astype(jnp.float32)
        logz = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, tt[..., None], axis=-1)[..., 0]
        return acc + ((logz - gold) * ww).sum(), None

    body = jax.checkpoint(body, prevent_cse=False)
    tot, _ = jax.lax.scan(body, jnp.float32(0), (xc, tc, wc))
    return tot / (B * (S - 1))


def forward_train(params, cfg: ModelConfig, batch, *, impl="chunked",
                  remat=True):
    """Causal-LM loss. batch: tokens (B,S) [+ enc_embeds / vis_embeds]."""
    tokens = batch["tokens"]
    extras = {k: v for k, v in batch.items() if k != "tokens"}
    memory = None
    if cfg.family == "audio":
        memory = _encode_audio(params, cfg, batch["enc_embeds"], impl, remat)

    x = _embed(params, cfg, tokens, extras)
    x = constrain(x, "resid")
    positions = jnp.arange(x.shape[1])
    x, _, aux = _run_segments(params, cfg, x, positions, impl=impl,
                              remat=remat, want_cache=False,
                              cache_window=False, memory=memory)

    # next-token CE over text positions (skip prepended vision tokens)
    vis = cfg.vision_tokens if cfg.family == "vlm" else 0
    ce = _chunked_ce(params, cfg, x, tokens, vis)
    loss = ce
    if cfg.family == "moe":
        loss = loss + 0.01 * aux["moe_lb_loss"] + 1e-3 * aux["moe_z_loss"]
    metrics = {"ce": ce, **aux}
    return loss, metrics


def forward_prefill(params, cfg: ModelConfig, batch, *, impl="chunked"):
    """Full-sequence forward building the decode cache.

    Returns (last-position logits, cache).  Cache layout mirrors the plan:
    one entry per segment (see init_cache for shapes).
    """
    tokens = batch["tokens"]
    extras = {k: v for k, v in batch.items() if k != "tokens"}
    memory = None
    if cfg.family == "audio":
        memory = _encode_audio(params, cfg, batch["enc_embeds"], impl,
                               remat=False)
    x = _embed(params, cfg, tokens, extras)
    positions = jnp.arange(x.shape[1])
    x, caches, _ = _run_segments(params, cfg, x, positions, impl=impl,
                                 remat=False, want_cache=True,
                                 cache_window=True, memory=memory)
    logits = _logits(params, cfg, x[:, -1:, :])
    return logits, {"segments": caches, "pos": jnp.int32(tokens.shape[1])}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, ctx_len: int,
               dtype=jnp.bfloat16):
    """Zero-initialized decode cache (also used abstractly by the dry-run).

    Full-attention segments get (L, B, ctx, KH, D) ring-free buffers
    written at ``pos``; SWA segments get (L, B, window, KH, D) shift
    buffers; mamba segments get O(1) recurrent state.
    """
    plan = build_plan(cfg)
    kh, hd = cfg.num_kv_heads, cfg.head_dim
    segs = []
    for seg in plan:
        if seg.kind in ("attn", "moe", "shared_attn", "xattn"):
            wlen = seg.window if seg.window > 0 else ctx_len
            wlen = min(wlen, ctx_len)
            n = 1 if seg.kind == "shared_attn" else seg.count
            lead = () if seg.kind == "shared_attn" else (n,)
            c = {"k": jnp.zeros(lead + (batch, wlen, kh, hd), dtype),
                 "v": jnp.zeros(lead + (batch, wlen, kh, hd), dtype)}
            if seg.kind == "xattn":
                c["xk"] = jnp.zeros(lead + (batch, cfg.encoder_seq, kh, hd),
                                    dtype)
                c["xv"] = jnp.zeros(lead + (batch, cfg.encoder_seq, kh, hd),
                                    dtype)
            segs.append(c)
        elif seg.kind == "mamba":
            conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
            segs.append({
                "h": jnp.zeros((seg.count, batch, cfg.ssm_nheads,
                                cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((seg.count, batch, cfg.ssm_conv - 1,
                                   conv_dim), dtype),
            })
        else:
            raise ValueError(seg.kind)
    return {"segments": segs, "pos": jnp.int32(0)}


def _decode_attn(bp, x, cfg, seg: Segment, pos, ck, cv):
    """One decode step of an attention block against its cache."""
    kh = cfg.num_kv_heads
    g = cfg.num_heads // kh
    B = x.shape[0]
    h = L.apply_norm(bp["ln1"], x, cfg.norm)
    q, k, v = L.attn_qkv(bp["attn"], h, cfg,
                         jnp.full((1,), pos, jnp.int32))
    q = q.reshape(B, 1, kh, g, cfg.head_dim)
    W = ck.shape[1]
    if seg.window > 0 and W == seg.window:
        # SWA shift buffer: slot j holds absolute position pos-W+1+j
        ck = jnp.concatenate([ck[:, 1:], k.astype(ck.dtype)], axis=1)
        cv = jnp.concatenate([cv[:, 1:], v.astype(cv.dtype)], axis=1)
        k_pos = pos - W + 1 + jnp.arange(W)
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype),
                                                 pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype),
                                                 pos, axis=1)
        k_pos = jnp.arange(W)
    valid = (k_pos >= 0) & (k_pos <= pos)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   ck.astype(jnp.float32)) * (cfg.head_dim ** -0.5)
    if cfg.attn_logit_softcap:
        s = jnp.tanh(s / cfg.attn_logit_softcap) * cfg.attn_logit_softcap
    s = jnp.where(valid[None, None, None, None, :], s, L.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(cv.dtype), cv)
    o = o.reshape(B, 1, cfg.num_heads, cfg.head_dim)
    x = x + L.attn_out(bp["attn"], o, x.dtype)
    return x, ck, cv


def _decode_xattn(bp, x, cfg, xk, xv):
    kh = cfg.num_kv_heads
    g = cfg.num_heads // kh
    B = x.shape[0]
    h = L.apply_norm(bp["lnx"], x, cfg.norm)
    q = jnp.einsum("bsd,dhe->bshe", h, bp["xattn"]["wq"].astype(h.dtype))
    q = q.reshape(B, 1, kh, g, cfg.head_dim)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   xk.astype(jnp.float32)) * (cfg.head_dim ** -0.5)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(xv.dtype), xv)
    o = o.reshape(B, 1, cfg.num_heads, cfg.head_dim)
    return x + L.attn_out(bp["xattn"], o, x.dtype)


def forward_decode(params, cfg: ModelConfig, tokens, cache, *, pos=None):
    """One decode step. tokens: (B, 1) -> logits (B, 1, V), updated cache."""
    plan = build_plan(cfg)
    pos = cache["pos"] if pos is None else pos
    x = params["embed"].astype(jnp.bfloat16)[tokens]
    if cfg.family == "dense" and cfg.global_every > 0:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    new_segs = []
    for si, seg in enumerate(plan):
        c = cache["segments"][si]
        if seg.kind == "shared_attn":
            x, ck, cv = _decode_attn(params["shared"], x, cfg, seg, pos,
                                     c["k"], c["v"])
            h = L.apply_norm(params["shared"]["ln2"], x, cfg.norm)
            x = x + L.apply_mlp(params["shared"]["mlp"], h, cfg)
            new_segs.append({"k": ck, "v": cv})
            continue

        seg_params = params["segments"][si]

        if seg.kind == "mamba":
            def body(carry, inp):
                xx = carry
                lp, hs, conv = inp
                h = L.apply_norm(lp["ln"], xx, "rmsnorm")
                out, (h_new, conv_new) = M2.mamba2_decode(
                    lp["mixer"], h, cfg, (hs, conv))
                return xx + out, {"h": h_new, "conv": conv_new}
            x, cc = jax.lax.scan(body, x, (seg_params, c["h"], c["conv"]))
            new_segs.append(cc)
            continue

        # attention-family segment
        def body(carry, inp, seg=seg):
            xx = carry
            lp, cc = inp
            xx, ck, cv = _decode_attn(lp, xx, cfg, seg, pos,
                                      cc["k"], cc["v"])
            out_c = {"k": ck, "v": cv}
            if seg.kind == "xattn":
                xx = _decode_xattn(lp, xx, cfg, cc["xk"], cc["xv"])
                out_c.update({"xk": cc["xk"], "xv": cc["xv"]})
            h = L.apply_norm(lp["ln2"], xx, cfg.norm)
            if seg.kind == "moe":
                m, _ = MOE.apply_moe(lp["moe"], h, cfg)
            else:
                m = L.apply_mlp(lp["mlp"], h, cfg)
            return xx + m, out_c

        x, cc = jax.lax.scan(body, x, (seg_params, c))
        new_segs.append(cc)

    logits = _logits(params, cfg, x)
    return logits, {"segments": new_segs, "pos": pos + 1}
