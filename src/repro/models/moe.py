"""Mixture-of-Experts layer: top-k routing with GShard einsum dispatch.

Experts are sharded over the "model" mesh axis (expert parallelism).
Tokens are grouped by batch row; per-group capacity bounds the dispatch
tensors so all shapes stay static under pjit.  The one-hot dispatch /
combine einsums are the canonical TPU formulation (GShard/Switch): under
a (data=batch, model=experts) mesh GSPMD turns them into slice +
all-reduce pairs; the ragged all-to-all variant is a recorded perf
iteration (EXPERIMENTS.md SPerf).

Aux losses (load-balance + router z-loss) follow Switch Transformer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import spec


def moe_spec(cfg):
    """Expert weights are 2-D sharded AT REST: experts over "model" (EP) x
    expert-ffn over "data".  Unlike ZeRO-3 (embed-dim over data), this
    layout never all-gathers expert weights - under gradient accumulation
    ZeRO-3 re-gathers per microbatch (measured 16.7 TB/step wire for
    dbrx train, EXPERIMENTS SPerf iteration 4); here the weights stay
    put and the (tokens, d) partial sums are reduced instead (~60x less).
    """
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    p = {
        "router": spec((d, e), ("embed", "experts"), scale=0.02),
        "wi": spec((e, d, f), ("experts", None, "moe_ffn")),
        "wo": spec((e, f, d), ("experts", "moe_ffn", None),
                   scale=0.02 / max(1, cfg.num_layers) ** 0.5),
    }
    if cfg.act == "swiglu":
        p["wg"] = spec((e, d, f), ("experts", None, "moe_ffn"))
    return p


def _capacity(tokens_per_group: int, num_experts: int, k: int, factor: float) -> int:
    c = int(tokens_per_group * k * factor / num_experts)
    return max(c, 1)


def apply_moe(p, x, cfg, *, capacity_factor=None, group_size=256):
    """x: (B, S, d) -> (B, S, d), aux dict.

    Tokens are regrouped to (G, group_size, d) before the dispatch
    einsums: the (G, S_g, E, C) dispatch/combine tensors scale as
    tokens * E * C, so small groups keep them a fraction of the residual
    stream.  ``group_size`` matches the SP shard (S / TP) so the reshape
    never crosses shard boundaries.  Top-k gating with per-expert
    capacity; overflow tokens drop (GShard semantics).
    """
    B0, S0, d = x.shape
    gs = min(group_size, S0)
    if S0 % gs == 0:
        x = x.reshape(B0 * (S0 // gs), gs, d)
    B, S, _ = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    C = _capacity(S, E, K, capacity_factor or cfg.capacity_factor)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                       # (B,S,E)

    gate_vals, gate_idx = jax.lax.top_k(probs, K)                  # (B,S,K)
    # renormalize selected gates (mixtral/dbrx convention)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    # one-hot (B,S,K,E); position of each token within its expert queue
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)        # (B,S,K,E)
    # priority: earlier tokens first, k=0 before k=1 (flatten S,K)
    flat = onehot.reshape(B, S * K, E)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat                # (B,S*K,E)
    within_cap = pos_in_expert < C
    flat = flat * within_cap
    slot = jnp.einsum("bte,btec->btec", flat,
                      jax.nn.one_hot(pos_in_expert, C, dtype=jnp.float32))
    dispatch = slot.reshape(B, S, K, E, C).sum(axis=2)             # (B,S,E,C) 0/1
    gate_w = jnp.einsum("bske,bsk->bse", onehot, gate_vals)        # (B,S,E)
    combine = dispatch * gate_w[..., None]                         # (B,S,E,C)

    xin = jnp.einsum("bsec,bsd->ebcd", dispatch.astype(x.dtype), x)  # (E,B,C,d)

    # expert FFN, vectorized over E (sharded over "model")
    wi = p["wi"].astype(x.dtype)
    wo = p["wo"].astype(x.dtype)
    h = jnp.einsum("ebcd,edf->ebcf", xin, wi)
    if "wg" in p:
        g = jnp.einsum("ebcd,edf->ebcf", xin, p["wg"].astype(x.dtype))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    out_e = jnp.einsum("ebcf,efd->ebcd", h, wo)                    # (E,B,C,d)

    y = jnp.einsum("ebcd,bsec->bsd", out_e, combine.astype(x.dtype))

    # --- aux losses (fp32) ---
    # load-balance: E * sum_e mean_prob_e * frac_tokens_e (Switch eq. 4)
    me = probs.mean(axis=(0, 1))                                   # (E,)
    ce = onehot.sum(axis=2).mean(axis=(0, 1))                      # (E,) frac routed
    lb_loss = E * jnp.sum(me * ce / K)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    dropped = 1.0 - dispatch.sum(axis=(2, 3)).mean() / K
    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss,
           "moe_drop_frac": dropped}
    return y.reshape(B0, S0, d), aux
