"""Mamba2 (SSD, state-space duality) block: chunked training scan and
O(1)-state decode step.

Follows Dao & Gu (arXiv:2405.21060).  The SSD chunked algorithm splits
the sequence into chunks of length Q: intra-chunk terms are computed as
a masked quadratic attention-like product (MXU-friendly), inter-chunk
terms flow through a scan over per-chunk states (B, H, P, N).

Shapes:  d_inner = expand * d_model;  H = d_inner / head_dim (P);
         N = ssm_state;  G = ssm_groups (B/C shared across heads/group).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import spec


def mamba2_spec(cfg):
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm_state
    g = cfg.ssm_groups
    nh = cfg.ssm_nheads
    conv_dim = di + 2 * g * n
    return {
        "in_proj": spec((d, 2 * di + 2 * g * n + nh), ("embed", "ssm_inner")),
        "conv_w": spec((cfg.ssm_conv, conv_dim), ("conv", "ssm_inner"),
                       scale=0.1),
        "conv_b": spec((conv_dim,), ("ssm_inner",), init="zeros"),
        "A_log": spec((nh,), ("ssm_heads",), init="arange_neg"),
        "D": spec((nh,), ("ssm_heads",), init="ones"),
        "dt_bias": spec((nh,), ("ssm_heads",), init="zeros"),
        "norm_scale": spec((di,), ("ssm_inner",), init="ones"),
        "out_proj": spec((di, d), ("ssm_inner", "embed"),
                         scale=0.02 / max(1, cfg.num_layers) ** 0.5),
    }


def _segsum(x):
    """Stable 'segment sum': out[..., i, j] = sum_{j<k<=i} x[..., k].

    Returns (..., Q, Q) with -inf above the diagonal (j > i).
    """
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), dtype=bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B,S,Cd), w: (W,Cd)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):  # W is tiny (4): unrolled taps
        out = out + xp[:, i:i + x.shape[1], :] * w[i]
    return out + b


def ssd_chunked(x, dt, A, Bc, Cc, D, *, chunk: int, h0=None):
    """SSD forward.

    x:  (B, S, H, P) values
    dt: (B, S, H)    positive step sizes
    A:  (H,)         negative decay rates
    Bc: (B, S, G, N) input projections
    Cc: (B, S, G, N) output projections
    D:  (H,)         skip
    h0: optional initial state (B, H, P, N)
    Returns y (B, S, H, P), h_final (B, H, P, N).
    """
    Bsz, S, H, P = x.shape
    G, N = Bc.shape[2], Bc.shape[3]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nC = S // Q
    rep = H // G

    f32 = jnp.float32
    xb = (x * dt[..., None]).astype(f32)                  # fold dt into x
    dA = (dt.astype(f32) * A.astype(f32)).astype(f32)     # (B,S,H) negative

    # chunked views
    xc = xb.reshape(Bsz, nC, Q, H, P)
    dAc = dA.reshape(Bsz, nC, Q, H).transpose(0, 1, 3, 2)   # (B,C,H,Q)
    Bcc = Bc.reshape(Bsz, nC, Q, G, N).astype(f32)
    Ccc = Cc.reshape(Bsz, nC, Q, G, N).astype(f32)

    dA_cum = jnp.cumsum(dAc, axis=-1)                       # (B,C,H,Q)
    dA_tot = dA_cum[..., -1]                                # (B,C,H)

    # group -> head broadcast for B/C projections
    Bh = jnp.repeat(Bcc, rep, axis=3)                       # (B,C,Q,H,N)
    Ch = jnp.repeat(Ccc, rep, axis=3)                       # (B,C,Q,H,N)

    # ---- intra-chunk (diagonal blocks): quadratic masked product ----
    L = jnp.exp(_segsum(dAc))                               # (B,C,H,Q,Q)
    CB = jnp.einsum("bclhn,bcshn->bchls", Ch, Bh)           # (B,C,H,Q,Q)
    M = CB * L                                              # masked decay
    y_diag = jnp.einsum("bchls,bcshp->bclhp", M, xc)

    # ---- chunk states: B^T x with decay-to-end ----
    decay_end = jnp.exp(dA_tot[..., None] - dA_cum)         # (B,C,H,Q)
    Bx = jnp.einsum("bcshn,bcshp,bchs->bchpn",
                    Bh, xc, decay_end)                      # (B,C,H,P,N)

    # ---- inter-chunk recurrence over chunk states ----
    def step(h, inp):
        Bx_c, dA_tot_c = inp                                # (B,H,P,N),(B,H)
        h_new = h * jnp.exp(dA_tot_c)[..., None, None] + Bx_c
        return h_new, h                                     # emit state BEFORE chunk

    h_init = (jnp.zeros((Bsz, H, P, N), f32) if h0 is None
              else h0.astype(f32))
    h_last, h_prevs = jax.lax.scan(
        step, h_init,
        (Bx.transpose(1, 0, 2, 3, 4), dA_tot.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)              # (B,C,H,P,N)

    # ---- inter-chunk output: C h_prev with decay-from-start ----
    decay_in = jnp.exp(dA_cum)                              # (B,C,H,Q)
    y_off = jnp.einsum("bclhn,bchpn,bchl->bclhp",
                       Ch, h_prevs, decay_in)

    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    y = y + (D.astype(f32)[None, None, :, None] * x.astype(f32))
    return y.astype(x.dtype), h_last


def mamba2_block(p, x, cfg, *, h0=None, conv0=None, return_state=False):
    """Full Mamba2 block (no outer norm/residual).

    x: (B, S, d_model) -> (B, S, d_model)
    """
    B, S, d = x.shape
    di, n, g = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
    nh, hd = cfg.ssm_nheads, cfg.ssm_head_dim

    from repro.distributed.actctx import constrain
    zxbcdt = constrain(
        jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype)), "ffn")
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * g * n], axis=-1)

    if conv0 is not None:
        # decode path stitches conv state; training uses zero left-context
        xbc_ext = jnp.concatenate([conv0.astype(xbc.dtype), xbc], axis=1)
        conv_out = _causal_conv(xbc_ext, p["conv_w"].astype(x.dtype),
                                p["conv_b"].astype(x.dtype))
        xbc_conv = conv_out[:, conv0.shape[1]:, :]
        new_conv = xbc_ext[:, -(cfg.ssm_conv - 1):, :]
    else:
        xbc_conv = _causal_conv(xbc, p["conv_w"].astype(x.dtype),
                                p["conv_b"].astype(x.dtype))
        new_conv = xbc[:, -(cfg.ssm_conv - 1):, :]
    xbc_conv = jax.nn.silu(xbc_conv)

    xs, Bc, Cc = jnp.split(xbc_conv, [di, di + g * n], axis=-1)
    xs = xs.reshape(B, S, nh, hd)
    Bc = Bc.reshape(B, S, g, n)
    Cc = Cc.reshape(B, S, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    y, h_last = ssd_chunked(xs, dt, A, Bc, Cc, p["D"],
                            chunk=cfg.ssm_chunk, h0=h0)
    y = y.reshape(B, S, di)

    # gated RMSNorm (mamba2 uses norm(y * silu(z)))
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + 1e-6)
         * p["norm_scale"].astype(jnp.float32)).astype(x.dtype)

    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    if return_state:
        return out, (h_last, new_conv)
    return out


def mamba2_decode(p, x, cfg, state):
    """O(1) single-token decode. x: (B, 1, d); state = (h, conv_buf).

    h: (B, H, P, N); conv_buf: (B, ssm_conv-1, conv_dim).
    """
    h, conv_buf = state
    out, (h_new, conv_new) = mamba2_block(
        p, x, cfg, h0=h, conv0=conv_buf, return_state=True)
    return out, (h_new, conv_new)


def init_ssm_state(cfg, batch, dtype=jnp.float32):
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    h = jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state),
                  jnp.float32)
    conv = jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype)
    return h, conv
