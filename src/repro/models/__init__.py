from repro.models.model import (
    build_plan,
    forward_decode,
    forward_prefill,
    forward_train,
    init_cache,
    param_spec,
)
from repro.models.params import (
    abstract_params,
    init_params,
    param_count,
    param_shardings,
)

__all__ = [
    "build_plan",
    "forward_decode",
    "forward_prefill",
    "forward_train",
    "init_cache",
    "param_spec",
    "abstract_params",
    "init_params",
    "param_count",
    "param_shardings",
]
