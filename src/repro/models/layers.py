"""Core neural layers: norms, RoPE, GQA attention (full / sliding-window /
local-global / cross), and MLPs.

Attention has three interchangeable implementations:
  * ``naive``   -- materializes (Sq, Sk) scores; oracle for tests.
  * ``chunked`` -- XLA flash attention (double-scanned, online softmax);
                   O(chunk^2) memory; used for training/prefill lowering.
  * ``pallas``  -- the Pallas TPU kernel in repro.kernels.flash_attention
                   (selected on real TPU backends; validated in interpret
                   mode by tests).

All attention entry points take q of shape (B, Sq, KH, G, D) and k/v of
shape (B, Sk, KH, D): GQA is expressed by the (KH, G) factorization so
that kv heads are never materialized repeated.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.actctx import constrain
from repro.models.params import spec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm_spec(d):
    return {"scale": spec((d,), (None,), init="ones")}


def layernorm_spec(d):
    return {"scale": spec((d,), (None,), init="ones"),
            "bias": spec((d,), (None,), init="zeros")}


def norm_spec(kind, d):
    return rmsnorm_spec(d) if kind == "rmsnorm" else layernorm_spec(d)


def apply_norm(p, x, kind="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, ..., D) with positions broadcastable to the S axis.

    x shape (B, S, H..., D); positions (S,) or (B, S).
    """
    d = x.shape[-1]
    d2 = d // 2
    freqs = rope_freqs(d, theta)  # (d2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d2)
    # broadcast angles over any head dims between S and D
    extra = x.ndim - ang.ndim - 1
    for _ in range(extra):
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :d2], x[..., d2:2 * d2]
    xr1 = x1 * cos - x2 * sin
    xr2 = x2 * cos + x1 * sin
    out = jnp.concatenate([xr1, xr2], axis=-1)
    if 2 * d2 != d:  # odd head_dim (e.g. danube3's 120 stays even; guard anyway)
        out = jnp.concatenate([out, x[..., 2 * d2:]], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Masking
# ---------------------------------------------------------------------------
def attn_mask(q_pos, k_pos, *, causal: bool, window: int):
    """Boolean (..., Sq, Sk) mask; True = attend."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    m = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), dtype=bool)
    if causal:
        m &= kp <= qp
    if window > 0:
        m &= kp > qp - window
    return m


# ---------------------------------------------------------------------------
# Attention implementations
# ---------------------------------------------------------------------------
def _scores_softcap(s, softcap):
    if softcap and softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    return s


def attention_naive(q, k, v, *, q_pos, k_pos, causal, window, softcap=0.0):
    """q/k/v: (B, S, H, D), kv heads pre-repeated -> (B, Sq, H, D)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = _scores_softcap(s, softcap)
    mask = attn_mask(q_pos, k_pos, causal=causal, window=window)  # (Sq, Sk)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
    return out


def _pick_chunk(s: int, target: int) -> int:
    """Largest divisor of s that is <= target (falls back to s)."""
    if s <= target:
        return s
    for c in range(target, 0, -1):
        if s % c == 0:
            return c
    return s


# ---------------------------------------------------------------------------
# XLA flash attention with a hand-written VJP.
#
# A plain scan-based online-softmax forward is memory-safe, but its
# autodiff saves the (m, l, acc) carries per kv-block - an O(S^2)-scale
# residual footprint.  The custom VJP saves only (q, k, v, out, lse) and
# recomputes probabilities blockwise in the backward (two passes: dq by
# q-block, dk/dv by kv-block) - the standard flash-attention treatment,
# expressed in pure lax.scan so it lowers on any backend.
# ---------------------------------------------------------------------------
def _flash_fwd_impl(q, k, v, *, causal, window, softcap, q_chunk, kv_chunk):
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    qc = _pick_chunk(Sq, q_chunk)
    kc = _pick_chunk(Sk, kv_chunk)
    nq, nk = Sq // qc, Sk // kc
    scale = D ** -0.5

    qs = q.reshape(B, nq, qc, H, D).transpose(1, 0, 2, 3, 4)
    ks = k.reshape(B, nk, kc, H, D).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kc, H, D).transpose(1, 0, 2, 3, 4)

    def q_block(_, qi):
        qcb, qidx = qi

        def kv_block(state, ki):
            m, l, acc = state
            kcb, vcb, kidx = ki
            s = jnp.einsum("bqhd,bkhd->bhqk", qcb.astype(jnp.float32),
                           kcb.astype(jnp.float32)) * scale
            s = _scores_softcap(s, softcap)
            qp = qidx * qc + jnp.arange(qc)
            kp = kidx * kc + jnp.arange(kc)
            mask = attn_mask(qp, kp, causal=causal, window=window)
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vcb.dtype), vcb)
            acc = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l, acc), None

        m0 = jnp.full((B, H, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, qc), jnp.float32)
        a0 = jnp.zeros((B, H, qc, D), v.dtype)
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0), (ks, vs, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out.transpose(0, 2, 1, 3), lse)  # (B,qc,H,D),(B,H,qc)

    _, (outs, lses) = jax.lax.scan(q_block, None, (qs, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, D)
    lse = lses.transpose(1, 2, 0, 3).reshape(B, H, Sq)
    return out, lse


def _flash_bwd_impl(q, k, v, out, lse, do, *, causal, window, softcap,
                    q_chunk, kv_chunk):
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    qc = _pick_chunk(Sq, q_chunk)
    kc = _pick_chunk(Sk, kv_chunk)
    nq, nk = Sq // qc, Sk // kc
    scale = D ** -0.5
    f32 = jnp.float32

    delta = jnp.einsum("bshd,bshd->bhs", do.astype(f32), out.astype(f32))

    qs = q.reshape(B, nq, qc, H, D).transpose(1, 0, 2, 3, 4)
    ks = k.reshape(B, nk, kc, H, D).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kc, H, D).transpose(1, 0, 2, 3, 4)
    dos = do.reshape(B, nq, qc, H, D).transpose(1, 0, 2, 3, 4)
    lses = lse.reshape(B, H, nq, qc).transpose(2, 0, 1, 3)     # (nq,B,H,qc)
    deltas = delta.reshape(B, H, nq, qc).transpose(2, 0, 1, 3)

    def _p_ds(qcb, kcb, vcb, docb, lseb, delb, qidx, kidx):
        """Recompute p and ds for one (q-block, kv-block) pair."""
        s_raw = jnp.einsum("bqhd,bkhd->bhqk", qcb.astype(f32),
                           kcb.astype(f32)) * scale
        if softcap and softcap > 0:
            t = jnp.tanh(s_raw / softcap)
            s = t * softcap
            dcap = 1.0 - t * t
        else:
            s, dcap = s_raw, 1.0
        qp = qidx * qc + jnp.arange(qc)
        kp = kidx * kc + jnp.arange(kc)
        mask = attn_mask(qp, kp, causal=causal, window=window)
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lseb[..., None])                        # (B,H,q,k)
        dp = jnp.einsum("bqhd,bkhd->bhqk", docb.astype(f32), vcb.astype(f32))
        ds = p * (dp - delb[..., None]) * scale * dcap
        ds = jnp.where(mask, ds, 0.0)
        return p, ds

    # pass 1: dq by q-block (scan kv inside)
    def dq_block(_, qi):
        qcb, docb, lseb, delb, qidx = qi

        def inner(dq, ki):
            kcb, vcb, kidx = ki
            p, ds = _p_ds(qcb, kcb, vcb, docb, lseb, delb, qidx, kidx)
            dq = dq + jnp.einsum("bhqk,bkhd->bqhd", ds, kcb.astype(f32))
            return dq, None

        dq0 = jnp.zeros((B, qc, H, D), f32)
        dq, _ = jax.lax.scan(inner, dq0, (ks, vs, jnp.arange(nk)))
        return None, dq

    _, dqs = jax.lax.scan(dq_block, None,
                          (qs, dos, lses, deltas, jnp.arange(nq)))
    dq = dqs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, D)

    # pass 2: dk/dv by kv-block (scan q inside)
    def dkv_block(_, ki):
        kcb, vcb, kidx = ki

        def inner(carry, qi):
            dk, dv = carry
            qcb, docb, lseb, delb, qidx = qi
            p, ds = _p_ds(qcb, kcb, vcb, docb, lseb, delb, qidx, kidx)
            dv = dv + jnp.einsum("bhqk,bqhd->bkhd", p, docb.astype(f32))
            dk = dk + jnp.einsum("bhqk,bqhd->bkhd", ds, qcb.astype(f32))
            return (dk, dv), None

        z = jnp.zeros((B, kc, H, D), f32)
        (dk, dv), _ = jax.lax.scan(inner, (z, z),
                                   (qs, dos, lses, deltas, jnp.arange(nq)))
        return None, (dk, dv)

    _, (dks, dvs) = jax.lax.scan(dkv_block, None, (ks, vs, jnp.arange(nk)))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, Sk, H, D)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, Sk, H, D)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention_xla(q, k, v, causal, window, softcap, q_chunk, kv_chunk):
    out, _ = _flash_fwd_impl(q, k, v, causal=causal, window=window,
                             softcap=softcap, q_chunk=q_chunk,
                             kv_chunk=kv_chunk)
    return out


def _flash_fwd_rule(q, k, v, causal, window, softcap, q_chunk, kv_chunk):
    out, lse = _flash_fwd_impl(q, k, v, causal=causal, window=window,
                               softcap=softcap, q_chunk=q_chunk,
                               kv_chunk=kv_chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(causal, window, softcap, q_chunk, kv_chunk, res, do):
    q, k, v, out, lse = res
    return _flash_bwd_impl(q, k, v, out, lse, do, causal=causal,
                           window=window, softcap=softcap,
                           q_chunk=q_chunk, kv_chunk=kv_chunk)


flash_attention_xla.defvjp(_flash_fwd_rule, _flash_bwd_rule)


# ---------------------------------------------------------------------------
# GQA attention block (projections + rope + core)
# ---------------------------------------------------------------------------
def attn_spec(cfg):
    d, h, kh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": spec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": spec((d, kh, hd), ("embed", "kv_heads", "head_dim")),
        "wv": spec((d, kh, hd), ("embed", "kv_heads", "head_dim")),
        "wo": spec((h, hd, d), ("heads", "head_dim", "embed"),
                   scale=0.02 / max(1, cfg.num_layers) ** 0.5),
    }
    if cfg.attn_bias:
        p["bq"] = spec((h, hd), ("heads", "head_dim"), init="zeros")
        p["bk"] = spec((kh, hd), ("kv_heads", "head_dim"), init="zeros")
        p["bv"] = spec((kh, hd), ("kv_heads", "head_dim"), init="zeros")
    return p


def attn_qkv(p, x, cfg, positions, rope=True):
    """Project and rope. Returns q (B,S,H,D), k/v (B,S,KH,D) (unrepeated)."""
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def repeat_kv(k, groups: int):
    """(B, S, KH, D) -> (B, S, KH*G, D). Head axis stays shardable."""
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def attn_out(p, o, x_dtype):
    """o: (B, S, H, D) -> (B, S, d_model)."""
    return jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(x_dtype))


def attention_block(p, x, cfg, *, positions, causal=True, window=0,
                    impl="chunked", kv=None, kv_positions=None):
    """Full attention sub-block (no norm/residual). kv!=None => cross-attn.

    Returns (out, (k, v)) with k/v in UNREPEATED (B, S, KH, D) form for
    the decode cache.
    """
    g = cfg.num_heads // cfg.num_kv_heads
    if kv is None:
        q, k, v = attn_qkv(p, x, cfg, positions)
        k_pos = positions
    else:
        # cross-attention: keys/values from encoder memory, no rope on kv
        q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(x.dtype))
        k = jnp.einsum("bsd,dhe->bshe", kv, p["wk"].astype(kv.dtype))
        v = jnp.einsum("bsd,dhe->bshe", kv, p["wv"].astype(kv.dtype))
        k_pos = (kv_positions if kv_positions is not None
                 else jnp.arange(kv.shape[1]))
        causal, window = False, 0

    if impl == "chunked":
        # pin the head-parallel layout: (B,S,H,D) with H over "model"
        # (the surrounding SP-sharded residual would otherwise tempt GSPMD
        # into a replicated-heads seq-parallel layout with f32 residue)
        qf = constrain(q, "heads")
        kf = constrain(repeat_kv(k, g), "heads")
        vf = constrain(repeat_kv(v, g), "heads")
        # positions are arange in every full-sequence path
        o = flash_attention_xla(qf, kf, vf,
                                causal, window, cfg.attn_logit_softcap,
                                1024, 1024)
        o = constrain(o, "heads")
    else:
        o = attention_naive(q, repeat_kv(k, g), repeat_kv(v, g),
                            q_pos=positions, k_pos=k_pos, causal=causal,
                            window=window, softcap=cfg.attn_logit_softcap)
    return attn_out(p, o, x.dtype), (k, v)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def mlp_spec(cfg, d_ff=None):
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    if cfg.act == "swiglu":
        return {
            "wi": spec((d, f), ("embed", "ffn")),
            "wg": spec((d, f), ("embed", "ffn")),
            "wo": spec((f, d), ("ffn", "embed"),
                       scale=0.02 / max(1, cfg.num_layers) ** 0.5),
        }
    return {
        "wi": spec((d, f), ("embed", "ffn")),
        "wo": spec((f, d), ("ffn", "embed"),
                   scale=0.02 / max(1, cfg.num_layers) ** 0.5),
    }


def apply_mlp(p, x, cfg):
    wi = p["wi"].astype(x.dtype)
    h = constrain(jnp.einsum("bsd,df->bsf", x, wi), "ffn")
    if cfg.act == "swiglu":
        g = constrain(jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype)),
                      "ffn")
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))
