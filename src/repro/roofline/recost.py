"""Merge jaxpr-exact FLOP/byte counts into dry-run artifacts.

XLA's cost_analysis() counts while bodies once (verified empirically:
flops are identical for scan lengths 4/8/16), so every scanned program is
undercounted by its trip counts.  This pass re-traces each cell's program
(trace only - no compile, seconds per cell) and walks the jaxpr with
static scan lengths for exact logical FLOPs/bytes; per-device terms
divide by the mesh size.  Collective wire bytes in the artifacts are
already trip-count-corrected by the HLO computation-graph parser.

  PYTHONPATH=src python -m repro.roofline.recost --art artifacts/dryrun
"""

import argparse
import json
import pathlib

import jax

from repro.configs.registry import get_arch, get_shape
from repro.launch import steps as S
from repro.launch.steps import default_train_config
from repro.models import model as MDL
from repro.models.params import abstract_params
from repro.roofline import analysis as RA
from repro.roofline.jaxpr_cost import count_fn


def analytic_memory_bytes(cfg, shape) -> float:
    """HBM traffic model per device per step (post-fusion, TPU target).

    The jaxpr byte count is an UNFUSED upper bound (every intermediate
    counted), and XLA's 'bytes accessed' is body-once; neither is a
    usable roofline term.  This model counts what actually moves through
    HBM with fused kernels:

      train:  optimizer state sweep (p,g,m,v: 7 fp32 passes) + params
              read fwd+bwd+recompute (3 bf16 passes) + activation
              residual/IO traffic (~12 bf16 passes of the token stream
              per layer: fwd write+read, remat re-write, bwd read, plus
              attention/MLP block IO)
      prefill: params 1 bf16 pass + KV-cache write + ~6 activation passes
      decode:  params 1 pass + KV-cache read at the active length
    """
    n_total = cfg.params_total()
    n_active = cfg.params_active()
    tokens = shape.tokens if shape.kind != "decode" else shape.global_batch
    d = cfg.d_model
    L = cfg.num_layers + cfg.encoder_layers

    if shape.kind == "train":
        opt_sweep = 7 * 4 * n_total
        param_passes = 3 * 2 * n_active
        act = 12 * 2 * tokens * d * L
        return opt_sweep + param_passes + act

    if shape.kind == "prefill":
        cache = 2 * 2 * tokens * cfg.num_kv_heads * cfg.head_dim * L \
            if cfg.num_heads else 0
        act = 6 * 2 * tokens * d * L
        return 2 * n_active + cache + act

    # decode: dominated by reading the KV cache / SSM state per token
    cache_read = 0.0
    from repro.models.model import build_plan
    for seg in build_plan(cfg):
        cnt = 1 if seg.kind == "shared_attn" else seg.count
        if seg.kind in ("attn", "moe", "shared_attn", "xattn"):
            wlen = min(seg.window, shape.seq_len) if seg.window > 0 \
                else shape.seq_len
            cache_read += (2 * 2 * wlen * cfg.num_kv_heads * cfg.head_dim
                           * cnt * shape.global_batch)
            if seg.kind == "xattn":
                cache_read += (2 * 2 * cfg.encoder_seq * cfg.num_kv_heads
                               * cfg.head_dim * cnt * shape.global_batch)
        elif seg.kind == "mamba":
            state = (cfg.ssm_nheads * cfg.ssm_head_dim * cfg.ssm_state * 4
                     + (cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state)
                     * (cfg.ssm_conv - 1) * 2)
            cache_read += 2 * state * cnt * shape.global_batch
    return 2 * n_active + cache_read


def jaxpr_cost_for_cell(arch: str, shape_name: str):
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    batch_abs = S.input_specs(cfg, shape)
    params_abs = abstract_params(MDL.param_spec(cfg))

    if shape.kind == "train":
        tc = default_train_config(cfg)
        fn = S.make_train_step(cfg, tc)
        opt_abs = S.abstract_opt_state(MDL.param_spec(cfg))
        cost = count_fn(fn, params_abs, opt_abs, batch_abs)
    elif shape.kind == "prefill":
        fn = S.make_prefill_step(cfg)
        cost = count_fn(fn, params_abs, batch_abs)
    else:
        fn = S.make_decode_step(cfg)
        cache_abs = S.abstract_cache(cfg, shape.global_batch, shape.seq_len)
        cost = count_fn(fn, params_abs, cache_abs, batch_abs)
    return cost


def update_artifact(path: pathlib.Path):
    rec = json.loads(path.read_text())
    if rec.get("status") != "ok" or rec.get("arch", "").startswith("graph-"):
        return None
    cost = jaxpr_cost_for_cell(rec["arch"], rec["shape"])
    cfg = get_arch(rec["arch"])
    shape = get_shape(rec["shape"])
    dev = rec["devices"]
    mem_bytes = analytic_memory_bytes(cfg, shape)
    rec["jaxpr_matmul_flops_total"] = cost.matmul_flops
    rec["jaxpr_elementwise_flops_total"] = cost.elementwise_flops
    rec["jaxpr_bytes_unfused_total"] = cost.bytes_touched
    rec["analytic_hbm_bytes_total"] = mem_bytes
    rec["flops_per_device"] = cost.total_flops / dev
    rec["bytes_per_device"] = mem_bytes / dev
    rec["compute_s"] = cost.matmul_flops / dev / RA.PEAK_FLOPS_BF16 \
        + cost.elementwise_flops / dev / (RA.PEAK_FLOPS_BF16 / 16)  # VPU
    rec["memory_s"] = mem_bytes / dev / RA.HBM_BW
    rec["collective_s"] = rec["collective_wire_bytes"] / RA.ICI_LINK_BW
    terms = {"compute": rec["compute_s"], "memory": rec["memory_s"],
             "collective": rec["collective_s"]}
    rec["bottleneck"] = max(terms, key=terms.get)
    rec["useful_flops_ratio"] = (
        rec["model_flops_total"] / max(cost.matmul_flops, 1.0))
    path.write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--art", default="artifacts/dryrun")
    ap.add_argument("--only", default=None, help="substring filter")
    args = ap.parse_args()
    art = pathlib.Path(args.art)
    for path in sorted(art.glob("*.json")):
        if args.only and args.only not in path.name:
            continue
        if "multipod" in path.name:
            pass  # cost identical per device; still recost for bookkeeping
        try:
            rec = update_artifact(path)
            if rec:
                print(f"{path.stem:55s} c={rec['compute_s']*1e3:9.2f}ms "
                      f"m={rec['memory_s']*1e3:9.2f}ms "
                      f"x={rec['collective_s']*1e3:9.2f}ms "
                      f"-> {rec['bottleneck']:10s} "
                      f"useful={rec['useful_flops_ratio']:.2f}")
        except Exception as e:  # noqa: BLE001
            print(f"{path.stem}: RECOST FAILED {e!r}")


if __name__ == "__main__":
    main()
