"""Jaxpr-walking FLOP/byte counter with static scan trip counts.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
independent of trip count, so any scanned program (all of ours: layers,
attention chunks, CE chunks) is undercounted by the scan lengths.  All
loops in this framework are ``lax.scan`` with static length, so walking
the jaxpr gives EXACT logical FLOPs:

  * dot_general: 2 * prod(batch) * prod(lhs_free) * prod(rhs_free)
                   * prod(contract)
  * scan: length * cost(body)  (recursive; handles nesting)
  * remat/pjit/custom_vjp wrappers: recurse into sub-jaxprs
  * elementwise / reductions: prod(output shape) (second-order; reported
    in a separate counter)

Bytes are estimated as sum of operand+result sizes per eqn (an upper
bound on HBM traffic that ignores fusion; the XLA number is reported
alongside).  These are LOGICAL (pre-SPMD) totals: divide by device count
for per-device terms, which assumes even sharding - padding waste from
uneven head counts is called out separately in EXPERIMENTS.md.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import numpy as np


@dataclass
class Cost:
    matmul_flops: float = 0.0
    elementwise_flops: float = 0.0
    bytes_touched: float = 0.0

    def __iadd__(self, other):
        self.matmul_flops += other.matmul_flops
        self.elementwise_flops += other.elementwise_flops
        self.bytes_touched += other.bytes_touched
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.matmul_flops * k, self.elementwise_flops * k,
                    self.bytes_touched * k)

    @property
    def total_flops(self) -> float:
        return self.matmul_flops + self.elementwise_flops


def _size(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except Exception:
        return 0


def _bytes(aval) -> int:
    try:
        return _size(aval) * aval.dtype.itemsize
    except Exception:
        return 0


def _dot_flops(eqn) -> float:
    (lhs, rhs) = (eqn.invars[0].aval, eqn.invars[1].aval)
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    batch = math.prod(lhs.shape[i] for i in lb) if lb else 1
    contract = math.prod(lhs.shape[i] for i in lc) if lc else 1
    lfree = math.prod(lhs.shape[i] for i in range(len(lhs.shape))
                      if i not in lc and i not in lb)
    rfree = math.prod(rhs.shape[i] for i in range(len(rhs.shape))
                      if i not in rc and i not in rb)
    return 2.0 * batch * contract * lfree * rfree


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # flops = 2 * out_elems * (kernel_spatial * in_channels)
    kernel = math.prod(rhs.shape[:-1]) if rhs.shape else 1
    return 2.0 * _size(out) * kernel


_SUBJAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr",
                    "body_jaxpr")


def _sub_jaxprs(eqn):
    for name in _SUBJAXPR_PARAMS:
        if name in eqn.params:
            yield name, eqn.params[name]
    if "branches" in eqn.params:
        for b in eqn.params["branches"]:
            yield "branch", b


def _as_jaxpr(j):
    return j.jaxpr if hasattr(j, "jaxpr") else j


def count_jaxpr(jaxpr) -> Cost:
    cost = Cost()
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            cost.matmul_flops += _dot_flops(eqn)
            cost.bytes_touched += sum(_bytes(v.aval) for v in eqn.invars)
            cost.bytes_touched += sum(_bytes(v.aval) for v in eqn.outvars)
        elif prim == "conv_general_dilated":
            cost.matmul_flops += _conv_flops(eqn)
            cost.bytes_touched += sum(_bytes(v.aval) for v in eqn.invars)
            cost.bytes_touched += sum(_bytes(v.aval) for v in eqn.outvars)
        elif prim == "scan":
            body = count_jaxpr(_as_jaxpr(eqn.params["jaxpr"]))
            cost += body.scaled(eqn.params["length"])
        elif prim == "while":
            # not used by this framework; count body once and flag
            cost += count_jaxpr(_as_jaxpr(eqn.params["body_jaxpr"]))
        elif prim == "cond":
            branches = [count_jaxpr(_as_jaxpr(b))
                        for b in eqn.params["branches"]]
            if branches:
                worst = max(branches, key=lambda c: c.total_flops)
                cost += worst
        elif any(n in eqn.params for n in ("jaxpr", "call_jaxpr",
                                           "fun_jaxpr")):
            for _, sj in _sub_jaxprs(eqn):
                cost += count_jaxpr(_as_jaxpr(sj))
        else:
            out_elems = sum(_size(v.aval) for v in eqn.outvars)
            cost.elementwise_flops += out_elems
            cost.bytes_touched += sum(_bytes(v.aval) for v in eqn.invars)
            cost.bytes_touched += out_elems and sum(
                _bytes(v.aval) for v in eqn.outvars)
    return cost


def count_fn(fn, *args, **kwargs) -> Cost:
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return count_jaxpr(closed.jaxpr)
