"""Roofline-term extraction from compiled XLA artifacts.

Three terms per (arch x shape x mesh), all in seconds-per-step on the
TARGET hardware (TPU v5e):

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_wire_bytes_per_device / link_bw

``cost_analysis()`` provides per-device FLOPs and bytes (the SPMD
partitioner emits a per-device program).  Collective bytes are NOT in
cost_analysis: we parse the compiled HLO text, find every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, and apply
a ring cost model using each op's replica-group size.
"""

from __future__ import annotations

import dataclasses
import json
import re
from dataclasses import dataclass, field

# --- TPU v5e hardware constants (per chip) ---
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_LINK_BW = 50e9             # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_ndim(shape_str: str) -> int:
    nd = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dims = [d for d in m.group(2).split(",") if d]
        nd = max(nd, len(dims))
    return nd


def _is_f32(shape_str: str) -> bool:
    m = _SHAPE_RE.search(shape_str)
    return bool(m) and m.group(1) == "f32"


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].split("{")[-1]
        return max(1, len([x for x in first.split(",") if x.strip()]))
    return 2


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)
    raw_bytes: dict = field(default_factory=dict)       # result-shape bytes
    wire_bytes: dict = field(default_factory=dict)      # ring-model bytes on the wire
    total_wire_bytes: float = 0.0
    act_wire_bytes: float = 0.0      # rank>=3 results: bf16 activations
                                     # promoted to f32 by the host backend

    def add(self, op: str, nbytes: int, gsize: int, mult: float = 1.0,
            ndim: int = 0):
        """nbytes is the RESULT-shape size from the HLO line.

        Ring wire cost per participant:
          all-reduce     result = full tensor      -> 2 (g-1)/g * result
          all-gather     result = gathered (big)   ->   (g-1)/g * result
          reduce-scatter result = scattered (small)->   (g-1)   * result
          all-to-all     result ~ input            ->   (g-1)/g * result
          collective-permute                       ->   result
        """
        self.counts[op] = self.counts.get(op, 0) + mult
        self.raw_bytes[op] = self.raw_bytes.get(op, 0) + nbytes * mult
        if op == "all-reduce":
            wire = 2.0 * (gsize - 1) / gsize * nbytes
        elif op == "reduce-scatter":
            wire = float(gsize - 1) * nbytes
        elif op in ("all-gather", "all-to-all"):
            wire = (gsize - 1) / gsize * nbytes
        else:  # collective-permute: point-to-point
            wire = float(nbytes)
        wire *= mult
        self.wire_bytes[op] = self.wire_bytes.get(op, 0.0) + wire
        self.total_wire_bytes += wire
        if ndim >= 3:
            self.act_wire_bytes += wire

    @property
    def tpu_wire_bytes(self) -> float:
        """TPU-target wire: rank>=3 f32 payloads are bf16 activations
        promoted to f32 by the host backend -> halve that share.
        Integer payloads (graph exchanges) are never promoted."""
        return self.total_wire_bytes - self.act_wire_bytes / 2.0


# header params may be tuples (nested parens): match greedily to '->'
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALL_RE = re.compile(
    r"(?:calls|to_apply|body|condition|branches|true_computation|"
    r"false_computation|branch_computations)=\{?%?"
    r"([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")
_CONST_RE = re.compile(r"%?([\w\.\-]+)\s*=\s*s32\[\]\s*constant\((\d+)\)")
_COMPARE_RE = re.compile(r"compare\(%?([\w\.\-]+),\s*%?([\w\.\-]+)\)")


def _split_computations(hlo_text: str):
    """name -> (lines, is_entry)."""
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR_RE.match(line.strip())
        if m and "{" in line:
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
        elif cur is not None:
            comps[cur].append(line)
    return comps, entry


def _trip_count(cond_lines: list[str]) -> int:
    consts = {}
    for line in cond_lines:
        m = _CONST_RE.search(line)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for line in cond_lines:
        m = _COMPARE_RE.search(line)
        if m:
            for operand in m.groups():
                if operand in consts:
                    return max(1, consts[operand])
    if consts:
        return max(1, max(consts.values()))
    return 1


_BRANCHES_RE = re.compile(
    r"(?:branch_computations|branches)=\{?%?"
    r"([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")
_TF_RE = re.compile(r"true_computation=%?([\w\.\-]+),\s*"
                    r"false_computation=%?([\w\.\-]+)")
_PLAIN_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Collective stats with while-trip-count multipliers and
    worst-branch conditionals.

    Computes a per-computation cost bottom-up: a collective inside a
    while body counts trip_count times (nested whiles multiply); a
    conditional contributes its most expensive branch (only one branch
    executes per invocation).  This corrects XLA's body-once text dump
    the same way the jaxpr counter corrects cost_analysis() FLOPs.
    """
    comps, entry = _split_computations(hlo_text)
    stats = CollectiveStats()

    if entry is None:
        for line in hlo_text.splitlines():
            m = _COLL_RE.search(line)
            if m:
                stats.add(m.group("op"), _shape_bytes(m.group("shape")),
                          _group_size(line),
                          ndim=_shape_ndim(m.group("shape"))
                          if _is_f32(m.group("shape")) else 0)
        return stats

    memo: dict[str, dict] = {}

    def merge(into: dict, frm: dict, mult: float = 1.0):
        for op, (cnt, raw, wire, act) in frm.items():
            c0, r0, w0, a0 = into.get(op, (0.0, 0.0, 0.0, 0.0))
            into[op] = (c0 + cnt * mult, r0 + raw * mult, w0 + wire * mult,
                        a0 + act * mult)

    def cost(name: str, stack: tuple) -> dict:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return {}
        stack = stack + (name,)
        out: dict = {}
        for line in comps[name]:
            cm = _COLL_RE.search(line)
            if cm:
                op = cm.group("op")
                nbytes = _shape_bytes(cm.group("shape"))
                g = _group_size(line)
                tmp = CollectiveStats()
                tmp.add(op, nbytes, g,
                        ndim=_shape_ndim(cm.group("shape"))
                        if _is_f32(cm.group("shape")) else 0)
                merge(out, {op: (tmp.counts[op], tmp.raw_bytes[op],
                                 tmp.wire_bytes[op], tmp.act_wire_bytes)})
                continue
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond, []))
                merge(out, cost(body, stack), mult=trips)
                continue
            bm = _TF_RE.search(line) or _BRANCHES_RE.search(line)
            if bm and "conditional(" in line:
                names = [b for g_ in bm.groups() if g_
                         for b in re.split(r",\s*%?", g_)]
                branch_costs = [cost(b, stack) for b in names]
                if branch_costs:
                    worst = max(branch_costs,
                                key=lambda c: sum(v[2] for v in c.values()))
                    merge(out, worst)
                continue
            pm = _PLAIN_CALL_RE.search(line)
            if pm:
                merge(out, cost(pm.group(1), stack))
        memo[name] = out
        return out

    total = cost(entry, ())
    for op, (cnt, raw, wire, act) in total.items():
        stats.counts[op] = cnt
        stats.raw_bytes[op] = raw
        stats.wire_bytes[op] = wire
        stats.total_wire_bytes += wire
        stats.act_wire_bytes += act
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    devices: int
    flops_per_device: float
    bytes_per_device: float
    collective_wire_bytes: float
    model_flops_total: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_flops_ratio: float = 0.0
    peak_hbm_bytes: float = 0.0
    collectives: dict = field(default_factory=dict)

    def finalize(self):
        self.compute_s = self.flops_per_device / PEAK_FLOPS_BF16
        self.memory_s = self.bytes_per_device / HBM_BW
        self.collective_s = self.collective_wire_bytes / ICI_LINK_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        total_hlo = self.flops_per_device * self.devices
        self.useful_flops_ratio = (
            self.model_flops_total / total_hlo if total_hlo else 0.0)
        return self

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE); D = tokens.

    Train counts fwd+bwd (the 6N convention); inference programs count
    forward only (2N per token).
    """
    n = cfg.params_active()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n * tokens


def analyze(compiled, *, arch: str, shape_name: str, mesh_name: str,
            devices: int, model_flops_total: float) -> Roofline:
    from repro.core.compat import cost_analysis
    ca = cost_analysis(compiled)
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    stats = parse_collectives(compiled.as_text())
    mem = compiled.memory_analysis()
    peak = float(mem.argument_size_in_bytes + mem.output_size_in_bytes
                 + mem.temp_size_in_bytes)
    r = Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, devices=devices,
        flops_per_device=flops, bytes_per_device=nbytes,
        collective_wire_bytes=stats.tpu_wire_bytes,
        model_flops_total=model_flops_total,
        peak_hbm_bytes=peak,
        collectives={
            "counts": stats.counts,
            "raw_bytes": stats.raw_bytes,
            "wire_bytes": stats.wire_bytes,
            "wire_bytes_f32_upper": stats.total_wire_bytes,
            "act_wire_bytes": stats.act_wire_bytes,
        },
    )
    return r.finalize()
