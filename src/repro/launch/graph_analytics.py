"""Graph-analytics driver: the paper's workload end to end.

Generates a urand/rmat/smallworld graph, partitions it over the
available devices, runs EVERY algorithm program in the registry (BFS +
PageRank in both BSP-baseline and HPX-adapted modes, SSSP, CC, triangle
counting, k-core, betweenness), verifies results, and reports timings.
Programs whose ``n_budget`` the graph exceeds (the O(n^2/P)
triangle-counting bitmap) are skipped with a note.  ``--multi-source B``
additionally runs the batched multi-source traversal programs (B roots
per launch) and reports per-query amortized time — the
serve-many-queries scenario.  ``--layout coo`` is the escape hatch back
to the COO scatter reference path (the default ``ell`` routes every
hot loop through the blocked-ELL local ops in ``core/localops.py``).
``--obs`` re-runs each program with engine telemetry on (per-round
halt/probe series + wire bytes per exchange primitive, ``repro.obs``)
and ``--trace-out trace.json`` exports those runs as a Chrome trace.

  PYTHONPATH=src python -m repro.launch.graph_analytics --graph urand18
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.graph_analytics \
      --graph urand20 --parts 8 --multi-source 16
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import graph_workloads
from repro.core import GraphEngine, incremental, partition_graph, registry
from repro.core.registry import program_label
from repro.graphs import generate_edges
from repro.launch.mesh import make_graph_mesh
from repro.obs import chrome_trace, write_trace

def _timed(fn, args):
    out = fn(*args)               # compile
    jax.block_until_ready(out)
    t0 = time.time()
    out = fn(*args)
    jax.block_until_ready(out)
    return out, time.time() - t0


def run(graph_name: str, parts: int, *, pr_iters: int = 50,
        verify: bool = True, seed: int = 42, multi_source: int = 0,
        layout: str = "ell", exec_mode: str = "all", obs: bool = False,
        trace_out: str | None = None):
    from repro.core import localops
    gcfg = graph_workloads.ALL[graph_name]
    print(f"[graph] generating {graph_name}: 2^{gcfg.scale} vertices, "
          f"{gcfg.num_edges:,} edges ({gcfg.generator})")
    edges = generate_edges(gcfg, seed)
    t0 = time.time()
    g = partition_graph(edges, gcfg.num_vertices, parts)
    ell_slots = sum(m.slots for m in g.ell_meta.values())
    print(f"[graph] partitioned over {parts} parts in {time.time()-t0:.1f}s "
          f"(n_local={g.n_local:,}, e_max={g.e_max:,}; layout={layout} "
          f"ell_slots/part={ell_slots:,} localops={localops.get_mode()})")
    eng = GraphEngine(g, make_graph_mesh(parts), layout=layout)
    garr = eng.device_graph()
    root = jnp.int32(0)
    results = {}
    obs = obs or bool(trace_out)
    engine_tracks = []     # (label, RunTelemetry, parts) for the export

    for algo, variant in registry.available():
        spec = registry.get_spec(algo, variant)
        name = program_label(algo, variant)
        if exec_mode != "all" and spec.exec_mode != exec_mode:
            continue
        if spec.n_budget and g.n > spec.n_budget:
            print(f"[graph] {name:14s}   skipped (n={g.n:,} exceeds its "
                  f"n_budget={spec.n_budget:,})")
            continue
        params = {"iters": pr_iters} if algo == "pagerank" else {}
        prog = eng.program(algo, variant, **params)
        if any(k != "scalar" for k in spec.input_kinds):
            # seeded incremental variants run from their cold seed here
            # (the warm path needs a previous epoch — that's the server)
            (seed_arr,) = incremental.cold_seed(spec, g)
            args = (garr, eng.scatter_vertex_field(
                seed_arr, incremental.KIND_DTYPES[spec.input_kinds[0]]))
        else:
            args = (garr,) + (root,) * len(spec.inputs)
        out, dt = _timed(prog, args)
        results[name] = (out, dt)
        print(f"[graph] {name:14s} {dt*1e3:9.1f} ms")
        if obs:
            # a SEPARATE telemetry build (telemetry is a compile-cache
            # dimension), run after the timed one so the headline ms
            # stays the un-instrumented number
            tprog = eng.program(algo, variant, telemetry=True, **params)
            tout = tprog(*args)
            tel = tprog.run_telemetry(tout[-1])
            engine_tracks.append((name, tel, parts))
            s = tel.summary()
            wire = s.get("wire_bytes_per_round", {})
            print(f"[obs]   {name:14s} rounds={s['rounds']:3d} "
                  f"wall={s.get('wall_ms', 0.0):8.1f} ms  wire/round="
                  + (" ".join(f"{op}:{b:,}B"
                              for op, b in wire.items()) or "none"))

    if multi_source:
        roots = jnp.arange(multi_source, dtype=jnp.int32)
        for algo, variant in registry.available():
            spec = registry.get_spec(algo, variant)
            if (not spec.inputs or variant == "bsp"
                    or any(k != "scalar" for k in spec.input_kinds)):
                continue          # batch only the rooted traversal fast paths
            if exec_mode != "all" and spec.exec_mode != exec_mode:
                continue
            if spec.n_budget and g.n > spec.n_budget:
                continue
            prog = eng.program(algo, variant, batch=multi_source)
            name = f"{program_label(algo, variant)}_x{multi_source}"
            out, dt = _timed(prog, (garr, roots))
            results[name] = (out, dt)
            print(f"[graph] {name:14s} {dt*1e3:9.1f} ms "
                  f"({dt*1e3/multi_source:7.1f} ms/query)")

    if verify:
        if "bfs_bsp" in results and "bfs_fast" in results:
            p_bsp = eng.gather_vertex_field(results["bfs_bsp"][0][0])
            p_fast = eng.gather_vertex_field(results["bfs_fast"][0][0])
            same = ((p_bsp < 2 ** 30) == (p_fast < 2 ** 30)).all()
            print(f"[verify] BFS reachability bsp==fast: {bool(same)}")
        if "pagerank_bsp" in results and "pagerank_fast" in results:
            r_bsp = eng.gather_vertex_field(results["pagerank_bsp"][0][0])
            r_fast = eng.gather_vertex_field(results["pagerank_fast"][0][0])
            rel = np.abs(r_bsp - r_fast).max() / r_bsp.max()
            print(f"[verify] PageRank bsp-vs-fast max rel diff: {rel:.2e}")
        # async-vs-bsp cross-checks when both modes ran
        if "bfs_async" in results and "bfs_fast" in results:
            pa = eng.gather_vertex_field(results["bfs_async"][0][0])
            pf = eng.gather_vertex_field(results["bfs_fast"][0][0])
            same = ((pa < 2 ** 30) == (pf < 2 ** 30)).all()
            print(f"[verify] BFS reachability async==fast: {bool(same)}")
        if "pagerank_async" in results and "pagerank_bsp" in results:
            ra = eng.gather_vertex_field(results["pagerank_async"][0][0])
            rb = eng.gather_vertex_field(results["pagerank_bsp"][0][0])
            rel = np.abs(ra - rb).max() / rb.max()
            print(f"[verify] PageRank bsp-vs-async max rel diff: {rel:.2e}")
        if "cc_async" in results and "cc" in results:
            la = eng.gather_vertex_field(results["cc_async"][0][0])
            lb = eng.gather_vertex_field(results["cc"][0][0])
            print(f"[verify] CC labels async==bsp: "
                  f"{bool((la == lb).all())}")
        if "sssp_async" in results and "sssp" in results:
            da = eng.gather_vertex_field(results["sssp_async"][0][0])
            db = eng.gather_vertex_field(results["sssp"][0][0])
            print(f"[verify] SSSP dist async==bsp: "
                  f"{bool((da == db).all())}")
        if "kcore" in results:
            kmax = int(results["kcore"][0][1])
            print(f"[verify] k-core degeneracy: {kmax}")
        if "betweenness" in results:
            bc0 = float(eng.gather_vertex_field(
                results["betweenness"][0][0])[0])
            print(f"[verify] betweenness delta_s(s) == 0: {bc0 == 0.0}")
        if "triangles" in results:
            tri = eng.gather_vertex_field(results["triangles"][0][0])
            total = int(results["triangles"][0][1])
            print(f"[verify] triangles sum/3 == total: "
                  f"{int(tri.sum()) // 3 == total} ({total:,})")
        if multi_source:
            mb = eng.gather_batched_vertex_field(
                results[f"bfs_fast_x{multi_source}"][0][0])
            same = ((mb[0] < 2 ** 30) == (p_fast < 2 ** 30)).all()
            print(f"[verify] multi-source BFS root0 == single-source: "
                  f"{bool(same)}")

    if trace_out and engine_tracks:
        counts = write_trace(trace_out, chrome_trace(engine=engine_tracks))
        print(f"[graph] wrote {trace_out} (chrome trace, "
              f"{sum(counts.values())} events; open in ui.perfetto.dev)")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="urand16")
    ap.add_argument("--parts", type=int, default=len(jax.devices()))
    ap.add_argument("--pr-iters", type=int, default=50)
    ap.add_argument("--multi-source", type=int, default=0,
                    help="also run batched multi-source traversals "
                         "with this many roots")
    ap.add_argument("--layout", choices=("ell", "coo"), default="ell",
                    help="edge layout for the superstep hot loops: "
                         "blocked-ELL (backend-tuned local ops) or the "
                         "COO scatter reference path (escape hatch); "
                         "REPRO_LOCALOPS={auto,ref,kernel} further "
                         "overrides the localops dispatch")
    ap.add_argument("--exec-mode", choices=("all", "bsp", "async"),
                    default="all",
                    help="restrict to one superstep driver: bsp runs "
                         "the synchronous programs only, async the "
                         "stale-tolerant double-buffered ones; all "
                         "runs both and cross-checks them in verify")
    ap.add_argument("--obs", action="store_true",
                    help="also run each program with telemetry=True "
                         "(separate compile-cache entry) and report "
                         "per-round series + wire bytes per primitive")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace-event JSON of the "
                         "telemetry runs (implies --obs; open in "
                         "ui.perfetto.dev)")
    ap.add_argument("--no-verify", action="store_true")
    args = ap.parse_args()
    run(args.graph, args.parts, pr_iters=args.pr_iters,
        verify=not args.no_verify, multi_source=args.multi_source,
        layout=args.layout, exec_mode=args.exec_mode, obs=args.obs,
        trace_out=args.trace_out)


if __name__ == "__main__":
    main()
