"""Graph-analytics driver: the paper's workload end to end.

Generates a urand/rmat graph, partitions it over the available devices,
runs BFS + PageRank (+ SSSP, CC) in both BSP-baseline and HPX-adapted
modes, verifies results, and reports timings.

  PYTHONPATH=src python -m repro.launch.graph_analytics --graph urand18
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.graph_analytics \
      --graph urand20 --parts 8
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import graph_workloads
from repro.core import GraphEngine, partition_graph
from repro.graphs import generate_edges
from repro.launch.mesh import make_graph_mesh


def run(graph_name: str, parts: int, *, pr_iters: int = 50,
        verify: bool = True, seed: int = 42):
    gcfg = graph_workloads.ALL[graph_name]
    print(f"[graph] generating {graph_name}: 2^{gcfg.scale} vertices, "
          f"{gcfg.num_edges:,} edges ({gcfg.generator})")
    edges = generate_edges(gcfg, seed)
    t0 = time.time()
    g = partition_graph(edges, gcfg.num_vertices, parts)
    print(f"[graph] partitioned over {parts} parts in {time.time()-t0:.1f}s "
          f"(n_local={g.n_local:,}, e_max={g.e_max:,})")
    eng = GraphEngine(g, make_graph_mesh(parts))
    garr = eng.device_graph()
    root = jnp.int32(0)
    results = {}

    for name, fn, args in [
        ("bfs_bsp", eng.bfs(mode="bsp"), (garr, root)),
        ("bfs_fast", eng.bfs(mode="fast"), (garr, root)),
        ("pagerank_bsp", eng.pagerank(mode="bsp", iters=pr_iters), (garr,)),
        ("pagerank_fast", eng.pagerank(mode="fast", iters=pr_iters), (garr,)),
        ("sssp", eng.sssp(), (garr, root)),
        ("cc", eng.cc(), (garr,)),
    ]:
        out = fn(*args)           # compile
        jax.block_until_ready(out)
        t0 = time.time()
        out = fn(*args)
        jax.block_until_ready(out)
        dt = time.time() - t0
        results[name] = (out, dt)
        print(f"[graph] {name:14s} {dt*1e3:9.1f} ms")

    if verify:
        p_bsp = eng.gather_vertex_field(results["bfs_bsp"][0][0])
        p_fast = eng.gather_vertex_field(results["bfs_fast"][0][0])
        same = ((p_bsp < 2 ** 30) == (p_fast < 2 ** 30)).all()
        print(f"[verify] BFS reachability bsp==fast: {bool(same)}")
        r_bsp = eng.gather_vertex_field(results["pagerank_bsp"][0][0])
        r_fast = eng.gather_vertex_field(results["pagerank_fast"][0][0])
        rel = np.abs(r_bsp - r_fast).max() / r_bsp.max()
        print(f"[verify] PageRank bsp-vs-fast max rel diff: {rel:.2e}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="urand16")
    ap.add_argument("--parts", type=int, default=len(jax.devices()))
    ap.add_argument("--pr-iters", type=int, default=50)
    ap.add_argument("--no-verify", action="store_true")
    args = ap.parse_args()
    run(args.graph, args.parts, pr_iters=args.pr_iters,
        verify=not args.no_verify)


if __name__ == "__main__":
    main()
