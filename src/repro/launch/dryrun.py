import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-lower + compile every (architecture x input
shape) cell against the production mesh, print memory/cost analysis, and
write roofline artifacts.

Runs with 512 placeholder host devices (the two lines above MUST precede
any other import -- JAX locks the device count on first init).

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --arch all --mesh both --out artifacts/dryrun
  python -m repro.launch.dryrun --graph urand28      # paper-side engine
"""

import argparse
import json
import pathlib
import time
import traceback


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir, *,
             impl: str = "chunked", save_hlo: bool = False) -> dict:
    import jax

    from repro.configs.registry import get_arch, get_shape
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import lower_cell
    from repro.roofline import analysis as RA

    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    devices = mesh.size

    t0 = time.time()
    lowered, meta = lower_cell(cfg, shape, mesh, impl=impl)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    print(f"[{arch} x {shape_name} x {mesh_name}] {meta['program']}")
    print(f"  memory_analysis: {mem}")
    from repro.core.compat import cost_analysis
    ca = cost_analysis(compiled)
    print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
          f"bytes={ca.get('bytes accessed', 0):.3e}")

    roof = RA.analyze(
        compiled, arch=arch, shape_name=shape_name, mesh_name=mesh_name,
        devices=devices,
        model_flops_total=RA.model_flops(cfg, shape))
    rec = roof.to_json()
    rec.update({
        "program": meta["program"],
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "arg_bytes_per_device": mem.argument_size_in_bytes,
        "temp_bytes_per_device": mem.temp_size_in_bytes,
        "out_bytes_per_device": mem.output_size_in_bytes,
        "status": "ok",
    })
    hbm = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
           + mem.output_size_in_bytes) / 1e9
    print(f"  per-device HBM: {hbm:.2f} GB "
          f"(args {mem.argument_size_in_bytes/1e9:.2f} + "
          f"temps {mem.temp_size_in_bytes/1e9:.2f}) "
          f"| bottleneck: {roof.bottleneck} "
          f"(c={roof.compute_s*1e3:.1f}ms m={roof.memory_s*1e3:.1f}ms "
          f"x={roof.collective_s*1e3:.1f}ms) "
          f"useful-flops={roof.useful_flops_ratio:.2f}")

    if out_dir:
        out_dir = pathlib.Path(out_dir)
        out_dir.mkdir(parents=True, exist_ok=True)
        name = f"{arch}__{shape_name}__{mesh_name}"
        (out_dir / f"{name}.json").write_text(json.dumps(rec, indent=2))
        if save_hlo:
            (out_dir / f"{name}.hlo.txt").write_text(compiled.as_text())
    return rec


def run_graph_dryrun(graph_name: str, mesh_name: str, out_dir) -> list[dict]:
    """Dry-run the paper's graph engine (BFS + PageRank) on the mesh."""
    from repro.core.dryrun import lower_graph_programs

    return lower_graph_programs(graph_name, mesh_name, out_dir)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--graph", default=None,
                    help="run the graph-engine dry-run for this workload")
    ap.add_argument("--impl", default="chunked")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    if args.graph:
        for m in (["pod", "multipod"] if args.mesh == "both" else [args.mesh]):
            run_graph_dryrun(args.graph, m, args.out)
        return

    from repro.configs.base import shapes_for
    from repro.configs.registry import ARCHS, get_arch

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        cfg = get_arch(arch)
        shape_names = ([s.name for s in shapes_for(cfg)]
                       if args.shape == "all" else [args.shape])
        for shape_name in shape_names:
            for mesh_name in meshes:
                try:
                    run_cell(arch, shape_name, mesh_name, args.out,
                             impl=args.impl, save_hlo=args.save_hlo)
                except Exception as e:  # noqa: BLE001 - report and continue
                    traceback.print_exc()
                    failures.append((arch, shape_name, mesh_name, repr(e)[:200]))
                    if args.out:
                        out = pathlib.Path(args.out)
                        out.mkdir(parents=True, exist_ok=True)
                        name = f"{arch}__{shape_name}__{mesh_name}"
                        (out / f"{name}.json").write_text(json.dumps(
                            {"arch": arch, "shape": shape_name,
                             "mesh": mesh_name, "status": "fail",
                             "error": repr(e)[:500]}, indent=2))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nAll dry-run cells passed.")


if __name__ == "__main__":
    main()
