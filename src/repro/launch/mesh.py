"""Production mesh construction.

Single pod:  (16, 16)    axes ("data", "model")   = 256 chips (TPU v5e pod)
Multi-pod:   (2, 16, 16) axes ("pod", "data", "model") = 512 chips

Defined as functions (not module-level constants) so importing this
module never touches JAX device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any JAX
import; everything else sees the real device count.
"""

from __future__ import annotations

import jax

from repro.core.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devices = jax.devices()[:need]
    if len(devices) < need:
        raise RuntimeError(
            f"production mesh needs {need} devices, found {len(devices)}; "
            "the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return make_mesh(shape, axes, devices=devices)


def make_local_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    return make_mesh((data, model), ("data", "model"))


def make_graph_mesh(parts: int) -> jax.sharding.Mesh:
    """1D mesh for the graph engine: vertex partitions over all chips."""
    return make_mesh((parts,), ("parts",))


def batch_axes(mesh: jax.sharding.Mesh, batch: int):
    """Largest prefix of (pod, data) that divides the batch."""
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    chosen: list[str] = []
    size = 1
    for a in axes:
        if batch % (size * mesh.shape[a]) == 0:
            chosen.append(a)
            size *= mesh.shape[a]
    if not chosen:
        return None
    return tuple(chosen) if len(chosen) > 1 else chosen[0]
