"""GRAPH query-server driver: resident engine + coalesced mixed traffic.

Generates and partitions a graph once, keeps it device-resident in a
:class:`~repro.serve.server.GraphServer`, warms the bucket ladder for
every program in the mix, then replays a synthetic arrival trace
(Poisson arrivals, Zipfian roots, weighted algorithm mix) through the
coalescing/double-buffered serve pipeline and reports queries/sec and
p50/p95/p99 latency per (program, bucket) cell.

  PYTHONPATH=src python -m repro.launch.graph_serve \
      --graph urand16 --parts 2 --mix bfs:8,sssp:4,cc:1 --duration 10

``--mutate-every S --mutate-size K`` merges a timed mutation stream
(``repro.serve.dynamic.mutation_stream``) into the trace: every S
seconds a K-edge delete/insert batch applies in place and opens a new
snapshot epoch, so the replay exercises serving under churn.

``--wal-dir DIR`` makes the server durable (write-ahead mutation log +
crash-consistent snapshots every ``--snapshot-every`` epochs, see
``repro.serve.persist``); ``--recover --wal-dir DIR`` resumes a killed
server from that directory instead of regenerating the graph.

``--obs`` traces the serving path (every pipeline stage as spans in a
bounded ring, see ``repro.obs``) and prints a trace summary;
``--trace-out trace.json`` additionally exports the session as Chrome
trace-event JSON for ui.perfetto.dev (implies ``--obs``).  The
``--json`` payload gains a ``trace_summary`` block when tracing is on.

(Use XLA_FLAGS=--xla_force_host_platform_device_count=N for --parts N
on a single host, as with repro.launch.graph_analytics.)

This is the GRAPH server.  The other serving driver in this package,
``repro.launch.serve``, is the seed's LLM token-serving driver (batched
prefill + decode over the transformer stack); the two share nothing
but the name.
"""

from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs import graph_workloads
from repro.core import GraphEngine, localops, partition_graph
from repro.core.compat import runtime_fingerprint
from repro.graphs import generate_edges
from repro.launch.mesh import make_graph_mesh
from repro.obs import SpanRecorder, chrome_trace, trace_summary, \
    write_trace
from repro.serve import GraphServer, Persistence, mutation_stream, \
    parse_mix, synthetic_trace


def run(graph_name: str, parts: int, *, mix: str = "bfs:8,sssp:4,cc:1",
        duration: float = 10.0, rate: float = 64.0, buckets=(1, 8, 32, 128),
        depth: int = 2, zipf_s: float = 1.05, seed: int = 42,
        layout: str = "ell", json_path: str | None = None,
        mutate_every: float = 0.0, mutate_size: int = 64,
        wal_dir: str | None = None, snapshot_every: int = 8,
        recover: bool = False, obs: bool = False,
        trace_out: str | None = None):
    gcfg = graph_workloads.ALL[graph_name]
    # --trace-out implies tracing; a SpanRecorder on the server records
    # every pipeline stage (admission -> ... -> demux) plus durability
    # spans and resilience events
    rec = SpanRecorder() if (obs or trace_out) else None
    edges = None
    if recover:
        if not wal_dir:
            raise SystemExit("[serve] --recover requires --wal-dir")
        t0 = time.time()
        server = GraphServer.recover(wal_dir, buckets=buckets, depth=depth,
                                     snapshot_every=snapshot_every,
                                     obs=rec)
        eng = server.engine
        rep = server.recovery_report
        print(f"[serve] recovered {wal_dir} in {time.time()-t0:.1f}s: "
              f"epoch {server.epoch} (snapshot {rep.snapshot_epoch} "
              f"+ {rep.replayed} WAL records replayed, "
              f"{rep.skipped} skipped, {rep.rebuilds} rebuilds)")
    else:
        print(f"[serve] generating {graph_name}: 2^{gcfg.scale} vertices, "
              f"{gcfg.num_edges:,} edges ({gcfg.generator})")
        edges = generate_edges(gcfg, seed)
        t0 = time.time()
        g = partition_graph(edges, gcfg.num_vertices, parts)
        print(f"[serve] partitioned over {parts} parts in "
              f"{time.time()-t0:.1f}s "
              f"(layout={layout} localops={localops.get_mode()})")
        eng = GraphEngine(g, make_graph_mesh(parts), layout=layout)
        persistence = Persistence(dir=wal_dir,
                                  snapshot_every=snapshot_every) \
            if wal_dir else None
        server = GraphServer(eng, buckets=buckets, depth=depth,
                             persistence=persistence, obs=rec)
        if persistence:
            print(f"[serve] durable: wal-dir={wal_dir} "
                  f"snapshot_every={snapshot_every}")

    keys = parse_mix(mix)
    t0 = time.time()
    launches = server.warmup([k for k, _ in keys])
    print(f"[serve] warmed {launches} (program x bucket) launches in "
          f"{time.time()-t0:.1f}s; ladder={server.ladder.sizes} "
          f"depth={depth}")

    trace = synthetic_trace(eng.g.n_orig, keys, rate=rate,
                            duration=duration, zipf_s=zipf_s, seed=seed)
    n_mut = 0
    if mutate_every > 0:
        src_edges = edges if edges is not None \
            else server.dynamic_graph().current_edges()
        events = mutation_stream(src_edges, every=mutate_every,
                                 size=mutate_size, duration=duration,
                                 seed=seed)
        trace = trace + events          # serve_trace sorts by time
        n_mut = len(events)
        print(f"[serve] merged {n_mut} mutation batches "
              f"(every {mutate_every:.1f}s, {mutate_size} edges each)")
    print(f"[serve] replaying {len(trace)-n_mut} queries over "
          f"{duration:.0f}s (rate={rate:.0f}/s, mix={mix}, "
          f"zipf_s={zipf_s})")
    results = server.serve_trace(trace)
    print(f"[serve] served {len(results)} queries "
          f"({len(results)/server.metrics.window_s:.1f} q/s overall)")
    if server.mutation_log:
        rebuilds = sum(m["rebuild"] for m in server.mutation_log)
        print(f"[serve] applied {len(server.mutation_log)} mutation "
              f"batches ({rebuilds} rebuilds); final epoch {server.epoch}")
    print(server.metrics.table())

    summ = None
    if rec is not None:
        summ = trace_summary(rec)
        top = ", ".join(f"{r['kind']}={r['p99_ms']:.2f}ms"
                        for r in summ["top_p99_ms"])
        print(f"[serve] obs: {summ['spans_total']} spans / "
              f"{summ['events_total']} events recorded; top p99: {top}")
    if trace_out:
        counts = write_trace(trace_out, chrome_trace(
            spans=rec.spans(), events=rec.events()))
        print(f"[serve] wrote {trace_out} "
              f"(chrome trace, {sum(counts.values())} events; open in "
              f"ui.perfetto.dev)")

    if json_path:
        snap = server.metrics.snapshot()
        payload = {
            "meta": {"graph": graph_name, "parts": parts, "mix": mix,
                     "rate": rate, "duration": duration,
                     "buckets": list(server.ladder.sizes), "depth": depth,
                     "zipf_s": zipf_s, "layout": layout,
                     "localops": localops.get_mode(),
                     "mutate_every": mutate_every,
                     "mutate_size": mutate_size,
                     "mutations": len(server.mutation_log),
                     "final_epoch": server.epoch,
                     "wal_dir": wal_dir, "recovered": bool(recover),
                     **runtime_fingerprint()},
            "rows": snap["rows"],
            # resilience + durability observability (the PR 8 counters
            # were log-only; overload/recovery drills script off these)
            "counts": snap["counts"],
            "epoch": snap["epoch"],
            "recoveries": snap["recoveries"],
            "wal_records": snap["wal_records"],
        }
        if summ is not None:
            payload["trace_summary"] = summ
        text = json.dumps(payload, indent=2)
        if json_path == "-":
            print("SERVE_JSON " + json.dumps(payload))
        else:
            with open(json_path, "w") as f:
                f.write(text + "\n")
            print(f"[serve] wrote {json_path}")
    return server


def main():
    ap = argparse.ArgumentParser(
        description="Graph query server: coalesced mixed-algorithm "
                    "traffic against a device-resident graph.",
        epilog="For the LLM token-serving driver (batched "
               "prefill/decode) see: python -m repro.launch.serve")
    ap.add_argument("--graph", default="urand16")
    ap.add_argument("--parts", type=int, default=len(jax.devices()))
    ap.add_argument("--mix", default="bfs:8,sssp:4,cc:1",
                    help="algo[/variant][:weight] list, e.g. "
                         "bfs:8,sssp:4,cc:1")
    ap.add_argument("--duration", type=float, default=10.0,
                    help="trace length in seconds")
    ap.add_argument("--rate", type=float, default=64.0,
                    help="Poisson arrival rate, queries/sec")
    ap.add_argument("--buckets", default="1,8,32,128",
                    help="coalescing batch-size ladder")
    ap.add_argument("--depth", type=int, default=2,
                    help="in-flight launch pipeline depth")
    ap.add_argument("--zipf", type=float, default=1.05,
                    help="Zipf skew of the root distribution")
    ap.add_argument("--layout", choices=("ell", "coo"), default="ell")
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--json", default=None,
                    help="write metrics rows to this path ('-' = stdout)")
    ap.add_argument("--mutate-every", type=float, default=0.0,
                    help="apply a mutation batch every this many seconds "
                         "(0 = static graph); epochs advance mid-trace")
    ap.add_argument("--mutate-size", type=int, default=64,
                    help="edges per mutation batch (alternating "
                         "delete/insert; see serve.dynamic.mutation_stream)")
    ap.add_argument("--wal-dir", default=None,
                    help="durability directory (WAL + snapshots); makes "
                         "the server crash-recoverable")
    ap.add_argument("--snapshot-every", type=int, default=8,
                    help="epochs between crash-consistent snapshots")
    ap.add_argument("--recover", action="store_true",
                    help="resume from --wal-dir instead of generating "
                         "and partitioning a fresh graph")
    ap.add_argument("--obs", action="store_true",
                    help="record serving-path spans (admission/dispatch/"
                         "device/demux/...) and report a trace summary")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace-event JSON of the serve "
                         "session (implies --obs; open in ui.perfetto.dev)")
    args = ap.parse_args()
    run(args.graph, args.parts, mix=args.mix, duration=args.duration,
        rate=args.rate,
        buckets=tuple(int(b) for b in args.buckets.split(",")),
        depth=args.depth, zipf_s=args.zipf, seed=args.seed,
        layout=args.layout, json_path=args.json,
        mutate_every=args.mutate_every, mutate_size=args.mutate_size,
        wal_dir=args.wal_dir, snapshot_every=args.snapshot_every,
        recover=args.recover, obs=args.obs, trace_out=args.trace_out)


if __name__ == "__main__":
    main()
