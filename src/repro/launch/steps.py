"""Jit entry points (train / prefill / decode) with full sharding plans,
plus ``input_specs()``: ShapeDtypeStruct stand-ins for every program
input (the dry-run lowers against these; nothing is allocated).
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.distributed import actctx
from repro.launch.mesh import batch_axes
from repro.models import model as MDL
from repro.models.params import (
    abstract_params,
    param_shardings,
    replicated_sharding,
)
from repro.optim import OptState, adamw_update, init_opt_state

P = jax.sharding.PartitionSpec
NS = jax.sharding.NamedSharding


# ---------------------------------------------------------------------------
# Input specs (abstract): every model input as ShapeDtypeStruct
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """Abstract batch for one (arch x shape) cell.

    train/prefill: {"tokens": (B, S) i32} (+ modality stubs)
    decode:        {"tokens": (B, 1) i32}
    """
    B = shape.global_batch
    if shape.kind == "decode":
        batch = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    else:
        batch = {"tokens": jax.ShapeDtypeStruct((B, shape.seq_len), jnp.int32)}
    if cfg.family == "audio":
        batch["enc_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm" and shape.kind != "decode":
        batch["vis_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    return batch


def abstract_cache(cfg: ModelConfig, batch: int, ctx_len: int):
    return jax.eval_shape(
        lambda: MDL.init_cache(cfg, batch, ctx_len))


def abstract_opt_state(spec_tree):
    params_abs = abstract_params(spec_tree)
    return jax.eval_shape(init_opt_state, params_abs)


# ---------------------------------------------------------------------------
# Sharding plans
# ---------------------------------------------------------------------------
def batch_shardings(cfg, shape, mesh, batch_abs):
    ba = batch_axes(mesh, shape.global_batch)

    def one(x):
        extra = (None,) * (x.ndim - 1)
        return NS(mesh, P(ba, *extra))

    return jax.tree.map(one, batch_abs)


def cache_shardings(cfg: ModelConfig, mesh, batch: int, ctx_len: int):
    """Cache sharding: B over data axes, cache-seq over "model".

    The seq axis of KV buffers is always divisible by the model axis
    (windows and context lengths are powers of two), which shards the
    dominant decode state evenly regardless of kv-head count.
    """
    ba = batch_axes(mesh, batch)
    m = mesh.shape["model"]
    plan = MDL.build_plan(cfg)
    segs = []
    for seg in plan:
        if seg.kind in ("attn", "moe", "shared_attn", "xattn"):
            wlen = seg.window if seg.window > 0 else ctx_len
            wlen = min(wlen, ctx_len)
            sa = "model" if wlen % m == 0 else None
            lead = () if seg.kind == "shared_attn" else (None,)
            c = {"k": NS(mesh, P(*lead, ba, sa, None, None)),
                 "v": NS(mesh, P(*lead, ba, sa, None, None))}
            if seg.kind == "xattn":
                xa = "model" if cfg.encoder_seq % m == 0 else None
                c["xk"] = NS(mesh, P(*lead, ba, xa, None, None))
                c["xv"] = NS(mesh, P(*lead, ba, xa, None, None))
            segs.append(c)
        elif seg.kind == "mamba":
            ha = "model" if cfg.ssm_nheads % m == 0 else None
            conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
            ca = "model" if conv_dim % m == 0 else None
            segs.append({
                "h": NS(mesh, P(None, ba, ha, None, None)),
                "conv": NS(mesh, P(None, ba, None, ca)),
            })
    return {"segments": segs, "pos": replicated_sharding(mesh)}


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, tc: TrainConfig, *, impl="chunked"):
    """Train step with optional gradient accumulation.

    With ``tc.grad_accum = N`` the global batch is split into N
    microbatches scanned sequentially; gradients accumulate in fp32 with
    the parameter sharding.  Activation memory scales 1/N while keeping
    the same global batch semantics.
    """

    def loss_fn(p, mb):
        return MDL.forward_train(p, cfg, mb, impl=impl, remat=tc.remat)

    def train_step(params, opt_state, batch):
        accum = tc.grad_accum
        if accum <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch)

            def mb_step(acc, mb):
                g_acc, l_acc = acc
                (l, m), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), m

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), metrics = jax.lax.scan(
                mb_step, (g0, jnp.float32(0)), mbs)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
            metrics = jax.tree.map(lambda m: m[-1], metrics)

        params2, opt2, om = adamw_update(params, grads, opt_state, tc)
        return params2, opt2, {"loss": loss, **metrics, **om}

    return train_step


def default_train_config(cfg: ModelConfig) -> TrainConfig:
    """Production defaults: scale grad accumulation with model size so
    per-device activation memory stays within HBM on the fixed mesh."""
    n = cfg.params_total()
    # accumulation trades activation memory against ZeRO-3 weight
    # re-gathers (one full gather pass per microbatch) - keep it as low
    # as the activation budget allows (EXPERIMENTS SPerf iteration 4/5)
    if n > 1e11:
        accum = 8        # dbrx: experts are 2-D sharded (no gathers)
    elif n > 2e10:
        accum = 4
    elif n > 5e9:
        accum = 4
    elif n > 3e9:
        accum = 2
    else:
        accum = 1
    return TrainConfig(grad_accum=accum)


def make_prefill_step(cfg: ModelConfig, *, impl="chunked"):
    def prefill_step(params, batch):
        return MDL.forward_prefill(params, cfg, batch, impl=impl)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, batch):
        return MDL.forward_decode(params, cfg, batch["tokens"], cache)

    return decode_step


# ---------------------------------------------------------------------------
# AOT lowering for one (arch x shape x mesh) cell
# ---------------------------------------------------------------------------
def _to_serving_dtype(abs_tree):
    """Serving checkpoints are bf16: halves inference HBM + weight-gather
    wire vs the fp32 training master copy."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        if s.dtype == jnp.float32 else s, abs_tree)


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               tc: Optional[TrainConfig] = None, *, impl="chunked"):
    """Lower (not compile) the step program for a cell against abstract
    inputs with the full sharding plan. Returns (lowered, meta)."""
    tc = tc or default_train_config(cfg)
    spec_tree = MDL.param_spec(cfg)
    params_abs = abstract_params(spec_tree)
    if shape.kind in ("prefill", "decode"):
        params_abs = _to_serving_dtype(params_abs)
    param_sh = param_shardings(spec_tree, mesh)
    batch_abs = input_specs(cfg, shape)
    batch_sh = batch_shardings(cfg, shape, mesh, batch_abs)
    ba = batch_axes(mesh, shape.global_batch)
    rep = replicated_sharding(mesh)

    if shape.kind == "train":
        policy = actctx.make_train_policy(mesh, batch_axes=ba)
        opt_abs = abstract_opt_state(spec_tree)
        opt_sh = OptState(m=param_sh, v=param_sh, step=rep)
        fn = make_train_step(cfg, tc, impl=impl)
        with actctx.policy(policy):
            lowered = jax.jit(
                fn,
                in_shardings=(param_sh, opt_sh, batch_sh),
                out_shardings=(param_sh, opt_sh, None),
                donate_argnums=(0, 1),
            ).lower(params_abs, opt_abs, batch_abs)
        return lowered, {"program": "train_step"}

    if shape.kind == "prefill":
        policy = actctx.make_infer_policy(mesh, batch_axes=ba)
        cache_sh = cache_shardings(cfg, mesh, shape.global_batch,
                                   shape.seq_len)
        fn = make_prefill_step(cfg, impl=impl)
        with actctx.policy(policy):
            lowered = jax.jit(
                fn,
                in_shardings=(param_sh, batch_sh),
                out_shardings=(None, cache_sh),
            ).lower(params_abs, batch_abs)
        return lowered, {"program": "prefill_step"}

    # decode
    policy = actctx.make_infer_policy(mesh, batch_axes=ba)
    cache_abs = abstract_cache(cfg, shape.global_batch, shape.seq_len)
    cache_sh = cache_shardings(cfg, mesh, shape.global_batch, shape.seq_len)
    fn = make_decode_step(cfg)
    with actctx.policy(policy):
        lowered = jax.jit(
            fn,
            in_shardings=(param_sh, cache_sh, batch_sh),
            out_shardings=(None, cache_sh),
            donate_argnums=(1,),
        ).lower(params_abs, cache_abs, batch_abs)
    return lowered, {"program": "serve_step(decode)"}
