"""Training driver: end-to-end loop with checkpoint/restart, straggler
watchdog, and (simulated) elastic remesh.

Runs real steps on whatever devices exist (CPU smoke scale through
production meshes).  Example:

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --smoke --steps 200 --batch 8 --seq 128

  # fault-tolerance demo: kill at step 60, auto-resume from checkpoint
  ... --simulate-failure 60
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.configs.base import TrainConfig
from repro.configs.registry import get_arch, smoke_config
from repro.data import TokenStream
from repro.distributed import actctx
from repro.distributed.fault_tolerance import StepWatchdog, plan_remesh
from repro.launch.mesh import batch_axes, make_local_mesh
from repro.launch.steps import make_train_step
from repro.models import init_params, param_spec, param_shardings
from repro.models.params import abstract_params
from repro.optim import init_opt_state


def build_state(cfg, tc, mesh):
    spec_tree = param_spec(cfg)
    shardings = param_shardings(spec_tree, mesh)
    params = init_params(spec_tree, jax.random.key(tc.seed))
    params = jax.tree.map(jax.device_put, params, shardings)
    opt = init_opt_state(params)
    return params, opt, shardings


def train(cfg, tc: TrainConfig, *, batch: int, seq: int, steps: int,
          mesh=None, simulate_failure: int = -1, log_every: int = 10,
          resume: bool = True):
    mesh = mesh or make_local_mesh(len(jax.devices()), 1)
    params, opt, shardings = build_state(cfg, tc, mesh)
    stream = TokenStream(global_batch=batch, seq_len=seq,
                         vocab_size=cfg.vocab_size, seed=tc.seed)

    start = 0
    if resume:
        last = ckpt.latest_step(tc.checkpoint_dir)
        if last is not None:
            params = ckpt.restore(tc.checkpoint_dir, last, params, shardings)
            opt_tpl = init_opt_state(params)
            opt = ckpt.restore(f"{tc.checkpoint_dir}/opt", last, opt_tpl)
            stream.restore(last)
            start = last
            print(f"[train] resumed from step {last}")

    ba = batch_axes(mesh, batch)
    policy = actctx.make_train_policy(mesh, batch_axes=ba) \
        if mesh.shape.get("model", 1) > 1 else None
    step_fn = make_train_step(cfg, tc)
    with actctx.policy(policy):
        step_jit = jax.jit(step_fn, donate_argnums=(0, 1))

    watchdog = StepWatchdog()
    losses = []
    for step in range(start, steps):
        if step == simulate_failure:
            print(f"[train] SIMULATED FAILURE at step {step}: "
                  "dropping state, planning remesh, restoring checkpoint")
            plan = plan_remesh(len(jax.devices()) * 256, 256)
            print(f"[train] remesh plan: {plan.mesh_shape} "
                  f"({plan.note})")
            last = ckpt.latest_step(tc.checkpoint_dir)
            assert last is not None, "no checkpoint to recover from"
            params = jax.tree.map(jnp.zeros_like, params)  # state lost
            params = ckpt.restore(tc.checkpoint_dir, last, params, shardings)
            opt = ckpt.restore(f"{tc.checkpoint_dir}/opt", last,
                               init_opt_state(params))
            stream.restore(last)
            simulate_failure = -1
            # re-run from the checkpoint step
            for s2 in range(last, step):
                b = stream.next()
                params, opt, m = step_jit(params, opt, b)
            print(f"[train] recovered; replayed {step - last} steps")

        b = stream.next()
        watchdog.start()
        params, opt, metrics = step_jit(params, opt, b)
        slow = watchdog.stop(step)
        if slow:
            print(f"[train] straggler flagged at step {step}")
        if step % log_every == 0 or step == steps - 1:
            loss = float(metrics["loss"])
            losses.append((step, loss))
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
        if tc.checkpoint_every and (step + 1) % tc.checkpoint_every == 0:
            ckpt.save(tc.checkpoint_dir, step + 1, params,
                      keep=tc.keep_checkpoints)
            ckpt.save(f"{tc.checkpoint_dir}/opt", step + 1, opt,
                      keep=tc.keep_checkpoints)
    return params, opt, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config for this arch")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--simulate-failure", type=int, default=-1)
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_arch(args.arch)
    tc = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                     warmup_steps=max(10, args.steps // 20),
                     checkpoint_dir=args.ckpt_dir,
                     checkpoint_every=args.ckpt_every)
    t0 = time.time()
    _, _, losses = train(cfg, tc, batch=args.batch, seq=args.seq,
                         steps=args.steps,
                         simulate_failure=args.simulate_failure,
                         resume=not args.no_resume)
    dt = time.time() - t0
    print(f"[train] done in {dt:.1f}s; loss {losses[0][1]:.3f} -> "
          f"{losses[-1][1]:.3f}")


if __name__ == "__main__":
    main()
