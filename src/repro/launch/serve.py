"""LLM token-serving driver: batched prefill + decode with the segment
cache over the transformer stack (the seed's model-serving path).

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --smoke --batch 4 --prompt-len 32 --gen 32

Not to be confused with the GRAPH query server,
``repro.launch.graph_serve`` (resident graph engine + coalesced
mixed-algorithm query traffic; see ``repro/serve/``) — the two serving
drivers share nothing but the name.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch, smoke_config
from repro.data import batch_at
from repro.models import (
    forward_decode,
    forward_prefill,
    init_cache,
    init_params,
    param_spec,
)
from repro.models.model import build_plan


def pad_cache_for_decode(cfg, cache, ctx_len: int, batch: int):
    """Align a prefill cache (lengths = prompt) to decode buffers
    (lengths = ctx or window), preserving position semantics."""
    target = init_cache(cfg, batch, ctx_len)
    plan = build_plan(cfg)
    out_segs = []
    for seg, have, want in zip(plan, cache["segments"], target["segments"]):
        o = {}
        for k, t_want in want.items():
            t_have = have.get(k)
            if t_have is None:
                o[k] = t_want
                continue
            if t_have.shape == t_want.shape:
                o[k] = t_have.astype(t_want.dtype)
                continue
            seq_axis = 1 if t_have.ndim == 4 else 2
            wlen = t_want.shape[seq_axis]
            hlen = t_have.shape[seq_axis]
            if seg.window > 0 and wlen == min(seg.window, ctx_len) \
                    and k in ("k", "v"):
                # SWA shift buffer: right-align history
                pad = [(0, 0)] * t_have.ndim
                pad[seq_axis] = (max(0, wlen - hlen), 0)
                t = jnp.pad(t_have[..., -wlen:, :, :]
                            if False else t_have, pad)
                # keep only last wlen entries along seq
                sl = [slice(None)] * t.ndim
                sl[seq_axis] = slice(-wlen, None)
                o[k] = t[tuple(sl)].astype(t_want.dtype)
            else:
                # full buffer: place history at [0, hlen)
                pad = [(0, 0)] * t_have.ndim
                pad[seq_axis] = (0, max(0, wlen - hlen))
                o[k] = jnp.pad(t_have, pad).astype(t_want.dtype)
        out_segs.append(o)
    return {"segments": out_segs, "pos": cache["pos"]}


def serve(cfg, *, batch: int, prompt_len: int, gen: int, greedy=True):
    params = init_params(param_spec(cfg), jax.random.key(0))
    toks = batch_at(0, global_batch=batch, seq_len=prompt_len,
                    vocab_size=cfg.vocab_size)
    extras = {}
    if cfg.family == "audio":
        extras["enc_embeds"] = 0.1 * jax.random.normal(
            jax.random.key(2), (batch, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        extras["vis_embeds"] = 0.02 * jax.random.normal(
            jax.random.key(2), (batch, cfg.vision_tokens, cfg.d_model))

    ctx = prompt_len + gen + (cfg.vision_tokens if cfg.family == "vlm" else 0)
    prefill = jax.jit(lambda p, b: forward_prefill(p, cfg, b))
    decode = jax.jit(lambda p, t, c: forward_decode(p, cfg, t, c))

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": toks, **extras})
    cache = pad_cache_for_decode(cfg, cache, ctx, batch)
    t_prefill = time.time() - t0

    out = []
    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    t0 = time.time()
    for _ in range(gen):
        out.append(tok)
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen_toks = jnp.concatenate(out, axis=1)
    return gen_toks, {"prefill_s": t_prefill, "decode_s": t_decode,
                      "tok_per_s": batch * gen / max(t_decode, 1e-9)}


def main():
    ap = argparse.ArgumentParser(
        description="LLM token serving: batched prefill + decode.",
        epilog="For the GRAPH query server (coalesced graph-algorithm "
               "traffic) see: python -m repro.launch.graph_serve")
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    cfg = smoke_config(args.arch) if args.smoke else get_arch(args.arch)
    toks, stats = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                        gen=args.gen)
    print(f"[serve] generated {toks.shape} tokens; "
          f"prefill {stats['prefill_s']:.2f}s, "
          f"decode {stats['decode_s']:.2f}s "
          f"({stats['tok_per_s']:.1f} tok/s)")
    print("[serve] sample:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
