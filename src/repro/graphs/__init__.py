from repro.graphs.generate import generate_edges, rmat_edges, \
    smallworld_edges, urand_edges

__all__ = ["generate_edges", "rmat_edges", "smallworld_edges",
           "urand_edges"]
