"""Graph generators: urand (Erdos-Renyi, as in the paper's SS5), RMAT
(GAP 'kron'-style), and Watts-Strogatz small-world - deterministic,
numpy-based.  The small-world family (ring lattice + random rewiring,
emitted as directed edge pairs) is the second graph family of the
oracle-conformance gate: high clustering exercises triangle counting
and k-core in a way ER graphs do not.

The paper evaluates on 'urand' graphs of varying scale (urand25 = 2^25
vertices); GAP's urand draws E = n*k directed edges with independently
uniform endpoints, which is what we implement.
"""

from __future__ import annotations

import numpy as np

from repro.configs.base import GraphConfig


def generate_edges(cfg: GraphConfig, seed: int = 42) -> np.ndarray:
    """Return (E, 2) int64 edge array [src, dst]."""
    if cfg.generator == "urand":
        return urand_edges(cfg.num_vertices, cfg.num_edges, seed)
    if cfg.generator == "rmat":
        return rmat_edges(cfg.scale, cfg.num_edges, seed)
    if cfg.generator == "smallworld":
        return smallworld_edges(cfg.num_vertices, k=cfg.avg_degree,
                                seed=seed)
    raise ValueError(cfg.generator)


def smallworld_edges(n: int, k: int = 8, p: float = 0.1,
                     seed: int = 42) -> np.ndarray:
    """Watts-Strogatz small-world graph as a directed edge list.

    Ring lattice: each vertex links to its k/2 nearest successors; every
    undirected lattice edge is emitted as BOTH directed edges (n*k edges
    total, matching ``GraphConfig.num_edges`` with avg_degree=k).  Each
    directed edge's head is then rewired to a uniform random vertex with
    probability ``p`` — deterministic in ``seed``.
    """
    half = max(1, k // 2)
    rng = np.random.default_rng(seed)
    u = np.repeat(np.arange(n, dtype=np.int64), half)
    offs = np.tile(np.arange(1, half + 1, dtype=np.int64), n)
    v = (u + offs) % n
    src = np.concatenate([u, v])
    dst = np.concatenate([v, u])
    rewire = rng.random(src.size) < p
    dst = np.where(rewire, rng.integers(0, n, size=src.size), dst)
    return np.stack([src, dst], axis=1)


def urand_edges(n: int, e: int, seed: int = 42) -> np.ndarray:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=e, dtype=np.int64)
    dst = rng.integers(0, n, size=e, dtype=np.int64)
    return np.stack([src, dst], axis=1)


def rmat_edges(scale: int, e: int, seed: int = 42,
               a: float = 0.57, b: float = 0.19, c: float = 0.19) -> np.ndarray:
    """GAP-style Kronecker/RMAT, vectorized over bits."""
    rng = np.random.default_rng(seed)
    src = np.zeros(e, dtype=np.int64)
    dst = np.zeros(e, dtype=np.int64)
    for _ in range(scale):
        src <<= 1
        dst <<= 1
        r1 = rng.random(e)
        r2 = rng.random(e)
        src_bit = r1 > (a + b)
        dst_bit = ((r1 <= a + b) & (r2 > a / (a + b))) | (
            (r1 > a + b) & (r2 > c / max(1e-12, (1.0 - a - b))))
        src |= src_bit.astype(np.int64)
        dst |= dst_bit.astype(np.int64)
    # GAP permutes vertex ids to destroy locality artifacts
    perm = rng.permutation(1 << scale)
    return np.stack([perm[src], perm[dst]], axis=1)
