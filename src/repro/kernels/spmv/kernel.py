"""ELL SpMV Pallas kernel: y[r] = sum_k val[r,k] * x[idx[r,k]].

TPU adaptation of the paper's PageRank contribution accumulation (the
per-partition SpMV between exchanges).  The GPU-style CSR row-per-thread
formulation does not map to the TPU's vector units; instead rows are
ELL-packed (fixed K slots, sentinel-padded) so a (RB, K) tile is a dense
VPU-friendly block, and the x vector is resident in VMEM (per-partition
slices are O(n/P) = a few MB at production scale).

BlockSpec tiling: grid over row blocks; per step the kernel sees
  idx_ref (RB, K) int32 | val_ref (RB, K) f32 | x_ref (n_pad,) f32
and writes y_ref (RB,).  Gathers from VMEM use vectorized jnp.take.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.compat import tpu_compiler_params


def _spmv_kernel(idx_ref, val_ref, x_ref, y_ref):
    idx = idx_ref[...]                        # (RB, K) int32, sentinel = n_pad-1
    val = val_ref[...]                        # (RB, K) f32 (0.0 at padding)
    x = x_ref[...]                            # (n_pad,) f32
    gathered = jnp.take(x, idx, axis=0)       # VMEM gather
    y_ref[...] = (gathered * val).sum(axis=1)


@functools.partial(jax.jit, static_argnames=("row_block", "interpret"))
def spmv_ell(idx, val, x, *, row_block: int = 256, interpret: bool = False):
    """idx/val: (n_rows, K); x: (n_cols,). Returns y: (n_rows,) f32.

    n_rows must be a multiple of row_block; padding entries must carry
    val == 0 (idx may point anywhere valid).
    """
    n_rows, k = idx.shape
    assert n_rows % row_block == 0, (n_rows, row_block)
    grid = (n_rows // row_block,)
    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block, k), lambda r: (r, 0)),
            pl.BlockSpec((row_block, k), lambda r: (r, 0)),
            pl.BlockSpec(x.shape, lambda r: (0,)),   # x resident in VMEM
        ],
        out_specs=pl.BlockSpec((row_block,), lambda r: (r,)),
        out_shape=jax.ShapeDtypeStruct((n_rows,), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(idx, val, x.astype(jnp.float32))
