from repro.kernels.spmv.ops import spmv

__all__ = ["spmv"]
