"""Pure-jnp oracle for the ELL SpMV kernel."""

import jax.numpy as jnp


def spmv_ell_ref(idx, val, x):
    gathered = jnp.take(x.astype(jnp.float32), idx, axis=0)
    return (gathered * val).sum(axis=1)
