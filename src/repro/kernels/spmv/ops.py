"""Dispatch wrapper: Pallas kernel on TPU, jnp oracle elsewhere.

Standalone/benchmark entry point.  The PRODUCTION dispatch for the
superstep programs is ``core/localops.py`` (``spmv_pull`` /
``scatter_combine``), which drives this kernel per blocked-ELL bucket
and adds the COO-scatter reference path + REPRO_LOCALOPS override."""

import jax

from repro.kernels.spmv.kernel import spmv_ell
from repro.kernels.spmv.ref import spmv_ell_ref


def spmv(idx, val, x, *, row_block: int = 256, force_kernel: bool = False,
         interpret: bool = False):
    if force_kernel or jax.default_backend() == "tpu":
        return spmv_ell(idx, val, x, row_block=row_block,
                        interpret=interpret or jax.default_backend() != "tpu")
    return spmv_ell_ref(idx, val, x)
