"""Pure-jnp oracle for the flash attention kernel."""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q/k/v: (BH, S, D) -> (BH, Sq, D)."""
    sq, sk = q.shape[1], k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (q.shape[-1] ** -0.5)
    if softcap and softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kp <= qp
    if window > 0:
        mask &= kp > qp - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)
