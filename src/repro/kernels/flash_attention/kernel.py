"""Blocked online-softmax (flash) attention Pallas kernel.

Forward-only TPU kernel used for LM training/prefill compute; the
backward pass uses the custom-VJP XLA path (models/layers.py), whose
blocked recompute is already memory-optimal - the kernel accelerates the
forward hot loop on the MXU.

Grid: (B*H, n_q_blocks, n_kv_blocks), kv innermost with "arbitrary"
semantics so the VMEM scratch accumulators (m, l, acc) persist across kv
steps; the output block is written on the last kv step.  BlockSpecs keep
one (Bq, D) q tile and one (Bk, D) k/v tile in VMEM per step; D and the
block sizes should be multiples of 128 for MXU alignment (danube3's
head_dim 120 is padded by ops.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                  causal: bool, window: int, bq: int, bk: int, nk: int,
                  softcap: float):
    kv_i = pl.program_id(2)
    q_i = pl.program_id(1)

    @pl.when(kv_i == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc[...], NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc[...])
        acc_sc[...] = jnp.zeros_like(acc_sc[...])

    q = q_ref[0]                                  # (Bq, D)
    k = k_ref[0]                                  # (Bk, D)
    v = v_ref[0]
    s = jax.lax.dot_general(
        q.astype(jnp.float32), k.astype(jnp.float32),
        (((1,), (1,)), ((), ()))) * (q.shape[-1] ** -0.5)   # (Bq, Bk)
    if softcap and softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    qp = q_i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kp = kv_i * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=bool)
    if causal:
        mask &= kp <= qp
    if window > 0:
        mask &= kp > qp - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_sc[...]
    l_prev = l_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * corr + p.sum(axis=1)
    pv = jax.lax.dot_general(p.astype(v.dtype), v,
                             (((1,), (0,)), ((), ()))).astype(jnp.float32)
    acc_sc[...] = acc_sc[...] * corr[:, None] + pv
    m_sc[...] = m_new
    l_sc[...] = l_new

    @pl.when(kv_i == nk - 1)
    def _finish():
        o_ref[0] = (acc_sc[...]
                    / jnp.maximum(l_sc[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "block_q", "block_k", "interpret"))
def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0, block_q: int = 256,
                        block_k: int = 256, interpret: bool = False):
    """q/k/v: (BH, S, D) flattened batch*heads. Returns (BH, S, D)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    nq, nk = sq // bq, sk // bk
    grid = (bh, nq, nk)
    kern = functools.partial(_flash_kernel, causal=causal, window=window,
                             bq=bq, bk=bk, nk=nk, softcap=softcap)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
