"""Dispatch wrapper for flash attention.

(B, S, H, D) <-> (B*H, S, D) adapters, head_dim padding to a multiple of
128 (danube3's 120), and backend dispatch: Pallas kernel on TPU, the
custom-VJP XLA implementation elsewhere (and always for backward).
"""

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import flash_attention_ref


def _pad_d(x, mult=128):
    d = x.shape[-1]
    pad = (-d) % mult
    if pad == 0:
        return x, d
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]), d


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    force_kernel=False, interpret=False):
    """q/k/v: (B, S, H, D) with kv heads pre-repeated -> (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    if force_kernel or jax.default_backend() == "tpu":
        qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
        kf = k.transpose(0, 2, 1, 3).reshape(B * H, Sk, D)
        vf = v.transpose(0, 2, 1, 3).reshape(B * H, Sk, D)
        qf, d0 = _pad_d(qf)
        kf, _ = _pad_d(kf)
        vf, _ = _pad_d(vf)
        if qf.shape[-1] != d0:
            # kernel scales by padded D; compensate to the true 1/sqrt(d0)
            qf = qf * jnp.asarray((qf.shape[-1] / d0) ** 0.5, qf.dtype)
        # padded key dims contribute zeros to q.k^T; padded v dims sliced off
        o = flash_attention_fwd(
            qf, kf, vf, causal=causal, window=window, softcap=softcap,
            interpret=interpret or jax.default_backend() != "tpu")
        o = o[..., :d0].reshape(B, H, Sq, d0).transpose(0, 2, 1, 3)
        return o
    from repro.models.layers import flash_attention_xla
    return flash_attention_xla(q, k, v, causal, window, softcap, 1024, 1024)
