"""Pallas TPU kernels for the framework's compute hot spots.

  spmv            -- ELL segment-sum SpMV (PageRank contribution pull)
  frontier        -- BFS pull step over packed frontier bitmaps
  flash_attention -- blocked online-softmax attention (LM train/prefill)

Each subpackage: kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd dispatch wrapper), ref.py (pure-jnp oracle).  Kernels are
validated against ref.py in interpret mode (tests/test_kernels_*.py) and
selected automatically on TPU backends.
"""
