"""Pure-jnp oracle for the BFS pull kernel."""

import jax.numpy as jnp

INT_INF = jnp.int32(2 ** 30)


def bfs_pull_ref(nbr, bits, unvisited):
    word = jnp.take(bits, nbr >> 5, axis=0)
    hit = ((word >> (nbr & 31).astype(jnp.uint32)) & 1) == 1
    cand = jnp.where(hit, nbr, INT_INF)
    parent = cand.min(axis=1)
    return jnp.where(unvisited.astype(jnp.int32) == 1, parent, INT_INF)
