"""BFS pull-step Pallas kernel.

For a tile of (unvisited) local vertices with ELL-packed in-neighbor
lists, test each neighbor against the packed global frontier bitmap and
emit (hit, min-parent) per vertex - the owner-side parent derivation of
the HPX-adapted BFS (core/bfs.py).

Per grid step the kernel sees:
  nbr_ref  (RB, K) int32 global neighbor ids (sentinel = n_pad)
  bits_ref (n_words,) uint32 packed frontier (resident in VMEM: n/32)
  unv_ref  (RB,) int32 1 = unvisited
and writes parent_ref (RB,) int32 (INT_INF when no frontier neighbor).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.compat import tpu_compiler_params

INT_INF = 2 ** 30


def _frontier_kernel(nbr_ref, bits_ref, unv_ref, parent_ref):
    nbr = nbr_ref[...]                               # (RB, K)
    bits = bits_ref[...]                             # (W,)
    unv = unv_ref[...]                               # (RB,)
    word = jnp.take(bits, nbr >> 5, axis=0)          # (RB, K) u32
    hit = ((word >> (nbr & 31).astype(jnp.uint32)) & 1) == 1
    cand = jnp.where(hit, nbr, jnp.int32(INT_INF))
    parent = cand.min(axis=1)                        # min-id parent
    parent_ref[...] = jnp.where(unv == 1, parent, jnp.int32(INT_INF))


@functools.partial(jax.jit, static_argnames=("row_block", "interpret"))
def bfs_pull(nbr, bits, unvisited, *, row_block: int = 256,
             interpret: bool = False):
    """nbr: (n_rows, K) int32 < 32*len(bits); bits: (W,) uint32;
    unvisited: (n_rows,) int32. Returns parents (n_rows,) int32."""
    n_rows, k = nbr.shape
    assert n_rows % row_block == 0, (n_rows, row_block)
    grid = (n_rows // row_block,)
    return pl.pallas_call(
        _frontier_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block, k), lambda r: (r, 0)),
            pl.BlockSpec(bits.shape, lambda r: (0,)),
            pl.BlockSpec((row_block,), lambda r: (r,)),
        ],
        out_specs=pl.BlockSpec((row_block,), lambda r: (r,)),
        out_shape=jax.ShapeDtypeStruct((n_rows,), jnp.int32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(nbr, bits, unvisited.astype(jnp.int32))
