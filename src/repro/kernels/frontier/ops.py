"""Dispatch wrapper: Pallas kernel on TPU, jnp oracle elsewhere.

Standalone/benchmark entry point.  The PRODUCTION dispatch for the
superstep programs is ``core/localops.py`` (``frontier_pull``), which
drives this kernel per blocked-ELL bucket and adds the COO-scatter
reference path + REPRO_LOCALOPS override."""

import jax

from repro.kernels.frontier.kernel import bfs_pull
from repro.kernels.frontier.ref import bfs_pull_ref


def frontier_pull(nbr, bits, unvisited, *, row_block: int = 256,
                  force_kernel: bool = False, interpret: bool = False):
    if force_kernel or jax.default_backend() == "tpu":
        return bfs_pull(nbr, bits, unvisited, row_block=row_block,
                        interpret=interpret or jax.default_backend() != "tpu")
    return bfs_pull_ref(nbr, bits, unvisited)
