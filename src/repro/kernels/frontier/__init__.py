from repro.kernels.frontier.ops import frontier_pull

__all__ = ["frontier_pull"]
