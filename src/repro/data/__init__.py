from repro.data.tokens import TokenStream, batch_at

__all__ = ["TokenStream", "batch_at"]
