"""Deterministic synthetic token pipeline.

Produces a reproducible, shardable token stream without external data:
tokens are a stateless hash of (seed, stream position), so any worker can
materialize any batch index independently - exactly the property a
multi-host input pipeline needs for restart-without-replay (the data
side of fault tolerance: on restore, the loader resumes from the step
counter alone).

A light Zipfian shaping makes the stream non-uniform so cross-entropy
actually decreases during the example training runs.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp


def _hash_u32(x: jnp.ndarray, seed: int) -> jnp.ndarray:
    x = x.astype(jnp.uint32) + jnp.uint32(seed)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def batch_at(step: int | jnp.ndarray, *, global_batch: int, seq_len: int,
             vocab_size: int, seed: int = 0, zipf: float = 1.3):
    """Tokens for a given step: (global_batch, seq_len) int32.

    Stateless: batch_at(k) is identical across restarts and hosts.
    """
    base = (jnp.asarray(step, jnp.uint32) * jnp.uint32(global_batch * seq_len))
    pos = base + jnp.arange(global_batch * seq_len, dtype=jnp.uint32)
    h = _hash_u32(pos, seed)
    u = (h.astype(jnp.float32) + 0.5) / jnp.float32(2 ** 32)
    # inverse-CDF of a truncated Zipf-ish distribution
    r = jnp.power(u, jnp.float32(zipf))
    toks = jnp.clip((r * vocab_size).astype(jnp.int32), 0, vocab_size - 1)
    # inject local correlation: every position mixes with its predecessor
    toks2 = jnp.roll(toks, 1)
    mixed = jnp.where(h % 4 == 0, toks2, toks)
    return mixed.reshape(global_batch, seq_len)


@dataclass
class TokenStream:
    """Iterator facade used by the training driver."""

    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    step: int = 0

    def next(self):
        b = batch_at(self.step, global_batch=self.global_batch,
                     seq_len=self.seq_len, vocab_size=self.vocab_size,
                     seed=self.seed)
        self.step += 1
        return {"tokens": b}

    def restore(self, step: int):
        self.step = step
