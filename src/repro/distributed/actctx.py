"""Activation-sharding context.

The model code is mesh-agnostic; drivers (train/dry-run) install a
sharding policy here before tracing.  ``constrain(x, kind)`` applies
``jax.lax.with_sharding_constraint`` when a policy is active, otherwise
it is the identity - so tests and single-device runs are unaffected.

Kinds:
  "resid"  -- (B, S, D) residual stream. Train policy shards S over
              "model" (Megatron-style sequence parallelism) so the
              per-layer scan carry is 1/TP the size; GSPMD inserts the
              all-gather / reduce-scatter pairs around attention/MLP.
  "batch"  -- (B, ...) batch-leading tensors; shard B over data axes.
"""

from __future__ import annotations

import contextlib
from typing import Optional

import jax

_POLICY: Optional[dict] = None


def set_policy(policy: Optional[dict]):
    global _POLICY
    _POLICY = policy


@contextlib.contextmanager
def policy(p: Optional[dict]):
    global _POLICY
    old = _POLICY
    _POLICY = p
    try:
        yield
    finally:
        _POLICY = old


def constrain(x, kind: str):
    if _POLICY is None:
        return x
    sh = _POLICY.get(kind)
    if sh is None:
        return x
    if callable(sh):
        sh = sh(x)
        if sh is None:
            return x
    elif isinstance(sh, dict):
        sh = sh.get(x.ndim)
        if sh is None:
            return x
    return jax.lax.with_sharding_constraint(x, sh)


def _heads_rule(mesh, batch_axes):
    """(B, S, H, D) attention tensors: B over batch axes, H over model.

    Falls back to replicated heads when H < model-axis size (tiny models)
    to avoid mostly-padding shards.
    """
    P = jax.sharding.PartitionSpec
    NS = jax.sharding.NamedSharding
    m = mesh.shape["model"]

    def rule(x):
        if x.ndim != 4:
            return None
        h = x.shape[2]
        ha = "model" if h >= m else None
        return NS(mesh, P(batch_axes, None, ha, None))

    return rule


def make_train_policy(mesh, *, batch_axes, seq_axis="model"):
    """Residual stream (B,S,D): B over batch_axes, S over seq_axis (SP)."""
    P = jax.sharding.PartitionSpec
    NS = jax.sharding.NamedSharding
    ba = batch_axes if batch_axes else None
    return {
        "resid": {3: NS(mesh, P(ba, seq_axis, None))},
        "batch": {2: NS(mesh, P(ba, None)),
                  3: NS(mesh, P(ba, None, None))},
        "heads": _heads_rule(mesh, ba),
        "ffn": _ffn_rule(mesh, ba),
    }


def make_infer_policy(mesh, *, batch_axes):
    P = jax.sharding.PartitionSpec
    NS = jax.sharding.NamedSharding
    ba = batch_axes if batch_axes else None
    return {
        "resid": {3: NS(mesh, P(ba, None, None))},
        "batch": {2: NS(mesh, P(ba, None)),
                  3: NS(mesh, P(ba, None, None))},
        "heads": _heads_rule(mesh, ba),
        "ffn": _ffn_rule(mesh, ba),
    }


def _ffn_rule(mesh, batch_axes):
    """(B, S, F) hidden activations: F over model (Megatron pattern:
    gather the sequence, shard the hidden width)."""
    P = jax.sharding.PartitionSpec
    NS = jax.sharding.NamedSharding
    m = mesh.shape["model"]

    def rule(x):
        if x.ndim != 3:
            return None
        f = x.shape[2]
        fa = "model" if f >= m else None
        return NS(mesh, P(batch_axes, None, fa))

    return rule
