from repro.distributed import actctx

__all__ = ["actctx"]
