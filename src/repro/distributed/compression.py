"""Gradient compression: int8 quantization with error feedback.

Cross-pod data-parallel gradient all-reduces traverse the slow DCI links;
int8 + per-bucket scale cuts that wire 4x vs fp32.  Error feedback
(Karimireddy et al.) accumulates the quantization residual locally and
adds it back next step, preserving convergence.

Usage inside a train step (see launch/train.py):
    qgrads, new_state = compress_tree(grads, ef_state)
    # all-reduce qgrads over the pod axis (pjit inserts it), then
    grads = dequantize_tree(qgrads)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x, resid):
    """x + resid -> (int8 payload, scale, new resid)."""
    y = x.astype(jnp.float32) + resid
    scale = jnp.max(jnp.abs(y)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(y / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, y - deq


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def init_ef_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_tree(grads, ef_state):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef_state)
    qs, scales, resids = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, r = quantize_int8(g, e)
        qs.append(q)
        scales.append(s)
        resids.append(r)
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, scales),
            jax.tree.unflatten(treedef, resids))


def decompress_tree(qs, scales):
    return jax.tree.map(dequantize_int8, qs, scales)
