"""Fault tolerance and elasticity for multi-pod runs.

Layers:
  1. Checkpoint/restart (repro.checkpoint): atomic, sharded, restores
     onto a DIFFERENT mesh via device_put against target shardings, and
     the stateless data pipeline resumes from the step counter alone.
  2. Elastic remesh planning: on pod/slice loss, ``plan_remesh`` picks
     the largest healthy mesh consistent with the parallelism layout and
     returns the new mesh + whether batch/accum need rescaling.  The
     driver re-lowers its step against the new mesh and restores the
     last checkpoint (see launch/train.py --simulate-failure).
  3. Straggler mitigation: a step-time watchdog flags slow steps; the
     escalation path is documented per deployment (re-shard around the
     slow host at the next checkpoint boundary).  On-step mitigation
     (backup executors, as in the HPX work-stealing model) does not map
     to SPMD lockstep - recorded in DESIGN.md SHardware-adaptation.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax


@dataclass
class RemeshPlan:
    mesh_shape: tuple
    axis_names: tuple
    devices_used: int
    batch_scale: float       # multiply grad_accum by 1/this to keep tokens
    note: str = ""


def plan_remesh(total_devices: int, failed_devices: int,
                model_parallel: int = 16) -> RemeshPlan:
    """Largest (pod, data, model) mesh on the surviving devices.

    The model axis is preserved (parameter layout unchanged =>
    checkpoint resharding is pure data-axis movement); the data axis
    shrinks to the largest power-of-two that fits; lost throughput is
    recovered by raising grad accumulation so the global batch and the
    optimizer trajectory stay identical.
    """
    alive = total_devices - failed_devices
    data = 1
    while data * 2 * model_parallel <= alive:
        data *= 2
    used = data * model_parallel
    if used >= 2 * model_parallel * 16:
        pods = used // (model_parallel * 16)
        shape = (pods, 16, model_parallel)
        names = ("pod", "data", "model")
    else:
        shape = (data, model_parallel)
        names = ("data", "model")
    return RemeshPlan(
        mesh_shape=shape, axis_names=names, devices_used=used,
        batch_scale=used / total_devices,
        note=f"{failed_devices} devices lost; data axis {data}, "
             f"raise grad_accum x{total_devices // used} to keep global batch")


@dataclass
class StepWatchdog:
    """Flags straggler steps: step time > factor * trailing median."""

    factor: float = 2.0
    window: int = 32
    times: list = field(default_factory=list)
    flagged: list = field(default_factory=list)
    _t0: float = 0.0

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> bool:
        dt = time.perf_counter() - self._t0
        hist = sorted(self.times[-self.window:])
        median = hist[len(hist) // 2] if hist else dt
        slow = len(hist) >= 8 and dt > self.factor * median
        self.times.append(dt)
        if slow:
            self.flagged.append((step, dt, median))
        return slow
