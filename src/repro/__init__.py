"""repro: distributed graph analytics + multi-architecture LM framework in JAX.

Reproduces and extends "An Initial Evaluation of Distributed Graph
Algorithms using NWGraph and HPX" (Mohammadiporshokooh, Syskakis, Kaiser;
2026).  The paper's asynchronous, partitioned-container execution model for
distributed BFS and PageRank is adapted to TPU-native JAX (shard_map +
pjit + Pallas) and embedded in a production-scale training/serving
framework supporting 10 assigned LM architectures on multi-pod meshes.

Layout:
  repro.core         -- the paper's contribution: distributed graph engine
  repro.graphs       -- graph generation (urand / Erdos-Renyi, RMAT), CSR
  repro.models       -- unified LM stack (dense / MoE / SSM / hybrid / enc-dec / VLM)
  repro.kernels      -- Pallas TPU kernels (spmv, bfs frontier, flash attention)
  repro.distributed  -- mesh/sharding rules, collectives, compression, fault tolerance
  repro.optim        -- AdamW + schedules
  repro.data         -- deterministic sharded token pipeline
  repro.checkpoint   -- atomic sharded checkpoint/restore
  repro.configs      -- per-architecture configs + registry
  repro.launch       -- mesh construction, multi-pod dry-run, train/serve drivers
  repro.roofline     -- roofline-term extraction from compiled artifacts
"""

__version__ = "0.1.0"
