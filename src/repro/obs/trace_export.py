"""Chrome trace-event (Perfetto-loadable) JSON export + schema check.

``chrome_trace`` turns recorder spans/events (and optionally engine
telemetry) into the Trace Event Format dict ``chrome://tracing`` and
https://ui.perfetto.dev load directly:

  * the SERVER is pid 1 with one track (tid) per declared component
    (``registry.COMPONENTS`` order), so admission/validate/demux nest
    on the "server" track while batch formation and device launches
    read on their own lanes;
  * ENGINE telemetry is pid 2 with one track per PART — a run's
    measured wall-time is splayed uniformly over its rounds and the
    resulting ``engine_round`` spans are emitted on every part's track
    (each part executes every BSP round; per-part skew is not
    observable from the host), with the halt scalar and probe values
    in ``args``;
  * span kinds declared ``complete`` export as "X" events; kinds
    declared ``async`` (query / device / coalesce_wait — they overlap
    on their track) export as "b"/"e" pairs keyed by the recorder
    ``seq``; instant events export as "i".

Timestamps are microseconds relative to the earliest stamp in the
trace (Chrome wants µs; perf_counter's epoch is arbitrary anyway).

``validate_chrome_trace`` is the schema gate the CI ``obs`` lane and
the export tests run: required fields per event shape, matched and
ordered async begin/end pairs, non-decreasing per-track timestamps,
and proper "X" nesting (intervals on one track may contain each other
but never partially overlap).  It raises ``ValueError`` with the first
offending event; on success it returns per-``ph`` counts.
"""

from __future__ import annotations

import json
import pathlib

from repro.obs.registry import COMPONENTS, SPAN_KINDS

_PID_SERVE = 1
_PID_ENGINE = 2
_COMPONENT_TID = {name: i for i, name in enumerate(COMPONENTS)}


def _meta(pid: int, name: str, tid: int = 0, thread: str | None = None):
    ev = {"ph": "M", "pid": pid, "tid": tid, "ts": 0,
          "name": "process_name" if thread is None else "thread_name",
          "args": {"name": name if thread is None else thread}}
    return ev


def chrome_trace(spans=(), events=(), engine=()) -> dict:
    """Build the trace dict.

    ``spans`` / ``events`` come from ``SpanRecorder.spans()`` /
    ``.events()``.  ``engine`` is an iterable of ``(label, telemetry,
    parts)`` with ``telemetry`` a ``RunTelemetry``; each run's rounds
    are laid end to end after the previous run's on every part track.
    """
    spans = list(spans)
    events = list(events)
    stamps = [s.t0 for s in spans] + [e.t for e in events]
    base = min(stamps) if stamps else 0.0

    def us(t: float) -> float:
        return round((t - base) * 1e6, 3)

    out = []
    if spans or events:
        out.append(_meta(_PID_SERVE, "repro-serve"))
        for comp, tid in _COMPONENT_TID.items():
            out.append(_meta(_PID_SERVE, "", tid, thread=comp))
    for span in spans:
        tid = _COMPONENT_TID.get(span.component, len(_COMPONENT_TID))
        decl = SPAN_KINDS.get(span.kind)
        if decl is not None and decl[1] == "async":
            common = {"name": span.kind, "cat": span.component,
                      "pid": _PID_SERVE, "tid": tid, "id": span.seq}
            out.append({"ph": "b", "ts": us(span.t0),
                        "args": dict(span.args), **common})
            out.append({"ph": "e", "ts": us(span.t1), **common})
        else:
            out.append({"ph": "X", "name": span.kind,
                        "cat": span.component, "pid": _PID_SERVE,
                        "tid": tid, "ts": us(span.t0),
                        "dur": round(span.dur * 1e6, 3),
                        "args": dict(span.args)})
    for ev in events:
        tid = _COMPONENT_TID.get(ev.component, len(_COMPONENT_TID))
        out.append({"ph": "i", "s": "t", "name": ev.kind,
                    "cat": ev.component, "pid": _PID_SERVE, "tid": tid,
                    "ts": us(ev.t), "args": dict(ev.args)})

    engine = list(engine)
    if engine:
        out.append(_meta(_PID_ENGINE, "repro-engine"))
        parts_max = max(parts for _, _, parts in engine)
        for part in range(parts_max):
            out.append(_meta(_PID_ENGINE, "", part,
                             thread=f"part{part}"))
        cursor = 0.0
        for label, tel, parts in engine:
            rounds = tel.series.rounds
            total_us = max(tel.wall_s, 1e-6) * 1e6
            dur = total_us / max(rounds, 1)
            for r in range(rounds):
                row = tel.series.rows[r]
                args = {"run": label, "round": r,
                        "halt": float(row[1])}
                for name in tel.series.probe_names:
                    args[name] = float(tel.series.probe(name)[r])
                for part in range(parts):
                    out.append({"ph": "X", "name": "engine_round",
                                "cat": "engine", "pid": _PID_ENGINE,
                                "tid": part,
                                "ts": round(cursor + r * dur, 3),
                                "dur": round(dur, 3), "args": args})
            cursor += total_us
    out.sort(key=lambda e: (e["ph"] == "M" and -1, e["ts"]))
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def validate_chrome_trace(trace: dict) -> dict:
    """Schema-check ``trace``; raises ValueError, returns ph counts."""
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("trace must be a dict with 'traceEvents'")
    evs = trace["traceEvents"]
    if not isinstance(evs, list):
        raise ValueError("'traceEvents' must be a list")
    counts: dict[str, int] = {}
    tracks: dict[tuple, list] = {}
    open_async: dict[tuple, float] = {}
    for i, ev in enumerate(evs):
        for field in ("ph", "ts", "pid", "tid"):
            if field not in ev:
                raise ValueError(f"event {i} missing {field!r}: {ev}")
        ph = ev["ph"]
        counts[ph] = counts.get(ph, 0) + 1
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            raise ValueError(f"event {i} has bad ts: {ev}")
        if ph in ("X", "b", "e", "i", "M") and "name" not in ev:
            raise ValueError(f"event {i} missing name: {ev}")
        if ph == "M":
            continue
        tracks.setdefault((ev["pid"], ev["tid"], ph), []).append(ev)
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) or \
                    ev["dur"] < 0:
                raise ValueError(f"X event {i} has bad dur: {ev}")
        elif ph in ("b", "e"):
            if "cat" not in ev or "id" not in ev:
                raise ValueError(f"async event {i} missing cat/id: {ev}")
            key = (ev["pid"], ev["cat"], ev["id"])
            if ph == "b":
                if key in open_async:
                    raise ValueError(f"async id reused before end: {ev}")
                open_async[key] = ev["ts"]
            else:
                if key not in open_async:
                    raise ValueError(f"'e' without matching 'b': {ev}")
                if ev["ts"] < open_async.pop(key):
                    raise ValueError(f"async end before begin: {ev}")
    if open_async:
        raise ValueError(f"{len(open_async)} async span(s) never ended: "
                         f"{sorted(open_async)[:3]}")
    for (pid, tid, ph), evs_t in tracks.items():
        last = -1.0
        for ev in evs_t:
            if ev["ts"] < last:
                raise ValueError(
                    f"track (pid={pid}, tid={tid}, ph={ph}) timestamps "
                    f"decrease at {ev}")
            last = ev["ts"]
        if ph != "X":
            continue
        # "X" nesting: sort by (start, -dur) then stack-check — each
        # interval must close inside (or exactly at the edge of) its
        # enclosing interval; partial overlap is malformed.
        stack: list[float] = []
        eps = 1e-2  # µs; stamps are rounded to 3 decimals
        for ev in sorted(evs_t, key=lambda e: (e["ts"], -e["dur"])):
            end = ev["ts"] + ev["dur"]
            while stack and ev["ts"] >= stack[-1] - eps:
                stack.pop()
            if stack and end > stack[-1] + eps:
                raise ValueError(
                    f"track (pid={pid}, tid={tid}) spans partially "
                    f"overlap at {ev}")
            stack.append(end)
    return counts


def write_trace(path, trace: dict) -> dict:
    """Validate then write ``trace`` as JSON; returns the validator's
    per-``ph`` counts (what the launchers report)."""
    counts = validate_chrome_trace(trace)
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(trace, indent=None,
                               separators=(",", ":")) + "\n")
    return counts
