"""Serving-path span/event model: a bounded ring buffer of
monotonic-timestamped spans.

A ``Span`` is a named interval on a component track (``t0``..``t1`` in
``time.perf_counter()`` seconds); an ``Event`` is an instant.  The
``SpanRecorder`` is the only mutable object — everything downstream
(`report.trace_summary`, `trace_export.chrome_trace`) consumes the
immutable ``spans()`` / ``events()`` snapshots.

Design points, mirroring ``core/faults.py``'s cheap-when-off contract:

  * ``NULL_RECORDER`` is a disabled recorder; every instrumentation
    site guards on ``recorder.enabled`` so the un-traced serve path
    pays one attribute read per site and allocates nothing.
  * The buffers are RINGS (``maxlen`` spans / events each).  A long
    serve session cannot grow host memory without bound; the exporter
    simply sees the most recent window.  ``dropped_spans`` counts what
    fell off so roll-ups can say "truncated" instead of lying.
  * Timestamps come from one clock (``perf_counter``) for every
    component, so cross-track ordering in the exported trace is real.
  * Spans record ``seq`` — a recorder-global monotone id — so nesting
    on one track can be reconstructed even when two spans share a
    ``t0`` (ties broken by start order).

Span kinds, components, and which kinds export as Chrome *async*
events (they overlap on one track: ``query``, ``device``,
``coalesce_wait``) are declared in ``obs/registry.py``.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field


@dataclass(frozen=True)
class Span:
    """A closed interval on a component track."""

    kind: str           # registry.SPAN_KINDS key, e.g. "admission"
    component: str      # registry.COMPONENTS key -> its own track (tid)
    t0: float           # perf_counter seconds
    t1: float
    seq: int            # recorder-global start order (nesting tiebreak)
    args: dict = field(default_factory=dict)

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


@dataclass(frozen=True)
class Event:
    """An instant on a component track."""

    kind: str
    component: str
    t: float
    seq: int
    args: dict = field(default_factory=dict)


class _OpenSpan:
    """Context manager returned by ``SpanRecorder.span`` — closes the
    span on exit and lets the body attach args lazily."""

    __slots__ = ("_rec", "kind", "component", "t0", "seq", "args")

    def __init__(self, rec, kind, component, args):
        self._rec = rec
        self.kind = kind
        self.component = component
        self.args = dict(args) if args else {}
        self.t0 = time.perf_counter()
        self.seq = rec._next_seq()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            self.args.setdefault("error", exc_type.__name__)
        self._rec._push_span(Span(self.kind, self.component, self.t0,
                                  time.perf_counter(), self.seq, self.args))
        return False


class _NullSpan:
    """No-op stand-in so ``with rec.span(...)`` works when disabled."""

    __slots__ = ("args",)

    def __init__(self):
        self.args = {}

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_SPAN = _NullSpan()


class SpanRecorder:
    """Bounded, thread-safe recorder for spans and instant events.

    The serve pipeline closes spans from both the submitting thread and
    the executor's demux thread, so pushes take a lock; reads snapshot
    under the same lock.  ``maxlen`` bounds EACH ring (spans, events).
    """

    def __init__(self, maxlen: int = 65536, enabled: bool = True):
        self.enabled = enabled
        self.maxlen = maxlen
        self._spans: deque[Span] = deque(maxlen=maxlen)
        self._events: deque[Event] = deque(maxlen=maxlen)
        self._lock = threading.Lock()
        self._seq = 0
        self.dropped_spans = 0
        self.dropped_events = 0

    # -- recording ----------------------------------------------------
    def _next_seq(self) -> int:
        with self._lock:
            self._seq += 1
            return self._seq

    def _push_span(self, span: Span) -> None:
        with self._lock:
            if len(self._spans) == self.maxlen:
                self.dropped_spans += 1
            self._spans.append(span)

    def span(self, kind: str, component: str, **args):
        """``with rec.span("validate", "server"): ...`` — records a
        Span on exit; disabled recorders return a shared no-op."""
        if not self.enabled:
            return _NULL_SPAN
        return _OpenSpan(self, kind, component, args)

    def add_span(self, kind: str, component: str, t0: float, t1: float,
                 **args) -> None:
        """Record a span whose interval was measured elsewhere (e.g. a
        device launch stamped by the executor thread)."""
        if not self.enabled:
            return
        self._push_span(Span(kind, component, t0, t1, self._next_seq(),
                             args))

    def event(self, kind: str, component: str, **args) -> None:
        """Record an instant event at now."""
        if not self.enabled:
            return
        ev = Event(kind, component, time.perf_counter(), self._next_seq(),
                   args)
        with self._lock:
            if len(self._events) == self.maxlen:
                self.dropped_events += 1
            self._events.append(ev)

    # -- reading ------------------------------------------------------
    def spans(self) -> list[Span]:
        with self._lock:
            return list(self._spans)

    def events(self) -> list[Event]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self._events.clear()
            self.dropped_spans = 0
            self.dropped_events = 0


NULL_RECORDER = SpanRecorder(maxlen=1, enabled=False)
