"""Unified observability: engine telemetry, serving spans, trace export.

Three layers, one package (PR 10):

  ``telemetry.py``     the per-round ENGINE telemetry channel — device-
                       computed per-superstep series (halt scalar +
                       per-program probes such as frontier counts)
                       appended to the superstep drivers' loop carry,
                       plus trace-time wire-byte accounting at the
                       exchange taps in ``core/partitioned.py``.
                       Telemetry on/off is a compile-cache dimension
                       (like ``guard=``); the off path is bit-identical
                       to a pre-telemetry build.
  ``spans.py``         the SERVING-path span/event model: a bounded
                       ring buffer of monotonic-timestamped spans
                       (admission → validate → coalesce-wait →
                       dispatch → device → demux → reply, plus
                       mutation / WAL-append / snapshot / recovery and
                       the checkpoint-runner's detection/rollback
                       events).
  ``registry.py``      the declared span kinds + instrument registry
                       (counters / gauges / histograms) with the
                       markdown-table generators ``docs/API.md`` is
                       drift-tested against.
  ``report.py``        derived views: the plain-text roll-up report,
                       ``trace_summary`` (what ``graph_serve --json``
                       publishes), and the latency cells derived from
                       query spans (reconciled against
                       ``serve/metrics.py`` in tests).
  ``trace_export.py``  Chrome trace-event (Perfetto-loadable) JSON:
                       per-component tracks for the server, per-part
                       tracks for engine rounds, plus the schema
                       validator the CI ``obs`` lane runs.

Layering: this package imports NOTHING from ``repro.core`` or
``repro.serve`` (numpy + stdlib only), so ``core/`` may call into it
(the drivers publish trace-time phase marks and the exchange taps
report payload bytes) without a cycle — mirroring ``core/faults.py``.
"""

from repro.obs.registry import COMPONENTS, INSTRUMENTS, SPAN_KINDS, \
    Registry, instruments_markdown_table, spans_markdown_table
from repro.obs.report import derive_latency_cells, rollup, trace_summary
from repro.obs.spans import NULL_RECORDER, Event, Span, SpanRecorder
from repro.obs.telemetry import PhaseSeries, RunTelemetry, WireRecord
from repro.obs.trace_export import chrome_trace, validate_chrome_trace, \
    write_trace

__all__ = [
    "COMPONENTS", "Event", "INSTRUMENTS", "NULL_RECORDER", "PhaseSeries",
    "Registry", "RunTelemetry", "SPAN_KINDS", "Span", "SpanRecorder",
    "WireRecord", "chrome_trace", "derive_latency_cells",
    "instruments_markdown_table", "rollup", "spans_markdown_table",
    "trace_summary", "validate_chrome_trace", "write_trace",
]
