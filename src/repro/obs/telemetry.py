"""Engine telemetry: per-superstep series + trace-time wire accounting.

Two channels, both zero-cost when off:

**Device series** — the superstep drivers (``core/superstep.py``), when
built with ``telemetry=True``, append a ``(max_rounds, 2 + K)`` f32
buffer to the loop carry and write one row per round:

    [done, halt, *probes]

``done`` is 1.0 for rows a round actually wrote (the buffer is
zero-initialised and round counts are only known on device, so the
host trims on this column — essential for phased programs, where one
buffer accumulates rows across phases).  ``halt`` is the halt
predicate evaluated on the round's resulting state (1.0 once
converged); the interesting convergence scalars (frontier size,
residual, changed-count) are the program's declared
``probe_names``/``probe`` extras.  ``PhaseSeries.from_array`` parses
the fetched buffer.

**Wire record** — the exchange primitives in ``core/partitioned.py``
call ``tap_wire(op, payload)`` right where they already call
``faults.tap``.  While a ``recording(rec)`` context is active, each tap
adds the payload's trace-time byte size to the active ``WireRecord``
under the current ``phase(...)`` label.  Because a ``lax.while_loop``
body traces exactly ONCE, the accumulated totals are exact *per-round*
wire bytes; ``lax.cond`` traces both branches, so taps inside a cond
count both sides (a documented upper bound — no current exchange sits
under a cond).  ``recording`` CLEARS the record on entry, so a retrace
overwrites instead of double-counting.

The byte figure is the per-part payload entering the collective (the
arrays live inside ``shard_map``, so shapes are already per-device);
bit-packed frontiers therefore report their packed n/8 size, matching
what ``compare.py`` gates as ``wire_mb_per_part``.

``RunTelemetry`` bundles a run's series, wire snapshot, and host
wall-time into the summary dict the launchers and benches publish.
"""

from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass, field

import numpy as np

# Fixed leading columns of a series row, before the per-program probes.
SERIES_FIXED_COLS = ("done", "halt")


class WireRecord:
    """Trace-time wire-byte accounting: (phase, op) -> [bytes, taps].

    One record accumulates over a single trace of the loop body, so
    ``cells`` values are per-ROUND figures (see module docstring).
    """

    def __init__(self):
        self.cells: dict[tuple[str, str], list[int]] = {}

    def clear(self) -> None:
        self.cells.clear()

    def add(self, phase: str, op: str, nbytes: int) -> None:
        cell = self.cells.setdefault((phase, op), [0, 0])
        cell[0] += int(nbytes)
        cell[1] += 1

    def bytes_by_op(self) -> dict[str, int]:
        """Per-round bytes summed over phases, keyed by primitive."""
        out: dict[str, int] = {}
        for (_, op), (nbytes, _) in self.cells.items():
            out[op] = out.get(op, 0) + nbytes
        return out

    def bytes_per_round(self) -> int:
        return sum(nbytes for nbytes, _ in self.cells.values())

    def snapshot(self) -> dict:
        """JSON-friendly: {"phase/op": {"bytes": b, "taps": c}}."""
        return {f"{phase}/{op}": {"bytes": b, "taps": c}
                for (phase, op), (b, c) in sorted(self.cells.items())}


# Module-global recording context, mirroring core/faults.py: unarmed
# (the default) makes tap_wire a single None-check.
_ACTIVE: WireRecord | None = None
_PHASE: str = "round"


@contextmanager
def recording(rec: WireRecord):
    """Arm ``rec`` for the duration of a trace.  Clears it on entry so
    retracing (cache miss after eviction, explicit lower) overwrites
    rather than accumulates."""
    global _ACTIVE, _PHASE
    rec.clear()
    prev, prev_phase = _ACTIVE, _PHASE
    _ACTIVE, _PHASE = rec, "round"
    try:
        yield rec
    finally:
        _ACTIVE, _PHASE = prev, prev_phase


def phase(name: str) -> None:
    """Label subsequent taps (trace-time call, e.g. per driver phase)."""
    global _PHASE
    _PHASE = name


def tap_wire(op: str, payload) -> None:
    """Account ``payload``'s bytes to the active record; no-op when no
    recording context is armed (the telemetry-off path)."""
    if _ACTIVE is None:
        return
    _ACTIVE.add(_PHASE, op,
                int(np.prod(payload.shape)) * payload.dtype.itemsize)


@dataclass(frozen=True)
class PhaseSeries:
    """Host-side view of a fetched device series buffer: valid rows
    only (``done`` column > 0.5), fixed cols then probes."""

    probe_names: tuple
    rows: np.ndarray  # (rounds, 2 + K) float32

    @classmethod
    def from_array(cls, arr, probe_names=()) -> "PhaseSeries":
        arr = np.asarray(arr, dtype=np.float32)
        if arr.ndim != 2 or arr.shape[1] != len(SERIES_FIXED_COLS) + len(
                probe_names):
            raise ValueError(
                f"series shape {arr.shape} does not match probes "
                f"{probe_names!r}")
        return cls(tuple(probe_names), arr[arr[:, 0] > 0.5])

    @property
    def rounds(self) -> int:
        return int(self.rows.shape[0])

    def halt(self) -> np.ndarray:
        return self.rows[:, 1]

    def probe(self, name: str) -> np.ndarray:
        return self.rows[:, len(SERIES_FIXED_COLS)
                         + self.probe_names.index(name)]

    def summary(self) -> dict:
        out = {"rounds": self.rounds}
        if self.rounds:
            out["halt_first"] = float(self.rows[0, 1])
            out["halt_last"] = float(self.rows[-1, 1])
        for name in self.probe_names:
            vals = self.probe(name)
            if len(vals):
                out[f"{name}_mean"] = float(vals.mean())
                out[f"{name}_max"] = float(vals.max())
        return out


@dataclass
class RunTelemetry:
    """Everything one telemetry-on run yields: the parsed per-round
    series, the trace-time wire snapshot, and host wall-time."""

    series: PhaseSeries
    wire: dict = field(default_factory=dict)   # WireRecord.snapshot()
    wall_s: float = 0.0

    def wire_bytes_by_op(self, loop_only: bool = True) -> dict[str, int]:
        """Per-round bytes by primitive.  The drivers label taps by
        driver phase ("init" / "round" / "outputs"); only "round" taps
        repeat per superstep, so the default drops the one-shot ones."""
        out: dict[str, int] = {}
        for key, cell in self.wire.items():
            tap_phase, op = key.rsplit("/", 1)
            if loop_only and tap_phase != "round":
                continue
            out[op] = out.get(op, 0) + cell["bytes"]
        return out

    def summary(self) -> dict:
        """The JSON block benches attach per row and launchers print.

        ``wire_bytes_total`` = per-round loop bytes x rounds, plus the
        one-shot init/outputs taps once.  For phased programs the loop
        cells sum over phases while ``rounds`` is the total, so the
        figure is an upper bound there (exact for single-loop drivers).
        """
        by_op = self.wire_bytes_by_op()
        per_round = sum(by_op.values())
        oneshot = sum(cell["bytes"] for key, cell in self.wire.items()
                      if key.rsplit("/", 1)[0] != "round")
        out = self.series.summary()
        out["wire_bytes_per_round"] = {op: int(b)
                                       for op, b in sorted(by_op.items())}
        out["wire_bytes_total"] = int(per_round * self.series.rounds
                                      + oneshot)
        if self.wall_s:
            out["wall_ms"] = round(self.wall_s * 1e3, 3)
            if self.series.rounds:
                out["round_ms_mean"] = round(
                    self.wall_s * 1e3 / self.series.rounds, 3)
        return out
