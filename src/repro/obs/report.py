"""Derived views over a ``SpanRecorder``: the ``trace_summary`` block
``graph_serve --json`` publishes, the latency cells reconciled against
``serve/metrics.py``, and the plain-text roll-up report.

``derive_latency_cells`` is the subsumption contract from the issue:
every resolved query records a ``query`` span whose args carry the
SAME ``latency_s`` float handed to ``ServeMetrics.record`` (stored, not
recomputed from ``t1 - t0``, so the reconciliation test can demand
exact equality instead of float-rounding tolerance).
"""

from __future__ import annotations

from collections import Counter, defaultdict

import numpy as np


def _p99_ms(durs_s) -> float:
    arr = np.asarray(durs_s, dtype=np.float64)
    if arr.size == 0:
        return 0.0
    return float(np.percentile(arr, 99)) * 1e3


def trace_summary(rec, top: int = 3) -> dict:
    """Span counts per component + the top-``top`` p99 contributors
    (span kinds ranked by p99 duration) — the ``--json`` block."""
    spans = rec.spans()
    events = rec.events()
    durs = defaultdict(list)
    for s in spans:
        durs[s.kind].append(s.dur)
    ranked = sorted(
        ({"kind": kind, "count": len(ds), "p99_ms": round(_p99_ms(ds), 4)}
         for kind, ds in durs.items()),
        key=lambda row: -row["p99_ms"])
    return {
        "spans_total": len(spans),
        "events_total": len(events),
        "spans_per_component": dict(
            sorted(Counter(s.component for s in spans).items())),
        "spans_per_kind": dict(
            sorted(Counter(s.kind for s in spans).items())),
        "events_per_kind": dict(
            sorted(Counter(e.kind for e in events).items())),
        "top_p99_ms": ranked[:top],
        "dropped_spans": rec.dropped_spans,
        "dropped_events": rec.dropped_events,
    }


def derive_latency_cells(rec) -> dict:
    """{(label, bucket): [latency_s, ...]} from ``query`` spans — the
    derived view ``ServeMetrics`` latency cells must reconcile with.
    Only ``status == "ok"`` spans count, mirroring the metrics contract
    that latency cells hold answered queries (misses ride counters)."""
    cells: dict[tuple, list] = {}
    for s in rec.spans():
        if s.kind != "query" or s.args.get("status") != "ok":
            continue
        key = (s.args.get("label"), s.args.get("bucket"))
        cells.setdefault(key, []).append(s.args["latency_s"])
    return cells


def rollup(registry, rec=None) -> str:
    """Plain-text roll-up: the instrument registry, then (with a
    recorder) span counts and the p99 ranking."""
    snap = registry.snapshot()
    lines = ["== obs roll-up =="]
    if snap["counters"]:
        lines.append("-- counters --")
        for name, val in snap["counters"].items():
            lines.append(f"  {name:24s} {val:>10d}")
    if snap["gauges"]:
        lines.append("-- gauges --")
        for name, val in snap["gauges"].items():
            lines.append(f"  {name:24s} {val:>10.3f}")
    if snap["histograms"]:
        lines.append("-- histograms --")
        lines.append(f"  {'name':24s} {'count':>7s} {'mean':>10s} "
                     f"{'p99':>10s}")
        for name, cell in snap["histograms"].items():
            lines.append(f"  {name:24s} {cell['count']:>7d} "
                         f"{cell['mean']:>10.3f} {cell['p99']:>10.3f}")
    if rec is not None:
        summ = trace_summary(rec)
        lines.append("-- spans --")
        for comp, n in summ["spans_per_component"].items():
            lines.append(f"  {comp:24s} {n:>10d}")
        if summ["top_p99_ms"]:
            lines.append("-- top p99 --")
            for row in summ["top_p99_ms"]:
                lines.append(f"  {row['kind']:24s} {row['count']:>7d} "
                             f"{row['p99_ms']:>10.3f} ms")
        if summ["dropped_spans"] or summ["dropped_events"]:
            lines.append(f"  (ring truncated: {summ['dropped_spans']} "
                         f"spans, {summ['dropped_events']} events "
                         "dropped)")
    return "\n".join(lines)
