from repro.optim.adamw import (
    OptState,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    init_opt_state,
    lr_schedule,
)

__all__ = [
    "OptState",
    "adamw_update",
    "clip_by_global_norm",
    "global_norm",
    "init_opt_state",
    "lr_schedule",
]
