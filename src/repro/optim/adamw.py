"""AdamW with global-norm clipping and warmup-cosine schedule.

Pure-function implementation (no optax dependency).  Optimizer state is
an (m, v, step) pytree whose m/v leaves share the parameter shardings, so
ZeRO-style sharding of optimizer state falls out of the param sharding
rules for free.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class OptState(NamedTuple):
    m: object
    v: object
    step: jnp.ndarray


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(m=zeros,
                    v=jax.tree.map(jnp.copy, zeros),
                    step=jnp.zeros((), jnp.int32))


def lr_schedule(step, tc: TrainConfig):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, tc.warmup_steps))
    prog = jnp.clip((step - tc.warmup_steps)
                    / max(1, tc.total_steps - tc.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return tc.learning_rate * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def adamw_update(params, grads, state: OptState, tc: TrainConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
    step = state.step + 1
    lr = lr_schedule(state.step, tc)
    b1, b2 = tc.beta1, tc.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + tc.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = tc.weight_decay if p.ndim >= 2 else 0.0
        p_new = p.astype(jnp.float32) * (1.0 - lr * wd) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(new_m, new_v, step), metrics
