"""Atomic sharded checkpointing with resume.

Layout:  <dir>/step_<N>/
           manifest.json       {step, keys, shapes, dtypes, time}
           arr_<i>.npy         one file per leaf (host-gathered)
         <dir>/LATEST          text file naming the newest complete step

Writes go to a temp directory and are renamed into place only after the
manifest lands, so a crash mid-write can never corrupt the latest
checkpoint (the restart path reads LATEST -> last COMPLETE step).  On
restore, arrays are device_put against the target shardings, so a
checkpoint written on one mesh can be loaded onto another (elastic
resharding: see distributed/fault_tolerance.py).
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import time

import numpy as np

import jax


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str | pathlib.Path, step: int, tree, keep: int = 3):
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f".tmp_step_{step}"
    final = ckpt_dir / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves, treedef = _flatten(tree)
    meta = {"step": step, "num_leaves": len(leaves),
            "treedef": str(treedef), "time": time.time(),
            "shapes": [list(np.shape(l)) for l in leaves],
            "dtypes": [str(np.asarray(l).dtype) for l in leaves]}
    for i, leaf in enumerate(leaves):
        np.save(tmp / f"arr_{i}.npy", np.asarray(leaf))
    (tmp / "manifest.json").write_text(json.dumps(meta))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    (ckpt_dir / "LATEST").write_text(str(step))

    # retention
    steps = sorted(int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*"))
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s}", ignore_errors=True)
    return final


def latest_step(ckpt_dir: str | pathlib.Path):
    ckpt_dir = pathlib.Path(ckpt_dir)
    marker = ckpt_dir / "LATEST"
    if not marker.exists():
        return None
    step = int(marker.read_text().strip())
    if not (ckpt_dir / f"step_{step}" / "manifest.json").exists():
        # fall back to newest complete
        steps = sorted(
            int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
            if (p / "manifest.json").exists())
        return steps[-1] if steps else None
    return step


def restore(ckpt_dir: str | pathlib.Path, step: int, target_tree,
            shardings=None):
    """Load into the structure of ``target_tree`` (values replaced).

    ``shardings``: optional matching tree of NamedShardings - arrays are
    device_put against them (cross-mesh restore)."""
    ckpt_dir = pathlib.Path(ckpt_dir) / f"step_{step}"
    meta = json.loads((ckpt_dir / "manifest.json").read_text())
    leaves, treedef = _flatten(target_tree)
    assert meta["num_leaves"] == len(leaves), "checkpoint/tree mismatch"
    loaded = [np.load(ckpt_dir / f"arr_{i}.npy") for i in range(len(leaves))]
    if shardings is not None:
        sh_leaves = treedef.flatten_up_to(shardings)
        loaded = [jax.device_put(a, s) for a, s in zip(loaded, sh_leaves)]
    else:
        loaded = [jax.numpy.asarray(a) for a in loaded]
    return jax.tree.unflatten(treedef, loaded)
