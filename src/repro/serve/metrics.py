"""Serving metrics: queries/sec and latency percentiles per
(program, bucket) cell.

Latency is admission-to-demux (queue wait + launch + demux slice), the
number a client of the server would see.  Cells are keyed by the
program label and the launch bucket width the query actually rode
(0 = shared refresh launch), so the bench can compare the ladder rungs
directly — ``qps`` at bucket 32 vs bucket 1 IS the coalescing win.

The measurement window opens at the FIRST ADMISSION (``GraphServer``
calls :meth:`ServeMetrics.start` from ``submit_query``) and closes at
the last demux — the first query's queue wait is inside the window, so
``qps`` never overcounts a burst that sat queued before its first
launch.  ``start`` is idempotent; a bare :meth:`record` still
self-opens the window for direct/standalone use.
"""

from __future__ import annotations

import time

import numpy as np


COUNTERS = ("shed", "timed_out", "retries", "quarantined", "rejected")


def percentiles(lat, qs=(50, 95, 99)):
    """Latency percentiles with EXPLICIT small-sample semantics.

    ``np.percentile`` on tiny cells is easy to misread (one sample
    "has" a p99; two samples interpolate), so the degenerate cases are
    spelled out rather than inherited:

      0 samples -> all zeros (an empty cell reports 0.0, not NaN)
      1 sample  -> every percentile IS that sample
      2+        -> linear-interpolated ``np.percentile`` (the default
                   method), so p50 of two samples is their midpoint and
                   p99 leans toward the max — documented, not accidental.
    """
    lat = np.asarray(lat, np.float64)
    if lat.size == 0:
        return tuple(0.0 for _ in qs)
    if lat.size == 1:
        return tuple(float(lat[0]) for _ in qs)
    return tuple(float(v) for v in np.percentile(lat, qs))


class ServeMetrics:
    """Latency cells record only ``status == "ok"`` answers — p99 of a
    cell is the tail of latencies clients actually waited for an answer
    through.  Resilience events ride the ``counts`` dict instead
    (:data:`COUNTERS`): shed admissions, deadline misses, launch
    retries, quarantined poison queries, admission rejects."""

    def __init__(self):
        self._lat: dict[tuple[str, int], list[float]] = {}
        self._t0: float | None = None
        self._t1: float | None = None
        self.counts: dict[str, int] = {c: 0 for c in COUNTERS}
        # durability / dynamic-graph observability (the server keeps
        # these current): snapshot epoch being served, restarts this
        # process recovered through, valid records in the open WAL
        self.epoch = 0
        self.recoveries = 0
        self.wal_records = 0

    def count(self, name: str, k: int = 1) -> None:
        self.counts[name] = self.counts.get(name, 0) + k

    def start(self) -> None:
        if self._t0 is None:
            self._t0 = time.perf_counter()

    def stop(self) -> None:
        self._t1 = time.perf_counter()

    def record(self, label: str, bucket: int, latency_s: float) -> None:
        self.start()
        self._lat.setdefault((label, bucket), []).append(latency_s)
        self._t1 = time.perf_counter()

    def latencies(self) -> dict[tuple[str, int], list[float]]:
        """Raw per-cell ``ok`` latencies (seconds), copied.  The span
        layer (``obs.report.derive_latency_cells``) reconstructs this
        exact mapping from query spans — the reconciliation the obs
        tests pin — so the metrics cells are a derived view of the
        trace, not a second source of truth."""
        return {k: list(v) for k, v in self._lat.items()}

    @property
    def window_s(self) -> float:
        if self._t0 is None:
            return 0.0
        return max((self._t1 or time.perf_counter()) - self._t0, 1e-9)

    def rows(self) -> list[dict]:
        """One dict per (algo, bucket) cell: count, qps, p50/p95/p99 ms.

        ``qps`` is cell throughput over the shared measurement window —
        under a mixed stream the cells split the window, so per-cell qps
        sums to total throughput.
        """
        out = []
        for (label, bucket) in sorted(self._lat):
            lat = np.asarray(self._lat[(label, bucket)], np.float64)
            p50, p95, p99 = (v * 1e3 for v in percentiles(lat))
            out.append({
                "algo": label, "bucket": bucket, "count": int(lat.size),
                "qps": round(lat.size / self.window_s, 2),
                "p50_ms": round(float(p50), 2),
                "p95_ms": round(float(p95), 2),
                "p99_ms": round(float(p99), 2),
            })
        return out

    def snapshot(self) -> dict:
        """One JSON-ready dict of everything observable: the latency
        cells, the resilience counters, and the durability state
        (epoch / recoveries / wal_records) — what ``graph_serve --json``
        publishes, so overload and recovery drills are scriptable
        without grepping logs."""
        return {
            "window_s": round(self.window_s, 4),
            "epoch": int(self.epoch),
            "recoveries": int(self.recoveries),
            "wal_records": int(self.wal_records),
            "counts": dict(self.counts),
            "rows": self.rows(),
        }

    def table(self) -> str:
        rows = self.rows()
        lines = [f"{'program':16s} {'bucket':>6s} {'count':>6s} "
                 f"{'qps':>8s} {'p50_ms':>8s} {'p95_ms':>8s} {'p99_ms':>8s}"]
        for r in rows:
            b = str(r["bucket"]) if r["bucket"] else "shared"
            lines.append(
                f"{r['algo']:16s} {b:>6s} {r['count']:6d} {r['qps']:8.1f} "
                f"{r['p50_ms']:8.1f} {r['p95_ms']:8.1f} {r['p99_ms']:8.1f}")
        lines.append(f"{'total':16s} {'':>6s} "
                     f"{sum(r['count'] for r in rows):6d} "
                     f"{sum(r['qps'] for r in rows):8.1f} "
                     f"(window {self.window_s:.2f}s)")
        if any(self.counts.values()):
            lines.append("  ".join(f"{k}={v}"
                                   for k, v in sorted(self.counts.items())
                                   if v))
        return "\n".join(lines)
