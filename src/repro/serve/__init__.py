"""Query-serving subsystem: a resident-engine graph server.

The ROADMAP's serve-path layer: keep the partitioned graph device-
resident inside one :class:`~repro.core.api.GraphEngine`, stream mixed
typed queries (BFS/SSSP/betweenness source queries, PageRank/CC/k-core
refreshes) through an admission queue, coalesce compatible queries into
a fixed bucket ladder of already-compiled batched programs, pipeline
launches double-buffered over JAX async dispatch, and demultiplex
per-query answers back out — measuring queries/sec and latency
percentiles per (program, bucket).

The graph is NOT frozen: ``GraphServer.mutate`` applies batched edge
inserts/deletes in place (``repro.serve.dynamic``) under snapshot-epoch
versioning, and the seeded incremental programs (``pagerank/warm``,
``cc/incremental``, ``kcore/incremental``) recompute from the previous
epoch's served outputs.

Serving state is DURABLE on request: ``GraphServer(...,
persistence=Persistence(dir))`` write-ahead-logs every mutation batch
and snapshots the whole serving state (``repro.serve.persist``), and
``GraphServer.recover(dir)`` resumes a killed server at the exact
epoch with bit-identical answers.

CLI: ``python -m repro.launch.graph_serve``; bench:
``python -m benchmarks.bench_serve`` (writes ``BENCH_serve.json``) and
``python -m benchmarks.bench_mutate`` (writes ``BENCH_mutate.json``).
The LLM token-serving driver is separate: ``repro.launch.serve``.
"""

from repro.serve.coalescer import Batch, BucketLadder, Coalescer, \
    DEFAULT_BUCKETS
from repro.serve.dynamic import DynamicGraph, EllOverflow, MutationBatch, \
    MutationStats, mutation_stream
from repro.serve.executor import DoubleBufferedExecutor
from repro.serve.metrics import ServeMetrics
from repro.serve.persist import Persistence
from repro.serve.query import Query, QueryKey, QueryResult, make_key, \
    query, validate_query
from repro.serve.server import GraphServer
from repro.serve.workload import parse_mix, synthetic_trace, \
    zipf_root_sampler

__all__ = [
    "Batch", "BucketLadder", "Coalescer", "DEFAULT_BUCKETS",
    "DoubleBufferedExecutor", "DynamicGraph", "EllOverflow", "GraphServer",
    "MutationBatch", "MutationStats", "Persistence", "Query", "QueryKey",
    "QueryResult",
    "ServeMetrics", "make_key", "mutation_stream", "parse_mix", "query",
    "synthetic_trace", "validate_query", "zipf_root_sampler",
]
