"""Double-buffered launch pipeline.

JAX dispatch is asynchronous: calling a jitted program returns device
arrays immediately while the backend executes.  The executor exploits
that to overlap host work with device work — it holds up to ``depth``
launches in flight, and only blocks (``jax.block_until_ready``) on the
OLDEST launch when a new one needs its slot or at drain.  With
``depth=2`` the server forms and dispatches batch ``k+1`` while the
device is still executing batch ``k``; the only synchronization point
is the demux, exactly as the serving layer wants it.

The executor knows nothing about queries or programs — it pipelines
``(payload, device_outputs)`` pairs and hands completed ones back in
dispatch order.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass

import jax


@dataclass
class Launch:
    """One in-flight dispatch: opaque payload + unblocked device outputs."""

    payload: object
    out: tuple
    t_dispatch: float
    t_done: float = 0.0


class DoubleBufferedExecutor:
    def __init__(self, depth: int = 2):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self.depth = depth
        self._inflight: deque[Launch] = deque()

    def __len__(self) -> int:
        return len(self._inflight)

    def push(self, payload, out) -> list[Launch]:
        """Enqueue an async launch; returns the launches this push had
        to retire to stay within ``depth`` (0 or 1 of them)."""
        done = []
        while len(self._inflight) >= self.depth:
            done.append(self._complete_oldest())
        self._inflight.append(Launch(payload, out, time.perf_counter()))
        return done

    def complete_one(self) -> Launch | None:
        """Block on and retire the oldest in-flight launch, if any."""
        if not self._inflight:
            return None
        return self._complete_oldest()

    def drain(self) -> list[Launch]:
        """Retire everything in flight, oldest first."""
        done = []
        while self._inflight:
            done.append(self._complete_oldest())
        return done

    def _complete_oldest(self) -> Launch:
        launch = self._inflight.popleft()
        jax.block_until_ready(launch.out)
        launch.t_done = time.perf_counter()
        return launch
