"""Double-buffered launch pipeline.

JAX dispatch is asynchronous: calling a jitted program returns device
arrays immediately while the backend executes.  The executor exploits
that to overlap host work with device work — it holds up to ``depth``
launches in flight, and only blocks (``jax.block_until_ready``) on the
OLDEST launch when a new one needs its slot or at drain.  With
``depth=2`` the server forms and dispatches batch ``k+1`` while the
device is still executing batch ``k``; the only synchronization point
is the demux, exactly as the serving layer wants it.

The executor knows nothing about queries or programs — it pipelines
``(payload, device_outputs)`` pairs and hands completed ones back in
dispatch order.

Failure safety: JAX surfaces async-dispatch errors at the blocking
call, so ``block_until_ready`` on one launch may raise long after the
push that enqueued it.  The executor converts that into data — the
launch is popped BEFORE blocking and the exception lands in
``Launch.error`` — so a poisoned launch can never orphan its in-flight
peers or wedge the pipeline: ``push``/``complete_one``/``drain`` never
raise, and a drain after a failed launch still returns every remaining
result.  Routing (retry, quarantine) is the server's job.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass

import jax


@dataclass
class Launch:
    """One in-flight dispatch: opaque payload + unblocked device
    outputs.  ``error`` is the exception ``block_until_ready`` raised,
    if any — a failed launch completes like any other and the consumer
    decides what to do with it."""

    payload: object
    out: tuple
    t_dispatch: float
    t_done: float = 0.0
    error: Exception | None = None
    seq: int = -1       # executor-global dispatch order (trace correlation)


class DoubleBufferedExecutor:
    def __init__(self, depth: int = 2):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self.depth = depth
        self._inflight: deque[Launch] = deque()
        self._seq = 0

    def __len__(self) -> int:
        return len(self._inflight)

    def push(self, payload, out) -> list[Launch]:
        """Enqueue an async launch; returns the launches this push had
        to retire to stay within ``depth`` (0 or 1 of them)."""
        done = []
        while len(self._inflight) >= self.depth:
            done.append(self._complete_oldest())
        self._inflight.append(
            Launch(payload, out, time.perf_counter(), seq=self._seq))
        self._seq += 1
        return done

    def complete_one(self) -> Launch | None:
        """Block on and retire the oldest in-flight launch, if any."""
        if not self._inflight:
            return None
        return self._complete_oldest()

    def drain(self) -> list[Launch]:
        """Retire everything in flight, oldest first."""
        done = []
        while self._inflight:
            done.append(self._complete_oldest())
        return done

    def _complete_oldest(self) -> Launch:
        # pop FIRST: if the block raises, the launch is already out of
        # the pipeline and the ones behind it stay retrievable
        launch = self._inflight.popleft()
        try:
            jax.block_until_ready(launch.out)
        except Exception as e:
            launch.error = e
        launch.t_done = time.perf_counter()
        return launch
