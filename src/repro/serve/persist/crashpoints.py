"""Deterministic crash points for the durability drills.

A kill drill needs the victim to die at an EXACT place in the
WAL/snapshot protocol, not "roughly during a mutation" — otherwise the
drill proves nothing about the ordering invariants.  Each named point
below is a ``maybe_crash(name)`` call compiled into the protocol; a
victim process opts in via the environment::

    REPRO_CRASH_POINT=<name>[:k]     # die at the k-th occurrence (default 1)

and dies with ``os._exit(CRASH_EXIT_CODE)`` — no atexit handlers, no
buffered flushes, exactly what ``kill -9`` at that instruction would
leave on disk.  Unset, every hook is a no-op.
"""

from __future__ import annotations

import os
import sys

ENV_VAR = "REPRO_CRASH_POINT"
CRASH_EXIT_CODE = 113

# name -> where in the protocol it fires (the docs table renders this)
CRASH_POINTS = {
    "between-batches":
        "top of `GraphServer.mutate()`, before the batch is logged "
        "or applied",
    "after-wal-append":
        "after the WAL record is written and fsynced, before the "
        "batch applies to the graph",
    "mid-snapshot-temp-write":
        "halfway through the snapshot temp-file write — a torn temp "
        "that is never renamed",
    "post-rename":
        "right after the snapshot's atomic rename, before old "
        "snapshots are pruned",
}

_counts: dict[str, int] = {}


def reset_counts() -> None:
    """Forget occurrence counts (tests that exercise ``:k`` specs)."""
    _counts.clear()


def maybe_crash(point: str) -> None:
    """Die here iff ``REPRO_CRASH_POINT`` names this point (and its
    occurrence count, ``name:k``, has been reached)."""
    if point not in CRASH_POINTS:
        raise ValueError(f"unknown crash point {point!r}; "
                         f"known: {sorted(CRASH_POINTS)}")
    spec = os.environ.get(ENV_VAR)
    if not spec:
        return
    name, _, at = spec.partition(":")
    if name != point:
        return
    _counts[point] = _counts.get(point, 0) + 1
    if _counts[point] >= int(at or 1):
        sys.stderr.write(f"[persist] crash point {spec} firing\n")
        sys.stderr.flush()
        os._exit(CRASH_EXIT_CODE)


def crash_points_markdown_table() -> str:
    """The docs/API.md crash-point table (drift-tested verbatim)."""
    lines = ["| crash point | fires |", "| --- | --- |"]
    for name, where in CRASH_POINTS.items():
        lines.append(f"| `{name}` | {where} |")
    return "\n".join(lines)
