"""Durable serving state: WAL + crash-consistent snapshots + recovery.

The resident ``GraphServer`` is long-lived infrastructure; this package
makes it crash-recoverable with BIT-IDENTICAL post-restart answers:

  ``wal.py``       write-ahead log of every mutation batch — logged and
                   fsynced BEFORE the batch applies, with a
                   commutative post-apply edge-multiset digest.
  ``snapshot.py``  periodic whole-state snapshots via write-temp +
                   atomic rename: graph mirrors, the planner's exact
                   free-slot state, warm seeds, the epoch watermark.
  ``recover.py``   newest digest-valid snapshot + WAL-suffix replay
                   through ``DynamicGraph.apply`` (idempotent on batch
                   id, rebuild records re-take the rebuild path), then
                   an end-to-end digest check of ``current_edges()``.

Wiring: ``GraphServer(engine, persistence=Persistence(dir=...))``
starts durable from scratch; ``GraphServer.recover(dir)`` resumes.
:class:`DurabilityState` is the per-server protocol driver the server
calls from ``mutate()`` — ``logged_apply`` (WAL-before-apply ordering)
then ``maybe_snapshot`` (every ``snapshot_every`` epochs).

Crash points (``crashpoints.py``) compile deterministic kill sites into
the protocol so the drills in ``tests/test_persist.py`` prove, per
site, that recovery lands on the exact epoch + edge multiset.
"""

from __future__ import annotations

import os
from dataclasses import dataclass

from repro.obs import NULL_RECORDER
from repro.serve.persist.crashpoints import CRASH_EXIT_CODE, CRASH_POINTS, \
    ENV_VAR, crash_points_markdown_table, maybe_crash, reset_counts
from repro.serve.persist.snapshot import SnapshotCorrupt, capture_state, \
    find_snapshots, load_snapshot, prune_snapshots, write_snapshot
from repro.serve.persist.wal import WalError, WalRecord, WriteAheadLog, \
    edge_digest, update_digest, wal_path

__all__ = [
    "CRASH_EXIT_CODE", "CRASH_POINTS", "ENV_VAR", "DurabilityState",
    "Persistence", "SnapshotCorrupt", "WalError", "WalRecord",
    "WriteAheadLog", "as_persistence", "crash_points_markdown_table",
    "edge_digest", "maybe_crash", "reset_counts", "update_digest",
    "wal_path",
]


@dataclass
class Persistence:
    """Durability config for one server.

    ``dir`` holds the WAL (``wal.log``) and snapshots; ``snapshot_every``
    is the epoch stride between snapshot pumps; ``retain`` how many
    published snapshots to keep (>= 2 so a corrupt newest still has a
    fallback); ``fsync=False`` trades durability for test speed."""

    dir: str
    snapshot_every: int = 8
    retain: int = 2
    fsync: bool = True

    def __post_init__(self):
        if self.snapshot_every < 1:
            raise ValueError(
                f"snapshot_every must be >= 1: {self.snapshot_every}")
        if self.retain < 1:
            raise ValueError(f"retain must be >= 1: {self.retain}")


def as_persistence(obj) -> Persistence:
    if isinstance(obj, Persistence):
        return obj
    if isinstance(obj, (str, os.PathLike)):
        return Persistence(dir=str(obj))
    raise TypeError(f"persistence must be a dir path or Persistence: "
                    f"{type(obj).__name__}")


class DurabilityState:
    """The WAL/snapshot protocol driver attached to one GraphServer.

    Holds the open log plus the running (digest, count, batch_id)
    watermark — the arithmetic shadow of the edge multiset that lets
    each record carry its POST-apply digest while still being written
    ahead of the apply."""

    def __init__(self, cfg: Persistence, wal: WriteAheadLog, digest: int,
                 count: int, batch_id: int,
                 last_snapshot_epoch: int | None):
        self.cfg = cfg
        self.wal = wal
        self.digest = digest
        self.count = count
        self.batch_id = batch_id
        self.last_snapshot_epoch = last_snapshot_epoch
        # span recorder for durability-path observability; the owning
        # server swaps in its own (obs/spans.py) when tracing is on
        self.obs = NULL_RECORDER

    @property
    def wal_records(self) -> int:
        return self.wal.n_records

    @classmethod
    def create(cls, server, persistence) -> "DurabilityState":
        """Start durable from scratch: refuses a directory that already
        holds durable state (that is ``GraphServer.recover``'s job),
        writes the base snapshot so the WAL always has a floor."""
        cfg = as_persistence(persistence)
        os.makedirs(cfg.dir, exist_ok=True)
        if find_snapshots(cfg.dir) or os.path.exists(wal_path(cfg.dir)):
            raise ValueError(
                f"{cfg.dir!r} already holds durable state; use "
                f"GraphServer.recover({cfg.dir!r}) to resume it")
        dyn = server.dynamic_graph()
        digest, count = edge_digest(dyn.current_edges())
        wal = WriteAheadLog(wal_path(cfg.dir), fsync=cfg.fsync)
        st = cls(cfg, wal, digest, count, batch_id=0,
                 last_snapshot_epoch=None)
        st.snapshot_now(server)
        return st

    @classmethod
    def resume(cls, cfg: Persistence, wal: WriteAheadLog, digest: int,
               count: int, batch_id: int,
               last_snapshot_epoch: int) -> "DurabilityState":
        return cls(cfg, wal, digest, count, batch_id, last_snapshot_epoch)

    # -- the protocol --------------------------------------------------------

    def logged_apply(self, dyn, inserts=None, deletes=None):
        """WAL-before-apply: plan the batch (validation + the
        patch-vs-rebuild decision), log + fsync its record, THEN apply.
        An apply that still fails after logging truncates the orphan
        record back off — the log never names a batch that neither
        applied nor can replay."""
        ins, dels, rebuild = dyn.plan(inserts, deletes)
        digest, count = update_digest(self.digest, self.count, ins, dels)
        rec = WalRecord(batch_id=self.batch_id + 1, epoch=dyn.epoch + 1,
                        rebuild=rebuild, digest=digest, count=count,
                        inserts=ins, deletes=dels)
        with self.obs.span("wal_append", "durability",
                           batch_id=rec.batch_id, epoch=rec.epoch,
                           n_insert=len(ins), n_delete=len(dels),
                           rebuild=bool(rebuild)):
            off = self.wal.append(rec)
        try:
            stats = dyn.apply(ins, dels, force_rebuild=rebuild)
        except BaseException:
            self.wal.truncate_to(off)
            raise
        self.digest, self.count = digest, count
        self.batch_id += 1
        return stats

    def maybe_snapshot(self, server) -> bool:
        due = (self.last_snapshot_epoch is None
               or server.epoch - self.last_snapshot_epoch
               >= self.cfg.snapshot_every)
        if due:
            self.snapshot_now(server)
        return due

    def snapshot_now(self, server) -> None:
        with self.obs.span("snapshot", "durability", epoch=server.epoch):
            state = capture_state(server, self)
            write_snapshot(self.cfg.dir, server.epoch, state,
                           fsync=self.cfg.fsync)
            self.last_snapshot_epoch = server.epoch
            prune_snapshots(self.cfg.dir, self.cfg.retain)

    def close(self) -> None:
        self.wal.close()
