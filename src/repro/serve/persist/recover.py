"""Restart recovery: newest digest-valid snapshot + WAL-suffix replay.

The loop, newest snapshot first:

  1. load + CRC-validate the snapshot (a flipped bit or torn write
     raises :class:`SnapshotCorrupt` -> try the next older one);
  2. rebuild the engine from the pickled mirrors, cross-check
     ``layout_signature()``, and restore the dynamic planner's EXACT
     free-slot state so replayed mutations land in the original slots;
  3. replay the WAL suffix through ``DynamicGraph.apply``: records with
     ``batch_id <= snapshot.batch_id`` are already folded in and SKIP
     (idempotence), rebuild records re-take the rebuild path
     (``force_rebuild=True``), and the scan stops at the first torn or
     corrupt record — the prefix-durability contract;
  4. verify: recompute the edge-multiset digest of the recovered
     ``current_edges()`` against the last replayed record's digest (or
     the snapshot's, when nothing replayed).  A mismatch condemns this
     snapshot and the loop falls back.

Only :class:`RecoveryFailed` escapes — carrying every per-snapshot
failure so a dead store is diagnosable from the exception alone.

This module imports the engine stack (jax) and is therefore loaded
lazily by ``GraphServer.recover``; the jax-free wal/snapshot modules
never pull it in.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from repro.core.api import GraphEngine
from repro.serve.dynamic.mutation import DynamicGraph
from repro.serve.persist.snapshot import SnapshotCorrupt, find_snapshots, \
    load_snapshot
from repro.serve.persist.wal import WriteAheadLog, edge_digest, wal_path


class RecoveryFailed(RuntimeError):
    """No snapshot in the directory survives validation + replay."""


@dataclass
class RecoveryReport:
    """What one successful recovery did (surfaced on the server as
    ``recovery_report`` and in the bench's ``recovery`` row)."""

    snapshot_epoch: int          # epoch of the snapshot recovery used
    epoch: int                   # epoch recovered to (snapshot + replay)
    batch_id: int                # last batch folded into the state
    replayed: int                # WAL records applied
    skipped: int                 # WAL records idempotently skipped
    rebuilds: int                # replayed records that re-partitioned
    wal_records: int             # valid records in the log
    snapshots_tried: int         # snapshots examined (1 = newest worked)


@dataclass
class RecoveredState:
    """Everything ``GraphServer.recover`` needs to resume serving."""

    engine: GraphEngine
    dynamic: DynamicGraph
    epoch: int
    seeds: dict
    mutation_log: list
    wal: WriteAheadLog
    digest: int
    count: int
    batch_id: int
    persist_cfg: dict
    report: RecoveryReport


def recover_state(dir_: str, *, mesh: Any = None) -> RecoveredState:
    """Recover the serving state from a durability directory; raises
    :class:`RecoveryFailed` when no snapshot validates end to end."""
    snaps = find_snapshots(dir_)
    if not snaps:
        raise RecoveryFailed(f"{dir_!r}: no snapshots to recover from")
    wal = WriteAheadLog(wal_path(dir_))   # truncates any torn tail
    errors = []
    for tried, (snap_epoch, path) in enumerate(snaps, start=1):
        try:
            epoch, state = load_snapshot(path)
            if epoch != snap_epoch:
                raise SnapshotCorrupt(
                    f"header epoch {epoch} != filename epoch {snap_epoch}")
            return _recover_from(state, wal, mesh, tried)
        except (SnapshotCorrupt, RecoveryFailed) as e:
            errors.append(f"  {path}: {e}")
    wal.close()
    raise RecoveryFailed(
        f"{dir_!r}: no digest-valid snapshot (tried {len(snaps)}):\n"
        + "\n".join(errors))


def _recover_from(state: dict, wal: WriteAheadLog, mesh: Any,
                  tried: int) -> RecoveredState:
    g = state["graph"]
    if g.layout_signature() != state["layout_signature"]:
        raise RecoveryFailed(
            "pickled mirrors disagree with the recorded layout signature")
    if mesh is None:
        from repro.launch.mesh import make_graph_mesh
        mesh = make_graph_mesh(g.parts)
    engine = GraphEngine(g, mesh, layout=state["layout"])
    dyn = DynamicGraph(engine, planner_state=state["planner"])
    dyn.epoch = int(state["epoch"])

    digest, count = int(state["digest"]), int(state["count"])
    batch_id = int(state["batch_id"])
    mutation_log = [dict(m) for m in state["mutation_log"]]
    replayed = skipped = rebuilds = 0
    for rec in wal.records:
        if rec.batch_id <= batch_id:
            skipped += 1                    # already folded into the snapshot
            continue
        if rec.batch_id != batch_id + 1:
            raise RecoveryFailed(
                f"WAL gap: record {rec.batch_id} after batch {batch_id}")
        stats = dyn.apply(rec.inserts, rec.deletes,
                          force_rebuild=rec.rebuild)
        if dyn.epoch != rec.epoch:
            raise RecoveryFailed(
                f"replay of batch {rec.batch_id} landed on epoch "
                f"{dyn.epoch}, record says {rec.epoch}")
        mutation_log.append({
            "epoch": stats.epoch, "n_insert": stats.n_insert,
            "n_delete": stats.n_delete, "rebuild": stats.rebuild})
        rebuilds += int(stats.rebuild)
        batch_id = rec.batch_id
        digest, count = rec.digest, rec.count
        replayed += 1

    actual = edge_digest(dyn.current_edges())
    if actual != (digest, count):
        raise RecoveryFailed(
            f"edge-multiset digest mismatch after replay: recovered "
            f"{actual}, log says {(digest, count)}")

    report = RecoveryReport(
        snapshot_epoch=int(state["epoch"]), epoch=dyn.epoch,
        batch_id=batch_id, replayed=replayed, skipped=skipped,
        rebuilds=rebuilds, wal_records=wal.n_records,
        snapshots_tried=tried)
    return RecoveredState(
        engine=engine, dynamic=dyn, epoch=dyn.epoch,
        seeds={k: (ep, arr) for k, (ep, arr) in state["seeds"].items()},
        mutation_log=mutation_log, wal=wal, digest=digest, count=count,
        batch_id=batch_id, persist_cfg=dict(state.get("persist", {})),
        report=report)
