"""Crash-consistent snapshots of the serving state.

A snapshot is ONE file, written via the classic atomic-publish recipe:
serialize to ``.snapshot-<epoch>.tmp`` in the same directory, fsync the
temp, ``os.replace`` onto the final ``snapshot-<epoch>.bin`` name, then
fsync the directory.  A crash before the rename leaves a torn temp that
recovery ignores (and the next successful snapshot garbage-collects);
a crash after the rename leaves a complete, valid snapshot.  There is
no instruction at which a partially-written file is visible under a
snapshot name.

Envelope: ``RSNAP001 || u64 epoch || u32 crc32(epoch_le8 || payload) ||
u32 payload_len || payload`` where payload is the pickled state dict.
Everything after the magic is covered by the CRC (the epoch through its
inclusion in the checksummed bytes), so a single bit flip anywhere in
the file raises :class:`SnapshotCorrupt` on load — which is how
recovery decides to fall back to the previous snapshot.

What the state dict carries (``capture_state``): the pickled
:class:`~repro.core.graph.GraphShards` host mirrors, the dynamic
planner's EXACT free-slot state (occupancy, free-stack order, position
index — slot placement must replay identically or float reduction
orders drift and answers stop being bit-identical), the epoch /
batch-id / digest watermark, ``layout_signature()``, the warm-seed
store, and the mutation log.

jax-free, like ``wal.py``: pickling device arrays is never attempted —
mirrors are plain numpy, and recovery re-uploads them.
"""

from __future__ import annotations

import os
import pickle
import re
import struct
import zlib

import numpy as np

from repro.serve.persist.crashpoints import maybe_crash

SNAP_MAGIC = b"RSNAP001"
_SNAP_HEADER = struct.Struct("<QII")    # epoch, crc32, payload length
FORMAT_VERSION = 1
_NAME_RE = re.compile(r"^snapshot-(\d{10})\.bin$")


class SnapshotCorrupt(RuntimeError):
    """Snapshot file failed its envelope validation (flip / truncation)."""


# -- envelope ----------------------------------------------------------------

def pack_snapshot(epoch: int, state: dict) -> bytes:
    payload = pickle.dumps(state, protocol=4)
    crc = zlib.crc32(struct.pack("<Q", epoch) + payload)
    return SNAP_MAGIC + _SNAP_HEADER.pack(epoch, crc, len(payload)) \
        + payload


def unpack_snapshot(data: bytes) -> tuple[int, dict]:
    head = len(SNAP_MAGIC) + _SNAP_HEADER.size
    if len(data) < head or not data.startswith(SNAP_MAGIC):
        raise SnapshotCorrupt("bad snapshot magic / truncated header")
    epoch, crc, length = _SNAP_HEADER.unpack_from(data, len(SNAP_MAGIC))
    payload = data[head:]
    if len(payload) != length:
        raise SnapshotCorrupt(
            f"payload length {len(payload)} != stated {length}")
    if zlib.crc32(struct.pack("<Q", epoch) + payload) != crc:
        raise SnapshotCorrupt("snapshot CRC mismatch")
    try:
        state = pickle.loads(payload)
    except Exception as e:          # CRC passed but unpickle failed:
        raise SnapshotCorrupt(f"unpicklable payload: {e}") from e
    return epoch, state


# -- files -------------------------------------------------------------------

def snapshot_path(dir_: str, epoch: int) -> str:
    return os.path.join(str(dir_), f"snapshot-{epoch:010d}.bin")


def find_snapshots(dir_: str) -> list[tuple[int, str]]:
    """Published snapshots, newest epoch first.  Torn temps
    (``.snapshot-*.tmp``) are invisible here by construction."""
    out = []
    for name in os.listdir(dir_):
        m = _NAME_RE.match(name)
        if m:
            out.append((int(m.group(1)), os.path.join(str(dir_), name)))
    return sorted(out, reverse=True)


def write_snapshot(dir_: str, epoch: int, state: dict,
                   fsync: bool = True) -> str:
    data = pack_snapshot(epoch, state)
    tmp = os.path.join(str(dir_), f".snapshot-{epoch:010d}.tmp")
    with open(tmp, "wb") as f:
        half = len(data) // 2
        f.write(data[:half])
        f.flush()
        if fsync:
            os.fsync(f.fileno())
        maybe_crash("mid-snapshot-temp-write")
        f.write(data[half:])
        f.flush()
        if fsync:
            os.fsync(f.fileno())
    final = snapshot_path(dir_, epoch)
    os.replace(tmp, final)           # the atomic publish
    if fsync:
        fd = os.open(str(dir_), os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
    maybe_crash("post-rename")
    return final


def load_snapshot(path: str) -> tuple[int, dict]:
    try:
        with open(path, "rb") as f:
            data = f.read()
    except OSError as e:
        raise SnapshotCorrupt(f"unreadable: {e}") from e
    return unpack_snapshot(data)


def prune_snapshots(dir_: str, retain: int) -> None:
    """Keep the ``retain`` newest snapshots; drop older ones and any
    stale temp files a crashed writer left behind."""
    for _, path in find_snapshots(dir_)[retain:]:
        os.unlink(path)
    for name in os.listdir(dir_):
        if name.startswith(".snapshot-") and name.endswith(".tmp"):
            os.unlink(os.path.join(str(dir_), name))


# -- state capture -----------------------------------------------------------

def capture_state(server, durability) -> dict:
    """Everything a restart needs for bit-identical serving, read off
    the live server (duck-typed: any GraphServer-shaped object works)."""
    dyn = server.dynamic_graph()
    cfg = durability.cfg
    return {
        "format": FORMAT_VERSION,
        "epoch": int(server.epoch),
        "batch_id": int(durability.batch_id),
        "digest": int(durability.digest),
        "count": int(durability.count),
        "layout": server.engine.layout,
        "layout_signature": server.engine.g.layout_signature(),
        "graph": server.engine.g,
        "planner": dyn.planner_state(),
        "seeds": {k: (int(ep), np.asarray(arr))
                  for k, (ep, arr) in server._seeds.items()},
        "mutation_log": [dict(m) for m in server.mutation_log],
        "persist": {"snapshot_every": cfg.snapshot_every,
                    "retain": cfg.retain, "fsync": cfg.fsync},
    }
