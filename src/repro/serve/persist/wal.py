"""Write-ahead log of mutation batches: length-prefixed, CRC-checksummed
append-only records, one per ``GraphServer.mutate()`` batch.

File layout::

    RWAL0001                                   8-byte file magic
    [u32 payload_len][u32 crc32(payload)][payload]   repeated

Payload (all little-endian, no padding)::

    u64 batch_id    monotone from 1; the replay idempotence key
    u64 epoch       the epoch this batch PRODUCES when applied
    u8  rebuild     1 = the batch overflowed the free pools and took
                    the re-partition path; replay forces the same path
    u64 digest      post-apply edge-multiset digest (see below)
    u64 count       post-apply live-edge count
    u32 n_ins, u32 n_del
    n_ins x (i64 u, i64 v) insert pairs, then n_del x (i64, i64) deletes

The record is written and fsynced BEFORE the batch applies (the digest
is computable up front because it is commutative — see
``update_digest``), so a crash at any instruction leaves one of two
states: record absent and batch unapplied, or record present and batch
applied-or-replayable.  Never an applied batch missing from the log.

A torn tail (partial final record after a crash mid-append) is detected
by the length prefix / CRC on open and truncated away; a bit flip
anywhere in a record fails its CRC, and the scan stops at the first bad
record — everything after it is unreachable, which is exactly the
prefix-durability contract recovery relies on.

This module is jax-free on purpose: the hypothesis property suite in
``tests/test_property.py`` round-trips records without paying a jax
import.
"""

from __future__ import annotations

import os
import struct
import zlib
from dataclasses import dataclass, field

import numpy as np

from repro.serve.persist.crashpoints import maybe_crash

FILE_MAGIC = b"RWAL0001"
_HEADER = struct.Struct("<II")       # payload length, crc32(payload)
_FIXED = struct.Struct("<QQBQQII")   # batch_id epoch rebuild digest count
                                     # n_ins n_del
_U64 = (1 << 64) - 1


class WalError(RuntimeError):
    """Malformed WAL framing (bad magic / short or inconsistent payload)."""


@dataclass
class WalRecord:
    """One logged mutation batch (see module docstring for semantics)."""

    batch_id: int
    epoch: int
    rebuild: bool
    digest: int          # post-apply edge-multiset digest, in [0, 2^64)
    count: int           # post-apply live-edge count
    inserts: np.ndarray = field(default_factory=lambda: np.zeros((0, 2),
                                                                 np.int64))
    deletes: np.ndarray = field(default_factory=lambda: np.zeros((0, 2),
                                                                 np.int64))


# -- edge-multiset digest ----------------------------------------------------
#
# Commutative over edges: digest = sum over (u, v) of mix64(u, v) mod
# 2^64, plus the live count.  Commutativity is the load-bearing
# property — the post-apply digest of a batch is computable BEFORE the
# batch applies (old digest + inserts - deletes), which is what lets
# the WAL record carry it while still being written ahead of the apply.

def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer on a uint64 array (wraps mod 2^64)."""
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


def edge_digest(edges) -> tuple[int, int]:
    """(digest, count) of an edge multiset — order-independent, and
    sensitive to multiplicity through the count + per-edge hash sum."""
    e = np.asarray(edges, np.int64).reshape(-1, 2)
    if not len(e):
        return 0, 0
    with np.errstate(over="ignore"):
        u = e[:, 0].astype(np.uint64)
        v = e[:, 1].astype(np.uint64)
        h = _mix64(_mix64(u + np.uint64(0x9E3779B97F4A7C15)) ^
                   (v * np.uint64(0xC2B2AE3D27D4EB4F)))
        return int(np.sum(h, dtype=np.uint64)), len(e)


def update_digest(digest: int, count: int, inserts, deletes
                  ) -> tuple[int, int]:
    """Fold one batch into (digest, count) arithmetically — the
    pre-apply computation of the post-apply digest."""
    di, ci = edge_digest(inserts)
    dd, cd = edge_digest(deletes)
    return (digest + di - dd) & _U64, count + ci - cd


# -- record framing ----------------------------------------------------------

def encode_record(rec: WalRecord) -> bytes:
    """One framed record: ``[len][crc][payload]`` (canonical — equal
    records encode to identical bytes)."""
    ins = np.ascontiguousarray(np.asarray(rec.inserts, np.int64)
                               .reshape(-1, 2))
    dels = np.ascontiguousarray(np.asarray(rec.deletes, np.int64)
                                .reshape(-1, 2))
    payload = _FIXED.pack(rec.batch_id, rec.epoch, int(rec.rebuild),
                          rec.digest & _U64, rec.count,
                          len(ins), len(dels)) \
        + ins.tobytes() + dels.tobytes()
    return _HEADER.pack(len(payload), zlib.crc32(payload)) + payload


def decode_payload(payload: bytes) -> WalRecord:
    if len(payload) < _FIXED.size:
        raise WalError(f"payload too short: {len(payload)} bytes")
    bid, epoch, rebuild, digest, count, n_ins, n_del = \
        _FIXED.unpack_from(payload)
    need = _FIXED.size + 16 * (n_ins + n_del)
    if len(payload) != need:
        raise WalError(f"payload length {len(payload)} != {need} "
                       f"for {n_ins} inserts + {n_del} deletes")
    ins = np.frombuffer(payload, np.int64, 2 * n_ins,
                        _FIXED.size).reshape(-1, 2)
    dels = np.frombuffer(payload, np.int64, 2 * n_del,
                         _FIXED.size + 16 * n_ins).reshape(-1, 2)
    return WalRecord(bid, epoch, bool(rebuild), digest, count,
                     ins.copy(), dels.copy())


def scan_records(data: bytes, offset: int = 0
                 ) -> tuple[list[WalRecord], int]:
    """Parse the maximal valid record prefix of ``data[offset:]``;
    returns ``(records, end_offset)`` where ``end_offset`` is the byte
    after the last valid record.  A torn tail, a flipped bit, or any
    framing damage stops the scan — it never raises."""
    recs: list[WalRecord] = []
    while True:
        if offset + _HEADER.size > len(data):
            break
        length, crc = _HEADER.unpack_from(data, offset)
        end = offset + _HEADER.size + length
        if length < _FIXED.size or end > len(data):
            break
        payload = data[offset + _HEADER.size:end]
        if zlib.crc32(payload) != crc:
            break
        try:
            recs.append(decode_payload(payload))
        except WalError:
            break
        offset = end
    return recs, offset


# -- the log file ------------------------------------------------------------

class WriteAheadLog:
    """Append-only record log over one file.

    Opening an existing log scans it, keeps the valid record prefix in
    ``self.records``, and truncates any torn tail off the file; opening
    a fresh path writes the file magic.  ``append`` is durable before
    it returns (write + flush + fsync) and returns the pre-append byte
    offset so a caller whose apply subsequently fails can
    ``truncate_to`` it — keeping "record present <=> batch applied or
    replayable" an invariant rather than a hope.
    """

    def __init__(self, path, fsync: bool = True):
        self.path = str(path)
        self.fsync = bool(fsync)
        self.records: list[WalRecord] = []
        if os.path.exists(self.path) and os.path.getsize(self.path):
            with open(self.path, "rb") as f:
                data = f.read()
            if not data.startswith(FILE_MAGIC):
                raise WalError(f"{self.path}: not a WAL (bad file magic)")
            self.records, end = scan_records(data, len(FILE_MAGIC))
            if end < len(data):              # torn tail from a crash
                with open(self.path, "r+b") as f:
                    f.truncate(end)
        else:
            with open(self.path, "wb") as f:
                f.write(FILE_MAGIC)
                f.flush()
                if self.fsync:
                    os.fsync(f.fileno())
        self._f = open(self.path, "ab")
        self._end = os.path.getsize(self.path)

    @property
    def n_records(self) -> int:
        return len(self.records)

    def append(self, rec: WalRecord) -> int:
        """Durably append one record; returns the byte offset the
        record starts at (the ``truncate_to`` target on apply failure)."""
        buf = encode_record(rec)
        off = self._end
        self._f.write(buf)
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        maybe_crash("after-wal-append")
        self._end = off + len(buf)
        self.records.append(rec)
        return off

    def truncate_to(self, offset: int) -> None:
        """Drop every record at/after ``offset`` (undo of appends whose
        apply failed, so the log never outruns reality by a dead record)."""
        if not len(FILE_MAGIC) <= offset <= self._end:
            raise WalError(f"truncate offset {offset} outside "
                           f"[{len(FILE_MAGIC)}, {self._end}]")
        self._f.close()
        with open(self.path, "r+b") as f:
            f.truncate(offset)
        while self._end > offset and self.records:
            self._end -= len(encode_record(self.records.pop()))
        if self._end != offset:
            raise WalError(f"truncate offset {offset} is not a record "
                           "boundary")
        self._f = open(self.path, "ab")

    def close(self) -> None:
        self._f.close()


def wal_path(dir_: str) -> str:
    return os.path.join(str(dir_), "wal.log")
