"""The resident-engine graph server.

``GraphServer`` keeps a :class:`~repro.core.api.GraphEngine` and its
device-resident graph alive across queries and drives mixed-algorithm
traffic through the engine's compile cache:

  admission  ``submit()`` validates against the registry, stamps
             ``(qid, t_submit)`` and queues per coalescing key.
  coalescing ``core.serve.coalescer``: source queries pack into the
             bucket ladder (padding with duplicate roots) so every
             launch hits an already-compiled ``batch=bucket`` program;
             refresh queries of one key share a single launch.
  execution  ``DoubleBufferedExecutor``: launches dispatch
             asynchronously and up to ``depth`` ride in flight, so
             host-side batch formation overlaps device execution; the
             pipeline blocks only at demux.
  demux      per-query answers slice back out of the batched
             ``(P, B, n_local)`` outputs into host-side
             :class:`QueryResult`\\ s, identical to what a direct
             ``engine.program(...)`` call returns (the conformance
             gate in ``tests/test_serve.py`` pins this bit-exactly).

Synchronous by construction: ``pump()`` advances the pipeline one step
and the caller owns the loop (``serve`` for a closed-loop query list,
``serve_trace`` to replay a timed arrival trace in real time).  No
threads — JAX's async dispatch provides the only concurrency that
matters here, device/host overlap.
"""

from __future__ import annotations

import time

import numpy as np

import jax.numpy as jnp

from repro.core.api import GraphEngine
from repro.serve.coalescer import Batch, BucketLadder, Coalescer
from repro.serve.executor import DoubleBufferedExecutor, Launch
from repro.serve.metrics import ServeMetrics
from repro.serve.query import Query, QueryKey, QueryResult, make_key


class GraphServer:
    def __init__(self, engine: GraphEngine, *, buckets=None, depth: int = 2):
        self.engine = engine
        self.garr = engine.device_graph()      # resident device graph
        self.ladder = BucketLadder(buckets) if buckets else BucketLadder()
        self.coalescer = Coalescer(self.ladder)
        self.executor = DoubleBufferedExecutor(depth)
        self.metrics = ServeMetrics()
        # mailbox of demuxed-but-uncollected answers: serve()/
        # serve_trace() POP what they return, so a long-running server
        # holds only results nobody has picked up yet (callers driving
        # submit/pump directly should pop too — vertex fields are
        # (n_orig,) arrays and an unbounded dict is an OOM over hours
        # of traffic)
        self.results: dict[int, QueryResult] = {}
        self._next_qid = 0

    # -- admission -----------------------------------------------------------
    def submit(self, algo: str, variant: str | None = None, *,
               root: int | None = None, **params) -> int:
        """Admit one query; returns its qid (resolved in ``results``)."""
        return self.submit_query(
            Query(make_key(algo, variant, **params), root))

    def submit_query(self, q: Query, t_submit: float | None = None) -> int:
        if q.qid != -1:
            # admission stamps the object in place; re-submitting it
            # would re-stamp it and orphan the first qid's result
            raise ValueError(
                f"query already admitted as qid={q.qid}; build a fresh "
                "Query to resubmit")
        q.qid, self._next_qid = self._next_qid, self._next_qid + 1
        q.t_submit = time.perf_counter() if t_submit is None else t_submit
        self.metrics.start()
        self.coalescer.admit(q)
        return q.qid

    # -- warmup --------------------------------------------------------------
    def warmup(self, keys) -> int:
        """Compile and run once every (key x ladder rung) so serving
        never pays a trace or compile; returns the launch count.  Source
        keys warm every bucket; refresh keys warm the single unbatched
        program.  Warmup launches bypass the metrics window."""
        launches = 0
        for key in keys:
            if isinstance(key, str):
                key = make_key(key)
            buckets = self.ladder.sizes if key.rooted else (0,)
            for b in buckets:
                batch = Batch(key, [], b, [0] * b)
                out = self._dispatch(batch)
                # warming mid-serving may retire REAL in-flight
                # launches to free slots: demux them, don't drop them
                for launch in self.executor.push(batch, out):
                    self._demux(launch)
                launches += 1
        for launch in self.executor.drain():
            self._demux(launch)
        return launches

    # -- the pipeline --------------------------------------------------------
    def pump(self) -> list[QueryResult]:
        """Advance one step: form + dispatch one batch if any query is
        pending (retiring the oldest launch when the pipeline is full),
        else retire one in-flight launch.  Returns completed results."""
        batch = self.coalescer.next_batch()
        if batch is not None:
            out = self._dispatch(batch)
            retired = self.executor.push(batch, out)
        else:
            launch = self.executor.complete_one()
            retired = [launch] if launch else []
        done = []
        for launch in retired:
            done.extend(self._demux(launch))
        return done

    def drain(self) -> list[QueryResult]:
        """Run the pipeline dry: every pending query dispatched, every
        in-flight launch demuxed."""
        done = []
        while self.coalescer.has_pending() or len(self.executor):
            done.extend(self.pump())
        self.metrics.stop()
        return done

    def serve(self, queries) -> list[QueryResult]:
        """Closed loop: admit everything, drain, return (and collect
        from the mailbox) results in submission order."""
        qids = [self.submit_query(q) for q in queries]
        self.drain()
        return [self.results.pop(qid) for qid in qids]

    def serve_trace(self, trace) -> list[QueryResult]:
        """Replay a timed arrival trace (``[(t_s, Query)]``, as built by
        ``serve.workload.synthetic_trace``) in real time: a query is
        admitted when its arrival time passes; between arrivals the
        pipeline keeps pumping, so queued work and in-flight launches
        overlap the wait.  Latency runs from the intended arrival."""
        trace = sorted(trace, key=lambda e: e[0])
        t0 = time.perf_counter()
        done, i = [], 0
        while i < len(trace) or self.coalescer.has_pending() \
                or len(self.executor):
            now = time.perf_counter() - t0
            while i < len(trace) and trace[i][0] <= now:
                self.submit_query(trace[i][1], t_submit=t0 + trace[i][0])
                i += 1
            if self.coalescer.has_pending() or len(self.executor):
                for res in self.pump():
                    self.results.pop(res.qid, None)   # collected here
                    done.append(res)
            elif i < len(trace):
                time.sleep(min(trace[i][0] - now, 0.005))
        self.metrics.stop()
        return done

    # -- dispatch / demux ----------------------------------------------------
    def _program(self, key: QueryKey, bucket: int):
        return self.engine.program(
            key.algo, key.variant, batch=bucket or None, **dict(key.params))

    def _dispatch(self, batch: Batch):
        prog = self._program(batch.key, batch.bucket)
        if batch.bucket:
            return prog(self.garr, jnp.asarray(batch.roots, jnp.int32))
        return prog(self.garr)

    def _demux(self, launch: Launch) -> list[QueryResult]:
        batch = launch.payload
        if not batch.queries:              # warmup launch: nothing to slice
            return []
        prog = self._program(batch.key, batch.bucket)
        names = prog.program.output_names
        is_vertex = prog.program.output_is_vertex
        *outs, rounds = launch.out
        eng = self.engine
        if batch.bucket:
            # drop padded dup-root lanes ON DEVICE so the host copy in
            # this (only) synchronous section is proportional to real
            # queries, not the bucket width
            k = batch.n_real
            gathered = [eng.gather_batched_vertex_field(o[:, :k]) if v
                        else np.asarray(o)[:k]
                        for o, v in zip(outs, is_vertex)]
            rounds = np.asarray(rounds[:k])
            per_query = [
                ({n: g[i] for n, g in zip(names, gathered)}, int(rounds[i]))
                for i in range(batch.n_real)]
        else:
            shared = {n: (eng.gather_vertex_field(o) if v
                          else np.asarray(o)[()])
                      for n, (o, v) in zip(names, zip(outs, is_vertex))}
            per_query = [(shared, int(rounds))] * batch.n_real
        results = []
        for q, (fields, r) in zip(batch.queries, per_query):
            res = QueryResult(
                qid=q.qid, key=q.key, root=q.root, fields=fields, rounds=r,
                latency_s=launch.t_done - q.t_submit, bucket=batch.bucket)
            self.metrics.record(q.key.label, batch.bucket, res.latency_s)
            self.results[q.qid] = res
            results.append(res)
        return results
