"""The resident-engine graph server.

``GraphServer`` keeps a :class:`~repro.core.api.GraphEngine` and its
device-resident graph alive across queries and drives mixed-algorithm
traffic through the engine's compile cache:

  admission  ``submit()`` validates against the registry, stamps
             ``(qid, t_submit)`` and queues per coalescing key.
  coalescing ``core.serve.coalescer``: source queries pack into the
             bucket ladder (padding with duplicate roots) so every
             launch hits an already-compiled ``batch=bucket`` program;
             refresh queries of one key share a single launch.
  execution  ``DoubleBufferedExecutor``: launches dispatch
             asynchronously and up to ``depth`` ride in flight, so
             host-side batch formation overlaps device execution; the
             pipeline blocks only at demux.
  demux      per-query answers slice back out of the batched
             ``(P, B, n_local)`` outputs into host-side
             :class:`QueryResult`\\ s, identical to what a direct
             ``engine.program(...)`` call returns (the conformance
             gate in ``tests/test_serve.py`` pins this bit-exactly).

Synchronous by construction: ``pump()`` advances the pipeline one step
and the caller owns the loop (``serve`` for a closed-loop query list,
``serve_trace`` to replay a timed arrival trace in real time).  No
threads — JAX's async dispatch provides the only concurrency that
matters here, device/host overlap.

**Dynamic graphs.**  ``mutate()`` applies a batched edge insert/delete
against the resident graph through ``repro.serve.dynamic`` and opens a
new SNAPSHOT EPOCH: pending queries are flushed against the old
buffers first, the device patch is functional (in-flight launches keep
their snapshot), and queries admitted afterwards read the new one.
Seeded queries (``pagerank/warm``, ``cc/incremental``,
``kcore/incremental``) resolve their vertex-field seed from the
server's seed store — previously served outputs, adopted warm only
when the mutation history since their epoch keeps them exact
(``registry.IncrementalSpec.mutations``), cold otherwise.

**Overload & failure resilience.**  Every terminal disposition is a
typed :class:`QueryResult` (``status`` in ``ok`` / ``timed_out`` /
``shed`` / ``failed``) — the server never silently drops an admitted
query and never lets one bad query take the pipeline down:

  * **validation** — :func:`~repro.serve.query.validate_query` runs at
    admission (``validate=False`` opts out): out-of-range roots,
    non-finite float params and corrupt seed vectors are rejected
    BEFORE they can ride — or poison — a coalesced launch.
  * **deadlines** — a query may carry ``deadline_s`` (or inherit
    ``default_deadline_s``), an admission-to-demux budget.  Budgets
    never block a batch: a query already over budget when its batch
    forms is answered ``timed_out`` without launching, and one whose
    launch lands late has its answer withheld at demux.  Latency cells
    in the metrics record only ``ok`` answers; misses ride the
    ``timed_out`` counter.
  * **load shedding** — ``max_queued`` bounds the admission queue; an
    overflowing admission sheds the pending query with the soonest
    absolute deadline (oldest-deadline-first — see
    :class:`~repro.serve.coalescer.Coalescer`), resolved as ``shed``.
  * **retry & quarantine** — a launch that raises (at dispatch or
    surfacing from JAX's async runtime at the blocking call) is
    bisected: multi-query batches resubmit their members singly, so
    healthy queries complete and the poison one keeps failing alone;
    a singleton retries with exponential backoff (``retry_backoff_s *
    2**attempt``) up to ``max_retries``, then lands in
    ``server.quarantined`` with a ``failed`` result carrying the
    exception.  The executor itself never wedges — a failed launch
    cannot orphan its in-flight peers (``serve.executor``).
"""

from __future__ import annotations

import time

import numpy as np

import jax.numpy as jnp

from repro.core import registry
from repro.core.api import GraphEngine
from repro.obs import NULL_RECORDER
from repro.core.incremental import KIND_DTYPES, cold_seed
from repro.serve.coalescer import Batch, BucketLadder, Coalescer
from repro.serve.dynamic import DynamicGraph, MutationBatch, MutationStats
from repro.serve.executor import DoubleBufferedExecutor, Launch
from repro.serve.metrics import ServeMetrics
from repro.serve.persist import DurabilityState, Persistence, maybe_crash
from repro.serve.query import Query, QueryKey, QueryResult, make_key, \
    validate_query


class GraphServer:
    def __init__(self, engine: GraphEngine, *, buckets=None, depth: int = 2,
                 max_queued: int | None = None,
                 default_deadline_s: float | None = None,
                 max_retries: int = 2, retry_backoff_s: float = 0.02,
                 validate: bool = True,
                 persistence: Persistence | str | None = None,
                 obs=None):
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        self.engine = engine
        # serving-path observability: an obs.SpanRecorder records every
        # pipeline stage (admission -> validate -> coalesce_wait ->
        # dispatch -> device -> demux -> query) plus durability and
        # resilience events.  The default NULL_RECORDER is disabled —
        # each site pays one attribute read and allocates nothing, so
        # the un-traced server is the pre-obs server.
        self.obs = obs if obs is not None else NULL_RECORDER
        self.garr = engine.device_graph()      # resident device graph
        self.ladder = BucketLadder(buckets) if buckets else BucketLadder()
        self.coalescer = Coalescer(self.ladder, max_queued=max_queued)
        self.executor = DoubleBufferedExecutor(depth)
        self.metrics = ServeMetrics()
        self.default_deadline_s = default_deadline_s
        self.max_retries = int(max_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self.validate = bool(validate)
        # quarantined poison queries (their `failed` results), and
        # out-of-band resolutions (shed at admission) the next pump()
        # hands back to whoever drives the loop
        self.quarantined: list[QueryResult] = []
        self._oob: list[QueryResult] = []
        # mailbox of demuxed-but-uncollected answers: serve()/
        # serve_trace() POP what they return, so a long-running server
        # holds only results nobody has picked up yet (callers driving
        # submit/pump directly should pop too — vertex fields are
        # (n_orig,) arrays and an unbounded dict is an OOM over hours
        # of traffic)
        self.results: dict[int, QueryResult] = {}
        self._next_qid = 0
        # dynamic-graph state: the snapshot epoch, the lazily built
        # mutation subsystem, the mutation history (what _seeds entries
        # are judged against), and the seed store itself —
        # (algo, field) -> (epoch, (n_orig,) array) harvested from
        # served refresh results
        self.epoch = 0
        self.dynamic: DynamicGraph | None = None
        self.mutation_log: list[dict] = []
        self._seeds: dict[tuple[str, str], tuple[int, np.ndarray]] = {}
        # durability (WAL + snapshots): None = fail-stop volatile, the
        # pre-persistence behavior.  ``persistence=`` starts durable
        # FROM SCRATCH (refusing a dir that already holds state);
        # ``GraphServer.recover(dir)`` is the resume constructor.
        self.durability: DurabilityState | None = None
        self.recovery_report = None
        if persistence is not None:
            self.durability = DurabilityState.create(self, persistence)
            self.durability.obs = self.obs
            self.metrics.wal_records = self.durability.wal_records

    # -- admission -----------------------------------------------------------
    def submit(self, algo: str, variant: str | None = None, *,
               root: int | None = None,
               deadline_s: float | None = None, **params) -> int:
        """Admit one query; returns its qid (resolved in ``results``)."""
        return self.submit_query(
            Query(make_key(algo, variant, **params), root,
                  deadline_s=deadline_s))

    def submit_query(self, q: Query, t_submit: float | None = None) -> int:
        if q.qid != -1:
            # admission stamps the object in place; re-submitting it
            # would re-stamp it and orphan the first qid's result
            raise ValueError(
                f"query already admitted as qid={q.qid}; build a fresh "
                "Query to resubmit")
        with self.obs.span("admission", "server", label=q.key.label):
            if self.validate:
                try:
                    with self.obs.span("validate", "server"):
                        validate_query(q, self.engine.g.n_orig)
                except ValueError:
                    self.metrics.count("rejected")
                    if self.obs.enabled:
                        self.obs.event("rejected", "server",
                                       label=q.key.label)
                    raise
            q.qid, self._next_qid = self._next_qid, self._next_qid + 1
            q.t_submit = (time.perf_counter() if t_submit is None
                          else t_submit)
            q.epoch = self.epoch
            if q.deadline_s is None:
                q.deadline_s = self.default_deadline_s
            # the metrics window opens at FIRST ADMISSION (idempotent),
            # so the first launch's queue + dispatch wait counts against
            # qps — record()'s own start() is only a fallback for
            # standalone use
            self.metrics.start()
            shed = self.coalescer.admit(q)
        if shed is not None:
            self._oob.append(self._resolve(shed, "shed"))
        return q.qid

    def _resolve(self, q: Query, status: str,
                 error: Exception | None = None,
                 t_done: float | None = None) -> QueryResult:
        """Terminal non-``ok`` disposition: typed result into the
        mailbox plus the matching resilience counter."""
        t_done = time.perf_counter() if t_done is None else t_done
        res = QueryResult(
            qid=q.qid, key=q.key, root=q.root, fields={}, rounds=-1,
            latency_s=t_done - q.t_submit, bucket=0, epoch=q.epoch,
            status=status, error=error)
        self.metrics.count(
            "quarantined" if status == "failed" else status)
        if status == "failed":
            self.quarantined.append(res)
        if self.obs.enabled:
            # the query's async span closes here even on a non-ok
            # disposition; the matching resilience event marks WHY
            self.obs.add_span("query", "server", q.t_submit, t_done,
                              qid=q.qid, label=q.key.label, bucket=0,
                              status=status,
                              latency_s=res.latency_s)
            if status == "failed":
                self.obs.event("launch_failure", "executor", qid=q.qid,
                               label=q.key.label)
            else:
                self.obs.event(status, "server", qid=q.qid,
                               label=q.key.label)
        self.results[q.qid] = res
        return res

    # -- warmup --------------------------------------------------------------
    def warmup(self, keys) -> int:
        """Compile and run once every (key x ladder rung) so serving
        never pays a trace or compile; returns the launch count.  Source
        keys warm every bucket; refresh keys warm the single unbatched
        program.  Warmup launches bypass the metrics window."""
        launches = 0
        for key in keys:
            if isinstance(key, str):
                key = make_key(key)
            buckets = self.ladder.sizes if key.rooted else (0,)
            for b in buckets:
                batch = Batch(key, [], b, [0] * b)
                out = self._dispatch(batch)
                # warming mid-serving may retire REAL in-flight
                # launches to free slots: demux them, don't drop them
                for launch in self.executor.push(batch, out):
                    self._demux(launch)
                launches += 1
        for launch in self.executor.drain():
            self._demux(launch)
        return launches

    # -- dynamic graphs ------------------------------------------------------
    def dynamic_graph(self) -> DynamicGraph:
        """The mutation subsystem over the resident graph (built lazily:
        the host free-slot index costs O(E) once)."""
        if self.dynamic is None:
            self.dynamic = DynamicGraph(self.engine, self.garr)
            self.dynamic.epoch = self.epoch
        return self.dynamic

    def mutate(self, inserts=None, deletes=None) -> MutationStats:
        """Apply one batched edge insert/delete and open a new snapshot
        epoch.

        Ordering vs. the pipeline: every PENDING query is flushed into
        the executor first, so it dispatches against the pre-mutation
        buffers it was admitted under; launches already in flight keep
        reading their snapshot because the device patch is functional
        (copy-on-write), never an in-place donation.  Queries admitted
        after this call read the new epoch.  A batch that overflows the
        free-slot pools falls back to a full re-partition + re-upload
        (``stats.rebuild=True``; programs for the new layout re-warm on
        first use — the compile-cache key covers the layout signature).

        Durability ordering (``persistence=`` servers): the batch is
        planned, WAL-logged and fsynced BEFORE it applies — a crash at
        any instruction leaves the log a superset of the applied
        epochs, never the reverse — and every ``snapshot_every`` epochs
        a crash-consistent snapshot pumps after the apply.
        """
        if self.durability is not None:
            maybe_crash("between-batches")
        with self.obs.span("mutation", "server") as msp:
            while True:
                batch = self.coalescer.next_batch()
                if batch is None:
                    break
                self._launch(batch)       # results wait in the mailbox
            dyn = self.dynamic_graph()
            if self.durability is not None:
                stats = self.durability.logged_apply(dyn, inserts, deletes)
            else:
                stats = dyn.apply(inserts, deletes)
            self.garr = dyn.garr
            self.epoch = dyn.epoch
            self.metrics.epoch = self.epoch
            self.mutation_log.append({
                "epoch": stats.epoch, "n_insert": stats.n_insert,
                "n_delete": stats.n_delete, "rebuild": stats.rebuild})
            msp.args.update(epoch=stats.epoch, n_insert=stats.n_insert,
                            n_delete=stats.n_delete,
                            rebuild=bool(stats.rebuild))
            if self.durability is not None:
                self.metrics.wal_records = self.durability.wal_records
                self.durability.maybe_snapshot(self)
        return stats

    @classmethod
    def recover(cls, dir, *, mesh=None, snapshot_every=None, retain=None,
                fsync=None, **kwargs) -> "GraphServer":
        """Resume serving from a durability directory: newest
        digest-valid snapshot + WAL-suffix replay, bit-identical to the
        uninterrupted server at the recovered epoch.  ``kwargs`` pass
        through to the constructor (buckets, depth, deadlines, ...);
        the persistence knobs default to what the snapshot recorded.
        The recovered server keeps appending to the same WAL; what it
        did is on ``server.recovery_report``."""
        from repro.serve.persist.recover import recover_state
        rec = kwargs.get("obs") or NULL_RECORDER
        with rec.span("recovery", "server", dir=str(dir)) as rsp:
            rs = recover_state(dir, mesh=mesh)
            rsp.args.update(epoch=rs.epoch,
                            wal_records=rs.report.wal_records,
                            replayed=rs.report.replayed)
        server = cls(rs.engine, **kwargs)
        server.dynamic = rs.dynamic
        server.garr = rs.dynamic.garr
        server.epoch = rs.epoch
        server.mutation_log = rs.mutation_log
        server._seeds = dict(rs.seeds)
        stored = rs.persist_cfg
        cfg = Persistence(
            dir=str(dir),
            snapshot_every=(snapshot_every if snapshot_every is not None
                            else stored.get("snapshot_every", 8)),
            retain=(retain if retain is not None
                    else stored.get("retain", 2)),
            fsync=(fsync if fsync is not None
                   else stored.get("fsync", True)))
        rs.wal.fsync = cfg.fsync
        server.durability = DurabilityState.resume(
            cfg, rs.wal, rs.digest, rs.count, rs.batch_id,
            last_snapshot_epoch=rs.report.snapshot_epoch)
        server.durability.obs = server.obs
        server.recovery_report = rs.report
        server.metrics.epoch = rs.epoch
        server.metrics.recoveries = 1
        server.metrics.wal_records = rs.report.wal_records
        return server

    def resolve_seed(self, key: QueryKey) -> tuple[tuple, bool]:
        """(seed arrays, warm?) for a seeded query without an explicit
        seed.  A stored previous-epoch output is adopted WARM only when
        every mutation since its epoch is of a kind the program stays
        exact under (``IncrementalSpec.mutations``); otherwise the cold
        seed — still exact, just a full-rate recompute."""
        inc = key.spec.incremental
        if inc is not None:
            stored = self._seeds.get((key.algo, inc.seed_output))
            if stored is not None:
                seed_epoch, arr = stored
                if self._mutations_ok(seed_epoch, inc.mutations):
                    return (arr,), True
        return cold_seed(key.spec, self.engine.g), False

    def _mutations_ok(self, since_epoch: int, kinds: str) -> bool:
        if kinds == "any":
            return True
        for entry in self.mutation_log:
            if entry["epoch"] <= since_epoch:
                continue
            if kinds == "insert" and entry["n_delete"]:
                return False
            if kinds == "delete" and entry["n_insert"]:
                return False
        return True

    def _harvest_seeds(self, key: QueryKey, fields: dict,
                       epoch: int) -> None:
        """Keep the newest served output usable as a warm seed: any
        incremental variant of this algo whose ``seed_output`` is among
        the result fields gets (epoch, field) stored."""
        for algo, variant in registry.available():
            spec = registry.get_spec(algo, variant)
            inc = spec.incremental
            if inc is None or inc.of != key.algo:
                continue
            arr = fields.get(inc.seed_output)
            if arr is None:
                continue
            prev = self._seeds.get((key.algo, inc.seed_output))
            if prev is None or prev[0] <= epoch:
                self._seeds[(key.algo, inc.seed_output)] = (epoch, arr)

    # -- the pipeline --------------------------------------------------------
    def pump(self) -> list[QueryResult]:
        """Advance one step: form + dispatch one batch if any query is
        pending (retiring the oldest launch when the pipeline is full),
        else retire one in-flight launch.  Returns completed results —
        including typed shed / timed-out / failed dispositions."""
        done = self._oob
        self._oob = []
        while True:
            batch = self.coalescer.next_batch()
            if batch is None:
                launch = self.executor.complete_one()
                if launch is not None:
                    done.extend(self._demux(launch))
                return done
            batch, expired = self._check_deadlines(batch)
            done.extend(expired)
            if batch is not None:
                done.extend(self._launch(batch))
                return done
            # every member had expired in the queue: try the next batch

    def _check_deadlines(self, batch: Batch):
        """Expire batch members already over budget BEFORE the launch
        (a deadline never blocks the batch — the live members re-pack
        and go).  Returns ``(batch | None, timed-out results)``."""
        now = time.perf_counter()
        live = [q for q in batch.queries if now <= q.deadline_abs]
        expired = [self._resolve(q, "timed_out", t_done=now)
                   for q in batch.queries if now > q.deadline_abs]
        if not expired:
            return batch, []
        if not live:
            return None, expired
        if batch.bucket:
            bucket = self.ladder.pick(len(live))
            roots = [q.root for q in live]
            roots += [roots[-1]] * (bucket - len(roots))
            batch = Batch(batch.key, live, bucket, roots, batch.epoch)
        else:
            batch = Batch(batch.key, live, batch.bucket, [], batch.epoch)
        return batch, expired

    def _singleton(self, q: Query, epoch: int) -> Batch:
        """A one-query batch for the retry / bisection path."""
        if q.key.rooted:
            b = self.ladder.pick(1)
            return Batch(q.key, [q], b, [q.root] * b, epoch)
        return Batch(q.key, [q], 0, [], epoch)

    def _launch(self, batch: Batch) -> list[QueryResult]:
        """Dispatch one batch; a raising dispatch routes to retry /
        quarantine instead of propagating.  Returns whatever completed
        as a side effect (retired peers, failure dispositions)."""
        if self.obs.enabled and batch.queries and batch.t_formed:
            # coalesce-wait: first member's admission -> batch formed
            self.obs.add_span(
                "coalesce_wait", "coalescer",
                min(q.t_submit for q in batch.queries), batch.t_formed,
                label=batch.key.label, bucket=batch.bucket,
                n=batch.n_real)
        try:
            with self.obs.span("dispatch", "executor",
                               label=batch.key.label, bucket=batch.bucket,
                               n=batch.n_real):
                out = self._dispatch(batch)
        except Exception as e:
            return self._on_launch_failure(batch, e)
        done = []
        for launch in self.executor.push(batch, out):
            done.extend(self._demux(launch))
        return done

    def _on_launch_failure(self, batch: Batch,
                           exc: Exception) -> list[QueryResult]:
        if not batch.queries:
            raise exc                      # warmup launch: surface it
        if len(batch.queries) > 1:
            # poison-query quarantine, step 1: bisect by resubmitting
            # the members singly — healthy queries complete, the poison
            # one keeps failing alone and exhausts its retries below
            done = []
            for q in batch.queries:
                done.extend(self._launch(self._singleton(q, batch.epoch)))
            return done
        q = batch.queries[0]
        q.attempts += 1
        if q.attempts > self.max_retries:
            return [self._resolve(q, "failed", error=exc)]
        self.metrics.count("retries")
        if self.retry_backoff_s:
            time.sleep(self.retry_backoff_s * (2 ** (q.attempts - 1)))
        return self._launch(self._singleton(q, batch.epoch))

    def drain(self) -> list[QueryResult]:
        """Run the pipeline dry: every pending query dispatched, every
        in-flight launch demuxed."""
        done = self._oob
        self._oob = []
        while self.coalescer.has_pending() or len(self.executor):
            done.extend(self.pump())
        self.metrics.stop()
        return done

    def serve(self, queries) -> list[QueryResult]:
        """Closed loop: admit everything, drain, return (and collect
        from the mailbox) results in submission order."""
        qids = [self.submit_query(q) for q in queries]
        self.drain()
        return [self.results.pop(qid) for qid in qids]

    def serve_trace(self, trace) -> list[QueryResult]:
        """Replay a timed arrival trace (``[(t_s, Query)]``, as built by
        ``serve.workload.synthetic_trace``) in real time: a query is
        admitted when its arrival time passes; between arrivals the
        pipeline keeps pumping, so queued work and in-flight launches
        overlap the wait.  Latency runs from the intended arrival.

        Events may also be ``(t_s, MutationBatch)`` (e.g. merged from
        ``serve.dynamic.mutation_stream``): the batch applies when its
        time passes, flushing pending queries against their own epoch
        first — so a trace interleaves queries and mutations exactly as
        an online service would see them."""
        trace = sorted(trace, key=lambda e: e[0])
        t0 = time.perf_counter()
        done, i = [], 0
        while i < len(trace) or self.coalescer.has_pending() \
                or len(self.executor) or self._oob:
            now = time.perf_counter() - t0
            while i < len(trace) and trace[i][0] <= now:
                item = trace[i][1]
                if isinstance(item, MutationBatch):
                    self.mutate(inserts=item.inserts, deletes=item.deletes)
                else:
                    self.submit_query(item, t_submit=t0 + trace[i][0])
                i += 1
            if self.coalescer.has_pending() or len(self.executor) \
                    or self._oob:
                for res in self.pump():
                    self.results.pop(res.qid, None)   # collected here
                    done.append(res)
            elif i < len(trace):
                time.sleep(min(trace[i][0] - now, 0.005))
        self.metrics.stop()
        return done

    # -- dispatch / demux ----------------------------------------------------
    def _program(self, key: QueryKey, bucket: int):
        return self.engine.program(
            key.algo, key.variant, batch=bucket or None, **dict(key.params))

    def _dispatch(self, batch: Batch):
        prog = self._program(batch.key, batch.bucket)
        if batch.key.seeded:
            # one seeded launch per query; warmup batches (no queries)
            # resolve a cold seed just to compile the right shapes
            explicit = batch.queries[0].seed if batch.queries else None
            seed = explicit if explicit is not None \
                else self.resolve_seed(batch.key)[0]
            args = tuple(
                self.engine.scatter_vertex_field(a, KIND_DTYPES[kind])
                for a, kind in zip(seed, batch.key.spec.input_kinds))
            return prog(self.garr, *args)
        if batch.bucket:
            return prog(self.garr, jnp.asarray(batch.roots, jnp.int32))
        return prog(self.garr)

    def _demux(self, launch: Launch) -> list[QueryResult]:
        batch = launch.payload
        if self.obs.enabled and batch.queries:
            # in-flight interval stamped by the executor (dispatch ->
            # block_until_ready); warmup launches stay un-traced
            self.obs.add_span(
                "device", "device", launch.t_dispatch, launch.t_done,
                label=batch.key.label, bucket=batch.bucket,
                n=batch.n_real, launch_seq=launch.seq,
                failed=launch.error is not None)
        if launch.error is not None:
            # the async runtime surfaced a failure at the blocking
            # call: same routing as a dispatch-time raise
            return self._on_launch_failure(batch, launch.error)
        if not batch.queries:              # warmup launch: nothing to slice
            return []
        with self.obs.span("demux", "server", label=batch.key.label,
                           bucket=batch.bucket, n=batch.n_real):
            prog = self._program(batch.key, batch.bucket)
            names = prog.program.output_names
            is_vertex = prog.program.output_is_vertex
            *outs, rounds = launch.out
            eng = self.engine
            if batch.bucket:
                # drop padded dup-root lanes ON DEVICE so the host copy
                # in this (only) synchronous section is proportional to
                # real queries, not the bucket width
                k = batch.n_real
                gathered = [eng.gather_batched_vertex_field(o[:, :k]) if v
                            else np.asarray(o)[:k]
                            for o, v in zip(outs, is_vertex)]
                rounds = np.asarray(rounds[:k])
                per_query = [
                    ({n: g[i] for n, g in zip(names, gathered)},
                     int(rounds[i]))
                    for i in range(batch.n_real)]
            else:
                shared = {n: (eng.gather_vertex_field(o) if v
                              else np.asarray(o)[()])
                          for n, (o, v) in zip(names, zip(outs, is_vertex))}
                per_query = [(shared, int(rounds))] * batch.n_real
                # refresh outputs double as warm seeds for the
                # incremental variants of the same algorithm
                self._harvest_seeds(batch.key, shared, batch.epoch)
            results = []
            for q, (fields, r) in zip(batch.queries, per_query):
                if launch.t_done > q.deadline_abs:
                    # the answer exists but missed its budget: withhold
                    # it (a client gone by now must not see a stale
                    # success)
                    results.append(
                        self._resolve(q, "timed_out", t_done=launch.t_done))
                    continue
                res = QueryResult(
                    qid=q.qid, key=q.key, root=q.root, fields=fields,
                    rounds=r, latency_s=launch.t_done - q.t_submit,
                    bucket=batch.bucket, epoch=batch.epoch)
                self.metrics.record(q.key.label, batch.bucket,
                                    res.latency_s)
                if self.obs.enabled:
                    # the query's async span closes with the IDENTICAL
                    # latency_s float metrics just recorded — the
                    # exact-reconciliation invariant the obs tests pin
                    self.obs.add_span(
                        "query", "server", q.t_submit, launch.t_done,
                        qid=q.qid, label=q.key.label, bucket=batch.bucket,
                        status="ok", latency_s=res.latency_s)
                self.results[q.qid] = res
                results.append(res)
            return results
