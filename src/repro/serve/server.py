"""The resident-engine graph server.

``GraphServer`` keeps a :class:`~repro.core.api.GraphEngine` and its
device-resident graph alive across queries and drives mixed-algorithm
traffic through the engine's compile cache:

  admission  ``submit()`` validates against the registry, stamps
             ``(qid, t_submit)`` and queues per coalescing key.
  coalescing ``core.serve.coalescer``: source queries pack into the
             bucket ladder (padding with duplicate roots) so every
             launch hits an already-compiled ``batch=bucket`` program;
             refresh queries of one key share a single launch.
  execution  ``DoubleBufferedExecutor``: launches dispatch
             asynchronously and up to ``depth`` ride in flight, so
             host-side batch formation overlaps device execution; the
             pipeline blocks only at demux.
  demux      per-query answers slice back out of the batched
             ``(P, B, n_local)`` outputs into host-side
             :class:`QueryResult`\\ s, identical to what a direct
             ``engine.program(...)`` call returns (the conformance
             gate in ``tests/test_serve.py`` pins this bit-exactly).

Synchronous by construction: ``pump()`` advances the pipeline one step
and the caller owns the loop (``serve`` for a closed-loop query list,
``serve_trace`` to replay a timed arrival trace in real time).  No
threads — JAX's async dispatch provides the only concurrency that
matters here, device/host overlap.

**Dynamic graphs.**  ``mutate()`` applies a batched edge insert/delete
against the resident graph through ``repro.serve.dynamic`` and opens a
new SNAPSHOT EPOCH: pending queries are flushed against the old
buffers first, the device patch is functional (in-flight launches keep
their snapshot), and queries admitted afterwards read the new one.
Seeded queries (``pagerank/warm``, ``cc/incremental``,
``kcore/incremental``) resolve their vertex-field seed from the
server's seed store — previously served outputs, adopted warm only
when the mutation history since their epoch keeps them exact
(``registry.IncrementalSpec.mutations``), cold otherwise.
"""

from __future__ import annotations

import time

import numpy as np

import jax.numpy as jnp

from repro.core import registry
from repro.core.api import GraphEngine
from repro.core.incremental import KIND_DTYPES, cold_seed
from repro.serve.coalescer import Batch, BucketLadder, Coalescer
from repro.serve.dynamic import DynamicGraph, MutationBatch, MutationStats
from repro.serve.executor import DoubleBufferedExecutor, Launch
from repro.serve.metrics import ServeMetrics
from repro.serve.query import Query, QueryKey, QueryResult, make_key


class GraphServer:
    def __init__(self, engine: GraphEngine, *, buckets=None, depth: int = 2):
        self.engine = engine
        self.garr = engine.device_graph()      # resident device graph
        self.ladder = BucketLadder(buckets) if buckets else BucketLadder()
        self.coalescer = Coalescer(self.ladder)
        self.executor = DoubleBufferedExecutor(depth)
        self.metrics = ServeMetrics()
        # mailbox of demuxed-but-uncollected answers: serve()/
        # serve_trace() POP what they return, so a long-running server
        # holds only results nobody has picked up yet (callers driving
        # submit/pump directly should pop too — vertex fields are
        # (n_orig,) arrays and an unbounded dict is an OOM over hours
        # of traffic)
        self.results: dict[int, QueryResult] = {}
        self._next_qid = 0
        # dynamic-graph state: the snapshot epoch, the lazily built
        # mutation subsystem, the mutation history (what _seeds entries
        # are judged against), and the seed store itself —
        # (algo, field) -> (epoch, (n_orig,) array) harvested from
        # served refresh results
        self.epoch = 0
        self.dynamic: DynamicGraph | None = None
        self.mutation_log: list[dict] = []
        self._seeds: dict[tuple[str, str], tuple[int, np.ndarray]] = {}

    # -- admission -----------------------------------------------------------
    def submit(self, algo: str, variant: str | None = None, *,
               root: int | None = None, **params) -> int:
        """Admit one query; returns its qid (resolved in ``results``)."""
        return self.submit_query(
            Query(make_key(algo, variant, **params), root))

    def submit_query(self, q: Query, t_submit: float | None = None) -> int:
        if q.qid != -1:
            # admission stamps the object in place; re-submitting it
            # would re-stamp it and orphan the first qid's result
            raise ValueError(
                f"query already admitted as qid={q.qid}; build a fresh "
                "Query to resubmit")
        q.qid, self._next_qid = self._next_qid, self._next_qid + 1
        q.t_submit = time.perf_counter() if t_submit is None else t_submit
        q.epoch = self.epoch
        # the metrics window opens at FIRST ADMISSION (idempotent), so
        # the first launch's queue + dispatch wait counts against qps —
        # record()'s own start() is only a fallback for standalone use
        self.metrics.start()
        self.coalescer.admit(q)
        return q.qid

    # -- warmup --------------------------------------------------------------
    def warmup(self, keys) -> int:
        """Compile and run once every (key x ladder rung) so serving
        never pays a trace or compile; returns the launch count.  Source
        keys warm every bucket; refresh keys warm the single unbatched
        program.  Warmup launches bypass the metrics window."""
        launches = 0
        for key in keys:
            if isinstance(key, str):
                key = make_key(key)
            buckets = self.ladder.sizes if key.rooted else (0,)
            for b in buckets:
                batch = Batch(key, [], b, [0] * b)
                out = self._dispatch(batch)
                # warming mid-serving may retire REAL in-flight
                # launches to free slots: demux them, don't drop them
                for launch in self.executor.push(batch, out):
                    self._demux(launch)
                launches += 1
        for launch in self.executor.drain():
            self._demux(launch)
        return launches

    # -- dynamic graphs ------------------------------------------------------
    def dynamic_graph(self) -> DynamicGraph:
        """The mutation subsystem over the resident graph (built lazily:
        the host free-slot index costs O(E) once)."""
        if self.dynamic is None:
            self.dynamic = DynamicGraph(self.engine, self.garr)
            self.dynamic.epoch = self.epoch
        return self.dynamic

    def mutate(self, inserts=None, deletes=None) -> MutationStats:
        """Apply one batched edge insert/delete and open a new snapshot
        epoch.

        Ordering vs. the pipeline: every PENDING query is flushed into
        the executor first, so it dispatches against the pre-mutation
        buffers it was admitted under; launches already in flight keep
        reading their snapshot because the device patch is functional
        (copy-on-write), never an in-place donation.  Queries admitted
        after this call read the new epoch.  A batch that overflows the
        free-slot pools falls back to a full re-partition + re-upload
        (``stats.rebuild=True``; programs for the new layout re-warm on
        first use — the compile-cache key covers the layout signature).
        """
        while True:
            batch = self.coalescer.next_batch()
            if batch is None:
                break
            for launch in self.executor.push(batch, self._dispatch(batch)):
                self._demux(launch)
        dyn = self.dynamic_graph()
        stats = dyn.apply(inserts, deletes)
        self.garr = dyn.garr
        self.epoch = dyn.epoch
        self.mutation_log.append({
            "epoch": stats.epoch, "n_insert": stats.n_insert,
            "n_delete": stats.n_delete, "rebuild": stats.rebuild})
        return stats

    def resolve_seed(self, key: QueryKey) -> tuple[tuple, bool]:
        """(seed arrays, warm?) for a seeded query without an explicit
        seed.  A stored previous-epoch output is adopted WARM only when
        every mutation since its epoch is of a kind the program stays
        exact under (``IncrementalSpec.mutations``); otherwise the cold
        seed — still exact, just a full-rate recompute."""
        inc = key.spec.incremental
        if inc is not None:
            stored = self._seeds.get((key.algo, inc.seed_output))
            if stored is not None:
                seed_epoch, arr = stored
                if self._mutations_ok(seed_epoch, inc.mutations):
                    return (arr,), True
        return cold_seed(key.spec, self.engine.g), False

    def _mutations_ok(self, since_epoch: int, kinds: str) -> bool:
        if kinds == "any":
            return True
        for entry in self.mutation_log:
            if entry["epoch"] <= since_epoch:
                continue
            if kinds == "insert" and entry["n_delete"]:
                return False
            if kinds == "delete" and entry["n_insert"]:
                return False
        return True

    def _harvest_seeds(self, key: QueryKey, fields: dict,
                       epoch: int) -> None:
        """Keep the newest served output usable as a warm seed: any
        incremental variant of this algo whose ``seed_output`` is among
        the result fields gets (epoch, field) stored."""
        for algo, variant in registry.available():
            spec = registry.get_spec(algo, variant)
            inc = spec.incremental
            if inc is None or inc.of != key.algo:
                continue
            arr = fields.get(inc.seed_output)
            if arr is None:
                continue
            prev = self._seeds.get((key.algo, inc.seed_output))
            if prev is None or prev[0] <= epoch:
                self._seeds[(key.algo, inc.seed_output)] = (epoch, arr)

    # -- the pipeline --------------------------------------------------------
    def pump(self) -> list[QueryResult]:
        """Advance one step: form + dispatch one batch if any query is
        pending (retiring the oldest launch when the pipeline is full),
        else retire one in-flight launch.  Returns completed results."""
        batch = self.coalescer.next_batch()
        if batch is not None:
            out = self._dispatch(batch)
            retired = self.executor.push(batch, out)
        else:
            launch = self.executor.complete_one()
            retired = [launch] if launch else []
        done = []
        for launch in retired:
            done.extend(self._demux(launch))
        return done

    def drain(self) -> list[QueryResult]:
        """Run the pipeline dry: every pending query dispatched, every
        in-flight launch demuxed."""
        done = []
        while self.coalescer.has_pending() or len(self.executor):
            done.extend(self.pump())
        self.metrics.stop()
        return done

    def serve(self, queries) -> list[QueryResult]:
        """Closed loop: admit everything, drain, return (and collect
        from the mailbox) results in submission order."""
        qids = [self.submit_query(q) for q in queries]
        self.drain()
        return [self.results.pop(qid) for qid in qids]

    def serve_trace(self, trace) -> list[QueryResult]:
        """Replay a timed arrival trace (``[(t_s, Query)]``, as built by
        ``serve.workload.synthetic_trace``) in real time: a query is
        admitted when its arrival time passes; between arrivals the
        pipeline keeps pumping, so queued work and in-flight launches
        overlap the wait.  Latency runs from the intended arrival.

        Events may also be ``(t_s, MutationBatch)`` (e.g. merged from
        ``serve.dynamic.mutation_stream``): the batch applies when its
        time passes, flushing pending queries against their own epoch
        first — so a trace interleaves queries and mutations exactly as
        an online service would see them."""
        trace = sorted(trace, key=lambda e: e[0])
        t0 = time.perf_counter()
        done, i = [], 0
        while i < len(trace) or self.coalescer.has_pending() \
                or len(self.executor):
            now = time.perf_counter() - t0
            while i < len(trace) and trace[i][0] <= now:
                item = trace[i][1]
                if isinstance(item, MutationBatch):
                    self.mutate(inserts=item.inserts, deletes=item.deletes)
                else:
                    self.submit_query(item, t_submit=t0 + trace[i][0])
                i += 1
            if self.coalescer.has_pending() or len(self.executor):
                for res in self.pump():
                    self.results.pop(res.qid, None)   # collected here
                    done.append(res)
            elif i < len(trace):
                time.sleep(min(trace[i][0] - now, 0.005))
        self.metrics.stop()
        return done

    # -- dispatch / demux ----------------------------------------------------
    def _program(self, key: QueryKey, bucket: int):
        return self.engine.program(
            key.algo, key.variant, batch=bucket or None, **dict(key.params))

    def _dispatch(self, batch: Batch):
        prog = self._program(batch.key, batch.bucket)
        if batch.key.seeded:
            # one seeded launch per query; warmup batches (no queries)
            # resolve a cold seed just to compile the right shapes
            explicit = batch.queries[0].seed if batch.queries else None
            seed = explicit if explicit is not None \
                else self.resolve_seed(batch.key)[0]
            args = tuple(
                self.engine.scatter_vertex_field(a, KIND_DTYPES[kind])
                for a, kind in zip(seed, batch.key.spec.input_kinds))
            return prog(self.garr, *args)
        if batch.bucket:
            return prog(self.garr, jnp.asarray(batch.roots, jnp.int32))
        return prog(self.garr)

    def _demux(self, launch: Launch) -> list[QueryResult]:
        batch = launch.payload
        if not batch.queries:              # warmup launch: nothing to slice
            return []
        prog = self._program(batch.key, batch.bucket)
        names = prog.program.output_names
        is_vertex = prog.program.output_is_vertex
        *outs, rounds = launch.out
        eng = self.engine
        if batch.bucket:
            # drop padded dup-root lanes ON DEVICE so the host copy in
            # this (only) synchronous section is proportional to real
            # queries, not the bucket width
            k = batch.n_real
            gathered = [eng.gather_batched_vertex_field(o[:, :k]) if v
                        else np.asarray(o)[:k]
                        for o, v in zip(outs, is_vertex)]
            rounds = np.asarray(rounds[:k])
            per_query = [
                ({n: g[i] for n, g in zip(names, gathered)}, int(rounds[i]))
                for i in range(batch.n_real)]
        else:
            shared = {n: (eng.gather_vertex_field(o) if v
                          else np.asarray(o)[()])
                      for n, (o, v) in zip(names, zip(outs, is_vertex))}
            per_query = [(shared, int(rounds))] * batch.n_real
            # refresh outputs double as warm seeds for the incremental
            # variants of the same algorithm
            self._harvest_seeds(batch.key, shared, batch.epoch)
        results = []
        for q, (fields, r) in zip(batch.queries, per_query):
            res = QueryResult(
                qid=q.qid, key=q.key, root=q.root, fields=fields, rounds=r,
                latency_s=launch.t_done - q.t_submit, bucket=batch.bucket,
                epoch=batch.epoch)
            self.metrics.record(q.key.label, batch.bucket, res.latency_s)
            self.results[q.qid] = res
            results.append(res)
        return results
