"""Timed mutation streams for trace replay: interleave MutationBatch
events with the synthetic query trace so ``GraphServer.serve_trace``
exercises epochs under load (the ``--mutate-every`` CLI path and
``examples/mutate_stream.py``)."""

from __future__ import annotations

import numpy as np

from repro.serve.dynamic.mutation import MutationBatch


def mutation_stream(edges: np.ndarray, *, every: float, size: int,
                    duration: float, seed: int = 0) -> list:
    """``[(t, MutationBatch), ...]`` alternating delete / insert batches
    of ``size`` edges every ``every`` seconds.

    Deletes draw WITHOUT replacement from the ORIGINAL edge list, so
    every delete batch names live instances no matter what already
    mutated; running a delete batch before each insert batch also frees
    COO positions for it.  Inserts are uniform random pairs — they may
    overflow a hot row's bucket, which exercises the rebuild fallback
    on purpose (a stress stream should hit both paths).
    """
    if every <= 0 or size <= 0:
        return []
    rng = np.random.default_rng(seed)
    n = int(edges.max()) + 1 if len(edges) else 1
    pool = rng.permutation(len(edges))
    events, pi, k = [], 0, 0
    t = every
    while t < duration:
        if k % 2 == 0 and pi + size <= len(pool):
            dels = np.asarray(edges)[pool[pi:pi + size]]
            pi += size
            events.append((t, MutationBatch(deletes=dels)))
        else:
            ins = np.stack([rng.integers(0, n, size=size),
                            rng.integers(0, n, size=size)], axis=1)
            events.append((t, MutationBatch(inserts=ins)))
        k += 1
        t += every
    return events
