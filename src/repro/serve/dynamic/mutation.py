"""In-place mutation of the resident device graph: batched edge
insert/delete as slot patches against the blocked-ELL + COO shards.

The free capacity was always there: ``build_ell`` rounds row widths to
lane multiples and maxes bucket widths across partitions, and
``partition_graph`` pads the COO shards to an ``e_max`` multiple of 128
— all of that slack is addressable as FREE SLOTS.  ``DynamicGraph``
tracks it host-side (per-row ELL occupancy, per-partition COO free
stacks and an exact (u, v) -> positions index) and turns a mutation
batch into a handful of scatter patches:

  * planning runs against host mirrors of every shard array, recording
    the set of touched (partition, flat-slot) coordinates per array —
    the final value of each touched slot is then read back OFF THE
    MIRROR, so duplicate writes within a batch collapse to one
    deterministic value and the device patch never relies on scatter
    ordering;
  * one jitted ``shard_map`` patch per touched array
    (``core.graph.make_scatter_patch``) writes those values with
    ``mode="drop"`` padding — only the patch lists cross host->device,
    never the shards;
  * the patch is FUNCTIONAL (copy-on-write), so launches already in
    flight keep reading the pre-mutation buffers: that is the snapshot
    isolation the server's epoch versioning advertises.

A batch whose net growth exceeds any row's free width (or a partition's
COO slack) cannot patch; ``apply`` detects this in a capacity dry-run
BEFORE mutating anything and falls back to a full re-partition +
re-upload (``MutationStats.rebuild=True``) — correct, just not cheap.

Invariants preserved (the ones the kernels rely on):
  * each ELL row's entries stay CONTIGUOUS from its slot base — inserts
    fill at ``base + occ``, deletes move the row's last entry into the
    hole and sentinel the tail;
  * COO padding convention: vacated positions get the global-id
    sentinel ``n`` and local-id 0, exactly like ``partition_graph``;
  * degrees track live edges (pagerank contributions, kcore bounds).
"""

from __future__ import annotations

import time
from collections import Counter
from dataclasses import dataclass

import numpy as np

import jax

from repro.core.graph import ell_occupancy, ell_row_layout, \
    make_scatter_patch, partition_graph

P = jax.sharding.PartitionSpec

_ELL_NAMES = ("ell_in", "ell_out", "ell_dst", "ell_src")
_COO_KEYS = ("out_src_local", "out_dst_global",
             "in_src_global", "in_dst_local")


class EllOverflow(RuntimeError):
    """A mutation batch does not fit the free-slot pools."""


@dataclass
class MutationBatch:
    """One batched edge mutation: (k, 2) ``[u, v]`` int arrays (global
    original vertex ids).  Deletes apply before inserts, so freed slots
    are reusable within the batch; a delete must name an edge instance
    present BEFORE the batch (multigraph: one instance per request)."""

    inserts: np.ndarray | None = None
    deletes: np.ndarray | None = None


@dataclass
class MutationStats:
    """What one ``apply`` did: patch-path telemetry or the rebuild flag."""

    epoch: int
    n_insert: int
    n_delete: int
    slots_patched: int                   # touched device slots, all arrays
    arrays_patched: int                  # device arrays that got a patch
    rebuild: bool                        # True = re-partition fallback
    apply_s: float


def _as_pairs(edges) -> np.ndarray:
    if edges is None:
        return np.zeros((0, 2), np.int64)
    a = np.asarray(edges, np.int64)
    if a.ndim != 2 or a.shape[1] != 2:
        raise ValueError(f"mutation edges must be (k, 2) [u, v]: {a.shape}")
    return a


class DynamicGraph:
    """Host-side mutation planner + device patcher over one engine.

    Construction builds the O(E) free-slot index from the engine's host
    shard mirrors (so build it once and keep it — the server does,
    lazily).  ``apply`` mutates the mirrors and the resident device
    arrays in lockstep; ``self.garr`` always names the newest epoch's
    device graph.
    """

    def __init__(self, engine, garr=None, *, planner_state=None):
        self.engine = engine
        self.garr = dict(garr) if garr is not None else engine.device_graph()
        self.epoch = 0
        self._patch_fn = make_scatter_patch(engine.mesh)
        # failure-atomicity journal: while an ``apply`` is in flight,
        # every state change (mirror slot, occupancy cell, free-stack /
        # position-index op) logs its inverse; an exception mid-batch
        # replays the journal in reverse so the planner state and the
        # mirrors roll back to the pre-batch graph exactly
        self._undo: list | None = None
        if planner_state is not None:
            self._restore_planner(planner_state)
        else:
            self._rebuild_index()

    def _log_undo(self, fn) -> None:
        if self._undo is not None:
            self._undo.append(fn)

    # -- index construction ------------------------------------------------

    def _rebuild_index(self):
        g = self.engine.g
        if not g.ell_meta:
            raise ValueError(
                "dynamic mutation needs the blocked-ELL layout "
                "(partition_graph(..., build_ell_layout=True))")
        self._row_layout = {name: ell_row_layout(g.ell_meta[name].buckets)
                            for name in _ELL_NAMES}
        self._occ = {name: ell_occupancy(g.ell_meta[name],
                                         g.ell_arrays[f"{name}_idx"])
                     for name in _ELL_NAMES}
        # COO free-position stacks + exact (u, v) -> positions lookup
        # (validity sentinel: global-id column == n marks padding)
        self._free_out, self._free_in = [], []
        self._pos_out, self._pos_in = [], []
        for p in range(g.parts):
            lo = p * g.n_local
            ee = np.flatnonzero(g.out_dst_global[p] < g.n)
            self._free_out.append(
                np.flatnonzero(g.out_dst_global[p] >= g.n)[::-1].tolist())
            us = g.out_src_local[p, ee].astype(np.int64) + lo
            vs = g.out_dst_global[p, ee].astype(np.int64)
            d: dict[tuple[int, int], list[int]] = {}
            for e, u, v in zip(ee.tolist(), us.tolist(), vs.tolist()):
                d.setdefault((u, v), []).append(e)
            self._pos_out.append(d)
            ee = np.flatnonzero(g.in_src_global[p] < g.n)
            self._free_in.append(
                np.flatnonzero(g.in_src_global[p] >= g.n)[::-1].tolist())
            us = g.in_src_global[p, ee].astype(np.int64)
            vs = g.in_dst_local[p, ee].astype(np.int64) + lo
            d = {}
            for e, u, v in zip(ee.tolist(), us.tolist(), vs.tolist()):
                d.setdefault((u, v), []).append(e)
            self._pos_in.append(d)

    # -- planner-state snapshot / restore ----------------------------------

    def planner_state(self) -> dict:
        """The EXACT free-slot planner state, in plain picklable types.

        Order matters: free stacks pop from the end and position lists
        pop newest-first, so slot placement — and therefore float
        reduction order in every downstream kernel — is a function of
        this state.  A snapshot restored through ``_restore_planner``
        replays mutations into the same slots the original run used,
        which is what makes recovered answers bit-identical rather than
        merely multiset-equal."""
        return {
            "occ": {name: occ.copy() for name, occ in self._occ.items()},
            "free_out": [list(x) for x in self._free_out],
            "free_in": [list(x) for x in self._free_in],
            "pos_out": [[(u, v, list(es)) for (u, v), es in d.items()]
                        for d in self._pos_out],
            "pos_in": [[(u, v, list(es)) for (u, v), es in d.items()]
                       for d in self._pos_in],
            "epoch": int(self.epoch),
        }

    def _restore_planner(self, state: dict) -> None:
        g = self.engine.g
        if not g.ell_meta:
            raise ValueError(
                "dynamic mutation needs the blocked-ELL layout "
                "(partition_graph(..., build_ell_layout=True))")
        self._row_layout = {name: ell_row_layout(g.ell_meta[name].buckets)
                            for name in _ELL_NAMES}
        self._occ = {name: np.array(occ)
                     for name, occ in state["occ"].items()}
        self._free_out = [list(x) for x in state["free_out"]]
        self._free_in = [list(x) for x in state["free_in"]]
        self._pos_out = [{(u, v): list(es) for u, v, es in part}
                         for part in state["pos_out"]]
        self._pos_in = [{(u, v): list(es) for u, v, es in part}
                        for part in state["pos_in"]]
        self.epoch = int(state.get("epoch", 0))

    # -- capacity ----------------------------------------------------------

    def _ell_row(self, name: str, p: int, orig_row: int) -> int:
        inv = self.engine.g.ell_arrays[f"{name}_inv"]
        return int(inv[p, orig_row])

    def _edge_rows(self, u: int, v: int):
        """The four (name, partition, ELL row) cells edge (u, v) lives in."""
        n_local = self.engine.g.n_local
        pu, pv = u // n_local, v // n_local
        ul, vl = u - pu * n_local, v - pv * n_local
        return ((("ell_in", pv, self._ell_row("ell_in", pv, vl)),
                 ("ell_out", pu, self._ell_row("ell_out", pu, ul)),
                 ("ell_dst", pu, self._ell_row("ell_dst", pu, v)),
                 ("ell_src", pv, self._ell_row("ell_src", pv, u))),
                pu, pv)

    def _check_capacity(self, ins: np.ndarray, dels: np.ndarray) -> None:
        """Dry-run the whole batch against the free pools; raises
        EllOverflow (or KeyError for an absent delete) BEFORE any mirror
        mutates, so a failed batch leaves the graph untouched."""
        g = self.engine.g
        n_local = g.n_local
        # deletes must all name live edge instances
        cd = Counter((int(u), int(v)) for u, v in dels)
        for (u, v), c in cd.items():
            have = len(self._pos_out[u // n_local].get((u, v), ()))
            if c > have:
                raise KeyError(
                    f"delete of edge ({u}, {v}) x{c}: only {have} "
                    "instance(s) present")
        # net per-cell growth vs. free width / free COO positions
        net_rows: Counter = Counter()
        net_out: Counter = Counter()
        net_in: Counter = Counter()
        for arr, sign in ((ins, +1), (dels, -1)):
            for u, v in arr:
                cells, pu, pv = self._edge_rows(int(u), int(v))
                for cell in cells:
                    net_rows[cell] += sign
                net_out[pu] += sign
                net_in[pv] += sign
        for p, d in net_out.items():
            if d > len(self._free_out[p]):
                raise EllOverflow(
                    f"partition {p}: out-COO needs {d} free positions, "
                    f"has {len(self._free_out[p])}")
        for p, d in net_in.items():
            if d > len(self._free_in[p]):
                raise EllOverflow(
                    f"partition {p}: in-COO needs {d} free positions, "
                    f"has {len(self._free_in[p])}")
        for (name, p, q), d in net_rows.items():
            if d <= 0:
                continue
            width = self._row_layout[name][1][q]
            if self._occ[name][p, q] + d > width:
                raise EllOverflow(
                    f"{name} partition {p} row {q}: occupancy "
                    f"{self._occ[name][p, q]}+{d} exceeds bucket width "
                    f"{width}")

    # -- host-mirror mutation ---------------------------------------------

    def _host_array(self, key: str) -> np.ndarray:
        g = self.engine.g
        return g.ell_arrays[key] if key.endswith("_idx") \
            else getattr(g, key)

    def _touch(self, touched, key: str, p: int, s: int) -> None:
        """Record a mirror write; call BEFORE overwriting slot (p, s)
        so the first touch journals the pre-batch value."""
        seen = touched.setdefault(key, set())
        if (p, s) not in seen and self._undo is not None:
            arr, old = self._host_array(key), self._host_array(key)[p, s]
            self._log_undo(lambda: arr.__setitem__((p, s), old))
        seen.add((p, s))

    def _set_occ(self, name, p, q, delta):
        occ = self._occ[name]
        old = int(occ[p, q])
        self._log_undo(lambda: occ.__setitem__((p, q), old))
        occ[p, q] = old + delta

    def _ell_fill(self, name, p, orig_row, value, touched):
        g = self.engine.g
        q = self._ell_row(name, p, orig_row)
        base, width = self._row_layout[name]
        occ = self._occ[name]
        if occ[p, q] >= width[q]:        # unreachable post-check; belt
            raise EllOverflow(f"{name} row {q} overflow mid-apply")
        s = int(base[q] + occ[p, q])
        self._touch(touched, f"{name}_idx", p, s)
        g.ell_arrays[f"{name}_idx"][p, s] = value
        self._set_occ(name, p, q, +1)

    def _ell_vacate(self, name, p, orig_row, value, touched):
        g = self.engine.g
        meta = g.ell_meta[name]
        q = self._ell_row(name, p, orig_row)
        base, _ = self._row_layout[name]
        occ = self._occ[name]
        o = int(occ[p, q])
        idx = g.ell_arrays[f"{name}_idx"]
        row = idx[p, base[q]:base[q] + o]
        hits = np.flatnonzero(row == value)
        if hits.size == 0:
            raise KeyError(f"{name} row {q}: value {value} not present")
        s = int(base[q] + hits[-1])
        last = int(base[q] + o - 1)
        if s != last:                     # keep the row contiguous
            self._touch(touched, f"{name}_idx", p, s)
            idx[p, s] = idx[p, last]
        self._touch(touched, f"{name}_idx", p, last)
        idx[p, last] = meta.sentinel
        self._set_occ(name, p, q, -1)

    def _coo_set(self, key, p, e, value, touched):
        self._touch(touched, key, p, e)
        getattr(self.engine.g, key)[p, e] = value

    def _bump_degree(self, key, p, vl, delta, touched):
        self._touch(touched, key, p, vl)
        getattr(self.engine.g, key)[p, vl] += delta

    def _insert_one(self, u, v, touched):
        g = self.engine.g
        n_local = g.n_local
        pu, pv = u // n_local, v // n_local
        ul, vl = u - pu * n_local, v - pv * n_local
        e_out = self._free_out[pu].pop()
        e_in = self._free_in[pv].pop()
        self._log_undo(lambda: self._free_out[pu].append(e_out))
        self._log_undo(lambda: self._free_in[pv].append(e_in))
        self._coo_set("out_src_local", pu, e_out, ul, touched)
        self._coo_set("out_dst_global", pu, e_out, v, touched)
        self._coo_set("in_src_global", pv, e_in, u, touched)
        self._coo_set("in_dst_local", pv, e_in, vl, touched)
        self._pos_out[pu].setdefault((u, v), []).append(e_out)
        self._pos_in[pv].setdefault((u, v), []).append(e_in)
        self._log_undo(lambda: self._pos_out[pu][(u, v)].pop())
        self._log_undo(lambda: self._pos_in[pv][(u, v)].pop())
        self._bump_degree("out_degree", pu, ul, +1, touched)
        self._bump_degree("in_degree", pv, vl, +1, touched)
        self._ell_fill("ell_in", pv, vl, u, touched)        # neighbor id
        self._ell_fill("ell_out", pu, ul, e_out, touched)   # edge position
        self._ell_fill("ell_dst", pu, v, e_out, touched)
        self._ell_fill("ell_src", pv, u, e_in, touched)

    def _delete_one(self, u, v, touched):
        g = self.engine.g
        n_local, n = g.n_local, g.n
        pu, pv = u // n_local, v // n_local
        ul, vl = u - pu * n_local, v - pv * n_local
        e_out = self._pos_out[pu][(u, v)].pop()
        e_in = self._pos_in[pv][(u, v)].pop()
        self._log_undo(lambda: self._pos_out[pu][(u, v)].append(e_out))
        self._log_undo(lambda: self._pos_in[pv][(u, v)].append(e_in))
        self._ell_vacate("ell_in", pv, vl, u, touched)
        self._ell_vacate("ell_out", pu, ul, e_out, touched)
        self._ell_vacate("ell_dst", pu, v, e_out, touched)
        self._ell_vacate("ell_src", pv, u, e_in, touched)
        self._coo_set("out_src_local", pu, e_out, 0, touched)
        self._coo_set("out_dst_global", pu, e_out, n, touched)
        self._coo_set("in_src_global", pv, e_in, n, touched)
        self._coo_set("in_dst_local", pv, e_in, 0, touched)
        self._bump_degree("out_degree", pu, ul, -1, touched)
        self._bump_degree("in_degree", pv, vl, -1, touched)
        self._free_out[pu].append(e_out)
        self._free_in[pv].append(e_in)
        self._log_undo(lambda: self._free_out[pu].pop())
        self._log_undo(lambda: self._free_in[pv].pop())

    # -- device patching ---------------------------------------------------

    def _apply_patches(self, touched) -> tuple[int, int]:
        g = self.engine.g
        sh = jax.sharding.NamedSharding(self.engine.mesh, P("parts", None))
        n_slots = n_arrays = 0
        for key, coords in sorted(touched.items()):
            if key not in self.garr:
                # layout="coo" engines never shipped the ELL arrays;
                # the host mirrors still track them for a later rebuild
                continue
            host = self._host_array(key)
            per_p: list[list[int]] = [[] for _ in range(g.parts)]
            for p, s in coords:
                per_p[p].append(s)
            longest = max(len(x) for x in per_p)
            if longest == 0:
                continue
            # pad every partition's list to a shared pow2 length with an
            # out-of-bounds slot (dropped): patch launches quantize to a
            # few trace shapes instead of one per batch size.  The pad
            # index must be >= the row length — JAX ``.at[]`` wraps
            # negative indices, so -1 would stomp the last element.
            L = max(8, 1 << (longest - 1).bit_length())
            slots = np.full((g.parts, L), host.shape[1], np.int32)
            vals = np.zeros((g.parts, L), np.int32)
            for p, ss in enumerate(per_p):
                if ss:
                    ss = np.asarray(sorted(ss), np.int64)
                    slots[p, :len(ss)] = ss
                    vals[p, :len(ss)] = host[p, ss]
            self.garr[key] = self._patch_fn(
                self.garr[key],
                jax.device_put(slots, sh), jax.device_put(vals, sh))
            n_slots += sum(len(x) for x in per_p)
            n_arrays += 1
        return n_slots, n_arrays

    # -- public API --------------------------------------------------------

    def plan(self, inserts=None, deletes=None
             ) -> tuple[np.ndarray, np.ndarray, bool]:
        """Validate one batch against the current graph WITHOUT mutating
        anything: returns ``(ins, dels, rebuild)`` where ``rebuild``
        says the batch overflows the free pools and ``apply`` would
        take the re-partition path.  Raises exactly what ``apply``
        would raise for an invalid batch (out-of-range endpoints,
        deletes of absent edges) — which is what lets the durability
        layer reject a batch BEFORE logging it."""
        ins, dels = _as_pairs(inserts), _as_pairs(deletes)
        g = self.engine.g
        for arr, what in ((ins, "insert"), (dels, "delete")):
            if len(arr) and not ((arr >= 0) & (arr < g.n_orig)).all():
                raise ValueError(
                    f"{what} endpoints must be in [0, {g.n_orig})")
        try:
            self._check_capacity(ins, dels)
        except EllOverflow:
            return ins, dels, True
        return ins, dels, False

    def apply(self, inserts=None, deletes=None, *,
              force_rebuild: bool = False) -> MutationStats:
        """Apply one mutation batch; returns patch-path stats, or
        ``rebuild=True`` when the batch overflowed the free pools and
        the graph was re-partitioned instead.  Either way ``self.garr``
        is the new epoch's device graph and ``self.epoch`` advanced.
        ``force_rebuild=True`` takes the re-partition path even when
        the batch would fit — WAL replay uses it so a logged rebuild
        record deterministically re-takes the path the original
        execution took."""
        t0 = time.perf_counter()
        ins, dels, overflow = self.plan(inserts, deletes)
        if overflow or force_rebuild:
            return self._rebuild(ins, dels, t0)
        touched: dict[str, set] = {}
        garr_prev = dict(self.garr)        # refs only: patches are CoW
        self._undo = []
        try:
            for u, v in dels:             # deletes first: free the slots
                self._delete_one(int(u), int(v), touched)
            for u, v in ins:
                self._insert_one(int(u), int(v), touched)
            n_slots, n_arrays = self._apply_patches(touched)
        except BaseException:
            # failure atomicity: an exception mid-batch (planning OR
            # device patching) replays the journal in reverse — free
            # stacks, position index, occupancy, mirrors and the
            # resident device graph all return to the pre-batch epoch
            for undo in reversed(self._undo):
                undo()
            self.garr = garr_prev
            raise
        finally:
            self._undo = None
        self.epoch += 1
        return MutationStats(
            epoch=self.epoch, n_insert=len(ins), n_delete=len(dels),
            slots_patched=n_slots, arrays_patched=n_arrays, rebuild=False,
            apply_s=time.perf_counter() - t0)

    def _rebuild(self, ins, dels, t0) -> MutationStats:
        g = self.engine.g
        cur = self.current_edges()
        if len(dels):
            cd = Counter(map(tuple, dels.tolist()))
            keep = np.ones(len(cur), bool)
            for i, uv in enumerate(map(tuple, cur.tolist())):
                if cd.get(uv, 0):
                    cd[uv] -= 1
                    keep[i] = False
            cur = cur[keep]
        if len(ins):
            cur = np.concatenate([cur, ins])
        new_g = partition_graph(cur, g.n_orig, g.parts)
        self.engine.g = new_g
        self.garr = self.engine.device_graph()
        self._rebuild_index()
        self.epoch += 1
        return MutationStats(
            epoch=self.epoch, n_insert=len(ins), n_delete=len(dels),
            slots_patched=0, arrays_patched=0, rebuild=True,
            apply_s=time.perf_counter() - t0)

    def current_edges(self) -> np.ndarray:
        """(E_live, 2) int64 edge list reconstructed from the out-shard
        mirrors (order arbitrary) — what a rebuild re-partitions and
        what the oracle referees post-mutation answers against."""
        g = self.engine.g
        out = []
        for p in range(g.parts):
            ee = np.flatnonzero(g.out_dst_global[p] < g.n)
            u = g.out_src_local[p, ee].astype(np.int64) + p * g.n_local
            v = g.out_dst_global[p, ee].astype(np.int64)
            out.append(np.stack([u, v], axis=1))
        return np.concatenate(out) if out else np.zeros((0, 2), np.int64)

    # -- capacity-aware sampling (tests / benches) -------------------------

    def sample_insertable(self, k: int, rng) -> np.ndarray:
        """Sample k (u, v) pairs guaranteed to fit the free pools AS ONE
        BATCH — the deterministic way to exercise the patch path (random
        pairs may overflow a hot row, which is the rebuild path's job)."""
        g = self.engine.g
        n_local = g.n_local
        occ = {name: self._occ[name].copy() for name in _ELL_NAMES}
        free_out = [len(x) for x in self._free_out]
        free_in = [len(x) for x in self._free_in]
        out: list[tuple[int, int]] = []
        tries = 0
        while len(out) < k:
            tries += 1
            if tries > 200 * k + 1000:
                raise EllOverflow(
                    f"could not sample {k} insertable edges: free pools "
                    "exhausted")
            u = int(rng.integers(0, g.n_orig))
            v = int(rng.integers(0, g.n_orig))
            cells, pu, pv = self._edge_rows(u, v)
            if free_out[pu] < 1 or free_in[pv] < 1:
                continue
            if any(occ[name][p, q] >= self._row_layout[name][1][q]
                   for name, p, q in cells):
                continue
            free_out[pu] -= 1
            free_in[pv] -= 1
            for name, p, q in cells:
                occ[name][p, q] += 1
            out.append((u, v))
        return np.asarray(out, np.int64)

    def sample_deletable(self, k: int, rng) -> np.ndarray:
        """Sample k DISTINCT live edge instances (multigraph-safe: the
        multiset of sampled pairs never exceeds live multiplicity)."""
        cur = self.current_edges()
        if len(cur) < k:
            raise ValueError(f"only {len(cur)} live edges; cannot "
                             f"sample {k} deletions")
        pick = rng.choice(len(cur), size=k, replace=False)
        return cur[pick]
