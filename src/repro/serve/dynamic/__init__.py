"""Dynamic-graph subsystem: in-place blocked-ELL mutation, snapshot
epochs, and the mutation-stream generator for trace replay.

``DynamicGraph`` (mutation.py) owns the host-side free-slot index and
the device patch path; ``GraphServer.mutate`` wraps it with pipeline
flushing and epoch bookkeeping; the incremental recompute programs the
epochs feed live in ``repro.core.incremental`` / the registry.
"""

from repro.serve.dynamic.mutation import DynamicGraph, EllOverflow, \
    MutationBatch, MutationStats
from repro.serve.dynamic.stream import mutation_stream

__all__ = ["DynamicGraph", "EllOverflow", "MutationBatch",
           "MutationStats", "mutation_stream"]
