"""Typed queries for the graph server.

A query names a registered program by ``(algo, variant, params)`` plus
— for traversal programs with per-query inputs — a source vertex.  The
``(algo, variant, params)`` triple is the **coalescing key**: queries
with equal keys resolve to the same ``CompiledProgram`` family and can
ride one batched launch (``core/api.py`` caches per batch width, so a
bucket ladder over one key never re-traces).

Three shapes of query flow through the server:

  * **source queries** (``bfs``, ``sssp``, ``betweenness``): carry a
    ``root``; the coalescer packs up to ``bucket`` of them into one
    ``batch=bucket`` launch and the demux slices lane ``i`` back out.
  * **refresh queries** (``pagerank``, ``cc``, ``kcore``,
    ``triangles``): no root; ONE launch serves every refresh query of
    the same key that is pending at dispatch time (they all want the
    same answer), recorded as ``bucket=0``.
  * **seeded queries** (``pagerank/warm``, ``cc/incremental``,
    ``kcore/incremental``): refresh queries whose program takes whole
    vertex-field inputs.  The server resolves the seed per launch — a
    stored previous-epoch output when the mutation history allows it,
    the program's cold seed otherwise — so seeded queries dispatch one
    launch each (``bucket=0``) and never vmap.

Every admitted query is stamped with the server's snapshot ``epoch``;
the epoch rides through the batch into ``QueryResult.epoch``, naming
exactly which graph version answered.

**Resilience surface.**  A query may carry a ``deadline_s`` — an
admission-to-demux latency budget.  The server never blocks a batch on
it: a query whose budget expires in the queue is answered ``timed_out``
without launching, one whose launch lands late gets its answer withheld
and the same typed result.  :func:`validate_query` is the admission
gate: malformed inputs (out-of-range roots, non-finite float params
such as an sssp ``weight_scale``, NaN/Inf or out-of-range seed vectors)
are rejected BEFORE they can poison a coalesced launch.  Every
terminal disposition is a :class:`QueryResult` whose ``status`` is one
of ``"ok"`` / ``"timed_out"`` / ``"shed"`` / ``"failed"``; only
``"ok"`` results carry fields.
"""

from __future__ import annotations

import math

import numpy as np

from dataclasses import dataclass, field

from repro.core import registry
from repro.core.registry import program_label


@dataclass(frozen=True)
class QueryKey:
    """The coalescing identity of a query: program + bound params."""

    algo: str
    variant: str
    params: tuple = ()                  # sorted (name, value) pairs

    @property
    def label(self) -> str:
        return program_label(self.algo, self.variant)

    @property
    def spec(self):
        return registry.get_spec(self.algo, self.variant)

    @property
    def rooted(self) -> bool:
        """Takes SCALAR per-query inputs (a root) — batches on the ladder."""
        spec = self.spec
        return bool(spec.inputs) and \
            all(k == "scalar" for k in spec.input_kinds)

    @property
    def seeded(self) -> bool:
        """Takes vertex-field inputs the server resolves per launch."""
        return any(k != "scalar" for k in self.spec.input_kinds)


def make_key(algo: str, variant: str | None = None, **params) -> QueryKey:
    """Resolve through the registry (so ``"bfs/fast"`` shorthand and
    default variants work, and unknown programs fail at admission with
    the registered-key list, not at dispatch)."""
    spec = registry.get_spec(algo, variant)
    unknown = set(params) - set(spec.defaults)
    if unknown:
        raise TypeError(
            f"{spec.key}: unknown params {sorted(unknown)}; "
            f"accepted: {sorted(spec.defaults)}")
    return QueryKey(spec.algo, spec.variant, tuple(sorted(params.items())))


@dataclass
class Query:
    """One admitted query.  ``qid`` / ``t_submit`` are assigned by the
    server at admission; ``t_submit`` doubles as the latency clock start
    (trace replay passes the intended arrival time instead).  ``epoch``
    is stamped at admission too: batches only coalesce queries of one
    epoch, so a launch reads exactly one graph snapshot.

    ``seed`` (seeded queries only) optionally pins the vertex-field
    inputs — a tuple of (n_orig,) host arrays, one per program input;
    left ``None``, the server resolves warm-vs-cold itself.

    ``deadline_s`` is the admission-to-demux latency budget (None =
    unbounded); ``attempts`` counts failed launches this query has
    ridden (the server's retry/quarantine bookkeeping).
    """

    key: QueryKey
    root: int | None = None
    qid: int = -1
    t_submit: float = 0.0
    seed: tuple | None = None
    epoch: int = -1
    deadline_s: float | None = None
    attempts: int = 0

    @property
    def deadline_abs(self) -> float:
        """Absolute wall-clock deadline on the ``t_submit`` clock
        (+inf when unbounded) — the load-shedder's eviction key."""
        if self.deadline_s is None:
            return math.inf
        return self.t_submit + self.deadline_s

    def __post_init__(self):
        if self.key.rooted and self.root is None:
            raise ValueError(
                f"{self.key.label} takes inputs {self.key.spec.inputs}; "
                "a source query needs root=")
        if not self.key.rooted and self.root is not None:
            raise ValueError(
                f"{self.key.label} takes no per-query inputs; "
                f"root={self.root} would be silently ignored")
        if self.seed is not None:
            if not self.key.seeded:
                raise ValueError(
                    f"{self.key.label} takes no vertex-field inputs; "
                    "seed= would be silently ignored")
            if len(self.seed) != len(self.key.spec.inputs):
                raise ValueError(
                    f"{self.key.label} takes {len(self.key.spec.inputs)} "
                    f"seed fields {self.key.spec.inputs}; got "
                    f"{len(self.seed)}")


def query(algo: str, variant: str | None = None, *,
          root: int | None = None, seed: tuple | None = None,
          deadline_s: float | None = None, **params) -> Query:
    """Convenience constructor: ``query("bfs", root=7)``."""
    return Query(make_key(algo, variant, **params), root, seed=seed,
                 deadline_s=deadline_s)


def validate_query(q: Query, n_orig: int) -> None:
    """Admission-time input validation; raises ``ValueError`` on inputs
    that would poison a launch (or silently corrupt a shared batch):

      * a root outside ``[0, n_orig)``;
      * a non-finite float param (an sssp ``weight_scale=inf`` scales
        every edge weight non-finite — rejected here, not at round 40);
      * a non-positive ``deadline_s``;
      * seed vectors of the wrong length, with NaN/Inf entries (float
        kinds), or with out-of-range entries (int kinds: labels and
        core bounds both live in ``[0, n_orig)``).

    The structural checks (root presence, seed arity) already ran in
    ``Query.__post_init__``; this adds the graph-sized range checks the
    dataclass cannot know.
    """
    if q.root is not None and not 0 <= int(q.root) < n_orig:
        raise ValueError(
            f"{q.key.label}: root {q.root} outside [0, {n_orig})")
    for name, value in q.key.params:
        if isinstance(value, float) and not math.isfinite(value):
            raise ValueError(
                f"{q.key.label}: param {name}={value!r} is not finite")
    if q.deadline_s is not None and not (
            math.isfinite(q.deadline_s) and q.deadline_s > 0):
        raise ValueError(
            f"{q.key.label}: deadline_s={q.deadline_s!r} must be a "
            "positive finite number of seconds")
    if q.seed is None:
        return
    for arr, kind, name in zip(q.seed, q.key.spec.input_kinds,
                               q.key.spec.inputs):
        a = np.asarray(arr)
        if a.shape != (n_orig,):
            raise ValueError(
                f"{q.key.label}: seed {name!r} has shape {a.shape}; "
                f"expected ({n_orig},)")
        if kind == "vertex_f32":
            if not np.isfinite(a).all():
                raise ValueError(
                    f"{q.key.label}: seed {name!r} has non-finite "
                    "entries")
        elif not ((a >= 0) & (a < n_orig)).all():
            raise ValueError(
                f"{q.key.label}: seed {name!r} has entries outside "
                f"[0, {n_orig})")


STATUSES = ("ok", "timed_out", "shed", "failed")


@dataclass
class QueryResult:
    """Demultiplexed per-query answer.

    ``fields`` maps the program's ``output_names`` to gathered host
    arrays — ``(n_orig,)`` for vertex fields, scalars for scalars —
    exactly what a direct ``engine.program(...)`` call plus
    ``gather_vertex_field`` yields.  Refresh queries coalesced into one
    launch SHARE the fields dict; treat it as read-only.  ``epoch`` is
    the snapshot epoch the answering launch read.

    ``status`` is the typed disposition: ``"ok"`` carries the answer;
    ``"timed_out"`` missed its ``deadline_s`` budget (fields withheld,
    ``rounds == -1``); ``"shed"`` was evicted by the bounded admission
    queue; ``"failed"`` exhausted its launch retries and was
    quarantined.  ``error`` holds the final exception for ``"failed"``.
    """

    qid: int
    key: QueryKey
    root: int | None
    fields: dict
    rounds: int
    latency_s: float
    bucket: int                         # launch batch width; 0 = refresh
    epoch: int = 0
    status: str = "ok"
    error: Exception | None = None

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    def __getitem__(self, name: str):
        if self.status != "ok":
            raise KeyError(
                f"qid={self.qid} ({self.key.label}) resolved "
                f"{self.status!r}; no fields")
        return self.fields[name]
