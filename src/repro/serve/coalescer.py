"""Batch coalescing: pack compatible pending queries into a fixed
ladder of batch sizes so every launch hits an already-compiled program.

The engine compile-caches per ``(algo, variant, params, batch)``
(``core/api.py``), so a server that launched whatever batch width the
queue happened to hold would re-trace constantly.  The ladder quantizes
instead: a batch of ``k`` source queries launches at the smallest
bucket ``>= k`` (capped at the top bucket), padding the root vector by
repeating the last root — padded lanes are real lanes whose answers the
demux discards.  After one warmup pass per bucket nothing ever traces
again (``tests/test_serve.py::test_bucket_ladder_no_retrace``).

Policy is deliberately work-conserving: a batch forms as soon as the
executor has room and ANY query is pending — there is no fill timer —
so light traffic rides small buckets at low latency and heavy traffic
climbs the ladder by itself.  Fairness across keys is oldest-head-first
(the key whose front query has waited longest dispatches next), which
bounds per-key starvation under a skewed mix.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass

from repro.serve.query import Query, QueryKey

DEFAULT_BUCKETS = (1, 8, 32, 128)


class BucketLadder:
    """Sorted fixed batch sizes; ``pick(k)`` = smallest bucket >= k,
    top bucket when k overflows (the rest stays queued)."""

    def __init__(self, buckets=DEFAULT_BUCKETS):
        sizes = sorted(set(int(b) for b in buckets))
        if not sizes or sizes[0] < 1:
            raise ValueError(f"buckets must be positive ints: {buckets!r}")
        self.sizes = tuple(sizes)

    def pick(self, pending: int) -> int:
        for b in self.sizes:
            if pending <= b:
                return b
        return self.sizes[-1]

    def __repr__(self):
        return f"BucketLadder{self.sizes}"


@dataclass
class Batch:
    """One coalesced launch: ``bucket`` source queries (roots padded to
    the bucket width by duplication), or — ``bucket == 0`` — every
    pending refresh query of one key sharing a single unbatched launch.
    ``epoch`` is the snapshot epoch all member queries were admitted at
    (a batch never mixes epochs)."""

    key: QueryKey
    queries: list
    bucket: int
    roots: list                          # padded, len == bucket; [] refresh
    epoch: int = -1
    t_formed: float = 0.0                # perf_counter at next_batch()

    @property
    def n_real(self) -> int:
        return len(self.queries)


class Coalescer:
    """Admission queue + batch formation over per-(key, epoch) FIFO
    queues.  Keying the queues on the admission epoch is what keeps
    coalescing snapshot-consistent: queries admitted before a mutation
    never share a launch with queries admitted after it, so every
    launch reads exactly one graph version.

    ``max_queued`` bounds the TOTAL pending count; an admission that
    would exceed it sheds one query first, **oldest-deadline-first**:
    the victim is the pending query whose absolute deadline expires
    soonest (ties, and the unbounded ``deadline_s=None`` tail, break
    to oldest admission).  Under overload that policy drops exactly
    the queries least likely to make their budget anyway and keeps
    no-deadline work last in the firing line.  The evicted query (which
    may be the one just admitted) is returned so the server can resolve
    it with a typed ``shed`` result instead of silence."""

    def __init__(self, ladder: BucketLadder | None = None,
                 max_queued: int | None = None):
        if max_queued is not None and max_queued < 1:
            raise ValueError(f"max_queued must be >= 1, got {max_queued}")
        self.ladder = ladder or BucketLadder()
        self.max_queued = max_queued
        self._pending: dict[tuple[QueryKey, int], deque[Query]] = {}

    def admit(self, q: Query) -> Query | None:
        """Queue ``q``; returns the query shed to stay within
        ``max_queued`` (None when the queue had room)."""
        self._pending.setdefault((q.key, q.epoch), deque()).append(q)
        if self.max_queued is None or \
                self.pending_count() <= self.max_queued:
            return None
        return self._shed_one()

    def _shed_one(self) -> Query:
        victim_ke, victim_i, victim_key = None, -1, None
        for ke, dq in self._pending.items():
            for i, q in enumerate(dq):
                k = (q.deadline_abs, q.t_submit, q.qid)
                if victim_key is None or k < victim_key:
                    victim_ke, victim_i, victim_key = ke, i, k
        dq = self._pending[victim_ke]
        victim = dq[victim_i]
        del dq[victim_i]
        return victim

    def pending_count(self, key: QueryKey | None = None) -> int:
        if key is not None:
            return sum(len(d) for (k, _), d in self._pending.items()
                       if k == key)
        return sum(len(d) for d in self._pending.values())

    def has_pending(self) -> bool:
        return any(self._pending.values())

    def next_batch(self) -> Batch | None:
        """Form ONE batch from the (key, epoch) whose head query is
        oldest."""
        live = [(d[0].t_submit, ke) for ke, d in self._pending.items() if d]
        if not live:
            return None
        _, (key, epoch) = min(live, key=lambda e: e[0])  # ties: admission
        dq = self._pending[(key, epoch)]
        now = time.perf_counter()          # batch formation time: the
        # coalesce-wait span for each member runs t_submit..t_formed
        if key.seeded:
            # one launch per seeded query: each carries (or resolves to)
            # its own vertex-field seed, so launches never share
            return Batch(key, [dq.popleft()], 0, [], epoch, t_formed=now)
        if not key.rooted:
            queries = list(dq)
            dq.clear()
            return Batch(key, queries, 0, [], epoch, t_formed=now)
        bucket = self.ladder.pick(len(dq))
        queries = [dq.popleft() for _ in range(min(bucket, len(dq)))]
        roots = [q.root for q in queries]
        roots += [roots[-1]] * (bucket - len(roots))   # dup-root padding
        return Batch(key, queries, bucket, roots, epoch, t_formed=now)
