"""Synthetic serving workload: Zipfian roots, weighted algorithm mix,
Poisson arrivals.

Real query traffic over a graph is skewed — a few hot sources dominate
(the "millions of users" scenario is mostly queries about the same
popular vertices) — so roots draw from a Zipf(s) distribution over a
seed-fixed permutation of the vertex ids (hot vertices are scattered
across partitions, not clustered at id 0).  Arrivals are a Poisson
process at ``rate`` queries/sec; the mix string gives per-program
weights, e.g. ``"bfs:8,sssp:4,cc:1"`` (``algo[/variant][:weight]``,
weight defaults to 1, variants resolve through the registry).
"""

from __future__ import annotations

import numpy as np

from repro.serve.query import Query, QueryKey, make_key


def parse_mix(mix: str) -> list[tuple[QueryKey, float]]:
    """``"bfs:8,sssp/default:4,cc:1"`` -> [(QueryKey, weight), ...]."""
    out = []
    for entry in mix.split(","):
        entry = entry.strip()
        if not entry:
            continue
        name, _, w = entry.partition(":")
        out.append((make_key(name.strip()), float(w) if w else 1.0))
    if not out:
        raise ValueError(f"empty mix: {mix!r}")
    return out


def zipf_root_sampler(n: int, s: float = 1.05, seed: int = 0):
    """``sample(size=None) -> vertex id(s)``, Zipf(s)-skewed over a
    permutation of [0, n)."""
    rng = np.random.default_rng(seed)
    w = np.arange(1, n + 1, dtype=np.float64) ** -s
    w /= w.sum()
    perm = rng.permutation(n)

    def sample(size=None):
        picked = rng.choice(n, size=size, p=w)
        return perm[picked] if size is not None else int(perm[picked])

    return sample


def synthetic_trace(n_vertices: int, mix, *, rate: float = 64.0,
                    duration: float = 5.0, zipf_s: float = 1.05,
                    seed: int = 0) -> list[tuple[float, Query]]:
    """Timed arrival trace: ``[(t_arrival_s, Query), ...]`` sorted by
    time.  ``mix`` is a mix string or pre-parsed [(key, weight)] list."""
    if isinstance(mix, str):
        mix = parse_mix(mix)
    keys = [k for k, _ in mix]
    w = np.asarray([wt for _, wt in mix], np.float64)
    w /= w.sum()
    rng = np.random.default_rng(seed)
    roots = zipf_root_sampler(n_vertices, s=zipf_s, seed=seed + 1)
    trace, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / rate)
        if t >= duration:
            return trace
        key = keys[rng.choice(len(keys), p=w)]
        root = roots() if key.rooted else None
        trace.append((t, Query(key, root)))
