"""Local-ops dispatch: backend-tuned kernels for the superstep work bundle.

"The Anatomy of Large-Scale Distributed Graph Algorithms" separates a
distributed graph algorithm's per-superstep *work bundle* from its
exchange machinery; ``core/partitioned.py`` owns the exchanges, and this
module owns the work bundle.  Every program hot loop routes through one
of three primitives:

  ``spmv_pull(g, ell, x)``
      y[v] = sum over in-neighbors u of v of x[u]  (PageRank pull).
  ``frontier_pull(g, ell, bits, unvisited)``
      min-id in-neighbor of v present in the packed frontier bitmap, or
      INT_INF (owner-side BFS parent derivation).
  ``scatter_combine(g, ell, vals, op, identity=...)``
      combine per-edge values into a per-row accumulator with
      op in {add, min, max, or} - the generalized push combine.
  ``pull_min_eq(g, ell, xg, target)``
      min-id in-neighbor u of v with xg[u] == target[v] - the
      level-keyed frontier_pull (bfs/async parent derivation).

Each primitive has THREE implementations, selected at trace time:

  * ``ref``     the COO scatter idiom the programs used to inline
                (``.at[...].add/min/max`` over the padded (P, E) edge
                list).  Lowers to serialized scatters on CPU - kept as
                the debugging baseline and the ``--layout coo`` path.
  * ``ell``     dense per-bucket gather + row reduction over the
                blocked-ELL layout (``core/graph.py``): fully vectorized
                on every backend, no scatters anywhere (results return
                to row order through the inverse-permutation GATHER).
  * ``pallas``  the TPU kernels in ``repro/kernels/{spmv,frontier}``,
                applied per ELL bucket (f32 additive combines route
                through the SpMV kernel; frontier tests through the BFS
                pull kernel; non-kernelizable ops stay on the ell path).

Mode resolution: the ``REPRO_LOCALOPS`` env var (or :func:`set_mode`)
picks ``auto`` (default: pallas on TPU, ell elsewhere), ``ref``, or
``kernel`` (force the Pallas kernels, interpreted off-TPU).  When the
graph dict carries no ELL arrays (``--layout coo``), every call falls
back to ``ref`` regardless of mode.

All functions are pure per-partition compute (no collectives), callable
inside or outside ``shard_map``, and vmap cleanly for batched
multi-source programs.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.graph import EllMeta
from repro.core.partitioned import test_bit

INT_INF = jnp.int32(2 ** 30)

MODES = ("auto", "ref", "kernel")
_MODE_OVERRIDE: str | None = None

# ref-path metadata: which COO key array feeds each ELL structure, and
# whether that key can carry the sentinel (needs a +1 drop slot)
_COO_KEY = {
    "ell_out": ("out_src_local", False),
    "ell_dst": ("out_dst_global", True),
    "ell_src": ("in_src_global", True),
}


def set_mode(mode: str | None) -> None:
    """Process-wide override of the REPRO_LOCALOPS env var (None clears).

    NOTE: the mode is read at TRACE time; ``GraphEngine.program`` keys
    its compile cache on the active mode so switching re-traces.
    """
    global _MODE_OVERRIDE
    if mode is not None and mode not in MODES:
        raise ValueError(f"localops mode {mode!r} not in {MODES}")
    _MODE_OVERRIDE = mode


def get_mode() -> str:
    """The active dispatch mode: override > $REPRO_LOCALOPS > auto."""
    mode = _MODE_OVERRIDE or os.environ.get("REPRO_LOCALOPS", "auto")
    if mode not in MODES:
        raise ValueError(
            f"REPRO_LOCALOPS={mode!r} invalid; expected one of {MODES}")
    return mode


def resolve(mode: str | None = None, backend: str | None = None) -> str:
    """Concrete implementation a call would take: ref | ell | pallas."""
    mode = mode or get_mode()
    backend = backend or jax.default_backend()
    if mode == "ref":
        return "ref"
    if mode == "kernel" or backend == "tpu":
        return "pallas"
    return "ell"


def _use_pallas(mode: str) -> bool:
    return resolve(mode) == "pallas"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _has_ell(g: dict, ell: EllMeta) -> bool:
    return f"{ell.name}_idx" in g


def _buckets(ell: EllMeta, flat):
    """Yield (row0, rows, width, (rows, width) idx block) per bucket."""
    off = 0
    r0 = 0
    for rows, k in ell.buckets:
        blk = flat[..., off:off + rows * k].reshape(
            flat.shape[:-1] + (rows, k)) if k else None
        yield r0, rows, k, blk
        off += rows * k
        r0 += rows


# ---------------------------------------------------------------------------
# spmv_pull
# ---------------------------------------------------------------------------

def spmv_pull(g: dict, ell: EllMeta, x, *, mode: str | None = None):
    """y[row] = sum of x[neighbor] over the row's ELL slots, f32.

    ``ell`` must be a neighbor-id structure (``ell_in``): slots hold
    GLOBAL vertex ids, sentinel contributes 0.  The ref path is the COO
    gather + scatter-add over the in-shard.
    """
    mode = mode or get_mode()
    x = x.astype(jnp.float32)
    if mode == "ref" or not _has_ell(g, ell):
        src = g["in_src_global"]
        dstl = g["in_dst_local"]
        valid = src < ell.sentinel
        gathered = jnp.where(valid, x[jnp.where(valid, src, 0)], 0.0)
        return jnp.zeros((ell.n_rows,), jnp.float32).at[dstl].add(
            gathered, mode="drop")

    idx = g[f"{ell.name}_idx"]
    inv = g[f"{ell.name}_inv"]
    xk = jnp.concatenate([x, jnp.zeros((1,), jnp.float32)])  # sentinel slot
    use_pallas = _use_pallas(mode)
    outs = []
    for _, rows, k, blk in _buckets(ell, idx):
        if k == 0:
            outs.append(jnp.zeros((rows,), jnp.float32))
            continue
        vmask = blk != ell.sentinel
        if use_pallas:
            from repro.kernels.spmv.kernel import spmv_ell
            outs.append(spmv_ell(blk, vmask.astype(jnp.float32), xk,
                                 row_block=128, interpret=_interpret()))
        else:
            outs.append(jnp.where(vmask, xk[blk], 0.0).sum(axis=1))
    return jnp.concatenate(outs)[inv]


# ---------------------------------------------------------------------------
# frontier_pull
# ---------------------------------------------------------------------------

def frontier_pull(g: dict, ell: EllMeta, bits, unvisited, *,
                  mode: str | None = None):
    """Min-id in-neighbor of each row present in the packed frontier.

    ``bits`` is the (n/32,) uint32 global frontier bitmap; ``unvisited``
    a (n_rows,) bool mask.  Returns (n_rows,) int32, INT_INF where the
    row is visited or has no in-frontier neighbor.  ``ell`` must be the
    neighbor-id structure (``ell_in``).
    """
    mode = mode or get_mode()
    n = ell.sentinel
    if mode == "ref" or not _has_ell(g, ell):
        src = g["in_src_global"]
        dstl = g["in_dst_local"]
        valid = src < n
        hit = test_bit(bits, jnp.where(valid, src, 0)) == 1
        hit = hit & valid & unvisited[dstl]
        return jnp.full((ell.n_rows,), INT_INF, jnp.int32).at[
            jnp.where(hit, dstl, ell.n_rows - 1)].min(
            jnp.where(hit, src, INT_INF), mode="drop")

    idx = g[f"{ell.name}_idx"]
    inv = g[f"{ell.name}_inv"]
    perm = g[f"{ell.name}_perm"]
    unv_ell = unvisited[perm]
    # sentinel n indexes one word past the bitmap: append a zero guard
    bits_g = jnp.concatenate([bits, jnp.zeros((1,), jnp.uint32)])
    use_pallas = _use_pallas(mode)
    outs = []
    for r0, rows, k, blk in _buckets(ell, idx):
        if k == 0:
            outs.append(jnp.full((rows,), INT_INF, jnp.int32))
            continue
        unv_b = unv_ell[r0:r0 + rows]
        if use_pallas:
            from repro.kernels.frontier.kernel import bfs_pull
            outs.append(bfs_pull(blk, bits_g, unv_b.astype(jnp.int32),
                                 row_block=128, interpret=_interpret()))
        else:
            hit = test_bit(bits_g, blk) == 1
            cand = jnp.where(hit, blk, INT_INF).min(axis=1)
            outs.append(jnp.where(unv_b, cand, INT_INF))
    return jnp.concatenate(outs)[inv]


# ---------------------------------------------------------------------------
# pull_min_eq
# ---------------------------------------------------------------------------

def pull_min_eq(g: dict, ell: EllMeta, xg, target, *,
                mode: str | None = None):
    """Min-id in-neighbor ``u`` of each row ``v`` with ``xg[u] ==
    target[v]``, or INT_INF when none matches.

    The level-keyed generalization of :func:`frontier_pull`: instead of
    testing membership in one frontier bitmap, each row names the value
    class it wants (``target``, e.g. ``level[v] - 1``) and slots whose
    global field ``xg`` equals it qualify.  bfs/async uses it to derive
    parents from converged levels in ONE pull — every level's parents at
    once, where the bitmap form needs a pass per level.  ``ell`` must be
    the neighbor-id structure (``ell_in``); no Pallas kernel applies, so
    the kernel mode rides the ell path (the module-doc rule for
    non-kernelizable ops).
    """
    mode = mode or get_mode()
    n = ell.sentinel
    if mode == "ref" or not _has_ell(g, ell):
        src = g["in_src_global"]
        dstl = g["in_dst_local"]
        valid = src < n
        hit = valid & (xg[jnp.where(valid, src, 0)] == target[dstl])
        return jnp.full((ell.n_rows,), INT_INF, jnp.int32).at[
            jnp.where(hit, dstl, ell.n_rows - 1)].min(
            jnp.where(hit, src, INT_INF), mode="drop")

    idx = g[f"{ell.name}_idx"]
    inv = g[f"{ell.name}_inv"]
    perm = g[f"{ell.name}_perm"]
    tgt_ell = target[perm]
    # sentinel n indexes one slot past xg: append a guard no real target
    # equals (INT_INF; targets are levels < n or INT_INF - 1 for
    # unreached rows)
    xg_g = jnp.concatenate([xg, jnp.full((1,), INT_INF, xg.dtype)])
    outs = []
    for r0, rows, k, blk in _buckets(ell, idx):
        if k == 0:
            outs.append(jnp.full((rows,), INT_INF, jnp.int32))
            continue
        hit = xg_g[blk] == tgt_ell[r0:r0 + rows][:, None]
        outs.append(jnp.where(hit, blk, INT_INF).min(axis=1))
    return jnp.concatenate(outs)[inv]


# ---------------------------------------------------------------------------
# scatter_combine
# ---------------------------------------------------------------------------

_REDUCERS = {
    "add": lambda a: a.sum(axis=1),
    "min": lambda a: a.min(axis=1),
    "max": lambda a: a.max(axis=1),
    "or": lambda a: a.any(axis=1),
}


def scatter_combine(g: dict, ell: EllMeta, vals, op: str, *, identity,
                    mode: str | None = None):
    """Combine per-edge ``vals`` into a (n_rows,) accumulator with ``op``.

    ``ell`` must be an edge-POSITION structure (``ell_out`` / ``ell_dst``
    / ``ell_src``): slots index into the partition's (E,) edge arrays,
    so ``vals`` must be aligned with that edge order and already carry
    ``identity`` at inactive/padding edges.  Rows no edge touches come
    back as ``identity`` — callers pass the same sentinel the old
    scatter idiom initialized its accumulator with (0, INT_INF, ...).
    """
    mode = mode or get_mode()
    if op not in _REDUCERS:
        raise ValueError(f"scatter_combine op {op!r} not in "
                         f"{tuple(_REDUCERS)}")
    if mode == "ref" or not _has_ell(g, ell):
        key_name, may_drop = _COO_KEY[ell.name]
        key = g[key_name]
        size = ell.n_rows + (1 if may_drop else 0)
        if op == "or":  # bool OR as the uint8 scatter-max idiom
            acc = jnp.zeros((size,), jnp.uint8).at[key].max(
                vals.astype(jnp.uint8))
            return acc[:ell.n_rows] > 0
        acc = jnp.full((size,), identity, vals.dtype)
        acc = getattr(acc.at[key], op)(vals)
        return acc[:ell.n_rows]

    idx = g[f"{ell.name}_idx"]
    inv = g[f"{ell.name}_inv"]
    # sentinel E indexes the pad slot, which carries the identity
    vpad = jnp.concatenate(
        [vals, jnp.full((1,), identity, vals.dtype)], axis=-1)
    kernel_add = (op == "add" and vals.dtype == jnp.float32
                  and _use_pallas(mode))
    outs = []
    for _, rows, k, blk in _buckets(ell, idx):
        if k == 0:
            outs.append(jnp.full((rows,), identity, vals.dtype))
            continue
        if kernel_add:
            from repro.kernels.spmv.kernel import spmv_ell
            vmask = (blk != ell.sentinel).astype(jnp.float32)
            outs.append(spmv_ell(blk, vmask, vpad, row_block=128,
                                 interpret=_interpret()))
        else:
            outs.append(_REDUCERS[op](vpad[blk]))
    return jnp.concatenate(outs)[inv]
