"""Incremental recompute programs for the dynamic-graph subsystem.

The dynamic server (``repro.serve.dynamic``) mutates the resident graph
in place and wants the next answer at less than full-recompute cost.
The registered incremental variants all share one safety property: they
are EXACT from their cold seed (``cold_seed``), and a warm seed from a
previous snapshot epoch is only adopted when the mutation history since
that epoch provably preserves exactness (``IncrementalSpec.mutations``).
Correctness therefore never depends on the seed choice — only round
counts do.

``kcore/incremental`` lives here: local support-decrement peeling.
Define a vertex's SUPPORT under an assignment ``c`` as the number of
incident non-loop edges (multigraph, both directions) whose other
endpoint ``u`` has ``c[u] >= c[v]``.  Each superstep decrements every
vertex whose support is below its own value:

    cnt[v] = #{incident edges (u, v) : c[u] >= c[v]}
    c[v]  <- c[v] - 1   where cnt[v] < c[v]

Starting from ANY pointwise upper bound on the true core numbers this
converges to exactly the core numbers:

  * invariant (``c >= core`` is preserved): if ``c[v] == core[v] = k``
    and ``c >= core`` everywhere, v has >= k neighbors in the k-core,
    each with ``c >= core >= k = c[v]`` — so ``cnt[v] >= k`` and v never
    drops below its core number;
  * at the fixed point ``c`` is feasible (every v has >= c[v] incident
    edges with ``c >= c[v]``), and any feasible assignment satisfies
    ``c <= core``: the vertex set ``{v : c[v] >= k}`` induces min degree
    >= k, hence sits inside the k-core.

Valid upper bounds: the undirected degree (cold start — this is plain
peeling, one threshold unit per round) and, after DELETE-only mutation
batches, the previous epoch's core numbers (cores never grow when edges
leave), which is the warm restart the dynamic server exploits.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import localops
from repro.core.graph import GraphShards
from repro.core.partitioned import AXIS, broadcast_global, exchange_sum, \
    psum_scalar
from repro.core.superstep import SuperstepProgram

# numpy dtype of each vertex-field input kind (registry.INPUT_KINDS)
KIND_DTYPES = {"vertex_i32": np.int32, "vertex_f32": np.float32}


def kcore_incremental_program(shards,
                              max_rounds: int = 2048) -> SuperstepProgram:
    """Support-decrement k-core peeling from a seed upper bound.

    Inputs: ``core0`` — per-vertex upper bound on the core numbers
    (vertex_i32).  Outputs match ``kcore/default`` (``core``, ``kmax``)
    so both variants share the conformance referee.
    """
    n, n_local, n_orig = shards.n, shards.n_local, shards.n_orig
    ell_dst = shards.ell("ell_dst")
    ell_src = shards.ell("ell_src")

    def init(g, *inputs):
        (core0,) = inputs
        lo = jax.lax.axis_index(AXIS) * n_local
        gid = jnp.arange(n_local, dtype=jnp.int32) + lo
        # padded tail vertices are edgeless (core 0); clamp real seeds
        # at zero so any non-negative field is a usable bound
        c0 = jnp.where(gid < n_orig,
                       jnp.maximum(core0.astype(jnp.int32), 0), 0)
        return c0, jnp.int32(1)

    def step(g, state):
        c, _ = state
        lo = jax.lax.axis_index(AXIS) * n_local
        cg = broadcast_global(c)                     # all-gather (n,) i32
        # support contributions, one per incident non-loop edge, posted
        # toward the endpoint being supported; both combines are
        # blocked-ELL gather+sums and ONE fused exchange delivers owners
        srcl, dst = g["out_src_local"], g["out_dst_global"]
        sup_dst = ((dst < n) & (dst != srcl + lo)
                   & (cg[srcl + lo] >= cg[dst])).astype(jnp.int32)
        src, dstl = g["in_src_global"], g["in_dst_local"]
        sup_src = ((src < n) & (src != dstl + lo)
                   & (cg[dstl + lo] >= cg[src])).astype(jnp.int32)
        acc = localops.scatter_combine(
            g, ell_dst, sup_dst, "add", identity=jnp.int32(0))
        acc = acc + localops.scatter_combine(
            g, ell_src, sup_src, "add", identity=jnp.int32(0))
        cnt = exchange_sum(acc)
        new_c = jnp.where(cnt < c, c - 1, c)
        changed = psum_scalar((new_c < c).sum(dtype=jnp.int32))
        return new_c, changed

    def outputs(state):
        c, _ = state
        kmax = jax.lax.pmax(c.max(), AXIS)
        return c, kmax

    def guard(g, prev, state):
        # support-decrement peeling: the assignment is non-negative and
        # non-increasing (decrements only); change count non-negative
        c, changed = state
        return (c >= 0).all() & (c <= prev[0]).all() & (changed >= 0)

    return SuperstepProgram(
        name="kcore", variant="incremental", inputs=("core0",),
        init=init, step=step,
        halt=lambda state: state[1] <= 0,
        outputs=outputs,
        output_names=("core", "kmax"),
        output_is_vertex=(True, False),
        max_rounds=max_rounds, guard=guard)


# ---------------------------------------------------------------------------
# cold seeds: exact-from-scratch starting vectors, computed host-side
# from the shard mirrors.  The server falls back to these whenever the
# mutation history invalidates a stored warm seed.
# ---------------------------------------------------------------------------

def host_und_degree(g: GraphShards) -> np.ndarray:
    """(n,) undirected multigraph degree from the host out-shard mirrors
    (self-loops dropped) — the cold upper bound for k-core peeling."""
    deg = (g.out_degree.astype(np.int64)
           + g.in_degree.astype(np.int64)).reshape(-1)
    lo = (np.arange(g.parts, dtype=np.int64) * g.n_local)[:, None]
    srcg = g.out_src_local.astype(np.int64) + lo
    is_loop = (g.out_dst_global < g.n) & (g.out_dst_global == srcg)
    loops = np.zeros(g.n, np.int64)
    np.add.at(loops, srcg[is_loop], 1)
    return deg - 2 * loops


def cold_seed(spec, g: GraphShards) -> tuple[np.ndarray, ...]:
    """Exact-from-scratch seed arrays ((n_orig,), kind dtypes) for an
    incremental program's vertex inputs: identity labels for cc, the
    degree bound for k-core, uniform mass for PageRank."""
    inc = spec.incremental
    if inc is None:
        raise ValueError(f"{spec.algo}/{spec.variant} is not incremental")
    if inc.seed_output == "labels":
        return (np.arange(g.n_orig, dtype=np.int32),)
    if inc.seed_output == "core":
        return (host_und_degree(g)[:g.n_orig].astype(np.int32),)
    if inc.seed_output == "rank":
        return (np.full(g.n_orig, 1.0 / g.n_orig, np.float32),)
    raise ValueError(f"no cold seed rule for output {inc.seed_output!r}")
