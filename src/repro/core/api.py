"""Public graph-engine API: jitted shard_map programs over a 1-D mesh.

``GraphEngine`` binds a partitioned graph to a mesh and exposes
BFS / PageRank / SSSP / CC in both BSP-baseline and optimized variants.
The same builders lower against abstract inputs for the multi-pod
dry-run (core/dryrun.py).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import bfs as BFS
from repro.core import cc as CC
from repro.core import pagerank as PR
from repro.core import sssp as SSSP
from repro.core.graph import GraphShards

P = jax.sharding.PartitionSpec


def _graph_specs(g: GraphShards):
    return {k: P("parts", None) for k in g.abstract_arrays()}


@dataclass
class GraphEngine:
    g: GraphShards
    mesh: jax.sharding.Mesh

    def _wrap(self, fn, extra_in_specs=(), out_specs=None):
        in_specs = (_graph_specs(self.g),) + tuple(extra_in_specs)
        return jax.jit(jax.shard_map(
            fn, mesh=self.mesh, in_specs=in_specs,
            out_specs=out_specs, check_vma=False))

    # -- BFS ------------------------------------------------------------
    def bfs(self, mode: str = "fast", max_levels: int = 64,
            static_iters: int = 0):
        g, m = self.g, self.mesh
        shard_fn = (BFS.bfs_fast_shard if mode == "fast"
                    else BFS.bfs_bsp_shard)

        def fn(garr, root):
            garr = {k: v[0] for k, v in garr.items()}
            parents, levels = shard_fn(garr, root, g.n, g.n_local,
                                       max_levels,
                                       static_iters=static_iters)
            return parents[None], levels

        return self._wrap(fn, extra_in_specs=(P(),),
                          out_specs=(P("parts", None), P()))

    # -- PageRank ---------------------------------------------------------
    def pagerank(self, mode: str = "fast", iters: int = 50,
                 tol: float = 1e-6, compress: bool = True,
                 static_iters: int = 0):
        g = self.g

        def fn(garr):
            garr = {k: v[0] for k, v in garr.items()}
            if mode == "fast":
                rank, err, it = PR.pagerank_fast_shard(
                    garr, g.n, g.n_local, g.n_orig, iters, tol,
                    compress=compress, static_iters=static_iters)
            else:
                rank, err, it = PR.pagerank_bsp_shard(
                    garr, g.n, g.n_local, g.n_orig, iters, tol,
                    static_iters=static_iters)
            return rank[None], err, it

        return self._wrap(fn, out_specs=(P("parts", None), P(), P()))

    # -- SSSP -------------------------------------------------------------
    def sssp(self, max_rounds: int = 64):
        g = self.g

        def fn(garr, root):
            garr = {k: v[0] for k, v in garr.items()}
            dist, rounds = SSSP.sssp_shard(garr, root, g.n, g.n_local,
                                           max_rounds)
            return dist[None], rounds

        return self._wrap(fn, extra_in_specs=(P(),),
                          out_specs=(P("parts", None), P()))

    # -- Connected components ----------------------------------------------
    def cc(self, max_rounds: int = 64):
        g = self.g

        def fn(garr):
            garr = {k: v[0] for k, v in garr.items()}
            labels, rounds = CC.cc_shard(garr, g.n, g.n_local, max_rounds)
            return labels[None], rounds

        return self._wrap(fn, out_specs=(P("parts", None), P()))

    # -- helpers -------------------------------------------------------------
    def device_graph(self):
        arrs = self.g.device_arrays()
        sh = jax.sharding.NamedSharding(self.mesh, P("parts", None))
        return {k: jax.device_put(v, sh) for k, v in arrs.items()}

    def gather_vertex_field(self, arr) -> np.ndarray:
        """(P, n_local) sharded -> (n_orig,) numpy."""
        return np.asarray(arr).reshape(-1)[: self.g.n_orig]
