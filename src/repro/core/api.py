"""Public graph-engine API: registry-driven superstep programs compiled
as jitted shard_map executables over a 1-D mesh.

``GraphEngine`` binds a partitioned graph to a mesh.  The single entry
point is :meth:`GraphEngine.program`:

    prog = engine.program("bfs", "fast", max_levels=32)
    parents, levels = prog(engine.device_graph(), jnp.int32(root))

``program()`` resolves the (algo, variant) pair through
``core/registry.py``, wraps the program's ``init/step/halt/outputs``
with the ONE shared superstep driver (``core/superstep.py``), and caches
the resulting compiled callable keyed on algorithm + params + graph
shapes + mesh — repeated calls return the SAME object, so nothing
re-traces.  ``batch=B`` builds the multi-source variant (roots shaped
(B,), vmapped inside the shard program).  The legacy ``bfs()/pagerank()/
sssp()/cc()`` methods are thin delegating wrappers.

The same callables lower against abstract inputs for the multi-pod
dry-run (core/dryrun.py) via :meth:`CompiledProgram.lower` /
:meth:`CompiledProgram.aot`.
"""

from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import localops, registry
from repro.core import faults as faults_mod
from repro.core.compat import shard_map
from repro.core.graph import GraphShards
from repro.core.superstep import run_program, run_program_batched
from repro.obs import telemetry as obs_telemetry

P = jax.sharding.PartitionSpec

# jnp dtype of each registry input kind; "scalar" inputs are replicated
# per-query values, vertex kinds are (P, n_local) sharded fields (the
# warm seeds of the incremental variants)
_KIND_DTYPE = {"scalar": jnp.int32,
               "vertex_i32": jnp.int32,
               "vertex_f32": jnp.float32}


def _graph_specs(g: GraphShards, layout: str):
    return {k: P("parts", None) for k in g.abstract_arrays(layout)}


class CompiledProgram:
    """A cached, callable, AOT-lowerable superstep program.

    ``__call__`` runs the jitted executable (jit's trace cache makes
    repeated calls free); ``lower()``/``aot()`` expose the AOT path the
    dry-run and roofline tooling use.  Instances are interned by
    :meth:`GraphEngine.program`, so object identity doubles as the
    compile-cache hit test.
    """

    def __init__(self, spec, program, fn, abstract_args,
                 guarded=False, faults=None, telemetry=False, wire=None):
        self.spec = spec                  # registry ProgramSpec
        self.program = program            # SuperstepProgram instance
        self.fn = fn                      # jitted shard_map callable
        self.abstract_args = abstract_args
        self.guarded = guarded            # trailing ok output appended
        self.faults = faults              # FaultSchedule or None
        self.telemetry = telemetry        # trailing series output appended
        self.wire = wire                  # obs WireRecord (telemetry builds)
        self.last_wall_s = 0.0            # telemetry-mode host wall-time
        self._aot = None

    def __call__(self, garr, *inputs):
        if not self.telemetry:
            return self.fn(garr, *inputs)
        # telemetry builds are MEASUREMENT mode: block on the result so
        # the recorded wall-time covers the device work, not just the
        # dispatch (documented perturbation — don't time the dispatch
        # overlap through a telemetry build)
        t0 = time.perf_counter()
        out = self.fn(garr, *inputs)
        jax.block_until_ready(out)
        self.last_wall_s = time.perf_counter() - t0
        return out

    def run_telemetry(self, series) -> "obs_telemetry.RunTelemetry":
        """Parse the trailing series output of a telemetry run into a
        ``RunTelemetry`` carrying this build's trace-time wire snapshot
        and the last ``__call__``'s wall-time."""
        if not self.telemetry:
            raise ValueError(f"{self.program.key} was not built with "
                             "telemetry=True")
        ps = obs_telemetry.PhaseSeries.from_array(
            np.asarray(series), self.program.probe_names)
        return obs_telemetry.RunTelemetry(
            series=ps, wire=self.wire.snapshot(), wall_s=self.last_wall_s)

    def lower(self, *args):
        """AOT-lower; defaults to the engine's abstract arg shapes."""
        return self.fn.lower(*(args if args else self.abstract_args))

    def aot(self):
        """Lowered + compiled executable against abstract args (cached)."""
        if self._aot is None:
            self._aot = self.lower().compile()
        return self._aot

    def trace_cache_size(self) -> int:
        """Number of traces jit holds for this callable (1 after warmup)."""
        return self.fn._cache_size()

    def __repr__(self):
        return (f"CompiledProgram({self.program.key}, "
                f"inputs={self.spec.inputs})")


@dataclass
class GraphEngine:
    g: GraphShards
    mesh: jax.sharding.Mesh
    # "ell" ships the blocked-ELL arrays so localops takes the tuned
    # gather path; "coo" withholds them - every program then traces the
    # reference scatter idiom (the escape hatch behind --layout coo)
    layout: str = "ell"
    _cache: dict = field(default_factory=dict, repr=False, compare=False)

    # -- the program API ----------------------------------------------------
    def program(self, algo: str, variant: str | None = None, *,
                static_iters: int = 0, batch: int | None = None,
                exec_mode: str | None = None, guard: bool = False,
                faults=None, telemetry: bool = False,
                **params) -> CompiledProgram:
        """Resolve, build, wrap and cache an algorithm program.

        ``static_iters > 0`` replaces the early-exit while loop with a
        fixed-trip scan (dry-run/roofline path).  ``batch=B`` compiles
        the multi-source variant: every ("root",)-style input becomes a
        (B,) array and vertex outputs gain a leading (P, B, ...) batch
        axis.  ``exec_mode`` selects the superstep driver by mode
        instead of variant name: with a bare algo it re-resolves to the
        algo's variant of that mode (``program("bfs",
        exec_mode="async")`` is ``program("bfs", "async")``); with an
        explicit variant it is a consistency ASSERTION and a mismatch
        raises rather than silently running the other driver.

        ``guard=True`` compiles the GUARDED driver: the program's
        per-round invariant check (``core/faults`` docs) plus the
        transport-stamp detector run every round, the loop stops on the
        first violation, and ONE extra replicated int32 output (1 = run
        clean, 0 = violation detected) is appended after ``rounds``.
        ``faults=`` takes a :class:`repro.core.faults.FaultSchedule`
        (or its string spec) and compiles deterministic fault injection
        into the exchange taps — detection fires only when ``guard``
        is also set.  Neither composes with ``batch``/``static_iters``
        (checkpointed recovery lives in ``core/recovery.py``).

        ``telemetry=True`` compiles the per-round telemetry series in
        (``core/superstep.py`` series block): ONE extra replicated
        ``(max_rounds, 2 + K)`` f32 output is appended LAST, trace-time
        wire bytes are captured on :attr:`CompiledProgram.wire`, and
        ``__call__`` blocks on the result to measure host wall-time —
        parse it all with :meth:`CompiledProgram.run_telemetry`.
        Composes with ``guard``; like it, incompatible with ``batch``
        and ``static_iters``.  ``telemetry=False`` builds are
        bit-identical to pre-telemetry builds (asserted in tests).

        The cache key covers algo, variant, params, loop mode, exec
        mode, guard/fault schedule, telemetry, graph shapes and mesh,
        so repeated calls return the same object and never re-trace.
        """
        bare = variant is None and "/" not in algo
        spec = registry.get_spec(algo, variant)
        if exec_mode is not None and spec.exec_mode != exec_mode:
            if exec_mode not in registry.EXEC_MODES:
                raise ValueError(
                    f"exec_mode {exec_mode!r} not in {registry.EXEC_MODES}")
            if not bare:
                raise ValueError(
                    f"{spec.key} is a {spec.exec_mode} program; "
                    f"exec_mode={exec_mode!r} contradicts the explicit "
                    f"variant — drop one (mode-variants: "
                    f"{registry.mode_variant(spec.algo, exec_mode)!r})")
            alt = registry.mode_variant(spec.algo, exec_mode)
            if alt is None:
                raise ValueError(
                    f"{spec.algo} has no {exec_mode} variant; "
                    f"async-capable pairs: "
                    f"{['/'.join(p) for p in registry.async_pairs()]}")
            spec = registry.get_spec(spec.algo, alt)
        if batch is not None and not spec.inputs:
            raise ValueError(
                f"{spec.key} takes no per-query inputs; batch="
                f"{batch} has nothing to vmap over")
        if batch is not None and any(k != "scalar" for k in spec.input_kinds):
            raise ValueError(
                f"{spec.key} takes whole vertex-field inputs "
                f"{spec.inputs}; only scalar per-query inputs batch")
        schedule = faults_mod.as_schedule(faults)
        if guard and static_iters:
            raise ValueError(
                "guard=True is incompatible with static_iters: the "
                "guarded loop must stop on the detected round")
        if (guard or schedule is not None) and batch is not None:
            raise ValueError(
                "guard/faults do not compose with batch: fault rounds "
                "and guard verdicts are per-run, not per-lane")
        if telemetry and static_iters:
            raise ValueError(
                "telemetry requires the while-loop driver; the "
                "static_iters dry-run has no data-dependent rounds to "
                "record")
        if telemetry and batch is not None:
            raise ValueError(
                "telemetry does not compose with batch: the series is "
                "per-run, not per-lane")
        # normalize params into full (defaults + overrides) form so an
        # explicitly spelled default hits the same cache entry; batched
        # builds additionally merge the spec's vmap-friendly overrides
        # (e.g. bfs/fast pins direction="pull": a per-lane cond would
        # run both branches under vmap).  Explicit caller params win.
        batch_over = spec.batch_defaults if batch is not None else {}
        params = {**spec.defaults, **batch_over, **params}
        g = self.g
        # the layout and localops mode steer TRACE-time dispatch in
        # core/localops.py, so both belong in the compile-cache key
        # layout_signature covers the blocked-ELL bucket runs: after a
        # mutation-overflow rebuild the shard SHAPES can coincide while
        # the bucket decomposition differs, and the traced per-bucket
        # loops would silently read the wrong rows on a stale cache hit
        key = (spec.algo, spec.variant, spec.exec_mode, static_iters,
               batch, guard, schedule, telemetry,
               tuple(sorted(params.items())),
               (g.n, g.n_orig, g.parts, g.n_local, g.e_max),
               g.layout_signature(),
               (tuple(self.mesh.shape.items()), self.mesh.devices.shape),
               (self.layout, localops.get_mode()))
        hit = self._cache.get(key)
        if hit is not None:
            return hit

        prog = spec.build(g, **params)
        n_inputs = len(spec.inputs)
        kinds = spec.input_kinds
        wire = obs_telemetry.WireRecord() if telemetry else None

        def fn(garr, *inputs):
            garr = {k: v[0] for k, v in garr.items()}
            inputs = tuple(x[0] if kind != "scalar" else x
                           for x, kind in zip(inputs, kinds))
            # the fault context is entered INSIDE the traced fn so taps
            # see the schedule at trace time (it's part of the cache
            # key); same for the telemetry wire recording — a retrace
            # re-fills the SAME record (recording clears on entry)
            cm = faults_mod.active(schedule, detect=guard) \
                if schedule is not None else contextlib.nullcontext()
            tcm = obs_telemetry.recording(wire) if telemetry \
                else contextlib.nullcontext()
            ok = series = None
            with cm, tcm:
                if guard or telemetry:
                    res = run_program(prog, garr, *inputs, guard=guard,
                                      telemetry=telemetry)
                    outs, rounds = res[0], res[1]
                    if guard:
                        ok = res[2]
                    if telemetry:
                        series = res[-1]
                elif batch is None:
                    outs, rounds = run_program(prog, garr, *inputs,
                                               static_iters=static_iters)
                else:
                    outs, rounds = run_program_batched(
                        prog, garr, *inputs, static_iters=static_iters)
            shaped = tuple(o[None] if is_v else o
                           for o, is_v in zip(outs, prog.output_is_vertex))
            tail = (rounds,) + ((ok.astype(jnp.int32),) if guard else ()) \
                + ((series,) if telemetry else ())
            return shaped + tail

        vspec = P("parts", None) if batch is None else P("parts", None, None)
        out_specs = tuple(vspec if is_v else P()
                          for is_v in prog.output_is_vertex) \
            + ((P(), P()) if guard else (P(),)) \
            + ((P(),) if telemetry else ())
        in_specs = (_graph_specs(g, self.layout),) + tuple(
            P() if kind == "scalar" else P("parts", None) for kind in kinds)
        jitted = jax.jit(shard_map(
            fn, mesh=self.mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False))

        root_shape = () if batch is None else (batch,)
        abstract_args = (g.abstract_arrays(self.layout),) + tuple(
            jax.ShapeDtypeStruct(
                root_shape if kind == "scalar" else (g.parts, g.n_local),
                _KIND_DTYPE[kind])
            for kind in kinds)
        compiled = CompiledProgram(spec, prog, jitted, abstract_args,
                                   guarded=guard, faults=schedule,
                                   telemetry=telemetry, wire=wire)
        self._cache[key] = compiled
        return compiled

    # -- thin legacy wrappers -----------------------------------------------
    def bfs(self, mode: str = "fast", max_levels: int = 64,
            static_iters: int = 0) -> CompiledProgram:
        return self.program("bfs", mode, static_iters=static_iters,
                            max_levels=max_levels)

    def pagerank(self, mode: str = "fast", iters: int = 50,
                 tol: float = 1e-6, compress=True,
                 static_iters: int = 0) -> CompiledProgram:
        params = {"iters": iters, "tol": tol}
        if mode == "fast":
            params["compress"] = compress
        return self.program("pagerank", mode, static_iters=static_iters,
                            **params)

    def sssp(self, max_rounds: int = 64,
             static_iters: int = 0) -> CompiledProgram:
        return self.program("sssp", static_iters=static_iters,
                            max_rounds=max_rounds)

    def cc(self, max_rounds: int = 64,
           static_iters: int = 0) -> CompiledProgram:
        return self.program("cc", static_iters=static_iters,
                            max_rounds=max_rounds)

    # -- helpers -------------------------------------------------------------
    def device_graph(self):
        arrs = self.g.device_arrays(self.layout)
        sh = jax.sharding.NamedSharding(self.mesh, P("parts", None))
        return {k: jax.device_put(v, sh) for k, v in arrs.items()}

    def gather_vertex_field(self, arr) -> np.ndarray:
        """(P, n_local) sharded -> (n_orig,) numpy."""
        return np.asarray(arr).reshape(-1)[: self.g.n_orig]

    def scatter_vertex_field(self, arr, dtype=None) -> jax.Array:
        """(n_orig,) host values -> (P, n_local) device vertex field,
        sharded like the device-graph arrays (the inverse of
        ``gather_vertex_field``; how warm/cold seeds reach seeded
        programs).  The padded tail is zero-filled — seeded inits
        re-normalize it, since padded vertices are edgeless."""
        g = self.g
        a = np.asarray(arr)
        if a.ndim != 1 or a.shape[0] < g.n_orig:
            raise ValueError(
                f"vertex field must be 1-D with >= n_orig={g.n_orig} "
                f"entries, got shape {a.shape}")
        dt = np.dtype(dtype) if dtype is not None else a.dtype
        full = np.zeros((g.n,), dt)
        full[: g.n_orig] = a[: g.n_orig]
        sh = jax.sharding.NamedSharding(self.mesh, P("parts", None))
        return jax.device_put(full.reshape(g.parts, g.n_local), sh)

    def gather_batched_vertex_field(self, arr) -> np.ndarray:
        """(P, B, n_local) batched sharded -> (B, n_orig) numpy."""
        a = np.asarray(arr)                       # (P, B, n_local)
        b = a.transpose(1, 0, 2).reshape(a.shape[1], -1)
        return b[:, : self.g.n_orig]
