"""Distributed betweenness centrality (Brandes) — the engine's first
MULTI-PHASE superstep program.

Brandes decomposes per-source betweenness into (1) a forward BFS that
counts shortest paths (sigma) while recording distance levels, then (2)
a backward dependency-accumulation sweep over the shortest-path DAG.
Phase (2) needs phase (1)'s outputs as its initial state, which is
exactly what :class:`~repro.core.superstep.PhasedProgram` /
``run_phases`` provide: the forward program's ``(dist, sigma)`` outputs
thread into the backward program's ``init``.

Semantics: single-source dependencies ``delta_s(v)`` on the DIRECTED
MULTIGRAPH underlying the edge list (parallel edges are parallel
shortest paths), unweighted, with the conventional ``delta_s(s) = 0``.
Summing the output over a batch of sources (``batch=B`` reuses
``run_program_batched`` — B forward sweeps share one graph residency)
yields sampled approximate betweenness; all n sources is the exact
score.

Forward pass: per level, frontier vertices push ``sigma`` along
out-edges into a length-n accumulator; ONE fused ``exchange_sum``
delivers owner slices; receivers that were unvisited adopt the level
and the path-count sum (all shortest-path predecessors of a level-L
vertex are, by level-synchrony, in the level-(L-1) frontier, so sigma
arrives complete in one superstep).

Backward sweep: rather than walking levels down with a counter, each
superstep recomputes the whole dependency relaxation

    delta(v) = sigma(v) * sum_{v->w, dist(w)=dist(v)+1}
                          (1 + delta(w)) / sigma(w)

from the current delta (one all-gather of the (n,) coefficient vector
per superstep, the pull-mode pattern of ``pagerank/bsp``).  Values
propagate up one level per superstep, so the sweep converges in
max-level rounds to the exact Brandes fixed point; further rounds
recompute bit-identical values, making the phase idempotent — halt on
zero changed entries, and safe under ``static_iters``.

sigma/delta arithmetic is f32; sigma values are integers (exact below
2^24), so conformance against the NumPy oracle is tight.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import localops
from repro.core.partitioned import AXIS, broadcast_global, exchange_sum, \
    psum_scalar
from repro.core.superstep import PhasedProgram, SuperstepProgram

INT_INF = jnp.int32(2 ** 30)


def bc_forward_program(shards, max_levels: int = 64) -> SuperstepProgram:
    """Phase 1: level-synchronous BFS counting shortest paths."""
    n, n_local = shards.n, shards.n_local
    ell_dst = shards.ell("ell_dst")

    def init(g, root):
        lo = jax.lax.axis_index(AXIS) * n_local
        at_root = (root >= lo) & (root < lo + n_local) & \
            (jnp.arange(n_local) == root - lo)
        dist0 = jnp.where(at_root, 0, INT_INF)
        sigma0 = jnp.where(at_root, 1.0, 0.0)
        return dist0, sigma0, at_root, jnp.int32(1), jnp.int32(1)

    def step(g, state):
        dist, sigma, frontier, level, _ = state
        srcl, dst = g["out_src_local"], g["out_dst_global"]
        active = frontier[srcl] & (dst < n)
        acc = localops.scatter_combine(
            g, ell_dst, jnp.where(active, sigma[srcl], 0.0), "add",
            identity=jnp.float32(0.0))
        recv = exchange_sum(acc)                    # (n_local,) f32
        newly = (recv > 0) & (dist == INT_INF)
        dist = jnp.where(newly, level, dist)
        sigma = sigma + jnp.where(newly, recv, 0.0)
        cnt = psum_scalar(newly.sum(dtype=jnp.int32))
        return dist, sigma, newly, level + 1, cnt

    def guard(g, prev, state):
        # forward invariants: levels adopt once (non-increasing from
        # INT_INF), path counts finite / non-negative / non-decreasing
        dist, sigma, _, level, cnt = state
        return (dist >= 0).all() & (dist <= prev[0]).all() \
            & jnp.isfinite(sigma).all() & (sigma >= prev[1]).all() \
            & (level >= prev[3]) & (cnt >= 0)

    return SuperstepProgram(
        name="betweenness", variant="forward", inputs=("root",),
        init=init, step=step,
        halt=lambda state: state[4] <= 0,
        outputs=lambda state: (state[0], state[1]),
        output_names=("dist", "sigma"), output_is_vertex=(True, True),
        max_rounds=max_levels, guard=guard)


def bc_backward_program(shards, max_levels: int = 64) -> SuperstepProgram:
    """Phase 2: dependency accumulation over the shortest-path DAG.

    ``init`` receives the forward phase's (dist, sigma) — the phase
    chaining contract.
    """
    n, n_local = shards.n, shards.n_local
    ell_out = shards.ell("ell_out")

    def init(g, dist, sigma):
        delta0 = jnp.zeros((n_local,), jnp.float32)
        dist_g = broadcast_global(dist)             # loop-invariant (n,)
        return delta0, dist, sigma, dist_g, jnp.int32(1)

    def step(g, state):
        delta, dist, sigma, dist_g, _ = state
        coef = jnp.where(sigma > 0, (1.0 + delta) / jnp.maximum(sigma, 1.0),
                         0.0)
        coef_g = broadcast_global(coef)             # (n,) pull replica
        srcl, dst = g["out_src_local"], g["out_dst_global"]
        valid = dst < n
        safe_dst = jnp.where(valid, dst, 0)
        deeper = valid & (dist_g[safe_dst] == dist[srcl] + 1)
        contrib = jnp.where(deeper, coef_g[safe_dst], 0.0)
        s = localops.scatter_combine(g, ell_out, contrib, "add",
                                     identity=jnp.float32(0.0))
        new_delta = sigma * s
        changed = psum_scalar((new_delta != delta).sum(dtype=jnp.int32))
        return new_delta, dist, sigma, dist_g, changed

    def outputs(state):
        delta, dist, sigma, _, _ = state
        bc = jnp.where(dist == 0, 0.0, delta)       # delta_s(s) := 0
        return bc, sigma, dist

    def guard(g, prev, state):
        # dependency accumulation is a sum of non-negative coefficient
        # terms: finite and non-negative (a NaN coefficient broadcast
        # lands in delta unfiltered); the frozen forward fields must
        # stay bit-frozen
        delta, dist, sigma, _, changed = state
        return jnp.isfinite(delta).all() & (delta >= 0).all() \
            & (dist == prev[1]).all() & (sigma == prev[2]).all() \
            & (changed >= 0)

    return SuperstepProgram(
        name="betweenness", variant="backward", inputs=(),
        init=init, step=step,
        halt=lambda state: state[4] <= 0,
        outputs=outputs,
        output_names=("bc", "sigma", "dist"),
        output_is_vertex=(True, True, True),
        max_rounds=max_levels, guard=guard)


def betweenness_program(shards, max_levels: int = 64) -> PhasedProgram:
    """Forward + backward Brandes as ONE phased program."""
    return PhasedProgram(
        name="betweenness", variant="default", inputs=("root",),
        phases=(bc_forward_program(shards, max_levels),
                bc_backward_program(shards, max_levels)),
        output_names=("bc", "sigma", "dist"),
        output_is_vertex=(True, True, True))
