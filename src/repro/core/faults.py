"""Deterministic fault injection for the exchange primitives.

Chaos engineering for the SPMD engine: every exchange primitive in
``partitioned.py`` calls :func:`tap` on its OUTGOING payload, and when a
:class:`FaultSchedule` is active the tap compiles seeded, schedule-
addressed perturbations straight into the traced program — so a chaos
run is exactly as reproducible as a clean one (same schedule, same
graph, same faults, bit for bit).  With no schedule active the tap is a
Python-level no-op and nothing reaches the jaxpr.

Fault model (one :class:`FaultEvent` per fault):

  * ``drop``    — the partition's outgoing payload for one exchange is
                  replaced by the combine identity (0 for sum/or/bcast/
                  perm, +max for min): the message never arrives.
  * ``stall``   — ``drop`` sustained for ``rounds`` consecutive rounds:
                  a partition that stops answering.
  * ``dup``     — duplicate delivery: sum payloads arrive twice
                  (doubled); min/or/bcast/perm payloads are idempotent
                  so the duplicate changes nothing — but the transport
                  still observes the replayed sequence number.
  * ``corrupt`` — one seeded payload element is overwritten with a
                  semantically invalid value: NaN for float payloads,
                  ``-2**30`` for signed ints (all legitimate engine state
                  is non-negative), all-ones for a packed uint32 word.
  * ``stale``   — a seeded ~half of the payload reverts to the combine
                  identity: partial delivery, the link flaking mid-
                  message.  Monotone programs absorb this exactly (the
                  lost half is re-proposed next round); it exists to
                  exercise the stale-tolerant ``/async`` variants and is
                  deliberately NOT transport-detectable.

Detection runs on two channels, both feeding the driver's per-round
``ok`` scalar (see ``superstep.run_program(..., guard=...)``):

  * **transport stamps** — in detect mode the driver's per-round check
    asks :func:`stamp_violation` whether a stamped-kind event (drop /
    stall / dup / corrupt) covers the current round: the emulation of
    sequence numbers + payload CRCs (in-flight corruption is what
    checksums exist for).  The verdict is a pure function of the static
    schedule and the traced round counter — it deliberately does NOT
    thread values out of the taps, because exchanges may execute inside
    ``lax.cond`` branches (bfs/fast direction switching) where an
    escaping intermediate would be a leaked tracer.  Consequence: a
    stamped event reports its round tainted whether or not a matching
    exchange actually consumed it that round (the transport layer knows
    a fault occurred even when the algorithm never read the payload);
    ``stale`` stays transport-silent.
  * **value guards** — the per-algorithm invariant checks (NaN screens,
    monotone non-increase, mass conservation, degree bounds) are the
    SECOND line: they catch semantic corruption no transport check can
    see — a bug, a bad kernel, memory corruption past the NIC — and in
    chaos runs they independently flag injected corruption whose value
    lands in the state (min-combines and rank sums apply payloads
    unfiltered, so NaN / negative-sentinel injection trips them the
    same round the CRC does).

Round addressing: ``FaultEvent.round`` matches the driver's round
counter at the moment the primitive executes (the driver publishes it
via :func:`set_round` before each step/fold).  For async programs the
exchange issued by ``init`` is round 0.
"""

from __future__ import annotations

import re
from contextlib import contextmanager
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

AXIS = "parts"

KINDS = ("drop", "dup", "corrupt", "stall", "stale")
OPS = ("sum", "min", "or", "bcast", "perm")

# kinds the transport stamp marks: sequence-number / liveness class
# plus CRC-detected payload corruption; ``stale`` alone is deliberately
# transport-silent (partial loss the monotone family absorbs).
_STAMP_KINDS = ("drop", "stall", "dup", "corrupt")


@dataclass(frozen=True)
class FaultEvent:
    """One schedule-addressable fault: ``kind`` fired by partition
    ``part`` at driver round ``round``, optionally restricted to one
    exchange ``op`` (None = every op that round), ``stall`` sustained
    for ``rounds``."""

    round: int
    part: int
    kind: str
    op: str | None = None
    rounds: int = 1

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"fault kind {self.kind!r} not in {KINDS}")
        if self.op is not None and self.op not in OPS:
            raise ValueError(f"fault op {self.op!r} not in {OPS}")
        if self.round < 0 or self.part < 0 or self.rounds < 1:
            raise ValueError(f"bad fault addressing: {self}")

    def spec(self) -> str:
        s = f"{self.kind}@r{self.round}p{self.part}"
        if self.op is not None:
            s += f":{self.op}"
        if self.rounds != 1:
            s += f"x{self.rounds}"
        return s


_EVENT_RE = re.compile(
    r"^(?P<kind>[a-z]+)@r(?P<round>\d+)p(?P<part>\d+)"
    r"(?::(?P<op>[a-z]+))?(?:x(?P<rounds>\d+))?$")


@dataclass(frozen=True)
class FaultSchedule:
    """A hashable, seeded set of fault events (fits the compile-cache
    key).  ``seed`` feeds every seeded choice (corrupt element index,
    stale mask), so one (schedule, graph) pair is one deterministic
    chaos run."""

    events: tuple[FaultEvent, ...]
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))

    def spec(self) -> str:
        return " ".join(ev.spec() for ev in self.events) + f" seed={self.seed}"

    @classmethod
    def parse(cls, text: str, seed: int = 0) -> "FaultSchedule":
        """Parse the compact CLI form: whitespace-separated
        ``kind@r<round>p<part>[:<op>][x<rounds>]`` events plus an
        optional ``seed=<n>`` token, e.g.
        ``"drop@r1p0 corrupt@r2p1:min stall@r3p0x2 seed=7"``."""
        events = []
        for tok in text.split():
            if tok.startswith("seed="):
                seed = int(tok[len("seed="):])
                continue
            m = _EVENT_RE.match(tok)
            if not m:
                raise ValueError(
                    f"bad fault event {tok!r}; expected "
                    "kind@r<round>p<part>[:<op>][x<rounds>]")
            events.append(FaultEvent(
                round=int(m.group("round")), part=int(m.group("part")),
                kind=m.group("kind"), op=m.group("op"),
                rounds=int(m.group("rounds") or 1)))
        return cls(events=tuple(events), seed=seed)


def as_schedule(faults) -> "FaultSchedule | None":
    """Coerce a schedule argument: None, a FaultSchedule, or the
    compact string form accepted by :meth:`FaultSchedule.parse`."""
    if faults is None or isinstance(faults, FaultSchedule):
        return faults
    if isinstance(faults, str):
        return FaultSchedule.parse(faults)
    raise TypeError(f"faults must be None, FaultSchedule, or str: "
                    f"{type(faults).__name__}")


# --------------------------------------------------------------------------
# Trace-time context.  ``active`` is entered INSIDE the traced function
# (api.py / recovery.py wrap the driver call), so every trace — first
# compile, shape retrace, lower()/aot() — sees the same schedule.
# --------------------------------------------------------------------------


class _Ctx:
    __slots__ = ("schedule", "detect", "round")

    def __init__(self, schedule: FaultSchedule, detect: bool):
        self.schedule = schedule
        self.detect = detect
        self.round = jnp.int32(0)


_ctx: _Ctx | None = None


@contextmanager
def active(schedule: FaultSchedule | None, detect: bool = False):
    """Arm ``schedule`` for taps traced inside the block.  ``detect``
    additionally compiles the transport-stamp checks in."""
    global _ctx
    prev = _ctx
    _ctx = _Ctx(schedule, detect) if schedule is not None else None
    try:
        yield
    finally:
        _ctx = prev


def is_active() -> bool:
    return _ctx is not None


def set_round(r) -> None:
    """Publish the driver's (traced) round counter for event matching."""
    if _ctx is not None:
        _ctx.round = r


def stamp_violation():
    """Transport-stamp verdict for the CURRENT round: a traced bool
    (uniform across partitions — it is a pure function of the static
    schedule and the published round scalar), True when a stamped-kind
    event covers this round.  None when no schedule is armed, detection
    is off, or the schedule has no stamped events.  The driver folds
    this into its per-round ``ok``."""
    if _ctx is None or not _ctx.detect:
        return None
    r = _ctx.round
    viol = None
    for ev in _ctx.schedule.events:
        if ev.kind not in _STAMP_KINDS:
            continue
        span = ev.rounds if ev.kind == "stall" else 1
        hit = (r >= ev.round) & (r < ev.round + span)
        viol = hit if viol is None else (viol | hit)
    return viol


# --------------------------------------------------------------------------
# The tap.
# --------------------------------------------------------------------------


def _identity_value(op: str, dtype):
    if op == "min":
        if jnp.issubdtype(dtype, jnp.floating):
            return jnp.array(jnp.inf, dtype)
        return jnp.array(jnp.iinfo(dtype).max, dtype)
    return jnp.array(0, dtype)


def _corrupt_value(dtype):
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.nan, dtype)
    if jnp.issubdtype(dtype, jnp.unsignedinteger):
        return jnp.array(jnp.iinfo(dtype).max, dtype)
    return jnp.array(-(2 ** 30), dtype)


def _rng(ev: FaultEvent, seed: int) -> np.random.Generator:
    return np.random.default_rng(
        np.array([seed, ev.round, ev.part, KINDS.index(ev.kind)],
                 np.uint64))


def _fire(ev: FaultEvent, axis_name: str):
    """Traced bool: does ``ev`` hit THIS partition at the CURRENT
    round?  (part/op are static; only the round is dynamic.)"""
    r = _ctx.round
    if ev.kind == "stall":
        in_round = (r >= ev.round) & (r < ev.round + ev.rounds)
    else:
        in_round = r == ev.round
    return in_round & (jax.lax.axis_index(axis_name) == ev.part)


def tap(op: str, payload, axis_name: str = AXIS):
    """Perturb an OUTGOING exchange payload per the active schedule.

    Called by every primitive in ``partitioned.py`` just before the
    collective.  Returns the (possibly perturbed) payload.  A no-op
    (returns ``payload`` untouched, traces nothing) when no schedule
    is active.  Detection is NOT the tap's job — see
    :func:`stamp_violation` for why.
    """
    if _ctx is None:
        return payload
    sched, dtype = _ctx.schedule, payload.dtype
    for ev in sched.events:
        if ev.op is not None and ev.op != op:
            continue
        fire = _fire(ev, axis_name)
        if ev.kind in ("drop", "stall"):
            ident = jnp.full(payload.shape, _identity_value(op, dtype))
            payload = jnp.where(fire, ident, payload)
        elif ev.kind == "dup":
            if op == "sum":                 # others are idempotent
                payload = jnp.where(fire, payload * 2, payload)
        elif ev.kind == "corrupt":
            idx = int(_rng(ev, sched.seed).integers(payload.size))
            flat = payload.reshape(-1)
            bad = flat.at[idx].set(_corrupt_value(dtype)).reshape(
                payload.shape)
            payload = jnp.where(fire, bad, payload)
        else:                               # stale: seeded partial loss
            keep = _rng(ev, sched.seed).random(payload.shape) < 0.5
            ident = jnp.full(payload.shape, _identity_value(op, dtype))
            stale = jnp.where(jnp.asarray(keep), payload, ident)
            payload = jnp.where(fire, stale, payload)
    return payload
