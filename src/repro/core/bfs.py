"""Distributed BFS: BSP baseline (PBGL-style) and the HPX-adapted
direction-optimizing implementation.

Paper mapping (SS4.1):
  * Listing 1.2 spawns an async task per remote discovery and relies on
    ``set_parent``'s compare_exchange for atomicity.  The TPU/SPMD
    adaptation aggregates all remote discoveries of a superstep into ONE
    fused exchange, and replaces CAS with an idempotent MIN-combine
    (smallest-id parent wins deterministically).
  * ``bfs/bsp``  -- level-synchronous push; every level exchanges a full
    (n,) int32 parent-proposal vector (all_to_all MIN) + a separate
    frontier-count all-reduce: the rigid-barrier BGL analogue.
  * ``bfs/fast`` -- direction-optimizing (Beamer-style push/pull chosen
    per level by frontier occupancy = the paper's runtime adaptivity),
    BIT-PACKED frontier exchange (n/32 u32 words: 32x less wire than the
    baseline), and parents derived owner-side from in-edges (no parent
    traffic at all).

The per-level LOCAL edge work routes through ``core/localops.py``: the
push-combine is ``scatter_combine`` over the blocked-ELL ``ell_dst``
structure and owner-side parent derivation is ``frontier_pull`` over
``ell_in`` (the Pallas BFS-pull kernel on TPU) - no serialized scatters
on any backend.  The push candidate exchange is the packed-uint32
``exchange_or`` of ``core/partitioned.py``.

Both are expressed as :class:`~repro.core.superstep.SuperstepProgram`
factories (``init / step / halt / outputs`` over per-shard arrays); the
shared driver in core/superstep.py supplies the while/scan loop, so the
same program lowers for the 256/512-chip production meshes (see
core/dryrun.py) and vmaps over batched roots for multi-source queries.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import localops
from repro.core.monotone import monotone_async_program
from repro.core.partitioned import AXIS, broadcast_global, \
    exchange_min_int, exchange_or, pack_bits, psum_scalar
from repro.core.superstep import AsyncSuperstepProgram, SuperstepProgram


INT_INF = jnp.int32(2 ** 30)


def _derive_parents(g, ell_in, gf_packed, unvisited):
    """Owner-side parent derivation by pulling over local in-edges.

    For every local unvisited vertex, find the min-id in-neighbor that is
    in the current global frontier. Returns (new_mask, parent_prop).
    """
    prop = localops.frontier_pull(g, ell_in, gf_packed, unvisited)
    new_mask = (prop < INT_INF) & unvisited
    return new_mask, prop


def _bsp_level(g, ell_dst, n, n_local, parents, frontier):
    """One BSP level: full (n,) parent-proposal exchange via a2a MIN."""
    lo = jax.lax.axis_index(AXIS) * n_local
    srcl = g["out_src_local"]
    dst = g["out_dst_global"]
    active = frontier[srcl] & (dst < n)
    src_g = (srcl + lo).astype(jnp.int32)
    prop = localops.scatter_combine(
        g, ell_dst, jnp.where(active, src_g, INT_INF), "min",
        identity=INT_INF)
    # exchange: every partition contributes proposals for every vertex
    mine = exchange_min_int(prop)                  # (n_local,)
    unvisited = parents == INT_INF
    new_mask = (mine < INT_INF) & unvisited
    parents = jnp.where(new_mask, mine, parents)
    # separate global barrier: frontier population count
    count = psum_scalar(new_mask.sum(dtype=jnp.int32))
    return parents, new_mask, count


def _fast_level(g, ell_in, parents, gf_packed):
    """One direction-optimizing level with bit-packed exchange."""
    unvisited = parents == INT_INF
    new_mask, prop = _derive_parents(g, ell_in, gf_packed, unvisited)
    parents = jnp.where(new_mask, prop, parents)
    # pack local next frontier; all-gather the global bitmap (n/32 words)
    nf_packed_local = pack_bits(new_mask)
    gf_next = broadcast_global(nf_packed_local)
    count = psum_scalar(new_mask.sum(dtype=jnp.int32))
    return parents, gf_next, count


def _fast_level_push(g, ell_in, ell_dst, n, parents,
                     frontier_local, gf_packed):
    """Push variant: OR-combine candidate bits from active out-edges,
    then ship ONLY the packed candidate bitmap (n/32 u32) through the
    packed ``exchange_or``."""
    srcl = g["out_src_local"]
    dst = g["out_dst_global"]
    active = frontier_local[srcl] & (dst < n)
    cand = localops.scatter_combine(g, ell_dst, active, "or",
                                    identity=False)        # (n,) bool
    # activation bits for my slice; derive parents by pulling in-edges
    unvisited = parents == INT_INF
    activated = exchange_or(cand) & unvisited
    # parent = min in-frontier in-neighbor of activated vertices
    _, prop = _derive_parents(g, ell_in, gf_packed, activated)
    new_mask = activated & (prop < INT_INF)
    parents = jnp.where(new_mask, prop, parents)
    nf_packed_local = pack_bits(new_mask)
    gf_next = broadcast_global(nf_packed_local)
    count = psum_scalar(new_mask.sum(dtype=jnp.int32))
    return parents, new_mask, gf_next, count


def _parents_guard(count_idx: int):
    """Invariant guard shared by the BSP/fast variants: parents stay in
    ``[0, INT_INF]`` and never move once set (min-combine on unvisited
    vertices only — a parent can only go INT_INF -> id), and the
    frontier count is non-negative.  A ``-2**30`` payload corruption
    lands straight in ``parents`` and trips the lower bound."""

    def guard(g, prev, state):
        parents, pparents = state[0], prev[0]
        return (parents >= 0).all() & (parents <= pparents).all() \
            & (state[count_idx] >= 0)

    return guard


def _seed_state(root, n_local):
    """(parents0, frontier0) with only the owner's root slot set."""
    lo = jax.lax.axis_index(AXIS) * n_local
    owned = (root >= lo) & (root < lo + n_local)
    at_root = owned & (jnp.arange(n_local) == root - lo)
    parents0 = jnp.where(at_root, root,
                         jnp.full((n_local,), INT_INF, jnp.int32))
    return parents0, at_root


def bfs_bsp_program(shards, max_levels: int = 64) -> SuperstepProgram:
    """Level-synchronous BSP BFS (the rigid-barrier BGL analogue).

    Levels past convergence are natural no-ops (an empty frontier
    proposes nothing), so the program is safe under the driver's
    fixed-trip ``static_iters`` scan.
    """
    n, n_local = shards.n, shards.n_local
    ell_dst = shards.ell("ell_dst")

    def init(g, root):
        parents0, frontier0 = _seed_state(root, n_local)
        return parents0, frontier0, jnp.int32(1)

    def step(g, state):
        parents, frontier, _ = state
        return _bsp_level(g, ell_dst, n, n_local, parents, frontier)

    return SuperstepProgram(
        name="bfs", variant="bsp", inputs=("root",),
        init=init, step=step,
        halt=lambda state: state[2] <= 0,
        outputs=lambda state: (state[0],),
        output_names=("parents",), output_is_vertex=(True,),
        max_rounds=max_levels, guard=_parents_guard(2),
        probe_names=("frontier",), probe=lambda state: (state[2],))


def bfs_fast_program(shards, max_levels: int = 64,
                     pull_threshold: float = 0.02,
                     direction: str = "adaptive") -> SuperstepProgram:
    """Direction-optimizing BFS with bit-packed frontier exchange.

    ``direction`` pins the per-level push/pull choice: ``"adaptive"``
    (the paper's runtime adaptivity, a ``lax.cond`` on frontier
    occupancy), ``"pull"``, or ``"push"``.  All three produce identical
    parents (both branches derive parents with the same min-id
    ``frontier_pull``); they differ only in work/wire per level.  Under
    ``batch=B`` vmapping the per-lane cond degenerates to running BOTH
    branches and selecting, so batched builds default to ``"pull"``
    via the registry's ``batch_defaults`` (4-12x per-query throughput
    at serving bucket sizes).
    """
    n, n_local = shards.n, shards.n_local
    ell_in = shards.ell("ell_in")
    ell_dst = shards.ell("ell_dst")
    thresh = jnp.int32(max(1, int(n * pull_threshold)))
    if direction not in ("adaptive", "pull", "push"):
        raise ValueError(f"direction must be adaptive|pull|push, "
                         f"got {direction!r}")

    def init(g, root):
        parents0, frontier0 = _seed_state(root, n_local)
        gf0 = broadcast_global(pack_bits(frontier0))
        return parents0, frontier0, gf0, jnp.int32(1)

    def step(g, state):
        parents, frontier, gf, count = state

        def push(_):
            p, f, g2, c = _fast_level_push(g, ell_in, ell_dst, n,
                                           parents, frontier, gf)
            return p, f, g2, c

        def pull(_):
            p, g2, c = _fast_level(g, ell_in, parents, gf)
            # recover local frontier from my slice of the packed bitmap
            lo_w = jax.lax.axis_index(AXIS) * (n_local // 32)
            words = jax.lax.dynamic_slice_in_dim(g2, lo_w, n_local // 32)
            f = ((words[jnp.arange(n_local) >> 5]
                  >> (jnp.arange(n_local) & 31).astype(jnp.uint32)) & 1
                 ).astype(bool)
            return p, f, g2, c

        if direction == "pull":
            return pull(None)
        if direction == "push":
            return push(None)
        return jax.lax.cond(count < thresh, push, pull, operand=None)

    return SuperstepProgram(
        name="bfs", variant="fast", inputs=("root",),
        init=init, step=step,
        halt=lambda state: state[3] <= 0,
        outputs=lambda state: (state[0],),
        output_names=("parents",), output_is_vertex=(True,),
        max_rounds=max_levels, guard=_parents_guard(3),
        probe_names=("frontier",), probe=lambda state: (state[3],))


def bfs_async_program(shards, max_levels: int = 64,
                      local_iters: int = 1) -> AsyncSuperstepProgram:
    """Async BFS on the double-buffered exchange.

    Per-level parent proposals don't survive staleness (a stale frontier
    can propose a parent one level too deep), so the async variant runs
    the stale-safe formulation instead: LEVELS via monotone min-combine
    (unit-weight SSSP — level k+1's relaxations overlap level k's
    in-flight exchange, and late/duplicate proposals are no-ops under
    min), with the halt count piggybacked on the level exchange itself —
    no separate psum collective per level, which is the fused
    halt-reduction this variant exists to demonstrate.  Parents are then
    derived AFTER convergence in one ``pull_min_eq`` pass over in-edges
    (min-id in-neighbor one level up), reproducing the BSP variants'
    deterministic min-id parent rule from exact levels.
    """
    n, n_local = shards.n, shards.n_local
    ell_in = shards.ell("ell_in")
    ell_dst = shards.ell("ell_dst")

    def init_vals(g, root):
        parents0, at_root = _seed_state(root, n_local)
        level0 = jnp.where(at_root, 0, INT_INF)
        return level0, at_root

    def relax(g, level, frontier):
        srcl = g["out_src_local"]
        active = frontier[srcl] & (g["out_dst_global"] < n)
        return localops.scatter_combine(
            g, ell_dst, jnp.where(active, level[srcl] + 1, INT_INF),
            "min", identity=INT_INF)

    def outputs(g, level):
        lvl_global = broadcast_global(level)
        # parent of v = min-id in-neighbor exactly one level up; the
        # root (level 0) is its own parent, unreached rows stay INT_INF
        # (their target INT_INF - 1 matches no real level)
        prop = localops.pull_min_eq(g, ell_in, lvl_global, level - 1)
        lo = jax.lax.axis_index(AXIS) * n_local
        gid = jnp.arange(n_local, dtype=jnp.int32) + lo
        return (jnp.where(level == 0, gid, prop),)

    return monotone_async_program(
        name="bfs", inputs=("root",), init_vals=init_vals, relax=relax,
        outputs=outputs, output_names=("parents",),
        output_is_vertex=(True,), n=n, n_local=n_local, inf=INT_INF,
        local_iters=local_iters, max_rounds=max_levels)
