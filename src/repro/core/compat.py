"""Version compatibility shims for the JAX APIs the engine layers on.

The engine targets the modern spellings (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh`` with ``axis_types``); older installs
(jax 0.4.x) only ship ``jax.experimental.shard_map.shard_map`` with the
``check_rep`` keyword and a ``make_mesh`` without ``axis_types``.  All
engine code goes through this module so the rest of the tree never
branches on the JAX version.
"""

from __future__ import annotations

import inspect

import jax

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KW = ("check_vma"
                 if "check_vma" in inspect.signature(_shard_map).parameters
                 else "check_rep")
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(fn, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across JAX versions (``check_vma``/``check_rep``)."""
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{_CHECK_KW: check_vma})


if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:  # jax <= 0.4.x: psum of a literal constant-folds to the axis size
    def axis_size(axis_name):
        return jax.lax.psum(1, axis_name)


def cost_analysis(compiled) -> dict:
    """Normalized ``Compiled.cost_analysis()``: jax <= 0.4.x returns a
    one-element list of dicts, newer versions the dict itself."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def tpu_compiler_params(**kwargs):
    """Pallas-TPU compiler params across the CompilerParams rename."""
    import jax.experimental.pallas.tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)


_MAKE_MESH_PARAMS = (inspect.signature(jax.make_mesh).parameters
                     if hasattr(jax, "make_mesh") else {})


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with Auto axis types where supported; falls back
    to a plain ``Mesh`` on jax builds without ``make_mesh``."""
    if not hasattr(jax, "make_mesh"):
        import numpy as _np
        need = 1
        for s in axis_shapes:
            need *= s
        devs = list(devices) if devices is not None else jax.devices()[:need]
        return jax.sharding.Mesh(
            _np.asarray(devs).reshape(axis_shapes), axis_names)
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None and "axis_types" in _MAKE_MESH_PARAMS:
        kwargs["axis_types"] = (axis_type.Auto,) * len(axis_names)
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def runtime_fingerprint() -> dict:
    """``{"jax": version, "device": kind}`` for bench/serve artifact
    metas.  ONE spelling for every artifact writer (benchmarks/run.py,
    benchmarks/bench_serve.py, repro.launch.graph_serve):
    benchmarks/compare.py keys its cross-config skip on these exact
    strings, so divergent copies would desynchronize the metas and
    silently re-trigger gate skips."""
    d = jax.devices()[0]
    return {"jax": jax.__version__,
            "device": getattr(d, "device_kind", d.platform)}
