"""Distributed connected components (label propagation / Shiloach-Vishkin
style hooking) - another paper "future work" algorithm.

Treats the graph as undirected by propagating labels along BOTH edge
directions; converges when no label changes.  Expressed as a
:class:`~repro.core.superstep.SuperstepProgram`; rounds past
convergence are no-ops (labels are already fixed points of min-combine),
so the program is safe under the driver's ``static_iters`` scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import localops
from repro.core.monotone import monotone_async_program
from repro.core.partitioned import AXIS, exchange_min_int, psum_scalar
from repro.core.superstep import AsyncSuperstepProgram, SuperstepProgram

INT_INF = jnp.int32(2 ** 30)


def cc_program(shards, max_rounds: int = 64,
               seeded: bool = False) -> SuperstepProgram:
    """Label propagation over both edge directions as a superstep program.

    With ``seeded=True`` the program becomes the ``cc/incremental``
    variant: init adopts a per-vertex ``labels0`` input instead of the
    identity labeling.  Min-propagation converges to
    ``min over u in component(v) of labels0[u]``, so a warm seed from a
    previous epoch is EXACT as long as every mutation since only ADDED
    edges (components only merge, and each old component carries its
    minimum vertex id on all members); the identity seed reproduces the
    cold start bit-for-bit.
    """
    n, n_local = shards.n, shards.n_local
    n_orig = shards.n_orig
    ell_dst = shards.ell("ell_dst")
    ell_src = shards.ell("ell_src")

    def init(g, *inputs):
        lo = jax.lax.axis_index(AXIS) * n_local
        gid = jnp.arange(n_local, dtype=jnp.int32) + lo
        if seeded:
            (labels0,) = inputs
            # padded tail vertices are edgeless: keep their identity
            # labels so they stay inert fixed points
            labels0 = jnp.where(gid < n_orig, labels0.astype(jnp.int32), gid)
        else:
            labels0 = gid
        return labels0, jnp.int32(1)

    def step(g, state):
        labels, _ = state
        srcl = g["out_src_local"]
        dst = g["out_dst_global"]
        valid = dst < n
        in_src = g["in_src_global"]
        in_dstl = g["in_dst_local"]
        in_valid = in_src < n
        # propose my label to out-neighbors (push direction); the local
        # MIN-combine is a blocked-ELL gather+reduce (localops)
        prop = localops.scatter_combine(
            g, ell_dst, jnp.where(valid, labels[srcl], INT_INF), "min",
            identity=INT_INF)
        mine = exchange_min_int(prop)
        new_labels = jnp.minimum(labels, mine)
        # pull direction: adopt min label of in-neighbors (needs their
        # labels -> ship proposals keyed by in-edge source owner)
        prop2 = localops.scatter_combine(
            g, ell_src, jnp.where(in_valid, new_labels[in_dstl], INT_INF),
            "min", identity=INT_INF)
        mine2 = exchange_min_int(prop2)
        new_labels = jnp.minimum(new_labels, mine2)
        cnt = psum_scalar((new_labels < labels).sum(dtype=jnp.int32))
        return new_labels, cnt

    def guard(g, prev, state):
        # min-propagation invariants: labels non-negative and
        # non-increasing; change count non-negative
        labels, plabels = state[0], prev[0]
        return (labels >= 0).all() & (labels <= plabels).all() \
            & (state[1] >= 0)

    return SuperstepProgram(
        name="cc", variant="incremental" if seeded else "default",
        inputs=("labels0",) if seeded else (),
        init=init, step=step,
        halt=lambda state: state[1] <= 0,
        probe_names=("changed",), probe=lambda state: (state[1],),
        outputs=lambda state: (state[0],),
        output_names=("labels",), output_is_vertex=(True,),
        max_rounds=max_rounds, guard=guard)


def cc_async_program(shards, max_rounds: int = 64,
                     local_iters: int = 1) -> AsyncSuperstepProgram:
    """Async label propagation on the double-buffered exchange.

    Min-label propagation is the textbook stale-safe monotone program:
    labels only decrease, min-combine is idempotent and commutative, so
    applying a stale or duplicated proposal can never produce a wrong
    label — the async run converges to the BIT-identical fixed point
    (min vertex id per component) the BSP variant reaches.  Both edge
    directions propose into ONE shared (n,) accumulator (a label
    proposal is addressed to a global vertex id either way), so one
    exchange per round carries push + pull + the piggybacked halt count.
    """
    n, n_local = shards.n, shards.n_local

    def init_vals(g):
        lo = jax.lax.axis_index(AXIS) * n_local
        gid = jnp.arange(n_local, dtype=jnp.int32) + lo
        # every vertex proposes its identity label in round one
        return gid, jnp.ones((n_local,), bool)

    def relax(g, labels, frontier):
        srcl = g["out_src_local"]
        valid = g["out_dst_global"] < n
        in_dstl = g["in_dst_local"]
        in_valid = g["in_src_global"] < n
        push = localops.scatter_combine(
            g, shards.ell("ell_dst"),
            jnp.where(frontier[srcl] & valid, labels[srcl], INT_INF),
            "min", identity=INT_INF)
        pull = localops.scatter_combine(
            g, shards.ell("ell_src"),
            jnp.where(frontier[in_dstl] & in_valid, labels[in_dstl],
                      INT_INF),
            "min", identity=INT_INF)
        return jnp.minimum(push, pull)

    return monotone_async_program(
        name="cc", inputs=(), init_vals=init_vals, relax=relax,
        outputs=lambda g, labels: (labels,), output_names=("labels",),
        output_is_vertex=(True,), n=n, n_local=n_local, inf=INT_INF,
        local_iters=local_iters, max_rounds=max_rounds)
