"""Distributed connected components (label propagation / Shiloach-Vishkin
style hooking) - another paper "future work" algorithm.

Treats the graph as undirected by propagating labels along BOTH edge
directions; converges when no label changes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.partitioned import AXIS, psum_scalar

INT_INF = jnp.int32(2 ** 30)


def cc_shard(g, n, n_local, max_rounds):
    """Per-partition label-propagation driver (call inside shard_map)."""
    parts = jax.lax.axis_size(AXIS)
    lo = jax.lax.axis_index(AXIS) * n_local
    labels0 = jnp.arange(n_local, dtype=jnp.int32) + lo

    srcl = g["out_src_local"]
    dst = g["out_dst_global"]
    valid = dst < n
    in_src = g["in_src_global"]
    in_dstl = g["in_dst_local"]
    in_valid = in_src < n

    def cond(state):
        _, cnt, r = state
        return (cnt > 0) & (r < max_rounds)

    def body(state):
        labels, _, r = state
        # propose my label to out-neighbors (push direction)
        prop = jnp.full((n + 1,), INT_INF, jnp.int32).at[
            jnp.where(valid, dst, n)].min(
            jnp.where(valid, labels[srcl], INT_INF))[:n]
        rows = jax.lax.all_to_all(prop.reshape(parts, 1, n_local), AXIS,
                                  split_axis=0, concat_axis=1)
        mine = rows.min(axis=(0, 1))
        new_labels = jnp.minimum(labels, mine)
        # pull direction: adopt min label of in-neighbors (needs their
        # labels -> ship proposals keyed by in-edge source owner)
        prop2 = jnp.full((n + 1,), INT_INF, jnp.int32).at[
            jnp.where(in_valid, in_src, n)].min(
            jnp.where(in_valid, new_labels[in_dstl], INT_INF))[:n]
        rows2 = jax.lax.all_to_all(prop2.reshape(parts, 1, n_local), AXIS,
                                   split_axis=0, concat_axis=1)
        mine2 = rows2.min(axis=(0, 1))
        new_labels = jnp.minimum(new_labels, mine2)
        cnt = psum_scalar((new_labels < labels).sum(dtype=jnp.int32))
        return new_labels, cnt, r + 1

    labels, _, rounds = jax.lax.while_loop(
        cond, body, (labels0, jnp.int32(1), jnp.int32(0)))
    return labels, rounds
