"""Checkpointed, fault-recovering execution of superstep programs.

``core/superstep.py`` supplies the chunked substrate (``init_carry`` /
``run_chunk`` / ``carry_outputs``); this module owns the HOST loop that
turns it into fault tolerance:

  * every ``checkpoint_every`` rounds the full loop carry — vertex
    state, in-flight async handle, round counter, guard verdict — is
    snapshotted to host memory (``Checkpoint``);
  * each chunk runs the GUARDED driver: the program's per-round
    invariant check plus the transport-stamp detector (``core/faults``)
    stop the loop on the first violated round;
  * on detection the runner restores the last checkpoint and replays
    the chunk with a CLEAN-compiled executable (no fault taps) — the
    transient-fault model: the injected fault belongs to one execution
    of those rounds, not to the rounds themselves.  Later chunks resume
    the fault-compiled executable, so later-round events still fire
    (and are recovered in turn).  A violation that SURVIVES a clean
    replay is a real algorithm/guard bug and raises
    :class:`RecoveryError` instead of looping;
  * ``run(..., resume_from=checkpoint)`` restarts from any snapshot.

Chunking never changes the traced per-round computation, and the
host round-trip (``device_get`` / ``device_put``) is bit-exact, so a
checkpointed, resumed, or recovered run produces BIT-IDENTICAL outputs
to an uninterrupted one (pagerank included — same arithmetic, same
order), which is what ``tests/test_chaos.py`` pins for every registered
program.

Everything crosses the shard_map boundary through one universal
wrapping rule: each per-shard leaf gains a leading axis of size 1
(globally: the ``parts`` axis), with a single ``P("parts")`` pytree
prefix as its spec — scalars, handles, vertex fields and round
counters all ride the same path, so the carry needs no per-leaf spec
bookkeeping.
"""

from __future__ import annotations

import contextlib
from dataclasses import dataclass, field
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import faults as faults_mod
from repro.core import registry
from repro.core.api import _graph_specs
from repro.core.compat import shard_map
from repro.core.superstep import PhasedProgram, carry_outputs, init_carry, \
    run_chunk
from repro.obs import telemetry as obs_telemetry
from repro.obs.spans import NULL_RECORDER

P = jax.sharding.PartitionSpec


class RecoveryError(RuntimeError):
    """A guard violation that checkpoint rollback cannot clear."""


def _wrap(tree):
    """Per-shard -> global: every leaf gains a leading parts axis."""
    return jax.tree_util.tree_map(lambda x: jnp.asarray(x)[None], tree)


def _unwrap(tree):
    """Global -> per-shard: strip the leading parts axis."""
    return jax.tree_util.tree_map(lambda x: x[0], tree)


@dataclass(frozen=True)
class Checkpoint:
    """A host-resident snapshot of one phase's loop carry.

    ``carry`` is the wrapped global form (numpy): restoring it is one
    ``device_put`` per leaf against the runner's parts sharding, which
    round-trips bits exactly.
    """

    phase: int
    rounds: int
    carry: Any


@dataclass
class RunReport:
    """What a checkpointed run did, beyond its outputs.

    ``outputs`` matches the engine convention: vertex fields arrive as
    (P, n_local) numpy arrays (``engine.gather_vertex_field`` applies),
    scalars as numpy scalars.  ``detections`` lists the round counter
    at each guard/transport detection (the first tainted round + 1);
    ``recoveries`` counts rollback-replays that cleared one.
    """

    outputs: tuple
    rounds: int
    recoveries: int = 0
    detections: tuple = ()
    checkpoints: int = 0
    history: tuple = ()
    telemetry: dict | None = None


class CheckpointRunner:
    """Run one registered program with superstep checkpointing, fault
    injection, and rollback recovery.

        runner = CheckpointRunner(engine, "bfs", "fast",
                                  checkpoint_every=2,
                                  faults="corrupt@r3p1:sum seed=7")
        report = runner.run(engine.device_graph(), jnp.int32(root))

    ``faults=None`` gives plain checkpointed execution (the
    checkpoint/resume bit-identity path); a
    :class:`~repro.core.faults.FaultSchedule` (or its string spec)
    compiles deterministic fault injection into the exchange taps of
    the PRIMARY executables — the recovery replays always run clean
    ones.  ``keep_history=True`` retains every checkpoint in the
    report (tests resume from a mid-run snapshot).
    """

    def __init__(self, engine, algo: str, variant: str | None = None, *,
                 checkpoint_every: int = 2, faults=None,
                 max_recoveries: int = 16, keep_history: bool = False,
                 telemetry: bool = False, obs=None, **params):
        if checkpoint_every < 1:
            raise ValueError(
                f"checkpoint_every must be >= 1, got {checkpoint_every}")
        self.engine = engine
        self.spec = registry.get_spec(algo, variant)
        self.schedule = faults_mod.as_schedule(faults)
        self.checkpoint_every = int(checkpoint_every)
        self.max_recoveries = int(max_recoveries)
        self.keep_history = bool(keep_history)
        # telemetry rides the carry as carry[4] (see superstep series
        # block): it checkpoints and rolls back with the state, so a
        # recovered run's series has no rows from discarded chunks.
        # ``obs`` is a SpanRecorder: chunk spans plus checkpoint /
        # fault_detection / rollback instant events on the recovery
        # track (NULL_RECORDER = off, the default).
        self.telemetry = bool(telemetry)
        self.wire = obs_telemetry.WireRecord() if telemetry else None
        self.obs = obs if obs is not None else NULL_RECORDER
        prog = self.spec.build(engine.g, **params)
        self.program = prog
        self.phases = prog.phases if isinstance(prog, PhasedProgram) \
            else (prog,)
        self._sh = jax.sharding.NamedSharding(engine.mesh, P("parts"))
        self._gspecs = _graph_specs(engine.g, engine.layout)
        self._pieces: dict = {}

    # -- compiled pieces ----------------------------------------------------

    def _ctx(self, faulty: bool):
        if faulty and self.schedule is not None:
            return faults_mod.active(self.schedule, detect=True)
        return contextlib.nullcontext()

    def _jit(self, fn, in_specs):
        return jax.jit(shard_map(
            fn, mesh=self.engine.mesh, in_specs=in_specs,
            out_specs=P("parts"), check_vma=False))

    def _init_piece(self, pi: int, faulty: bool):
        key = ("init", pi, faulty)
        if key in self._pieces:
            return self._pieces[key]
        prog = self.phases[pi]
        if pi == 0:
            kinds = self.spec.input_kinds

            def fn(garr, *inputs):
                garr = {k: v[0] for k, v in garr.items()}
                ins = tuple(x[0] if kind != "scalar" else x
                            for x, kind in zip(inputs, kinds))
                with self._ctx(faulty):
                    return _wrap(init_carry(prog, garr, *ins,
                                            telemetry=self.telemetry))

            in_specs = (self._gspecs,) + tuple(
                P() if kind == "scalar" else P("parts", None)
                for kind in kinds)
        else:
            # later phases are initialized from the previous phase's
            # wrapped outputs — unwrap uniformly
            def fn(garr, *chained):
                garr = {k: v[0] for k, v in garr.items()}
                ins = tuple(x[0] for x in chained)
                with self._ctx(faulty):
                    return _wrap(init_carry(prog, garr, *ins,
                                            telemetry=self.telemetry))

            n_prev = len(self.phases[pi - 1].output_names)
            in_specs = (self._gspecs,) + (P("parts"),) * n_prev
        piece = self._jit(fn, in_specs)
        self._pieces[key] = piece
        return piece

    def _chunk_piece(self, pi: int, faulty: bool):
        key = ("chunk", pi, faulty)
        if key in self._pieces:
            return self._pieces[key]
        prog = self.phases[pi]
        k = self.checkpoint_every

        def fn(garr, carry):
            garr = {k2: v[0] for k2, v in garr.items()}
            # arm the wire record during the chunk trace: the chunk body
            # IS the per-round loop, so its taps are the per-round bytes
            tcm = obs_telemetry.recording(self.wire) if self.telemetry \
                else contextlib.nullcontext()
            with self._ctx(faulty), tcm:
                carry2, halted = run_chunk(prog, garr, _unwrap(carry), k)
            return _wrap((carry2, halted))

        piece = self._jit(fn, (self._gspecs, P("parts")))
        self._pieces[key] = piece
        return piece

    def _out_piece(self, pi: int):
        key = ("out", pi)
        if key in self._pieces:
            return self._pieces[key]
        prog = self.phases[pi]

        def fn(garr, carry):
            garr = {k: v[0] for k, v in garr.items()}
            return _wrap(tuple(carry_outputs(prog, garr, _unwrap(carry))))

        piece = self._jit(fn, (self._gspecs, P("parts")))
        self._pieces[key] = piece
        return piece

    # -- host-side carry plumbing -------------------------------------------

    @staticmethod
    def _ok(carry) -> bool:
        return bool(np.asarray(carry[3])[0])

    @staticmethod
    def _rounds(carry) -> int:
        return int(np.asarray(carry[2])[0])

    def _snapshot(self, pi: int, carry) -> Checkpoint:
        return Checkpoint(phase=pi, rounds=self._rounds(carry),
                          carry=jax.device_get(carry))

    def _restore(self, host_carry):
        return jax.tree_util.tree_map(
            lambda x: jax.device_put(x, self._sh), host_carry)

    # -- the recovery loop --------------------------------------------------

    def _run_phase(self, pi: int, garr, inputs, stats: dict,
                   resume: Checkpoint | None):
        if resume is not None:
            carry = self._restore(resume.carry)
        else:
            carry = self._init_piece(pi, True)(garr, *inputs)
            if not self._ok(carry):
                stats["detections"].append(self._rounds(carry))
                self.obs.event("fault_detection", "recovery", phase=pi,
                               round=self._rounds(carry))
                self._bump(stats)
                self.obs.event("rollback", "recovery", phase=pi,
                               to_rounds=0)
                carry = self._init_piece(pi, False)(garr, *inputs)
                if not self._ok(carry):
                    raise RecoveryError(
                        f"{self.spec.key} phase {pi}: clean re-init "
                        f"still violates guards")
        ck = self._snapshot(pi, carry)
        stats["checkpoints"] += 1
        self.obs.event("checkpoint", "recovery", phase=pi,
                       rounds=ck.rounds)
        if self.keep_history:
            stats["history"].append(ck)
        while True:
            r0 = self._rounds(carry)
            with self.obs.span("chunk", "recovery", phase=pi,
                               from_round=r0) as chunk_span:
                nxt, halted = self._chunk_piece(pi, True)(garr, carry)
                if not self._ok(nxt):
                    stats["detections"].append(self._rounds(nxt))
                    self.obs.event("fault_detection", "recovery",
                                   phase=pi, round=self._rounds(nxt))
                    self._bump(stats)
                    self.obs.event("rollback", "recovery", phase=pi,
                                   to_rounds=ck.rounds)
                    carry = self._restore(ck.carry)
                    nxt, halted = self._chunk_piece(pi, False)(garr,
                                                               carry)
                    if not self._ok(nxt):
                        raise RecoveryError(
                            f"{self.spec.key} phase {pi}: guard "
                            f"violation at round {self._rounds(nxt)} "
                            f"persists on clean replay from the "
                            f"round-{ck.rounds} checkpoint")
                carry = nxt
                chunk_span.args["to_round"] = self._rounds(carry)
            ck = self._snapshot(pi, carry)
            stats["checkpoints"] += 1
            self.obs.event("checkpoint", "recovery", phase=pi,
                           rounds=ck.rounds)
            if self.keep_history:
                stats["history"].append(ck)
            if bool(np.asarray(halted)[0]) or self._rounds(carry) == r0:
                return carry

    def _bump(self, stats: dict):
        stats["recoveries"] += 1
        if stats["recoveries"] > self.max_recoveries:
            raise RecoveryError(
                f"{self.spec.key}: exceeded max_recoveries="
                f"{self.max_recoveries}")

    def run(self, garr, *inputs, resume_from: Checkpoint | None = None):
        """Execute (or resume) the program; returns a :class:`RunReport`.

        ``garr`` is ``engine.device_graph()``; ``inputs`` follow the
        spec's input kinds exactly like a :class:`CompiledProgram`
        call.  ``resume_from`` restarts from a snapshot: phases before
        it are already folded into its carry, later phases run
        normally.
        """
        stats = {"recoveries": 0, "detections": [], "checkpoints": 0,
                 "history": []}
        start = resume_from.phase if resume_from is not None else 0
        total = 0
        chained = inputs
        carry = None
        series_rows = []
        for pi in range(start, len(self.phases)):
            resume = resume_from if (resume_from is not None
                                     and pi == start) else None
            carry = self._run_phase(pi, garr, chained, stats, resume)
            total += self._rounds(carry)
            if self.telemetry:
                # wrapped global series: (P, max_rounds, 2 + K),
                # replicated — any part's copy is the run's series
                series_rows.append(np.asarray(carry[4])[0])
            if pi + 1 < len(self.phases):
                chained = self._out_piece(pi)(garr, carry)
        outs = self._out_piece(len(self.phases) - 1)(garr, carry)
        host = tuple(
            np.asarray(o) if is_v else np.asarray(o)[0]
            for o, is_v in zip(outs, self.program.output_is_vertex))
        telemetry = None
        if self.telemetry:
            ps = obs_telemetry.PhaseSeries.from_array(
                np.concatenate(series_rows, axis=0),
                self.program.probe_names)
            telemetry = obs_telemetry.RunTelemetry(
                series=ps, wire=self.wire.snapshot()).summary()
        return RunReport(
            outputs=host, rounds=total,
            recoveries=stats["recoveries"],
            detections=tuple(stats["detections"]),
            checkpoints=stats["checkpoints"],
            history=tuple(stats["history"]),
            telemetry=telemetry)
