"""Graph-engine dry-run: lower + compile every registered algorithm
program for paper-scale urand graphs on the production mesh (flattened
to a 1-D "parts" axis: 256 chips single-pod, 512 multi-pod).

This is the paper-side counterpart of the LM dry-run: it proves the
graph engine's collective schedule and per-partition memory are coherent
at production scale without touching real edges (abstract GraphShards).
Programs are enumerated from ``core/registry.py`` — every registered
algorithm x variant lowers with a fixed-trip ``static_iters`` scan so
trip counts are static and the roofline accounting is exact (SSSP and
CC inherit this from the shared superstep driver).
"""

from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.configs import graph_workloads
from repro.core import localops, registry
from repro.core.api import GraphEngine
from repro.core.graph import abstract_graph
from repro.core.registry import program_label
from repro.launch.mesh import make_graph_mesh
from repro.roofline import analysis as RA

# static trip counts per algorithm (documented in EXPERIMENTS): typical
# ER BFS depth is ~8; Bellman-Ford/label-prop converge in a few more
# rounds than the BFS depth; PageRank runs its full iteration budget;
# k-core peels in ~(degeneracy + wave) rounds; betweenness runs its
# static count PER PHASE (forward + backward).  "parts" means one
# superstep per partition (the triangle rotation runs exactly P rounds).
# Algorithms registered without an entry fall back to DEFAULT_STATIC_ITERS
# so extending the registry never breaks the dry-run.
STATIC_ITERS = {"bfs": 8, "pagerank": 50, "sssp": 12, "cc": 8,
                "triangles": "parts", "kcore": 30, "betweenness": 8}
DEFAULT_STATIC_ITERS = 12

# dry-run parameter overrides per (algo, variant)
DRYRUN_PARAMS = {
    # steady-state compressed exchange: no precision-switch branches in
    # the HLO, so the parsed wire bytes reflect the bf16 payload
    ("pagerank", "fast"): {"compress": "always"},
}


def _graph_model_flops(g, algo: str, iters: int) -> float:
    e_total = g.e_max * g.parts
    if algo == "pagerank":
        return 2.0 * e_total * iters      # multiply-add per edge per iter
    if algo == "sssp":
        return 2.0 * e_total * iters      # relax (add+min) per edge per round
    if algo == "cc":
        return 4.0 * e_total * iters      # min-combine both edge directions
    if algo == "triangles":
        # dense masked-matmul intersection: (n_local, n) x (n, n_local)
        # per round x P rounds = one n x n x n_local contraction total
        return 2.0 * float(g.n) * g.n * g.n_local
    if algo == "kcore":
        return 4.0 * e_total * iters      # decrement scan, both directions
    if algo == "betweenness":
        return 4.0 * e_total * iters      # forward push + backward pull
    return 2.0 * e_total                  # bfs: one relax pass over all edges


def lower_graph_programs(graph_name: str, mesh_name: str, out_dir=None,
                         algos=None) -> list[dict]:
    """Lower + compile programs; ``algos`` is a list of "algo_variant"
    labels (default: everything in the registry)."""
    cfg = graph_workloads.ALL[graph_name]
    parts = 512 if mesh_name == "multipod" else 256
    if len(jax.devices()) < parts:
        raise RuntimeError(
            f"graph dry-run needs {parts} devices "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=512)")
    mesh = make_graph_mesh(parts)
    g = abstract_graph(cfg.num_vertices, cfg.avg_degree, parts)
    eng = GraphEngine(g, mesh)

    cells = [(a, v) for a, v in registry.available()
             if algos is None or program_label(a, v) in algos]
    records = []
    for algo, variant in cells:
        label = program_label(algo, variant)
        it_count = STATIC_ITERS.get(algo, DEFAULT_STATIC_ITERS)
        if it_count == "parts":
            it_count = parts
        params = dict(DRYRUN_PARAMS.get((algo, variant), {}))
        prog = eng.program(algo, variant, static_iters=it_count, **params)

        t0 = time.time()
        compiled = prog.aot()
        dt = time.time() - t0
        mem = compiled.memory_analysis()
        roof = RA.analyze(
            compiled, arch=f"graph-{label}", shape_name=graph_name,
            mesh_name=mesh_name, devices=parts,
            model_flops_total=_graph_model_flops(g, algo, it_count))
        if (algo, variant) == ("pagerank", "fast"):
            # The exchanged payload is bf16 (error-feedback compression);
            # the CPU host backend promotes bf16 collectives to f32 in the
            # dumped HLO (convert fused ahead of the reduce-scatter), so
            # the parsed wire bytes for the reduce-scatter are 2x the TPU
            # wire.  Correct that op's share; all-reduce (f32 scalar err)
            # is unchanged.
            rs = roof.collectives["wire_bytes"].get("reduce-scatter", 0.0)
            roof.collective_wire_bytes -= rs / 2.0
            roof.collectives["wire_bytes"]["reduce-scatter"] = rs / 2.0
            roof.finalize()
        # jaxpr-exact compute/bytes (scan trip counts are static now)
        from repro.roofline.jaxpr_cost import count_fn
        cost = count_fn(prog.fn, *prog.abstract_args)
        roof.flops_per_device = cost.total_flops / parts
        roof.bytes_per_device = cost.bytes_touched / parts / 3.0  # fusion est.
        roof.finalize()
        rec = roof.to_json()
        rec["jaxpr_matmul_flops_total"] = cost.matmul_flops
        rec["jaxpr_elementwise_flops_total"] = cost.elementwise_flops
        rec["jaxpr_bytes_unfused_total"] = cost.bytes_touched
        rec.update({
            "program": label,
            # bsp | async: the superstep driver the lowering went
            # through (async lowers the double-buffered exchange, so
            # its collective schedule differs from the bsp twin's)
            "exec_mode": prog.spec.exec_mode,
            "lower_compile_s": round(dt, 2),
            "arg_bytes_per_device": mem.argument_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "status": "ok",
            "n_vertices": g.n, "e_max_per_part": g.e_max,
            # the blocked-ELL layout is lowered and priced too: slot
            # counts per structure so layout growth shows up in review
            "layout": eng.layout,
            "ell_slots_per_part": {name: m.slots
                                   for name, m in g.ell_meta.items()},
            # the RESOLVED implementation that was lowered (ref|ell|
            # pallas), not the raw mode: "auto" lowers different code on
            # CPU hosts vs TPU hosts
            "localops_impl": localops.resolve(),
        })
        hbm = (mem.argument_size_in_bytes + mem.temp_size_in_bytes) / 1e9
        print(f"[graph {label} x {graph_name} x {mesh_name}] "
              f"HBM/dev {hbm:.2f} GB | bottleneck {roof.bottleneck} "
              f"(c={roof.compute_s*1e3:.2f}ms m={roof.memory_s*1e3:.2f}ms "
              f"x={roof.collective_s*1e3:.2f}ms)")
        if out_dir:
            out = pathlib.Path(out_dir)
            out.mkdir(parents=True, exist_ok=True)
            (out / f"graph-{label}__{graph_name}__{mesh_name}.json").write_text(
                json.dumps(rec, indent=2))
        records.append(rec)
    return records
