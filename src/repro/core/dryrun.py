"""Graph-engine dry-run: lower + compile BFS and PageRank for paper-scale
urand graphs on the production mesh (flattened to a 1-D "parts" axis:
256 chips single-pod, 512 multi-pod).

This is the paper-side counterpart of the LM dry-run: it proves the
graph engine's collective schedule and per-partition memory are coherent
at production scale without touching real edges (abstract GraphShards).
"""

from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.configs import graph_workloads
from repro.core.api import GraphEngine
from repro.core.graph import abstract_graph
from repro.launch.mesh import make_graph_mesh
from repro.roofline import analysis as RA


def _graph_model_flops(g, algo: str, iters: int) -> float:
    e_total = g.e_max * g.parts
    if algo.startswith("pagerank"):
        return 2.0 * e_total * iters      # multiply-add per edge per iter
    return 2.0 * e_total                  # one relax pass over all edges


def lower_graph_programs(graph_name: str, mesh_name: str, out_dir=None,
                         algos=("bfs_fast", "bfs_bsp",
                                "pagerank_fast", "pagerank_bsp")) -> list[dict]:
    cfg = graph_workloads.ALL[graph_name]
    parts = 512 if mesh_name == "multipod" else 256
    if len(jax.devices()) < parts:
        raise RuntimeError(
            f"graph dry-run needs {parts} devices "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=512)")
    mesh = make_graph_mesh(parts)
    g = abstract_graph(cfg.num_vertices, cfg.avg_degree, parts)
    eng = GraphEngine(g, mesh)
    garr_abs = g.abstract_arrays()
    root_abs = jax.ShapeDtypeStruct((), jnp.int32)
    iters = 50

    records = []
    for algo in algos:
        bfs_levels = 8   # typical ER BFS depth (documented in EXPERIMENTS)
        if algo == "bfs_fast":
            fn = eng.bfs(mode="fast", static_iters=bfs_levels)
            args = (garr_abs, root_abs)
            it_count = bfs_levels
        elif algo == "bfs_bsp":
            fn = eng.bfs(mode="bsp", static_iters=bfs_levels)
            args = (garr_abs, root_abs)
            it_count = bfs_levels
        elif algo == "pagerank_fast":
            fn = eng.pagerank(mode="fast", iters=iters, static_iters=iters,
                              compress="always")
            args = (garr_abs,)
            it_count = iters
        else:
            fn = eng.pagerank(mode="bsp", iters=iters, static_iters=iters)
            args = (garr_abs,)
            it_count = iters

        t0 = time.time()
        lowered = fn.lower(*args)
        compiled = lowered.compile()
        dt = time.time() - t0
        mem = compiled.memory_analysis()
        roof = RA.analyze(
            compiled, arch=f"graph-{algo}", shape_name=graph_name,
            mesh_name=mesh_name, devices=parts,
            model_flops_total=_graph_model_flops(g, algo, it_count))
        if algo == "pagerank_fast":
            # The exchanged payload is bf16 (error-feedback compression);
            # the CPU host backend promotes bf16 collectives to f32 in the
            # dumped HLO (convert fused ahead of the reduce-scatter), so
            # the parsed wire bytes for the reduce-scatter are 2x the TPU
            # wire.  Correct that op's share; all-reduce (f32 scalar err)
            # is unchanged.
            rs = roof.collectives["wire_bytes"].get("reduce-scatter", 0.0)
            roof.collective_wire_bytes -= rs / 2.0
            roof.collectives["wire_bytes"]["reduce-scatter"] = rs / 2.0
            roof.finalize()
        # jaxpr-exact compute/bytes (scan trip counts are static now)
        from repro.roofline.jaxpr_cost import count_fn
        cost = count_fn(fn, *args)
        roof.flops_per_device = cost.total_flops / parts
        roof.bytes_per_device = cost.bytes_touched / parts / 3.0  # fusion est.
        roof.finalize()
        rec = roof.to_json()
        rec["jaxpr_matmul_flops_total"] = cost.matmul_flops
        rec["jaxpr_elementwise_flops_total"] = cost.elementwise_flops
        rec["jaxpr_bytes_unfused_total"] = cost.bytes_touched
        rec.update({
            "program": algo,
            "lower_compile_s": round(dt, 2),
            "arg_bytes_per_device": mem.argument_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "status": "ok",
            "n_vertices": g.n, "e_max_per_part": g.e_max,
        })
        hbm = (mem.argument_size_in_bytes + mem.temp_size_in_bytes) / 1e9
        print(f"[graph {algo} x {graph_name} x {mesh_name}] "
              f"HBM/dev {hbm:.2f} GB | bottleneck {roof.bottleneck} "
              f"(c={roof.compute_s*1e3:.2f}ms m={roof.memory_s*1e3:.2f}ms "
              f"x={roof.collective_s*1e3:.2f}ms)")
        if out_dir:
            out = pathlib.Path(out_dir)
            out.mkdir(parents=True, exist_ok=True)
            (out / f"graph-{algo}__{graph_name}__{mesh_name}.json").write_text(
                json.dumps(rec, indent=2))
        records.append(rec)
    return records
