"""Distributed triangle counting via rotated bit-packed neighbor-set
exchange — the first "full NWGraph set" algorithm beyond the traversal /
fixpoint families.

Semantics: triangles of the SIMPLE UNDIRECTED graph underlying the edge
list (parallel edges deduplicated, self-loops dropped) — the standard
convention, and what the NumPy oracle (``tests/oracle.py``) computes.

Adaptation notes: the classical distributed algorithm ships each
vertex's sorted neighbor list to its neighbors and intersects at the
receiver.  The SPMD/static-shape analogue represents a sorted neighbor
SET as a bit-packed row ((n/32,) uint32 — the same wire format as the
``bfs/fast`` frontier), so "intersection of sorted neighbor exchanges"
becomes AND+popcount.  Each superstep ``ppermute``-rotates the packed
adjacency block one partition to the left, so after P rounds every
partition has intersected its rows against every other partition's rows
— P supersteps, each moving n*n_local/8 bytes, no all-to-all.  The
intersection itself is evaluated as a masked dense matmul (unpack both
blocks to f32, one (n_local, n) x (n, n_local) contraction per round):
on TPU this is the MXU-friendly spelling of AND+popcount.

The per-partition adjacency bitmap is O(n^2 / P) memory: right for the
paper's benchmark scales, and the honest roofline story at 2^25
vertices (``ProgramSpec.n_budget`` keeps the launcher from running it
on graphs where the bitmap doesn't fit; the dry-run still lowers it to
price the layout).

Counting: with A the symmetric 0/1 adjacency,
``2 * tri(u) = sum_v A[u, v] * (A @ A)[u, v]`` and the global count is
``sum_u tri(u) / 3``.  Rounds past P are gated no-ops, so the program is
safe under the driver's fixed-trip ``static_iters`` scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.compat import axis_size
from repro.core.partitioned import AXIS, _tap, psum_scalar
from repro.core.superstep import SuperstepProgram


def _pack_rows(dense_u8):
    """(m, n) uint8 0/1 -> (m, n/32) uint32 bit rows."""
    m, n = dense_u8.shape
    w = dense_u8.reshape(m, n // 32, 32).astype(jnp.uint32)
    return (w << jnp.arange(32, dtype=jnp.uint32)).sum(axis=2,
                                                       dtype=jnp.uint32)


def _unpack_rows(bits, n):
    """(m, n/32) uint32 -> (m, n) f32 0/1 rows."""
    idx = jnp.arange(n)
    words = bits[:, idx >> 5]                       # (m, n)
    return ((words >> (idx & 31).astype(jnp.uint32)) & 1).astype(jnp.float32)


def _sym_adjacency_bits(g, n, n_local):
    """Bit-packed symmetric dedup'd adjacency rows of the local vertices.

    Row u_local holds the neighbor SET {v : u->v or v->u}, self-loops
    excluded; the bitmap is the deduplication (parallel edges set the
    same bit).
    """
    lo = jax.lax.axis_index(AXIS) * n_local
    dense = jnp.zeros((n_local, n + 1), jnp.uint8)  # slop col for sentinel
    srcl, dst = g["out_src_local"], g["out_dst_global"]
    keep = (dst < n) & (dst != srcl + lo)
    dense = dense.at[srcl, jnp.where(keep, dst, n)].max(jnp.uint8(1))
    src, dstl = g["in_src_global"], g["in_dst_local"]
    keep_in = (src < n) & (src != dstl + lo)
    dense = dense.at[dstl, jnp.where(keep_in, src, n)].max(jnp.uint8(1))
    return _pack_rows(dense[:, :n])


def triangles_program(n: int, n_local: int) -> SuperstepProgram:
    """Rotation triangle counting as a superstep program.

    Outputs: per-vertex triangle counts (vertex field) and the global
    triangle total (replicated scalar).  Runs exactly P supersteps.
    """
    parts = n // n_local

    def prepare(g):
        g = dict(g)
        g["adj_bits"] = _sym_adjacency_bits(g, n, n_local)
        return g

    def init(g, *_):
        return g["adj_bits"], jnp.zeros((n_local,), jnp.float32), jnp.int32(0)

    def step(g, state):
        block, tri2, r = state
        p = axis_size(AXIS)
        # round r holds the block of partition q = (me - r) mod P
        q = (jax.lax.axis_index(AXIS) - r) % p
        a = _unpack_rows(g["adj_bits"], n)          # (n_local, n) my rows
        b = _unpack_rows(block, n)                  # (n_local, n) q's rows
        common = a @ b.T                            # |N(u) ^ N(v)| for v in q
        gate = jax.lax.dynamic_slice_in_dim(a, q * n_local, n_local, axis=1)
        contrib = (gate * common).sum(axis=1)
        tri2 = tri2 + jnp.where(r < p, contrib, 0.0)  # no-op past P rounds
        block = jax.lax.ppermute(
            _tap("perm", block, AXIS), AXIS,
            [(i, (i + 1) % p) for i in range(p)])
        return block, tri2, r + 1

    def outputs(state):
        _, tri2, _ = state
        tri = (tri2 / 2.0).astype(jnp.int32)
        total = (psum_scalar(tri2.sum()) / 6.0 + 0.5).astype(jnp.int32)
        return tri, total

    def guard(g, prev, state):
        # per-vertex double-counts accumulate non-negative intersection
        # contributions: finite and non-decreasing.  The rotated
        # adjacency block itself is bitmap data — transport CRC
        # territory, no value invariant to check.
        tri2, ptri2 = state[1], prev[1]
        return jnp.isfinite(tri2).all() & (tri2 >= ptri2).all()

    return SuperstepProgram(
        name="triangles", variant="default", inputs=(),
        prepare=prepare, init=init, step=step,
        halt=lambda state: state[2] >= parts,
        outputs=outputs,
        output_names=("triangles", "total"),
        output_is_vertex=(True, False),
        max_rounds=parts, guard=guard)
