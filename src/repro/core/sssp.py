"""Distributed SSSP (Bellman-Ford with frontier pruning).

One of the paper's "future work: extend to the full NWGraph algorithm
set" items - included here as a third traversal-family algorithm.  Edge
weights are synthesized deterministically from endpoint ids (uniform in
[1, 2)); rounds relax only edges whose source distance changed in the
previous round (frontier pruning), with a MIN-combine exchange.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.partitioned import AXIS, psum_scalar

F32_INF = jnp.float32(1e30)


def edge_weight(src, dst):
    """Deterministic pseudo-random weight in [1, 2)."""
    h = (src.astype(jnp.uint32) * jnp.uint32(2654435761)
         ^ dst.astype(jnp.uint32) * jnp.uint32(40503))
    return 1.0 + (h % jnp.uint32(1 << 16)).astype(jnp.float32) / float(1 << 16)


def sssp_shard(g, root, n, n_local, max_rounds):
    """Per-partition Bellman-Ford driver (call inside shard_map)."""
    parts = jax.lax.axis_size(AXIS)
    lo = jax.lax.axis_index(AXIS) * n_local
    owned = (root >= lo) & (root < lo + n_local)
    dist0 = jnp.where(owned & (jnp.arange(n_local) == root - lo),
                      0.0, F32_INF)
    changed0 = owned & (jnp.arange(n_local) == root - lo)

    srcl = g["out_src_local"]
    dst = g["out_dst_global"]
    valid = dst < n
    w = edge_weight(srcl + lo, dst)

    def cond(state):
        _, _, cnt, r = state
        return (cnt > 0) & (r < max_rounds)

    def body(state):
        dist, changed, _, r = state
        active = changed[srcl] & valid
        cand = jnp.where(active, dist[srcl] + w, F32_INF)
        prop = jnp.full((n + 1,), F32_INF, jnp.float32).at[
            jnp.where(active, dst, n)].min(cand)[:n]
        rows = jax.lax.all_to_all(prop.reshape(parts, 1, n_local), AXIS,
                                  split_axis=0, concat_axis=1)
        mine = rows.min(axis=(0, 1))
        new_dist = jnp.minimum(dist, mine)
        new_changed = new_dist < dist
        cnt = psum_scalar(new_changed.sum(dtype=jnp.int32))
        return new_dist, new_changed, cnt, r + 1

    dist, _, _, rounds = jax.lax.while_loop(
        cond, body, (dist0, changed0, jnp.int32(1), jnp.int32(0)))
    return dist, rounds
