"""Distributed SSSP (Bellman-Ford with frontier pruning).

One of the paper's "future work: extend to the full NWGraph algorithm
set" items - included here as a third traversal-family algorithm.  Edge
weights are synthesized deterministically from endpoint ids (uniform in
[1, 2)); rounds relax only edges whose source distance changed in the
previous round (frontier pruning), with a MIN-combine exchange.

Expressed as a :class:`~repro.core.superstep.SuperstepProgram`: the
``prepare`` hook derives the loop-invariant weight array once, outside
the driver loop, and rounds past convergence are no-ops (empty change
set relaxes nothing), so the program is safe under ``static_iters`` and
vmaps over batched roots for multi-source queries.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import localops
from repro.core.monotone import monotone_async_program
from repro.core.partitioned import AXIS, exchange_min_int, psum_scalar
from repro.core.superstep import AsyncSuperstepProgram, SuperstepProgram

F32_INF = jnp.float32(1e30)


def edge_weight(src, dst):
    """Deterministic pseudo-random weight in [1, 2)."""
    h = (src.astype(jnp.uint32) * jnp.uint32(2654435761)
         ^ dst.astype(jnp.uint32) * jnp.uint32(40503))
    return 1.0 + (h % jnp.uint32(1 << 16)).astype(jnp.float32) / float(1 << 16)


def sssp_program(shards, max_rounds: int = 64,
                 weight_scale: float = 1.0) -> SuperstepProgram:
    """Frontier-pruned Bellman-Ford as a superstep program.

    ``weight_scale`` uniformly scales the synthesized edge weights (a
    query-time parameter for serving; 1.0 reproduces the oracle's
    weights bit-for-bit).  It must be finite and positive — the serve
    layer rejects anything else at admission (``validate_query``)
    because a NaN/Inf scale would poison every distance in a coalesced
    launch.
    """
    n, n_local = shards.n, shards.n_local
    ell_dst = shards.ell("ell_dst")

    def prepare(g):
        lo = jax.lax.axis_index(AXIS) * n_local
        g = dict(g)
        g["out_weight"] = edge_weight(g["out_src_local"] + lo,
                                      g["out_dst_global"]) \
            * jnp.float32(weight_scale)
        return g

    def init(g, root):
        lo = jax.lax.axis_index(AXIS) * n_local
        owned = (root >= lo) & (root < lo + n_local)
        at_root = owned & (jnp.arange(n_local) == root - lo)
        dist0 = jnp.where(at_root, 0.0, F32_INF)
        return dist0, at_root, jnp.int32(1)

    def step(g, state):
        dist, changed, _ = state
        srcl = g["out_src_local"]
        dst = g["out_dst_global"]
        valid = dst < n
        w = g["out_weight"]
        active = changed[srcl] & valid
        # edge relaxation = MIN-combine of candidates keyed by dst; the
        # blocked-ELL gather in localops replaces the serialized scatter
        prop = localops.scatter_combine(
            g, ell_dst, jnp.where(active, dist[srcl] + w, F32_INF), "min",
            identity=F32_INF)
        mine = exchange_min_int(prop)
        new_dist = jnp.minimum(dist, mine)
        new_changed = new_dist < dist
        cnt = psum_scalar(new_changed.sum(dtype=jnp.int32))
        return new_dist, new_changed, cnt

    def guard(g, prev, state):
        # distances non-negative and non-increasing (NaN corruption
        # fails both comparisons); change count non-negative
        dist, pdist = state[0], prev[0]
        return (dist >= 0).all() & (dist <= pdist).all() \
            & (state[2] >= 0)

    return SuperstepProgram(
        name="sssp", variant="default", inputs=("root",),
        prepare=prepare, init=init, step=step,
        halt=lambda state: state[2] <= 0,
        probe_names=("changed",), probe=lambda state: (state[2],),
        outputs=lambda state: (state[0],),
        output_names=("dist",), output_is_vertex=(True,),
        max_rounds=max_rounds, guard=guard)


def sssp_async_program(shards, max_rounds: int = 64, local_iters: int = 1,
                       weight_scale: float = 1.0) -> AsyncSuperstepProgram:
    """Async Bellman-Ford on the double-buffered exchange.

    Distance relaxation is monotone min-combine, so staleness is exact:
    a late or duplicated proposal ``dist[u] + w`` is still a valid upper
    bound and min-application can neither overshoot the true distance
    nor stick above it (every improvement is eventually delivered).
    The async run converges to the same distances as the BSP variant,
    with the halt count riding the distance exchange (the int-valued
    count is exact in the f32 payload).  The halt-count transport-dtype
    trick and the quiescence rule live in ``core/monotone.py``.
    """
    n, n_local = shards.n, shards.n_local
    ell_dst = shards.ell("ell_dst")

    def prepare(g):
        lo = jax.lax.axis_index(AXIS) * n_local
        g = dict(g)
        g["out_weight"] = edge_weight(g["out_src_local"] + lo,
                                      g["out_dst_global"]) \
            * jnp.float32(weight_scale)
        return g

    def init_vals(g, root):
        lo = jax.lax.axis_index(AXIS) * n_local
        owned = (root >= lo) & (root < lo + n_local)
        at_root = owned & (jnp.arange(n_local) == root - lo)
        return jnp.where(at_root, 0.0, F32_INF), at_root

    def relax(g, dist, frontier):
        srcl = g["out_src_local"]
        active = frontier[srcl] & (g["out_dst_global"] < n)
        return localops.scatter_combine(
            g, ell_dst,
            jnp.where(active, dist[srcl] + g["out_weight"], F32_INF),
            "min", identity=F32_INF)

    return monotone_async_program(
        name="sssp", inputs=("root",), init_vals=init_vals, relax=relax,
        outputs=lambda g, dist: (dist,), output_names=("dist",),
        output_is_vertex=(True,), n=n, n_local=n_local, inf=F32_INF,
        local_iters=local_iters, max_rounds=max_rounds, prepare=prepare)
