"""Distributed graph representation: 1-D vertex-partitioned edge shards.

This is the JAX/SPMD adaptation of the paper's
``hpx::partitioned_vector``-backed adjacency structure: vertex v is owned
by partition ``v // n_local`` (block distribution), and every per-vertex
quantity (parents, ranks, frontiers) is a (P, n_local) array sharded over
the 1-D "parts" mesh axis.

Edges are stored twice, both with static SPMD-uniform shapes:
  * out-shard: edges grouped by OWNER OF THE SOURCE (for push traversal):
      out_src_local (P, E) in [0, n_local), out_dst_global (P, E)
  * in-shard: edges grouped by OWNER OF THE DESTINATION (for pull):
      in_src_global (P, E), in_dst_local (P, E)

Padding uses sentinel vertex n (scatters with mode='drop' fall off the
end); every partition is padded to the max per-partition edge count so a
single SPMD program covers all partitions - the static-shape analogue of
HPX's dynamic per-locality segments.

Blocked-ELL edge layout (the local work-bundle layout)
------------------------------------------------------
The COO shards above are the exchange-facing layout; the per-superstep
LOCAL hot loops (PageRank contribution accumulation, BFS pull, MIN/OR
edge combines) additionally get a **blocked-ELL** view, built once here
and consumed through ``core/localops.py``:

  * rows are sorted by degree (per partition) and grouped into blocks of
    :data:`ELL_BLOCK` rows; each block stores a FIXED number of slots
    (the block's max degree, rounded up to :data:`ELL_LANE`), so a block
    is a dense ``(rows, K)`` tile - VPU/Pallas friendly, no serialized
    scatters;
  * consecutive blocks with equal K merge into *buckets*
    (``EllMeta.buckets``), so the traced program is a handful of dense
    gather+reduce ops instead of one per block;
  * unused slots carry a sentinel (``EllMeta.sentinel``); a permutation
    pair (``<name>_perm``: ELL row -> original row, ``<name>_inv``:
    original row -> ELL row) maps results back to vertex order with a
    GATHER, never a scatter.

Four instances are built (``GraphShards.ell_meta``):

  ``ell_in``   rows = local vertices, slots = global in-neighbor ids
               (pull: PageRank SpMV, BFS frontier test); sentinel n.
  ``ell_out``  rows = local vertices, slots = out-edge POSITIONS into
               the (E,) out-shard arrays (per-source combine); sentinel E.
  ``ell_dst``  rows = ALL n global vertices, slots = out-edge positions
               grouped by destination (push-combine into a length-n
               accumulator without scatters); sentinel E.
  ``ell_src``  rows = ALL n global vertices, slots = in-edge positions
               grouped by source (reverse-direction combine); sentinel E.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

ELL_BLOCK = 128   # rows per ELL block (n and n_local are multiples of 128)
ELL_LANE = 8      # block widths round up to this many slots


@dataclass(frozen=True)
class EllMeta:
    """Static (host-side) description of one blocked-ELL structure.

    ``buckets`` is a tuple of ``(rows, width)`` runs in ELL row order
    (rows are multiples of :data:`ELL_BLOCK`, widths non-increasing,
    possibly ending in a ``(rows, 0)`` run for edgeless rows); ``slots``
    is the flat slot count ``sum(rows * width)``.  ``device_suffixes``
    names which per-partition arrays ship to the device
    (``f"{name}_{suffix}"`` keys in the graph dict).
    """

    name: str
    n_rows: int
    buckets: tuple[tuple[int, int], ...]
    slots: int
    sentinel: int
    device_suffixes: tuple[str, ...] = ("idx", "inv")


def _round_lane(w: np.ndarray) -> np.ndarray:
    """Round widths up to ELL_LANE multiples (0 stays 0)."""
    return ((w + ELL_LANE - 1) // ELL_LANE) * ELL_LANE


def _run_length(widths: np.ndarray) -> tuple[tuple[int, int], ...]:
    """Merge consecutive equal-width blocks into (rows, width) buckets."""
    buckets = []
    for w in widths:
        if buckets and buckets[-1][1] == int(w):
            buckets[-1][0] += ELL_BLOCK
        else:
            buckets.append([ELL_BLOCK, int(w)])
    return tuple((r, w) for r, w in buckets)


def _ell_row_base(buckets) -> tuple[np.ndarray, np.ndarray]:
    """Per-ELL-row (slot offset, width) arrays from the bucket runs."""
    n_rows = sum(r for r, _ in buckets)
    base = np.zeros(n_rows, np.int64)
    width = np.zeros(n_rows, np.int64)
    off = 0
    r0 = 0
    for rows, k in buckets:
        base[r0:r0 + rows] = off + np.arange(rows) * k
        width[r0:r0 + rows] = k
        off += rows * k
        r0 += rows
    return base, width


def ell_row_layout(buckets) -> tuple[np.ndarray, np.ndarray]:
    """Public per-row (slot base, width) decomposition of the bucket
    runs — the free-slot capacity table of the dynamic-mutation path:
    a row holds ``width[q] - occupancy`` more entries before its bucket
    overflows (lane rounding + cross-partition width maxing ARE the
    free-slot pool)."""
    return _ell_row_base(buckets)


def ell_slot_rows(buckets) -> np.ndarray:
    """(slots,) ELL row of every flat slot position (host-side mutation
    bookkeeping: maps a patched slot back to the row whose occupancy it
    changes)."""
    rows = []
    r0 = 0
    for r, k in buckets:
        if k:
            rows.append(r0 + np.repeat(np.arange(r, dtype=np.int64), k))
        r0 += r
    if not rows:
        return np.zeros(0, np.int64)
    return np.concatenate(rows)


def ell_occupancy(meta: EllMeta, idx: np.ndarray) -> np.ndarray:
    """(P, n_rows) occupied-slot counts of a (P, slots) idx array.

    ``build_ell`` packs each row's entries contiguously from its slot
    base, and the mutation path preserves that invariant (inserts fill
    at ``base + occ``, deletes compact the tail into the hole), so the
    count doubles as the next free slot offset."""
    parts = idx.shape[0]
    occ = np.zeros((parts, meta.n_rows), np.int64)
    if meta.slots == 0:
        return occ
    s2r = ell_slot_rows(meta.buckets)
    for p in range(parts):
        filled = idx[p, :meta.slots] != meta.sentinel
        occ[p] = np.bincount(s2r[filled], minlength=meta.n_rows)
    return occ


def make_scatter_patch(mesh):
    """Build the jitted in-place slot patcher for (P, S) graph arrays.

    ``patch(arr, slots, vals)`` writes ``vals[p, i]`` at flat position
    ``slots[p, i]`` of partition p's row — slot lists are padded to a
    shared length with -1, which ``mode="drop"`` discards, so batch
    sizes quantize to a few trace shapes.  The update is FUNCTIONAL on
    purpose (no donation): launches already in flight keep reading the
    pre-mutation buffers — that copy-on-write is the snapshot-epoch
    isolation guarantee — while only the small patch lists ever cross
    host->device (never the full shards)."""
    from repro.core.compat import shard_map

    def _patch(arr, slots, vals):
        return arr[0].at[slots[0]].set(vals[0], mode="drop")[None]

    pspec = jax.sharding.PartitionSpec("parts", None)
    return jax.jit(shard_map(
        _patch, mesh=mesh, in_specs=(pspec, pspec, pspec),
        out_specs=pspec, check_vma=False))


def build_ell(name: str, row_ids: np.ndarray, values: np.ndarray,
              n_rows: int, sentinel: int,
              device_suffixes=("idx", "inv")) -> tuple[EllMeta, dict]:
    """Build one blocked-ELL structure from (P, E) host arrays.

    ``row_ids[p, e]`` is the row of entry e in partition p (or -1 for
    padding/invalid entries, which are skipped); ``values[p, e]`` is
    what the slot stores (a neighbor id or an edge position).  Returns
    ``(meta, arrays)`` with ``arrays`` holding ``{name}_idx`` (P, slots)
    int32, ``{name}_inv`` / ``{name}_perm`` (P, n_rows) int32.  Rows are
    degree-sorted per partition; bucket widths are maxed across
    partitions so ONE SPMD program covers all of them.
    """
    assert n_rows % ELL_BLOCK == 0, (name, n_rows)
    parts = row_ids.shape[0]
    n_blocks = n_rows // ELL_BLOCK

    counts = np.zeros((parts, n_rows), np.int64)
    perms = np.zeros((parts, n_rows), np.int64)
    for p in range(parts):
        valid = row_ids[p] >= 0
        counts[p] = np.bincount(row_ids[p][valid].astype(np.int64),
                                minlength=n_rows)
        perms[p] = np.argsort(-counts[p], kind="stable")

    # SPMD-uniform block widths: max over partitions, rounded to lanes.
    widths_pp = np.take_along_axis(counts, perms, axis=1) \
        .reshape(parts, n_blocks, ELL_BLOCK).max(axis=2)
    widths = _round_lane(widths_pp.max(axis=0))
    buckets = _run_length(widths)
    row_base, row_width = _ell_row_base(buckets)
    slots = int(sum(r * k for r, k in buckets))

    idx = np.full((parts, max(slots, 1)), sentinel, np.int64)
    inv = np.zeros((parts, n_rows), np.int64)
    for p in range(parts):
        inv[p, perms[p]] = np.arange(n_rows)
        valid = row_ids[p] >= 0
        rows_v = row_ids[p][valid].astype(np.int64)
        vals_v = values[p][valid].astype(np.int64)
        order = np.argsort(rows_v, kind="stable")
        rows_s, vals_s = rows_v[order], vals_v[order]
        first = np.concatenate([[0], np.cumsum(counts[p])[:-1]])
        rank = np.arange(rows_s.size) - first[rows_s]
        q = inv[p, rows_s]                       # ELL row of each entry
        assert (rank < row_width[q]).all(), name
        idx[p, row_base[q] + rank] = vals_s

    meta = EllMeta(name=name, n_rows=n_rows, buckets=buckets, slots=slots,
                   sentinel=sentinel,
                   device_suffixes=tuple(device_suffixes))
    arrays = {
        f"{name}_idx": idx[:, :max(slots, 1)].astype(np.int32),
        f"{name}_inv": inv.astype(np.int32),
    }
    if "perm" in device_suffixes:
        # only materialized when it ships (frontier_pull's row gather);
        # for the (P, n)-row structures an unused perm would be GBs at
        # paper scale
        arrays[f"{name}_perm"] = perms.astype(np.int32)
    return meta, arrays


def ell_entries(meta: EllMeta, idx_row: np.ndarray,
                inv_row: np.ndarray) -> list[tuple[int, int]]:
    """Decode ONE partition's ELL back into (row, value) pairs (host-side
    test helper: the blocked layout must round-trip the edge multiset)."""
    perm = np.empty(meta.n_rows, np.int64)
    perm[inv_row] = np.arange(meta.n_rows)
    pairs = []
    off = 0
    r0 = 0
    for rows, k in meta.buckets:
        if k:
            blk = idx_row[off:off + rows * k].reshape(rows, k)
            ell_rows, slots_k = np.nonzero(blk != meta.sentinel)
            for er, sk in zip(ell_rows, slots_k):
                pairs.append((int(perm[r0 + er]), int(blk[er, sk])))
        off += rows * k
        r0 += rows
    return pairs


@dataclass
class GraphShards:
    n: int                      # padded global vertex count (multiple of P)
    n_orig: int                 # original vertex count
    parts: int
    n_local: int
    e_max: int                  # per-partition padded edge count
    # numpy (host) arrays with leading partition dim:
    out_src_local: np.ndarray   # (P, E) int32
    out_dst_global: np.ndarray  # (P, E) int32, sentinel n for padding
    in_src_global: np.ndarray   # (P, E) int32, sentinel n for padding
    in_dst_local: np.ndarray    # (P, E) int32
    out_degree: np.ndarray      # (P, n_local) int32
    in_degree: np.ndarray       # (P, n_local) int32
    # blocked-ELL view (see module docstring); built by partition_graph,
    # shape-only under abstract_graph
    ell_meta: dict = field(default_factory=dict)     # name -> EllMeta
    ell_arrays: dict = field(default_factory=dict)   # key -> np.ndarray

    def ell(self, name: str) -> EllMeta:
        """Meta handle for program factories.  When the blocked-ELL
        layout was not built (``build_ell_layout=False``), returns a
        zero-slot placeholder carrying the row count and sentinel the
        REF path needs — no ELL arrays ship, so every localops call
        traces the COO scatter idiom, as documented."""
        meta = self.ell_meta.get(name)
        if meta is not None:
            return meta
        n_rows = self.n_local if name in ("ell_in", "ell_out") else self.n
        sentinel = self.n if name == "ell_in" else self.e_max
        return EllMeta(name=name, n_rows=n_rows, buckets=((n_rows, 0),),
                       slots=0, sentinel=sentinel, device_suffixes=())

    def _ell_device_keys(self):
        for meta in self.ell_meta.values():
            for suf in meta.device_suffixes:
                yield f"{meta.name}_{suf}", meta, suf

    def layout_signature(self) -> tuple:
        """Hashable fingerprint of the blocked-ELL bucket structure.
        Part of the engine's compile-cache key: a mutation-overflow
        rebuild can reproduce every shard SHAPE while the bucket runs
        (and therefore the traced per-bucket loops) differ, and a stale
        cache hit would read the wrong rows.  Equal signatures trace
        identical programs, so sharing the entry is safe."""
        return tuple(sorted(
            (m.name, m.n_rows, m.buckets, m.slots, m.sentinel)
            for m in self.ell_meta.values()))

    def device_arrays(self, layout: str = "ell"):
        """jnp views (host->device).  ``layout="coo"`` omits the ELL
        arrays: programs then trace the reference scatter path."""
        arrs = {
            "out_src_local": jnp.asarray(self.out_src_local),
            "out_dst_global": jnp.asarray(self.out_dst_global),
            "in_src_global": jnp.asarray(self.in_src_global),
            "in_dst_local": jnp.asarray(self.in_dst_local),
            "out_degree": jnp.asarray(self.out_degree),
            "in_degree": jnp.asarray(self.in_degree),
        }
        if layout == "ell":
            for key, _, _ in self._ell_device_keys():
                arrs[key] = jnp.asarray(self.ell_arrays[key])
        return arrs

    def abstract_arrays(self, layout: str = "ell"):
        """ShapeDtypeStructs for AOT lowering (dry-run: no allocation)."""
        P, E, NL = self.parts, self.e_max, self.n_local
        i32 = jnp.int32
        arrs = {
            "out_src_local": jax.ShapeDtypeStruct((P, E), i32),
            "out_dst_global": jax.ShapeDtypeStruct((P, E), i32),
            "in_src_global": jax.ShapeDtypeStruct((P, E), i32),
            "in_dst_local": jax.ShapeDtypeStruct((P, E), i32),
            "out_degree": jax.ShapeDtypeStruct((P, NL), i32),
            "in_degree": jax.ShapeDtypeStruct((P, NL), i32),
        }
        if layout == "ell":
            for key, meta, suf in self._ell_device_keys():
                shape = (P, max(meta.slots, 1)) if suf == "idx" \
                    else (P, meta.n_rows)
                arrs[key] = jax.ShapeDtypeStruct(shape, i32)
        return arrs


def _group_edges(key: np.ndarray, other: np.ndarray, parts: int,
                 n_local: int, e_max: int, n_sentinel: int, key_local: bool):
    """Group (key, other) pairs by key-owner partition into padded (P, E)."""
    owner = key // n_local
    order = np.argsort(owner, kind="stable")
    key_s, other_s, owner_s = key[order], other[order], owner[order]
    counts = np.bincount(owner_s, minlength=parts)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    k_out = np.full((parts, e_max), n_sentinel, dtype=np.int64)
    o_out = np.full((parts, e_max), n_sentinel, dtype=np.int64)
    for p in range(parts):
        c = counts[p]
        k_out[p, :c] = key_s[starts[p]:starts[p] + c]
        o_out[p, :c] = other_s[starts[p]:starts[p] + c]
    if key_local:
        k_out = np.where(k_out == n_sentinel, 0, k_out - np.arange(parts)[:, None] * n_local)
    return k_out, o_out, counts


def _build_graph_ells(g: "GraphShards") -> None:
    """Attach the four blocked-ELL structures to freshly built shards."""
    n, n_local, e_max = g.n, g.n_local, g.e_max
    pos = np.broadcast_to(np.arange(e_max, dtype=np.int64),
                          (g.parts, e_max))
    out_valid = g.out_dst_global < n
    in_valid = g.in_src_global < n

    specs = [
        # (name, row_ids, values, n_rows, sentinel, suffixes)
        ("ell_in",
         np.where(in_valid, g.in_dst_local, -1), g.in_src_global,
         n_local, n, ("idx", "inv", "perm")),
        ("ell_out",
         np.where(out_valid, g.out_src_local, -1), pos,
         n_local, e_max, ("idx", "inv")),
        ("ell_dst",
         np.where(out_valid, g.out_dst_global, -1), pos,
         n, e_max, ("idx", "inv")),
        ("ell_src",
         np.where(in_valid, g.in_src_global, -1), pos,
         n, e_max, ("idx", "inv")),
    ]
    for name, rows, vals, n_rows, sentinel, sufs in specs:
        meta, arrays = build_ell(name, rows, vals, n_rows, sentinel,
                                 device_suffixes=sufs)
        g.ell_meta[name] = meta
        g.ell_arrays.update(arrays)


def partition_graph(edges: np.ndarray, n_orig: int, parts: int,
                    build_ell_layout: bool = True) -> GraphShards:
    """Build GraphShards from an (E, 2) edge list.

    n is padded so n_local is a multiple of 128 (bit-packing needs 32;
    128 keeps TPU lanes aligned).  Padded vertices have no edges.  The
    blocked-ELL view is built alongside the COO shards unless
    ``build_ell_layout=False`` (then every program traces the COO
    scatter reference path).
    """
    block = parts * 128
    n = ((n_orig + block - 1) // block) * block
    n_local = n // parts
    src, dst = edges[:, 0].astype(np.int64), edges[:, 1].astype(np.int64)

    out_deg = np.bincount(src, minlength=n).astype(np.int32)
    in_deg = np.bincount(dst, minlength=n).astype(np.int32)

    src_owner = src // n_local
    dst_owner = dst // n_local
    e_max_out = int(np.bincount(src_owner, minlength=parts).max())
    e_max_in = int(np.bincount(dst_owner, minlength=parts).max())
    e_max = max(e_max_out, e_max_in, 1)
    # pad to a lane-friendly multiple
    e_max = ((e_max + 127) // 128) * 128

    out_src_local, out_dst_global, _ = _group_edges(
        src, dst, parts, n_local, e_max, n, key_local=True)
    in_dst_local, in_src_global, _ = _group_edges(
        dst, src, parts, n_local, e_max, n, key_local=True)

    g = GraphShards(
        n=n, n_orig=n_orig, parts=parts, n_local=n_local, e_max=e_max,
        out_src_local=out_src_local.astype(np.int32),
        out_dst_global=out_dst_global.astype(np.int32),
        in_src_global=in_src_global.astype(np.int32),
        in_dst_local=in_dst_local.astype(np.int32),
        out_degree=out_deg.reshape(parts, n_local),
        in_degree=in_deg.reshape(parts, n_local),
    )
    if build_ell_layout:
        _build_graph_ells(g)
    return g


def _abstract_ell(name: str, n_rows: int, k: int, nz_rows: int,
                  sentinel: int, suffixes=("idx", "inv")) -> EllMeta:
    """Shape-only EllMeta modelling a degree-bucketed layout: ``nz_rows``
    rows of width ``k`` plus an edgeless tail (the dominant shape of a
    near-uniform degree distribution after bucketing)."""
    nz = min(n_rows, ((nz_rows + ELL_BLOCK - 1) // ELL_BLOCK) * ELL_BLOCK)
    k = int(_round_lane(np.asarray(max(k, 1))))
    buckets = [(nz, k)]
    if n_rows > nz:
        buckets.append((n_rows - nz, 0))
    return EllMeta(name=name, n_rows=n_rows, buckets=tuple(buckets),
                   slots=nz * k, sentinel=sentinel,
                   device_suffixes=tuple(suffixes))


def abstract_graph(n_orig: int, avg_degree: int, parts: int) -> GraphShards:
    """Shape-only GraphShards for the dry-run (no edges materialized).

    e_max models the expected max partition load of an ER graph (~uniform,
    +12% headroom), rounded to 128.  The ELL metas model the bucketed
    layout of the same ER graph: local rows carry ~1.5x the mean degree
    after block-max padding; the global-row structures (ell_dst/ell_src)
    have ~min(E/P, n) populated rows of near-minimal width.
    """
    block = parts * 128
    n = ((n_orig + block - 1) // block) * block
    n_local = n // parts
    e_total = n_orig * avg_degree
    e_max = int(e_total / parts * 1.12)
    e_max = ((e_max + 127) // 128) * 128
    z = np.zeros((1,), np.int32)  # placeholders; only shapes are used
    g = GraphShards(
        n=n, n_orig=n_orig, parts=parts, n_local=n_local, e_max=e_max,
        out_src_local=z, out_dst_global=z, in_src_global=z, in_dst_local=z,
        out_degree=z, in_degree=z)
    k_local = int(avg_degree * 1.5)
    k_global = max(ELL_LANE, int(avg_degree / parts * 2))
    nz_global = min(n, e_max)
    for meta in (
        _abstract_ell("ell_in", n_local, k_local, n_local, n,
                      suffixes=("idx", "inv", "perm")),
        _abstract_ell("ell_out", n_local, k_local, n_local, e_max),
        _abstract_ell("ell_dst", n, k_global, nz_global, e_max),
        _abstract_ell("ell_src", n, k_global, nz_global, e_max),
    ):
        g.ell_meta[meta.name] = meta
    return g
