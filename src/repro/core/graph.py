"""Distributed graph representation: 1-D vertex-partitioned edge shards.

This is the JAX/SPMD adaptation of the paper's
``hpx::partitioned_vector``-backed adjacency structure: vertex v is owned
by partition ``v // n_local`` (block distribution), and every per-vertex
quantity (parents, ranks, frontiers) is a (P, n_local) array sharded over
the 1-D "parts" mesh axis.

Edges are stored twice, both with static SPMD-uniform shapes:
  * out-shard: edges grouped by OWNER OF THE SOURCE (for push traversal):
      out_src_local (P, E) in [0, n_local), out_dst_global (P, E)
  * in-shard: edges grouped by OWNER OF THE DESTINATION (for pull):
      in_src_global (P, E), in_dst_local (P, E)

Padding uses sentinel vertex n (scatters with mode='drop' fall off the
end); every partition is padded to the max per-partition edge count so a
single SPMD program covers all partitions - the static-shape analogue of
HPX's dynamic per-locality segments.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp


@dataclass
class GraphShards:
    n: int                      # padded global vertex count (multiple of P)
    n_orig: int                 # original vertex count
    parts: int
    n_local: int
    e_max: int                  # per-partition padded edge count
    # numpy (host) arrays with leading partition dim:
    out_src_local: np.ndarray   # (P, E) int32
    out_dst_global: np.ndarray  # (P, E) int32, sentinel n for padding
    in_src_global: np.ndarray   # (P, E) int32, sentinel n for padding
    in_dst_local: np.ndarray    # (P, E) int32
    out_degree: np.ndarray      # (P, n_local) int32
    in_degree: np.ndarray       # (P, n_local) int32

    def device_arrays(self):
        """jnp views (host->device)."""
        return {
            "out_src_local": jnp.asarray(self.out_src_local),
            "out_dst_global": jnp.asarray(self.out_dst_global),
            "in_src_global": jnp.asarray(self.in_src_global),
            "in_dst_local": jnp.asarray(self.in_dst_local),
            "out_degree": jnp.asarray(self.out_degree),
            "in_degree": jnp.asarray(self.in_degree),
        }

    def abstract_arrays(self):
        """ShapeDtypeStructs for AOT lowering (dry-run: no allocation)."""
        P, E, NL = self.parts, self.e_max, self.n_local
        i32 = jnp.int32
        return {
            "out_src_local": jax.ShapeDtypeStruct((P, E), i32),
            "out_dst_global": jax.ShapeDtypeStruct((P, E), i32),
            "in_src_global": jax.ShapeDtypeStruct((P, E), i32),
            "in_dst_local": jax.ShapeDtypeStruct((P, E), i32),
            "out_degree": jax.ShapeDtypeStruct((P, NL), i32),
            "in_degree": jax.ShapeDtypeStruct((P, NL), i32),
        }


def _group_edges(key: np.ndarray, other: np.ndarray, parts: int,
                 n_local: int, e_max: int, n_sentinel: int, key_local: bool):
    """Group (key, other) pairs by key-owner partition into padded (P, E)."""
    owner = key // n_local
    order = np.argsort(owner, kind="stable")
    key_s, other_s, owner_s = key[order], other[order], owner[order]
    counts = np.bincount(owner_s, minlength=parts)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    k_out = np.full((parts, e_max), n_sentinel, dtype=np.int64)
    o_out = np.full((parts, e_max), n_sentinel, dtype=np.int64)
    for p in range(parts):
        c = counts[p]
        k_out[p, :c] = key_s[starts[p]:starts[p] + c]
        o_out[p, :c] = other_s[starts[p]:starts[p] + c]
    if key_local:
        k_out = np.where(k_out == n_sentinel, 0, k_out - np.arange(parts)[:, None] * n_local)
    return k_out, o_out, counts


def partition_graph(edges: np.ndarray, n_orig: int, parts: int) -> GraphShards:
    """Build GraphShards from an (E, 2) edge list.

    n is padded so n_local is a multiple of 128 (bit-packing needs 32;
    128 keeps TPU lanes aligned).  Padded vertices have no edges.
    """
    block = parts * 128
    n = ((n_orig + block - 1) // block) * block
    n_local = n // parts
    src, dst = edges[:, 0].astype(np.int64), edges[:, 1].astype(np.int64)

    out_deg = np.bincount(src, minlength=n).astype(np.int32)
    in_deg = np.bincount(dst, minlength=n).astype(np.int32)

    src_owner = src // n_local
    dst_owner = dst // n_local
    e_max_out = int(np.bincount(src_owner, minlength=parts).max())
    e_max_in = int(np.bincount(dst_owner, minlength=parts).max())
    e_max = max(e_max_out, e_max_in, 1)
    # pad to a lane-friendly multiple
    e_max = ((e_max + 127) // 128) * 128

    out_src_local, out_dst_global, _ = _group_edges(
        src, dst, parts, n_local, e_max, n, key_local=True)
    in_dst_local, in_src_global, _ = _group_edges(
        dst, src, parts, n_local, e_max, n, key_local=True)

    return GraphShards(
        n=n, n_orig=n_orig, parts=parts, n_local=n_local, e_max=e_max,
        out_src_local=out_src_local.astype(np.int32),
        out_dst_global=out_dst_global.astype(np.int32),
        in_src_global=in_src_global.astype(np.int32),
        in_dst_local=in_dst_local.astype(np.int32),
        out_degree=out_deg.reshape(parts, n_local),
        in_degree=in_deg.reshape(parts, n_local),
    )


def abstract_graph(n_orig: int, avg_degree: int, parts: int) -> GraphShards:
    """Shape-only GraphShards for the dry-run (no edges materialized).

    e_max models the expected max partition load of an ER graph (~uniform,
    +12% headroom), rounded to 128.
    """
    block = parts * 128
    n = ((n_orig + block - 1) // block) * block
    n_local = n // parts
    e_total = n_orig * avg_degree
    e_max = int(e_total / parts * 1.12)
    e_max = ((e_max + 127) // 128) * 128
    z = np.zeros((1,), np.int32)  # placeholders; only shapes are used
    return GraphShards(
        n=n, n_orig=n_orig, parts=parts, n_local=n_local, e_max=e_max,
        out_src_local=z, out_dst_global=z, in_src_global=z, in_dst_local=z,
        out_degree=z, in_degree=z)
