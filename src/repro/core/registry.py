"""Algorithm registry: ``(algo, variant) -> SuperstepProgram`` factory
resolution, mirroring ``configs/registry.py`` for model architectures.

Every engine entry point (``GraphEngine.program``, the dry-run, the
launcher, the benchmark harness) enumerates programs from here instead
of hard-coding algorithm names, so adding a workload is ONE registration
plus an algorithm module — no per-layer edits.

Registered pairs: ``bfs/bsp``, ``bfs/fast``, ``bfs/async``,
``pagerank/bsp``, ``pagerank/fast``, ``pagerank/warm``,
``pagerank/async``, ``sssp``, ``sssp/async``, ``cc``,
``cc/incremental``, ``cc/async``, ``triangles``, ``kcore``,
``kcore/incremental``, ``betweenness`` (single-variant algorithms use
the ``"default"`` variant and may be addressed by bare algo name).

Inputs come in KINDS: ``"scalar"`` per-query values (a root vertex,
batchable through the bucket ladder) and ``"vertex_i32"`` /
``"vertex_f32"`` whole vertex fields (the warm seeds of the
incremental variants — one launch each, never vmapped).

Every spec carries an ``exec_mode``: ``"bsp"`` programs run the
barrier-per-round driver, ``"async"`` programs the double-buffered
``run_program_async`` driver (``core/superstep.py``).  Callers that
think in modes rather than variant names resolve through
:func:`mode_variant` (``GraphEngine.program(..., exec_mode="async")``
rides it).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

from repro.core import betweenness as _bc
from repro.core import bfs as _bfs
from repro.core import cc as _cc
from repro.core import incremental as _inc
from repro.core import kcore as _kcore
from repro.core import pagerank as _pr
from repro.core import sssp as _sssp
from repro.core import triangles as _tri
from repro.core.graph import GraphShards
from repro.core.superstep import SuperstepProgram

INPUT_KINDS = ("scalar", "vertex_i32", "vertex_f32")
EXEC_MODES = ("bsp", "async")


@dataclass(frozen=True)
class IncrementalSpec:
    """Dynamic-graph metadata for a warm-seeded program variant.

    ``of`` names the static algorithm this variant refreshes;
    ``seed_output`` is the output field of ``of``'s programs whose
    previous-epoch value seeds this one; ``mutations`` states which
    mutation kinds the WARM seed stays exact under ("insert", "delete",
    or "any").  Crucially this only gates the seed choice, never
    correctness: every incremental program is exact from its COLD seed
    too (``repro.core.incremental.cold_seed``), so an incompatible
    mutation history just costs a full-rate recompute.
    """

    of: str
    seed_output: str
    mutations: str


@dataclass(frozen=True)
class ProgramSpec:
    """One algorithm x variant entry.

    ``make(g, **params)`` builds the SuperstepProgram against a graph's
    shape metadata; ``params`` beyond ``defaults`` are rejected up front
    so typos fail fast rather than silently re-tracing.
    """

    algo: str
    variant: str
    make: Callable[..., SuperstepProgram]
    inputs: tuple[str, ...]              # per-query inputs ("root",) or ()
    defaults: dict = field(default_factory=dict)
    doc: str = ""
    # largest padded vertex count the implementation is sized for, or 0
    # for unbounded.  The launcher skips over-budget programs (e.g. the
    # O(n^2/P) triangle-counting bitmap); the dry-run still lowers them.
    n_budget: int = 0
    # param overrides for batched (batch=B) builds: knobs whose
    # single-query default degenerates under vmap (a per-lane lax.cond
    # runs BOTH branches and selects), e.g. bfs/fast pins
    # direction="pull".  Explicit caller params always win.
    batch_defaults: dict = field(default_factory=dict)
    # one kind per entry of ``inputs``; defaults to all-"scalar" so the
    # pre-existing registrations stay untouched.
    input_kinds: tuple[str, ...] = ()
    # set on warm-seeded dynamic-graph variants (see IncrementalSpec)
    incremental: IncrementalSpec | None = None
    # which superstep driver the built program runs under: "bsp"
    # (barrier per round) or "async" (double-buffered exchange with the
    # halt scalar piggybacked on the data payload)
    exec_mode: str = "bsp"
    # human statement of the per-round invariant the program's guard
    # checks under guard=True runs (the value-detection channel of the
    # fault-tolerance layer); "" means the default NaN/Inf screen
    guard_doc: str = ""

    def __post_init__(self):
        if not self.input_kinds:
            object.__setattr__(self, "input_kinds",
                               ("scalar",) * len(self.inputs))
        if len(self.input_kinds) != len(self.inputs):
            raise ValueError(
                f"{self.algo}/{self.variant}: {len(self.inputs)} inputs "
                f"but {len(self.input_kinds)} input_kinds")
        bad = set(self.input_kinds) - set(INPUT_KINDS)
        if bad:
            raise ValueError(
                f"{self.algo}/{self.variant}: unknown input kinds "
                f"{sorted(bad)}; valid: {INPUT_KINDS}")
        if self.exec_mode not in EXEC_MODES:
            raise ValueError(
                f"{self.algo}/{self.variant}: exec_mode "
                f"{self.exec_mode!r} not in {EXEC_MODES}")

    @property
    def key(self) -> str:
        return (self.algo if self.variant == "default"
                else f"{self.algo}/{self.variant}")

    @property
    def label(self) -> str:
        """Filesystem/record-safe spelling: "bfs_fast", "sssp"."""
        return program_label(self.algo, self.variant)

    def build(self, g: GraphShards, **params) -> SuperstepProgram:
        unknown = set(params) - set(self.defaults)
        if unknown:
            raise TypeError(
                f"{self.key}: unknown params {sorted(unknown)}; "
                f"accepted: {sorted(self.defaults)}")
        merged = {**self.defaults, **params}
        return self.make(g, **merged)


def program_label(algo: str, variant: str) -> str:
    """Canonical "algo_variant" label ("bfs_fast"; bare algo for the
    default-only variant) used in records, artifacts, and result keys."""
    return algo if variant == "default" else f"{algo}_{variant}"


_REGISTRY: dict[tuple[str, str], ProgramSpec] = {}
_DEFAULT_VARIANT: dict[str, str] = {}
_EXPLICIT_DEFAULT: set[str] = set()


def register(spec: ProgramSpec, *, default: bool = False) -> ProgramSpec:
    """Register an (algo, variant) pair.

    The algo's FIRST registered variant becomes its implicit default
    until some variant claims ``default=True`` explicitly; a second
    explicit claim for the same algo is a registration-order bug and
    raises (it used to be silently ignored when the loser registered
    first).
    """
    key = (spec.algo, spec.variant)
    if key in _REGISTRY:
        raise ValueError(f"duplicate program registration: {key}")
    if default and spec.algo in _EXPLICIT_DEFAULT:
        # validate BEFORE mutating: a rejected claim must not leave a
        # half-registered program behind
        raise ValueError(
            f"{spec.algo}: default variant already claimed by "
            f"{_DEFAULT_VARIANT[spec.algo]!r}; cannot also claim "
            f"{spec.variant!r}")
    _REGISTRY[key] = spec
    if default:
        _EXPLICIT_DEFAULT.add(spec.algo)
        _DEFAULT_VARIANT[spec.algo] = spec.variant
    elif spec.algo not in _DEFAULT_VARIANT:
        _DEFAULT_VARIANT[spec.algo] = spec.variant
    return spec


def default_variant(algo: str) -> str:
    """The variant bare-name resolution picks for ``algo``."""
    return _DEFAULT_VARIANT[algo]


def registered_keys() -> list[str]:
    """Human-readable registered program keys: ``["bfs/bsp", "bfs/fast",
    ..., "sssp", ...]`` (default-only variants spell as the bare algo)."""
    return [spec.key for spec in _REGISTRY.values()]


def get_spec(algo: str, variant: str | None = None) -> ProgramSpec:
    """Resolve an (algo, variant) pair; ``"bfs/fast"`` shorthand works.

    Unknown names raise a ``KeyError`` that lists every registered key,
    so a typo at any entry point (engine, launcher, server admission)
    names its valid alternatives instead of failing bare.
    """
    if variant is None and "/" in algo:
        algo, variant = algo.split("/", 1)
    if variant is None:
        if algo not in _DEFAULT_VARIANT:
            raise KeyError(
                f"unknown algorithm {algo!r}; registered programs: "
                f"{', '.join(registered_keys())}")
        variant = _DEFAULT_VARIANT[algo]
    key = (algo, variant)
    if key not in _REGISTRY:
        raise KeyError(
            f"unknown program {algo!r}/{variant!r}; registered programs: "
            f"{', '.join(registered_keys())}")
    return _REGISTRY[key]


def available() -> list[tuple[str, str]]:
    """All registered (algo, variant) pairs, registration order."""
    return list(_REGISTRY)


def variants(algo: str) -> list[str]:
    return [v for (a, v) in _REGISTRY if a == algo]


def async_pairs() -> list[tuple[str, str]]:
    """All registered pairs whose programs run the async driver."""
    return [k for k, spec in _REGISTRY.items() if spec.exec_mode == "async"]


def mode_variant(algo: str, exec_mode: str) -> str | None:
    """The variant bare-``algo`` resolution picks under ``exec_mode``:
    the algo's default variant for ``"bsp"``, its first registered async
    variant for ``"async"`` (``None`` when the algo has no async
    variant — e.g. ``triangles``, whose rotation is barrier-shaped)."""
    if exec_mode not in EXEC_MODES:
        raise ValueError(f"exec_mode {exec_mode!r} not in {EXEC_MODES}")
    if exec_mode == "bsp":
        v = _DEFAULT_VARIANT.get(algo)
        return v if v is not None \
            and _REGISTRY[(algo, v)].exec_mode == "bsp" else None
    for (a, v), spec in _REGISTRY.items():
        if a == algo and spec.exec_mode == "async":
            return v
    return None


# ---------------------------------------------------------------------------
# Built-in programs.  Factories receive the GraphShards for its shape
# and blocked-ELL metadata only — no device arrays are touched at build
# time (the ELL device arrays arrive per-call through the graph dict).
# ---------------------------------------------------------------------------

register(ProgramSpec(
    algo="bfs", variant="bsp",
    make=lambda g, **p: _bfs.bfs_bsp_program(g, **p),
    inputs=("root",), defaults={"max_levels": 64},
    doc="level-synchronous push BFS; full parent-proposal exchange "
        "(the rigid-barrier Boost/PBGL baseline)",
    guard_doc="parents non-negative and element-wise non-increasing; "
              "frontier count >= 0"))

register(ProgramSpec(
    algo="bfs", variant="fast",
    make=lambda g, **p: _bfs.bfs_fast_program(g, **p),
    inputs=("root",),
    defaults={"max_levels": 64, "pull_threshold": 0.02,
              "direction": "adaptive"},
    batch_defaults={"direction": "pull"},
    doc="direction-optimizing BFS with bit-packed frontier exchange "
        "(the HPX-adapted implementation)",
    guard_doc="parents non-negative and element-wise non-increasing; "
              "frontier count >= 0"), default=True)

register(ProgramSpec(
    algo="pagerank", variant="bsp",
    make=lambda g, **p: _pr.pagerank_bsp_program(g, **p),
    inputs=(), defaults={"iters": 50, "tol": 1e-6},
    doc="pull PageRank with full contribution all-gather (ghost "
        "replication baseline)",
    guard_doc="rank non-negative; global mass in ((1-alpha)*0.9, "
              "(n/n_orig)*1.02); residual >= 0"))

register(ProgramSpec(
    algo="pagerank", variant="fast",
    make=lambda g, **p: _pr.pagerank_fast_program(g, **p),
    inputs=(),
    defaults={"iters": 50, "tol": 1e-6, "compress": True,
              "switch_factor": 1e3, "err_every": 5},
    doc="push-aggregate PageRank: fused reduce-scatter + adaptive bf16 "
        "error-feedback compression",
    guard_doc="rank non-negative; global mass in ((1-alpha)*0.9, "
              "(n/n_orig)*1.02); error-feedback residual finite"),
    default=True)

register(ProgramSpec(
    algo="sssp", variant="default",
    make=lambda g, **p: _sssp.sssp_program(g, **p),
    inputs=("root",), defaults={"max_rounds": 64, "weight_scale": 1.0},
    doc="frontier-pruned Bellman-Ford with MIN-combine exchange; "
        "weight_scale uniformly scales the synthesized weights (must "
        "be finite and positive — serve admission rejects the rest)",
    guard_doc="distances non-negative and element-wise non-increasing "
              "(NaN fails both); change count >= 0"), default=True)

register(ProgramSpec(
    algo="cc", variant="default",
    make=lambda g, **p: _cc.cc_program(g, **p),
    inputs=(), defaults={"max_rounds": 64},
    doc="label propagation over both edge directions",
    guard_doc="labels non-negative and element-wise non-increasing; "
              "change count >= 0"), default=True)

register(ProgramSpec(
    algo="triangles", variant="default",
    make=lambda g, **p: _tri.triangles_program(g.n, g.n_local, **p),
    inputs=(), defaults={},
    doc="rotation triangle counting: bit-packed neighbor-set exchange "
        "(ppermute ring, P supersteps), intersection as masked matmul",
    n_budget=1 << 13,
    guard_doc="per-vertex double-counts finite and non-decreasing"),
    default=True)

register(ProgramSpec(
    algo="kcore", variant="default",
    make=lambda g, **p: _kcore.kcore_program(g, **p),
    inputs=(), defaults={"max_rounds": 512},
    doc="iterative peeling (threshold form) with fused degree-decrement "
        "exchange; degeneracy rides as a scalar output",
    guard_doc="live degrees within [0, undirected degree]; core numbers "
              "and threshold non-decreasing; alive count >= 0"),
    default=True)

register(ProgramSpec(
    algo="pagerank", variant="warm",
    make=lambda g, **p: _pr.pagerank_fast_program(g, seeded=True, **p),
    inputs=("rank0",), input_kinds=("vertex_f32",),
    defaults={"iters": 300, "tol": 1e-6, "compress": False,
              "err_every": 1},
    incremental=IncrementalSpec(of="pagerank", seed_output="rank",
                                mutations="any"),
    doc="push-aggregate PageRank warm-restarted from a previous epoch's "
        "rank vector; same fixed point from any seed, so it is exact "
        "after ANY mutation batch — the seed only buys fewer rounds",
    guard_doc="rank non-negative; global mass in ((1-alpha)*0.9, "
              "(n/n_orig)*1.02); error-feedback residual finite"))

register(ProgramSpec(
    algo="cc", variant="incremental",
    make=lambda g, **p: _cc.cc_program(g, seeded=True, **p),
    inputs=("labels0",), input_kinds=("vertex_i32",),
    defaults={"max_rounds": 128},
    incremental=IncrementalSpec(of="cc", seed_output="labels",
                                mutations="insert"),
    doc="min-label propagation warm-started from a previous epoch's "
        "labels: exact after insert-only batches (components only "
        "merge); identity seed = the cold start",
    guard_doc="labels non-negative and element-wise non-increasing; "
              "change count >= 0"))

register(ProgramSpec(
    algo="kcore", variant="incremental",
    make=lambda g, **p: _inc.kcore_incremental_program(g, **p),
    inputs=("core0",), input_kinds=("vertex_i32",),
    defaults={"max_rounds": 2048},
    incremental=IncrementalSpec(of="kcore", seed_output="core",
                                mutations="delete"),
    doc="local support-decrement peeling from a previous epoch's core "
        "numbers: exact from ANY pointwise upper bound, so old cores "
        "are valid after delete-only batches and the degree bound is "
        "the cold start",
    guard_doc="assignment non-negative and element-wise non-increasing; "
              "change count >= 0"))

register(ProgramSpec(
    algo="betweenness", variant="default",
    make=lambda g, **p: _bc.betweenness_program(g, **p),
    inputs=("root",), defaults={"max_levels": 64},
    doc="Brandes single-source dependencies: path-counting forward BFS "
        "then a dependency-accumulation backward sweep (the first "
        "two-phase program; sum over batched sources for centrality)",
    guard_doc="forward: levels adopt-once non-increasing, path counts "
              "finite/non-decreasing; backward: dependencies finite and "
              "non-negative, forward fields bit-frozen"), default=True)

# -- async (double-buffered) variants: stale-tolerant programs on
#    run_program_async, each conformance-gated against the same NumPy
#    oracle as its BSP siblings ------------------------------------------

register(ProgramSpec(
    algo="bfs", variant="async", exec_mode="async",
    make=lambda g, **p: _bfs.bfs_async_program(g, **p),
    inputs=("root",), defaults={"max_levels": 64, "local_iters": 1},
    doc="async BFS: monotone min-combine levels overlap the in-flight "
        "exchange, halt count piggybacked on the level payload (no "
        "separate psum), parents derived post-loop from exact levels",
    guard_doc="monotone values non-negative and element-wise "
              "non-increasing; quiescence counters >= 0"))

register(ProgramSpec(
    algo="pagerank", variant="async", exec_mode="async",
    make=lambda g, **p: _pr.pagerank_async_program(g, **p),
    inputs=(),
    defaults={"iters": 64, "tol": 1e-6, "staleness": 1},
    doc="bounded-staleness push PageRank: fresh own-slice term every "
        "round, remote term refreshed every `staleness` rounds by the "
        "double-buffered reduce-scatter with the residual piggybacked; "
        "remote age provably <= 2*staleness+1 (reported as max_age)",
    guard_doc="rank non-negative; global mass in ((1-alpha)*0.9, "
              "(n/n_orig)*1.05) (staleness transients); remote/ship "
              "terms finite and non-negative; ages >= 0"))

register(ProgramSpec(
    algo="cc", variant="async", exec_mode="async",
    make=lambda g, **p: _cc.cc_async_program(g, **p),
    inputs=(), defaults={"max_rounds": 64, "local_iters": 1},
    doc="async min-label propagation: both edge directions share one "
        "min-accumulator exchange per round; staleness-exact (labels "
        "only decrease under idempotent min-combine)",
    guard_doc="monotone values non-negative and element-wise "
              "non-increasing; quiescence counters >= 0"))

register(ProgramSpec(
    algo="sssp", variant="async", exec_mode="async",
    make=lambda g, **p: _sssp.sssp_async_program(g, **p),
    inputs=("root",),
    defaults={"max_rounds": 64, "local_iters": 1, "weight_scale": 1.0},
    doc="async Bellman-Ford: local closure relaxes own-partition "
        "improvements while the distance exchange is in flight; "
        "staleness-exact under min-combine",
    guard_doc="monotone values non-negative and element-wise "
              "non-increasing; quiescence counters >= 0"))


# ---------------------------------------------------------------------------
# Docs generation: the algorithms table in docs/API.md is this function's
# verbatim output (asserted by tests/test_registry.py), so it can't drift.
# ---------------------------------------------------------------------------

def algorithms_markdown_table() -> str:
    """Markdown table of every registered program, derived from the
    registry AND the built programs (outputs come from the program
    object itself, not a parallel description)."""
    from repro.core.graph import abstract_graph
    g = abstract_graph(256, 8, 1)
    lines = [
        "| program | exec | inputs | params (defaults) | outputs "
        "| description |",
        "| --- | --- | --- | --- | --- | --- |",
    ]
    for algo, variant in available():
        spec = _REGISTRY[(algo, variant)]
        prog = spec.build(g)
        mark = (" *(default)*"
                if _DEFAULT_VARIANT[algo] == variant
                and len(variants(algo)) > 1 else "")
        ins = ", ".join(spec.inputs) or "—"
        params = ", ".join(
            f"{k}={spec.defaults[k]!r}" for k in sorted(spec.defaults)) or "—"
        outs = ", ".join(prog.output_names) + ", rounds"
        lines.append(f"| `{spec.key}`{mark} | {spec.exec_mode} | {ins} "
                     f"| {params} | {outs} | {spec.doc} |")
    return "\n".join(lines)


def guards_markdown_table() -> str:
    """Markdown table of every registered program's fault-guard
    invariant, derived from the registry AND the built programs (the
    guarded column reads the program object's ``guard`` field, not a
    parallel claim) — same drift-test contract as
    ``algorithms_markdown_table``."""
    from repro.core.graph import abstract_graph
    from repro.core.superstep import PhasedProgram
    g = abstract_graph(256, 8, 1)
    lines = [
        "| program | guard | per-round invariant (guard=True) |",
        "| --- | --- | --- |",
    ]
    for algo, variant in available():
        spec = _REGISTRY[(algo, variant)]
        prog = spec.build(g)
        if isinstance(prog, PhasedProgram):
            guarded = all(ph.guard is not None for ph in prog.phases)
        else:
            guarded = prog.guard is not None
        mark = "custom" if guarded else "NaN/Inf screen"
        inv = spec.guard_doc or "float state leaves finite"
        lines.append(f"| `{spec.key}` | {mark} | {inv} |")
    return "\n".join(lines)


def incremental_markdown_table() -> str:
    """Markdown table of the registered incremental (dynamic-graph)
    variants, derived from their IncrementalSpec metadata — same
    drift-test contract as ``algorithms_markdown_table``."""
    lines = [
        "| program | refreshes | seed input | warm seed | exact warm after |",
        "| --- | --- | --- | --- | --- |",
    ]
    for algo, variant in available():
        spec = _REGISTRY[(algo, variant)]
        inc = spec.incremental
        if inc is None:
            continue
        lines.append(
            f"| `{spec.key}` | `{inc.of}` | {spec.inputs[0]} "
            f"({spec.input_kinds[0]}) | previous-epoch `{inc.seed_output}` "
            f"| {inc.mutations} |")
    return "\n".join(lines)
