"""Shared machinery for the monotone async program family.

bfs/async, cc/async and sssp/async are all the SAME algorithm shape:
a value per vertex (level / label / distance) that only ever DECREASES
under an idempotent, commutative MIN-combine.  That algebra is what
makes them stale-safe *exactly*: applying a proposal late, twice, or
out of order can never push a value below its true fixed point nor keep
it above one (every improvement is eventually delivered and min-applied),
so the async run converges to the bit-identical answer the BSP oracle
checks — the "min-combine tolerates staleness" claim, made executable.

:func:`monotone_async_program` builds the
:class:`~repro.core.superstep.AsyncSuperstepProgram` from one
algorithm-specific ``relax`` callback.  Per round:

  ``local``  runs ``local_iters`` relaxation sweeps on already-resident
      data while the previous round's exchange is still in flight (the
      overlap window): own-partition improvements are applied
      IMMEDIATELY (multi-hop progress inside one round — the async
      latency win), remote proposals accumulate into a carried ``(n,)``
      min-accumulator.
  ``fold``  finishes the in-flight handle, min-applies the delivered
      updates, relaxes ONCE from them (so a cross-partition hop still
      costs one round — BSP parity, the local closure only *adds*
      progress), then ships the accumulator through
      ``exchange_min_start`` with the round's change count piggybacked
      as the halt scalar — no separate psum collective anywhere.

Termination: the loop halts when TWO consecutive piggybacked global
change counts are zero.  One zero is not enough — proposals shipped in
a zero-change round may still derive from the round before it — but two
quiescent rounds imply the last shipped accumulator was empty and every
frontier is drained, so the state is a global fixed point.  Both counts
arrive on the data exchange, so ``halt`` is globally uniform and every
partition runs the same trip count (the while-loop requirement).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.partitioned import AXIS, exchange_min_start, \
    exchange_min_finish
from repro.core.superstep import AsyncSuperstepProgram


def _own_slice(vec, n_local: int):
    """This partition's (n_local,) slice of a global (n,) accumulator."""
    lo = jax.lax.axis_index(AXIS) * n_local
    return jax.lax.dynamic_slice_in_dim(vec, lo, n_local)


def monotone_async_program(*, name: str, variant: str = "async",
                           inputs, init_vals, relax, outputs,
                           output_names, output_is_vertex,
                           n: int, n_local: int, inf,
                           local_iters: int = 1, max_rounds: int = 64,
                           prepare=None) -> AsyncSuperstepProgram:
    """Build a monotone min-combine async program.

    ``init_vals(g, *inputs) -> (vals0, frontier0)`` seeds the (n_local,)
    value vector and the changed-vertex mask; ``relax(g, vals, frontier)
    -> (n,)`` proposes min-candidates for ALL vertices from the frontier
    sources (identity ``inf`` elsewhere); ``outputs(g, vals) -> tuple``
    finalizes (it runs outside the loop and may use collectives).
    ``local_iters`` is the closure depth: relaxation sweeps per overlap
    window (>= 1; more sweeps trade local FLOPs for rounds on graphs
    with long intra-partition chains).
    """
    if local_iters < 1:
        raise ValueError(f"local_iters must be >= 1, got {local_iters}")

    def _sweep(g, vals, frontier, acc, cnt):
        """One relaxation sweep: propose from ``frontier``, min-apply the
        own slice now, accumulate the rest for the next ship."""
        prop = relax(g, vals, frontier)
        acc = jnp.minimum(acc, prop)
        new_vals = jnp.minimum(vals, _own_slice(prop, n_local))
        changed = new_vals < vals
        return new_vals, changed, acc, cnt + changed.sum(dtype=jnp.int32)

    def init(g, *ins):
        vals0, frontier0 = init_vals(g, *ins)
        acc0 = jnp.full((n,), inf, vals0.dtype)
        # seed exchange: empty payload, count 1 so halt can't fire before
        # the first real round's count arrives
        handle0 = exchange_min_start(acc0, jnp.ones((), vals0.dtype))
        state0 = (vals0, frontier0, acc0,
                  jnp.int32(1), jnp.int32(1), jnp.int32(0))
        return state0, handle0

    def local(g, state):
        vals, frontier, acc, gprev, gprev2, cnt = state
        for _ in range(local_iters):
            vals, frontier, acc, cnt = _sweep(g, vals, frontier, acc, cnt)
        return vals, frontier, acc, gprev, gprev2, cnt

    def fold(g, state, handle):
        vals, frontier, acc, gprev, _, cnt = state
        mine, total = exchange_min_finish(handle)
        v1 = jnp.minimum(vals, mine)
        recv = v1 < vals
        # relax once from the delivered changes before shipping, so a
        # cross-partition relay costs one round, not two
        v2, own_changed, acc, _ = _sweep(g, v1, recv, acc, jnp.int32(0))
        cnt = cnt + recv.sum(dtype=jnp.int32) \
            + own_changed.sum(dtype=jnp.int32)
        new_handle = exchange_min_start(acc, cnt.astype(vals.dtype))
        state = (v2, frontier | own_changed,
                 jnp.full((n,), inf, vals.dtype),
                 total.astype(jnp.int32), gprev, jnp.int32(0))
        return state, new_handle

    def halt(state):
        return (state[3] <= 0) & (state[4] <= 0)

    def guard(g, prev, state):
        """Monotone invariants: values only ever DECREASE and stay in
        ``[0, inf]`` (min-combine applies delivered payloads unfiltered,
        so NaN / negative-sentinel corruption lands in ``vals`` and
        fails a comparison here), and the carried change counts are
        non-negative."""
        vals, pvals = state[0], prev[0]
        return (vals >= 0).all() & (vals <= pvals).all() \
            & (state[3] >= 0) & (state[4] >= 0) & (state[5] >= 0)

    kwargs = {} if prepare is None else {"prepare": prepare}
    return AsyncSuperstepProgram(
        name=name, variant=variant, inputs=tuple(inputs),
        init=init, local=local, fold=fold, halt=halt,
        outputs=lambda g, state: outputs(g, state[0]),
        output_names=tuple(output_names),
        output_is_vertex=tuple(output_is_vertex),
        max_rounds=max_rounds, guard=guard,
        probe_names=("changed",), probe=lambda state: (state[3],),
        **kwargs)
