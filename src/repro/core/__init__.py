"""The paper's primary contribution: a distributed graph-analytics engine
(NWGraph+HPX adapted to JAX SPMD).

The public surface is the superstep-program API: algorithms are
``SuperstepProgram`` definitions (core/superstep.py) registered in
core/registry.py and compiled/cached through ``GraphEngine.program``.
See core/bfs.py, core/pagerank.py for the algorithm-level adaptation
notes and DESIGN.md for the system view."""

from repro.core import localops, registry
from repro.core.api import CompiledProgram, GraphEngine
from repro.core.faults import FaultEvent, FaultSchedule
from repro.core.graph import EllMeta, GraphShards, abstract_graph, \
    partition_graph
from repro.core.recovery import Checkpoint, CheckpointRunner, \
    RecoveryError, RunReport
from repro.core.superstep import SuperstepProgram, run_program

__all__ = [
    "Checkpoint", "CheckpointRunner", "CompiledProgram", "EllMeta",
    "FaultEvent", "FaultSchedule", "GraphEngine", "GraphShards",
    "RecoveryError", "RunReport", "SuperstepProgram", "abstract_graph",
    "localops", "partition_graph", "registry", "run_program",
]
