"""The paper's primary contribution: a distributed graph-analytics engine
(NWGraph+HPX adapted to JAX SPMD).  See core/bfs.py, core/pagerank.py for
the algorithm-level adaptation notes and DESIGN.md for the system view."""

from repro.core.api import GraphEngine
from repro.core.graph import GraphShards, abstract_graph, partition_graph

__all__ = ["GraphEngine", "GraphShards", "abstract_graph", "partition_graph"]
