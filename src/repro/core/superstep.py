"""Superstep programs: the engine's declarative algorithm abstraction.

"The Anatomy of Large-Scale Distributed Graph Algorithms" (Firoz et al.)
decomposes distributed graph algorithms into reusable runtime pieces —
a work bundle (what one superstep does), an ordering/termination policy,
and a synchronization strategy.  This module makes that decomposition
the public API: an algorithm is a :class:`SuperstepProgram` (pure
``init / step / halt / outputs`` callables over per-partition graph
arrays + the ``partitioned.py`` exchange primitives), and ONE shared
driver (:func:`run_program`) supplies the loop machinery every
hand-rolled driver used to duplicate:

  * early-exit ``lax.while_loop`` when termination is data-dependent
    (the production path),
  * fixed-trip ``lax.scan`` when ``static_iters > 0`` (the dry-run /
    roofline path: static trip counts make the cost model exact; steps
    past convergence are natural no-ops by construction), and
  * round accounting (the returned round count is driver state, not
    program state).

Programs never call collectives for loop control themselves — ``halt``
reads a count/error scalar the step already reduced — so swapping the
driver (BSP scan vs early-exit, single- vs multi-source) never touches
algorithm code.  All callables run INSIDE ``shard_map`` over the
1-D "parts" axis; ``core/api.py`` owns the jit/shard_map wrapping and
the compile cache.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import faults
from repro.core.compat import axis_size
from repro.core.partitioned import AXIS, psum_scalar
from repro.obs import telemetry as obs_tel


@dataclass(frozen=True)
class SuperstepProgram:
    """A distributed graph algorithm as data.

    The per-shard callables (all traced inside ``shard_map``):

      prepare(g) -> g        optional: derive loop-invariant edge data
                             (e.g. SSSP weights) once, outside the loop
      init(g, *inputs) -> state
                             build the initial state pytree from the
                             per-query inputs (e.g. a root vertex)
      step(g, state) -> state
                             ONE superstep: local compute + exchange;
                             must fold any convergence scalar (frontier
                             count, residual error) into the state
      halt(state) -> bool    True when converged (driver also stops at
                             ``max_rounds``); ignored under static_iters
      outputs(state) -> tuple
                             final per-shard outputs, aligned with
                             ``output_names`` / ``output_is_vertex``
      guard(g, prev, state) -> bool
                             optional per-round invariant check (local
                             per-shard verdict; the driver makes it
                             uniform): True = the round's state is
                             consistent with the algorithm's invariants
                             (monotone non-increase, mass conservation,
                             non-negativity).  ``None`` falls back to
                             the NaN/Inf screen over float state leaves.
                             Compiled in only under ``guard=True`` runs.
      probe(state) -> tuple  optional telemetry probes, aligned with
                             ``probe_names``: globally-uniform scalars
                             (frontier size, residual — values the step
                             already reduced) recorded per round into
                             the telemetry series.  Compiled in only
                             under ``telemetry=True`` runs.
    """

    name: str
    variant: str
    inputs: tuple[str, ...]           # per-query input names, e.g. ("root",)
    init: Callable[..., Any]
    step: Callable[[dict, Any], Any]
    halt: Callable[[Any], Any]
    outputs: Callable[[Any], tuple]
    output_names: tuple[str, ...]
    output_is_vertex: tuple[bool, ...]  # True: (n_local,) field -> sharded
    max_rounds: int = 64
    prepare: Callable[[dict], dict] = field(default=lambda g: g)
    guard: Callable[[dict, Any, Any], Any] | None = None
    probe_names: tuple[str, ...] = ()
    probe: Callable[[Any], tuple] | None = None

    @property
    def key(self) -> str:
        return f"{self.name}/{self.variant}"


# Documented rounds slack for async vs BSP runs of the SAME monotone
# program: fold() relaxes delivered updates before re-shipping, so a
# cross-partition hop still costs one round (BSP parity) and the local
# closure only adds progress — the overhead is pipeline fill plus the
# two-quiescent-rounds halt rule.  tests/test_async.py and the
# benchmarks/compare.py rounds gate both read these.
ASYNC_ROUNDS_SLACK_FACTOR = 1.5
ASYNC_ROUNDS_SLACK_CONST = 4


@dataclass(frozen=True)
class AsyncSuperstepProgram:
    """A stale-tolerant algorithm for the double-buffered driver.

    Where :class:`SuperstepProgram.step` blocks on a full exchange every
    round (the BSP barrier), an async program splits one round into:

      init(g, *inputs) -> (state, handle)
                             seed the state AND issue the first exchange
                             (``partitioned.exchange_*_start``) so round
                             one has an in-flight handle to finish
      local(g, state) -> state
                             the overlap window: compute on already-
                             resident data only — NO collectives here;
                             this work hides the in-flight exchange
      fold(g, state, handle) -> (state, handle)
                             finish the handle (pure local reduction),
                             apply the delivered updates, and start the
                             next exchange
      halt(state) -> bool    must read only globally-uniform values (the
                             piggybacked scalar a finish returned) — all
                             partitions run the same trip count
      outputs(g, state) -> tuple
                             post-loop finalization; unlike the BSP form
                             it receives ``g`` (and MAY use collectives:
                             it runs outside the loop, uniformly)

    The driver calls ``local`` then ``fold`` each round, so the exchange
    started in round k's ``fold`` crosses the loop carry and is consumed
    after round k+1's ``local`` — local compute and wire movement
    overlap, which is the HPX insight the source paper's follow-up names
    as the fix for latency-bound BSP scaling.
    """

    name: str
    variant: str
    inputs: tuple[str, ...]
    init: Callable[..., Any]
    local: Callable[[dict, Any], Any]
    fold: Callable[[dict, Any, Any], Any]
    halt: Callable[[Any], Any]
    outputs: Callable[[dict, Any], tuple]
    output_names: tuple[str, ...]
    output_is_vertex: tuple[bool, ...]
    max_rounds: int = 64
    prepare: Callable[[dict], dict] = field(default=lambda g: g)
    guard: Callable[[dict, Any, Any], Any] | None = None
    probe_names: tuple[str, ...] = ()
    probe: Callable[[Any], tuple] | None = None

    @property
    def key(self) -> str:
        return f"{self.name}/{self.variant}"


# --------------------------------------------------------------------------
# Telemetry series.  Under ``telemetry=True`` the while-loop drivers
# append a zero-initialised ``(max_rounds, 2 + len(probe_names))`` f32
# buffer to the carry and write one row per executed round:
#
#     [done, halted, *probes]
#
# ``done`` = 1.0 marks rows a round actually wrote — round counts are
# only known on device, so the host (obs.telemetry.PhaseSeries) trims on
# this column; it is also what lets a PhasedProgram concatenate phase
# buffers (zero gaps between phases are simply invalid rows).  ``halted``
# is the halt predicate evaluated on the round's resulting state;
# ``probes`` are the program's declared globally-uniform scalars.  The
# telemetry-off path carries ``()`` in the series slot, which adds no
# leaves to the traced loop — outputs stay bit-identical.
# --------------------------------------------------------------------------


def _series_init(prog):
    return jnp.zeros((prog.max_rounds, 2 + len(prog.probe_names)),
                     jnp.float32)


def _series_write(prog, series, r, state):
    halted = jnp.asarray(prog.halt(state)).astype(jnp.float32).reshape(())
    probes = tuple(prog.probe(state)) if prog.probe is not None else ()
    if len(probes) != len(prog.probe_names):
        raise ValueError(
            f"{prog.key}: probe() returned {len(probes)} values for "
            f"probe_names {prog.probe_names!r}")
    row = jnp.stack(
        [jnp.float32(1.0), halted]
        + [jnp.asarray(p).astype(jnp.float32).reshape(()) for p in probes])
    return series.at[r].set(row)


# --------------------------------------------------------------------------
# Guard machinery.  A guard run folds THREE signals into one per-round
# uniform ``ok`` scalar: the program's invariant verdict (or the default
# NaN/Inf screen), the transport-stamp violations drained from the fault
# taps, and the previous round's ok (sticky — once bad, stays bad so the
# loop exits and the caller can roll back).
# --------------------------------------------------------------------------


def finite_state(state):
    """Default guard: every float leaf of the state is finite."""
    ok = jnp.bool_(True)
    for leaf in jax.tree_util.tree_leaves(state):
        if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating):
            ok = ok & jnp.isfinite(leaf).all()
    return ok


def _round_ok(prog, g, prev, state):
    """Uniform per-round verdict: invariant guard AND transport stamps."""
    gfn = prog.guard if prog.guard is not None \
        else (lambda g_, p_, s_: finite_state(s_))
    local = jnp.asarray(gfn(g, prev, state), bool)
    ok = psum_scalar(local.astype(jnp.int32)) == axis_size(AXIS)
    viol = faults.stamp_violation()
    if viol is not None:
        ok = ok & jnp.logical_not(viol)
    return ok


def run_program_async(prog: AsyncSuperstepProgram, g: dict, *inputs,
                      static_iters: int = 0, guard: bool = False,
                      telemetry: bool = False):
    """The double-buffered driver: same ``(outputs, rounds)`` contract
    as :func:`run_program`, same while/scan split, but each round is
    ``local`` (overlap window) then ``fold`` (finish + restart the
    exchange), with the in-flight handle carried across iterations.

    Fault-round addressing: the exchange issued by ``init`` is round 0;
    the one started in body iteration ``r`` is round ``r + 1`` (the
    (k+1)-th exchange started is round k+1).  With ``guard=True`` the
    return gains ``ok``; with ``telemetry=True`` it gains the series
    buffer (always LAST): ``(outputs, rounds[, ok][, series])``.
    """
    if telemetry and static_iters:
        raise ValueError("telemetry requires the while-loop driver "
                         "(static_iters=0)")
    g = prog.prepare(g)
    obs_tel.phase("init")
    faults.set_round(jnp.int32(0))
    state0, handle0 = prog.init(g, *inputs)

    if static_iters:
        def sbody(carry, _):
            state, handle, r = carry
            faults.set_round(r + 1)
            state, handle = prog.fold(g, prog.local(g, state), handle)
            return (state, handle, r + 1), None

        obs_tel.phase("round")
        (state, _, rounds), _ = jax.lax.scan(
            sbody, (state0, handle0, jnp.int32(0)), None,
            length=static_iters)
        faults.set_round(jnp.int32(-1))   # outputs are not addressable
        obs_tel.phase("outputs")
        return prog.outputs(g, state), rounds

    ok0 = _round_ok(prog, g, state0, state0) if guard else ()
    series0 = _series_init(prog) if telemetry else ()

    def cond(carry):
        state, _, r, ok, _series = carry
        live = jnp.logical_not(prog.halt(state)) & (r < prog.max_rounds)
        return (ok & live) if guard else live

    def body(carry):
        state, handle, r, ok, series = carry
        faults.set_round(r + 1)
        prev = state
        state, handle = prog.fold(g, prog.local(g, state), handle)
        if guard:
            ok = ok & _round_ok(prog, g, prev, state)
        if telemetry:
            series = _series_write(prog, series, r, state)
        return state, handle, r + 1, ok, series

    obs_tel.phase("round")
    state, _, rounds, ok, series = jax.lax.while_loop(
        cond, body, (state0, handle0, jnp.int32(0), ok0, series0))
    faults.set_round(jnp.int32(-1))
    obs_tel.phase("outputs")
    res = (prog.outputs(g, state), rounds)
    if guard:
        res += (ok,)
    if telemetry:
        res += (series,)
    return res


@dataclass(frozen=True)
class PhasedProgram:
    """A multi-phase algorithm: a tuple of :class:`SuperstepProgram`s run
    back to back, each phase's ``outputs`` threaded into the next phase's
    ``init`` (after the per-query ``inputs`` of phase 0).

    Brandes betweenness is the motivating case: a forward
    shortest-path-counting BFS, then a dependency-accumulation backward
    sweep seeded with the forward (dist, sigma) fields.  The driver is
    still :func:`run_program` — it dispatches to :func:`run_phases` — so
    every engine layer (compile cache, batching, dry-run static_iters)
    works on phased programs with no extra plumbing.

    ``output_names`` / ``output_is_vertex`` describe the LAST phase's
    outputs, which are the program's outputs.
    """

    name: str
    variant: str
    inputs: tuple[str, ...]
    phases: tuple[SuperstepProgram, ...]
    output_names: tuple[str, ...]
    output_is_vertex: tuple[bool, ...]

    @property
    def key(self) -> str:
        return f"{self.name}/{self.variant}"

    @property
    def probe_names(self) -> tuple[str, ...]:
        """Telemetry probes of a phased program: the phases share ONE
        series buffer layout, so every phase must declare the same
        probe names (phase 0's are canonical)."""
        names = self.phases[0].probe_names
        for ph in self.phases[1:]:
            if ph.probe_names != names:
                raise ValueError(
                    f"{self.key}: phases declare different probe_names "
                    f"({names!r} vs {ph.probe_names!r}); telemetry "
                    "needs one row layout")
        return names


def run_phases(prog: PhasedProgram, g: dict, *inputs,
               static_iters: int = 0, guard: bool = False,
               telemetry: bool = False):
    """Chain the phases of a :class:`PhasedProgram`: phase ``i+1`` is
    initialized with phase ``i``'s outputs.  Returns the last phase's
    outputs and the TOTAL round count (each phase runs ``static_iters``
    supersteps on the scan path, so the total is ``len(phases) *
    static_iters`` there).  Fault rounds address each phase's own
    counter (a round-2 event fires in EVERY phase's round 2).  Under
    ``guard=True`` the per-phase ok scalars AND together.  Under
    ``telemetry=True`` the per-phase series buffers concatenate (valid
    rows stay marked by the ``done`` column; the host trims)."""
    if telemetry:
        prog.probe_names        # raises if phases disagree on layout
    chained = inputs
    total = jnp.int32(0)
    ok = jnp.bool_(True)
    series_parts = []
    for phase in prog.phases:
        res = run_program(phase, g, *chained, static_iters=static_iters,
                          guard=guard, telemetry=telemetry)
        if telemetry:
            series_parts.append(res[-1])
            res = res[:-1]
        if guard:
            chained, rounds, phase_ok = res
            ok = ok & phase_ok
        else:
            chained, rounds = res
        total = total + rounds
    out = (chained, total) + ((ok,) if guard else ())
    if telemetry:
        out += (jnp.concatenate(series_parts, axis=0),)
    return out


def run_program(prog, g: dict, *inputs, static_iters: int = 0,
                guard: bool = False, telemetry: bool = False):
    """The ONE shared superstep driver (call inside shard_map).

    Returns ``(outputs_tuple, rounds)`` where ``rounds`` is the number of
    supersteps executed (== ``static_iters`` on the scan path).  A
    :class:`PhasedProgram` dispatches to :func:`run_phases`.

    ``guard=True`` compiles the per-round invariant check in: the while
    cond gains a sticky uniform ``ok`` scalar (invariant guard AND
    fault-transport stamps), the loop exits on the FIRST violated round,
    and the return becomes ``(outputs_tuple, rounds, ok)``.  Not
    supported on the ``static_iters`` scan path (the dry-run costs a
    clean loop).

    ``telemetry=True`` compiles the per-round series write in (see the
    series block above) and appends the ``(max_rounds, 2 + K)`` buffer
    as the LAST return element.  Composes with ``guard``; like it,
    incompatible with ``static_iters``.  The off path carries ``()`` in
    the series slot — zero extra leaves, bit-identical outputs.
    """
    if guard and static_iters:
        raise ValueError("guard=True is incompatible with static_iters")
    if telemetry and static_iters:
        raise ValueError("telemetry requires the while-loop driver "
                         "(static_iters=0)")
    if isinstance(prog, PhasedProgram):
        return run_phases(prog, g, *inputs, static_iters=static_iters,
                          guard=guard, telemetry=telemetry)
    if isinstance(prog, AsyncSuperstepProgram):
        return run_program_async(prog, g, *inputs,
                                 static_iters=static_iters, guard=guard,
                                 telemetry=telemetry)
    g = prog.prepare(g)
    obs_tel.phase("init")
    faults.set_round(jnp.int32(0))
    state0 = prog.init(g, *inputs)

    if static_iters:
        def sbody(carry, _):
            state, r = carry
            faults.set_round(r)
            return (prog.step(g, state), r + 1), None

        obs_tel.phase("round")
        (state, rounds), _ = jax.lax.scan(
            sbody, (state0, jnp.int32(0)), None, length=static_iters)
        faults.set_round(jnp.int32(-1))   # outputs are not addressable
        obs_tel.phase("outputs")
        return prog.outputs(state), rounds

    ok0 = _round_ok(prog, g, state0, state0) if guard else ()
    series0 = _series_init(prog) if telemetry else ()

    def cond(carry):
        state, r, ok, _series = carry
        live = jnp.logical_not(prog.halt(state)) & (r < prog.max_rounds)
        return (ok & live) if guard else live

    def body(carry):
        state, r, ok, series = carry
        faults.set_round(r)
        new = prog.step(g, state)
        if guard:
            ok = ok & _round_ok(prog, g, state, new)
        if telemetry:
            series = _series_write(prog, series, r, new)
        return new, r + 1, ok, series

    obs_tel.phase("round")
    state, rounds, ok, series = jax.lax.while_loop(
        cond, body, (state0, jnp.int32(0), ok0, series0))
    faults.set_round(jnp.int32(-1))
    obs_tel.phase("outputs")
    res = (prog.outputs(state), rounds)
    if guard:
        res += (ok,)
    if telemetry:
        res += (series,)
    return res


def run_program_batched(prog, g: dict, *batched_inputs,
                        static_iters: int = 0):
    """Multi-source driver: vmap :func:`run_program` over (B,)-batched
    query inputs (e.g. BFS/SSSP roots), amortizing one graph residency
    across B traversals — the serve-many-queries path.

    Vertex outputs gain a leading (B,) axis; ``rounds`` becomes (B,).
    Works for :class:`PhasedProgram` too (batched betweenness: B forward
    sweeps then B backward sweeps, vmapped as one phased traversal).
    """
    if not isinstance(prog, PhasedProgram):
        # hoist the loop-invariant prepare out of the vmap so per-query
        # traversals share one derived-edge-data computation
        g = prog.prepare(g)
        prog = dataclasses.replace(prog, prepare=lambda garr: garr)

    def one(*ins):
        outs, rounds = run_program(prog, g, *ins,
                                   static_iters=static_iters)
        return (*outs, rounds)

    res = jax.vmap(one)(*batched_inputs)
    return res[:-1], res[-1]


# --------------------------------------------------------------------------
# Chunked execution: the checkpointing substrate.
#
# ``core/recovery.py`` drives a program as a sequence of guarded CHUNKS of
# at most k rounds, snapshotting the carry to host between chunks.  The
# carry is ``(state, handle, rounds, ok)`` — handle is ``()`` for BSP
# programs, the in-flight exchange for async ones (it is plain array
# data, so it checkpoints and restores like any state leaf).  Chunking
# never changes the traced per-round computation, so a chunked run is
# bit-identical to the guarded un-chunked driver, which is bit-identical
# to the plain driver on fault-free rounds.
# --------------------------------------------------------------------------


def init_carry(prog, g: dict, *inputs, telemetry: bool = False):
    """Build the initial checkpointable carry ``(state, handle, rounds,
    ok)`` — prepare + init + the round-0 verdict (init-time exchanges
    are fault-addressable as round 0, so a tainted init reports
    ``ok=False`` and the caller re-inits clean rather than checkpointing
    poison).  ``telemetry=True`` appends the series buffer as carry[4]
    — it checkpoints, rolls back, and restores like any state leaf, so
    a recovered run's series has no rows from discarded chunks."""
    g = prog.prepare(g)
    obs_tel.phase("init")
    faults.set_round(jnp.int32(0))
    if isinstance(prog, AsyncSuperstepProgram):
        state0, handle0 = prog.init(g, *inputs)
    else:
        state0 = prog.init(g, *inputs)
        handle0 = ()
    ok0 = _round_ok(prog, g, state0, state0)
    base = (state0, handle0, jnp.int32(0), ok0)
    return base + (_series_init(prog),) if telemetry else base


def run_chunk(prog, g: dict, carry, chunk: int):
    """Advance ``carry`` by up to ``chunk`` guarded rounds.

    Exits early on halt, ``max_rounds``, or the first violated round
    (sticky ``ok``).  Returns ``(carry, halted)``; the caller inspects
    ``carry[3]`` (ok) to decide checkpoint vs rollback and ``halted`` /
    ``carry[2]`` (rounds) to decide whether to keep chunking.  A
    5-element carry (from ``init_carry(telemetry=True)``) carries the
    telemetry series and writes its row each round.
    """
    g = prog.prepare(g)
    is_async = isinstance(prog, AsyncSuperstepProgram)
    telemetry = len(carry) == 5

    def cond(c):
        (state, _, r, ok, *_), i = c
        return ok & jnp.logical_not(prog.halt(state)) \
            & (i < chunk) & (r < prog.max_rounds)

    def body(c):
        (state, handle, r, ok, *rest), i = c
        faults.set_round(r + 1 if is_async else r)
        prev = state
        if is_async:
            state, handle = prog.fold(g, prog.local(g, state), handle)
        else:
            state = prog.step(g, state)
        ok = ok & _round_ok(prog, g, prev, state)
        new = (state, handle, r + 1, ok)
        if telemetry:
            new += (_series_write(prog, rest[0], r, state),)
        return new, i + 1

    obs_tel.phase("round")
    carry, _ = jax.lax.while_loop(cond, body, (carry, jnp.int32(0)))
    faults.set_round(jnp.int32(-1))
    return carry, jnp.asarray(prog.halt(carry[0]), bool)


def carry_outputs(prog, g: dict, carry):
    """Finalize a halted carry into the program's outputs tuple."""
    g = prog.prepare(g)
    faults.set_round(jnp.int32(-1))
    state = carry[0]
    if isinstance(prog, AsyncSuperstepProgram):
        return prog.outputs(g, state)
    return prog.outputs(state)
