"""Superstep programs: the engine's declarative algorithm abstraction.

"The Anatomy of Large-Scale Distributed Graph Algorithms" (Firoz et al.)
decomposes distributed graph algorithms into reusable runtime pieces —
a work bundle (what one superstep does), an ordering/termination policy,
and a synchronization strategy.  This module makes that decomposition
the public API: an algorithm is a :class:`SuperstepProgram` (pure
``init / step / halt / outputs`` callables over per-partition graph
arrays + the ``partitioned.py`` exchange primitives), and ONE shared
driver (:func:`run_program`) supplies the loop machinery every
hand-rolled driver used to duplicate:

  * early-exit ``lax.while_loop`` when termination is data-dependent
    (the production path),
  * fixed-trip ``lax.scan`` when ``static_iters > 0`` (the dry-run /
    roofline path: static trip counts make the cost model exact; steps
    past convergence are natural no-ops by construction), and
  * round accounting (the returned round count is driver state, not
    program state).

Programs never call collectives for loop control themselves — ``halt``
reads a count/error scalar the step already reduced — so swapping the
driver (BSP scan vs early-exit, single- vs multi-source) never touches
algorithm code.  All callables run INSIDE ``shard_map`` over the
1-D "parts" axis; ``core/api.py`` owns the jit/shard_map wrapping and
the compile cache.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import faults
from repro.core.compat import axis_size
from repro.core.partitioned import AXIS, psum_scalar


@dataclass(frozen=True)
class SuperstepProgram:
    """A distributed graph algorithm as data.

    The per-shard callables (all traced inside ``shard_map``):

      prepare(g) -> g        optional: derive loop-invariant edge data
                             (e.g. SSSP weights) once, outside the loop
      init(g, *inputs) -> state
                             build the initial state pytree from the
                             per-query inputs (e.g. a root vertex)
      step(g, state) -> state
                             ONE superstep: local compute + exchange;
                             must fold any convergence scalar (frontier
                             count, residual error) into the state
      halt(state) -> bool    True when converged (driver also stops at
                             ``max_rounds``); ignored under static_iters
      outputs(state) -> tuple
                             final per-shard outputs, aligned with
                             ``output_names`` / ``output_is_vertex``
      guard(g, prev, state) -> bool
                             optional per-round invariant check (local
                             per-shard verdict; the driver makes it
                             uniform): True = the round's state is
                             consistent with the algorithm's invariants
                             (monotone non-increase, mass conservation,
                             non-negativity).  ``None`` falls back to
                             the NaN/Inf screen over float state leaves.
                             Compiled in only under ``guard=True`` runs.
    """

    name: str
    variant: str
    inputs: tuple[str, ...]           # per-query input names, e.g. ("root",)
    init: Callable[..., Any]
    step: Callable[[dict, Any], Any]
    halt: Callable[[Any], Any]
    outputs: Callable[[Any], tuple]
    output_names: tuple[str, ...]
    output_is_vertex: tuple[bool, ...]  # True: (n_local,) field -> sharded
    max_rounds: int = 64
    prepare: Callable[[dict], dict] = field(default=lambda g: g)
    guard: Callable[[dict, Any, Any], Any] | None = None

    @property
    def key(self) -> str:
        return f"{self.name}/{self.variant}"


# Documented rounds slack for async vs BSP runs of the SAME monotone
# program: fold() relaxes delivered updates before re-shipping, so a
# cross-partition hop still costs one round (BSP parity) and the local
# closure only adds progress — the overhead is pipeline fill plus the
# two-quiescent-rounds halt rule.  tests/test_async.py and the
# benchmarks/compare.py rounds gate both read these.
ASYNC_ROUNDS_SLACK_FACTOR = 1.5
ASYNC_ROUNDS_SLACK_CONST = 4


@dataclass(frozen=True)
class AsyncSuperstepProgram:
    """A stale-tolerant algorithm for the double-buffered driver.

    Where :class:`SuperstepProgram.step` blocks on a full exchange every
    round (the BSP barrier), an async program splits one round into:

      init(g, *inputs) -> (state, handle)
                             seed the state AND issue the first exchange
                             (``partitioned.exchange_*_start``) so round
                             one has an in-flight handle to finish
      local(g, state) -> state
                             the overlap window: compute on already-
                             resident data only — NO collectives here;
                             this work hides the in-flight exchange
      fold(g, state, handle) -> (state, handle)
                             finish the handle (pure local reduction),
                             apply the delivered updates, and start the
                             next exchange
      halt(state) -> bool    must read only globally-uniform values (the
                             piggybacked scalar a finish returned) — all
                             partitions run the same trip count
      outputs(g, state) -> tuple
                             post-loop finalization; unlike the BSP form
                             it receives ``g`` (and MAY use collectives:
                             it runs outside the loop, uniformly)

    The driver calls ``local`` then ``fold`` each round, so the exchange
    started in round k's ``fold`` crosses the loop carry and is consumed
    after round k+1's ``local`` — local compute and wire movement
    overlap, which is the HPX insight the source paper's follow-up names
    as the fix for latency-bound BSP scaling.
    """

    name: str
    variant: str
    inputs: tuple[str, ...]
    init: Callable[..., Any]
    local: Callable[[dict, Any], Any]
    fold: Callable[[dict, Any, Any], Any]
    halt: Callable[[Any], Any]
    outputs: Callable[[dict, Any], tuple]
    output_names: tuple[str, ...]
    output_is_vertex: tuple[bool, ...]
    max_rounds: int = 64
    prepare: Callable[[dict], dict] = field(default=lambda g: g)
    guard: Callable[[dict, Any, Any], Any] | None = None

    @property
    def key(self) -> str:
        return f"{self.name}/{self.variant}"


# --------------------------------------------------------------------------
# Guard machinery.  A guard run folds THREE signals into one per-round
# uniform ``ok`` scalar: the program's invariant verdict (or the default
# NaN/Inf screen), the transport-stamp violations drained from the fault
# taps, and the previous round's ok (sticky — once bad, stays bad so the
# loop exits and the caller can roll back).
# --------------------------------------------------------------------------


def finite_state(state):
    """Default guard: every float leaf of the state is finite."""
    ok = jnp.bool_(True)
    for leaf in jax.tree_util.tree_leaves(state):
        if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating):
            ok = ok & jnp.isfinite(leaf).all()
    return ok


def _round_ok(prog, g, prev, state):
    """Uniform per-round verdict: invariant guard AND transport stamps."""
    gfn = prog.guard if prog.guard is not None \
        else (lambda g_, p_, s_: finite_state(s_))
    local = jnp.asarray(gfn(g, prev, state), bool)
    ok = psum_scalar(local.astype(jnp.int32)) == axis_size(AXIS)
    viol = faults.stamp_violation()
    if viol is not None:
        ok = ok & jnp.logical_not(viol)
    return ok


def run_program_async(prog: AsyncSuperstepProgram, g: dict, *inputs,
                      static_iters: int = 0, guard: bool = False):
    """The double-buffered driver: same ``(outputs, rounds)`` contract
    as :func:`run_program`, same while/scan split, but each round is
    ``local`` (overlap window) then ``fold`` (finish + restart the
    exchange), with the in-flight handle carried across iterations.

    Fault-round addressing: the exchange issued by ``init`` is round 0;
    the one started in body iteration ``r`` is round ``r + 1`` (the
    (k+1)-th exchange started is round k+1).  With ``guard=True`` the
    return is ``(outputs, rounds, ok)``.
    """
    g = prog.prepare(g)
    faults.set_round(jnp.int32(0))
    state0, handle0 = prog.init(g, *inputs)

    if static_iters:
        def sbody(carry, _):
            state, handle, r = carry
            faults.set_round(r + 1)
            state, handle = prog.fold(g, prog.local(g, state), handle)
            return (state, handle, r + 1), None

        (state, _, rounds), _ = jax.lax.scan(
            sbody, (state0, handle0, jnp.int32(0)), None,
            length=static_iters)
        faults.set_round(jnp.int32(-1))   # outputs are not addressable
        return prog.outputs(g, state), rounds

    if guard:
        ok0 = _round_ok(prog, g, state0, state0)

        def gcond(carry):
            state, _, r, ok = carry
            return ok & jnp.logical_not(prog.halt(state)) \
                & (r < prog.max_rounds)

        def gbody(carry):
            state, handle, r, ok = carry
            faults.set_round(r + 1)
            prev = state
            state, handle = prog.fold(g, prog.local(g, state), handle)
            return state, handle, r + 1, ok & _round_ok(prog, g, prev,
                                                        state)

        state, _, rounds, ok = jax.lax.while_loop(
            gcond, gbody, (state0, handle0, jnp.int32(0), ok0))
        faults.set_round(jnp.int32(-1))
        return prog.outputs(g, state), rounds, ok

    def cond(carry):
        state, _, r = carry
        return jnp.logical_not(prog.halt(state)) & (r < prog.max_rounds)

    def body(carry):
        state, handle, r = carry
        faults.set_round(r + 1)
        state, handle = prog.fold(g, prog.local(g, state), handle)
        return state, handle, r + 1

    state, _, rounds = jax.lax.while_loop(
        cond, body, (state0, handle0, jnp.int32(0)))
    faults.set_round(jnp.int32(-1))
    return prog.outputs(g, state), rounds


@dataclass(frozen=True)
class PhasedProgram:
    """A multi-phase algorithm: a tuple of :class:`SuperstepProgram`s run
    back to back, each phase's ``outputs`` threaded into the next phase's
    ``init`` (after the per-query ``inputs`` of phase 0).

    Brandes betweenness is the motivating case: a forward
    shortest-path-counting BFS, then a dependency-accumulation backward
    sweep seeded with the forward (dist, sigma) fields.  The driver is
    still :func:`run_program` — it dispatches to :func:`run_phases` — so
    every engine layer (compile cache, batching, dry-run static_iters)
    works on phased programs with no extra plumbing.

    ``output_names`` / ``output_is_vertex`` describe the LAST phase's
    outputs, which are the program's outputs.
    """

    name: str
    variant: str
    inputs: tuple[str, ...]
    phases: tuple[SuperstepProgram, ...]
    output_names: tuple[str, ...]
    output_is_vertex: tuple[bool, ...]

    @property
    def key(self) -> str:
        return f"{self.name}/{self.variant}"


def run_phases(prog: PhasedProgram, g: dict, *inputs,
               static_iters: int = 0, guard: bool = False):
    """Chain the phases of a :class:`PhasedProgram`: phase ``i+1`` is
    initialized with phase ``i``'s outputs.  Returns the last phase's
    outputs and the TOTAL round count (each phase runs ``static_iters``
    supersteps on the scan path, so the total is ``len(phases) *
    static_iters`` there).  Fault rounds address each phase's own
    counter (a round-2 event fires in EVERY phase's round 2).  Under
    ``guard=True`` the per-phase ok scalars AND together."""
    chained = inputs
    total = jnp.int32(0)
    ok = jnp.bool_(True)
    for phase in prog.phases:
        res = run_program(phase, g, *chained, static_iters=static_iters,
                          guard=guard)
        if guard:
            chained, rounds, phase_ok = res
            ok = ok & phase_ok
        else:
            chained, rounds = res
        total = total + rounds
    return (chained, total, ok) if guard else (chained, total)


def run_program(prog, g: dict, *inputs, static_iters: int = 0,
                guard: bool = False):
    """The ONE shared superstep driver (call inside shard_map).

    Returns ``(outputs_tuple, rounds)`` where ``rounds`` is the number of
    supersteps executed (== ``static_iters`` on the scan path).  A
    :class:`PhasedProgram` dispatches to :func:`run_phases`.

    ``guard=True`` compiles the per-round invariant check in: the while
    cond gains a sticky uniform ``ok`` scalar (invariant guard AND
    fault-transport stamps), the loop exits on the FIRST violated round,
    and the return becomes ``(outputs_tuple, rounds, ok)``.  Not
    supported on the ``static_iters`` scan path (the dry-run costs a
    clean loop).
    """
    if guard and static_iters:
        raise ValueError("guard=True is incompatible with static_iters")
    if isinstance(prog, PhasedProgram):
        return run_phases(prog, g, *inputs, static_iters=static_iters,
                          guard=guard)
    if isinstance(prog, AsyncSuperstepProgram):
        return run_program_async(prog, g, *inputs,
                                 static_iters=static_iters, guard=guard)
    g = prog.prepare(g)
    faults.set_round(jnp.int32(0))
    state0 = prog.init(g, *inputs)

    if static_iters:
        def sbody(carry, _):
            state, r = carry
            faults.set_round(r)
            return (prog.step(g, state), r + 1), None

        (state, rounds), _ = jax.lax.scan(
            sbody, (state0, jnp.int32(0)), None, length=static_iters)
        faults.set_round(jnp.int32(-1))   # outputs are not addressable
        return prog.outputs(state), rounds

    if guard:
        ok0 = _round_ok(prog, g, state0, state0)

        def gcond(carry):
            state, r, ok = carry
            return ok & jnp.logical_not(prog.halt(state)) \
                & (r < prog.max_rounds)

        def gbody(carry):
            state, r, ok = carry
            faults.set_round(r)
            new = prog.step(g, state)
            return new, r + 1, ok & _round_ok(prog, g, state, new)

        state, rounds, ok = jax.lax.while_loop(
            gcond, gbody, (state0, jnp.int32(0), ok0))
        faults.set_round(jnp.int32(-1))
        return prog.outputs(state), rounds, ok

    def cond(carry):
        state, r = carry
        return jnp.logical_not(prog.halt(state)) & (r < prog.max_rounds)

    def body(carry):
        state, r = carry
        faults.set_round(r)
        return prog.step(g, state), r + 1

    state, rounds = jax.lax.while_loop(cond, body, (state0, jnp.int32(0)))
    faults.set_round(jnp.int32(-1))
    return prog.outputs(state), rounds


def run_program_batched(prog, g: dict, *batched_inputs,
                        static_iters: int = 0):
    """Multi-source driver: vmap :func:`run_program` over (B,)-batched
    query inputs (e.g. BFS/SSSP roots), amortizing one graph residency
    across B traversals — the serve-many-queries path.

    Vertex outputs gain a leading (B,) axis; ``rounds`` becomes (B,).
    Works for :class:`PhasedProgram` too (batched betweenness: B forward
    sweeps then B backward sweeps, vmapped as one phased traversal).
    """
    if not isinstance(prog, PhasedProgram):
        # hoist the loop-invariant prepare out of the vmap so per-query
        # traversals share one derived-edge-data computation
        g = prog.prepare(g)
        prog = dataclasses.replace(prog, prepare=lambda garr: garr)

    def one(*ins):
        outs, rounds = run_program(prog, g, *ins,
                                   static_iters=static_iters)
        return (*outs, rounds)

    res = jax.vmap(one)(*batched_inputs)
    return res[:-1], res[-1]


# --------------------------------------------------------------------------
# Chunked execution: the checkpointing substrate.
#
# ``core/recovery.py`` drives a program as a sequence of guarded CHUNKS of
# at most k rounds, snapshotting the carry to host between chunks.  The
# carry is ``(state, handle, rounds, ok)`` — handle is ``()`` for BSP
# programs, the in-flight exchange for async ones (it is plain array
# data, so it checkpoints and restores like any state leaf).  Chunking
# never changes the traced per-round computation, so a chunked run is
# bit-identical to the guarded un-chunked driver, which is bit-identical
# to the plain driver on fault-free rounds.
# --------------------------------------------------------------------------


def init_carry(prog, g: dict, *inputs):
    """Build the initial checkpointable carry ``(state, handle, rounds,
    ok)`` — prepare + init + the round-0 verdict (init-time exchanges
    are fault-addressable as round 0, so a tainted init reports
    ``ok=False`` and the caller re-inits clean rather than checkpointing
    poison)."""
    g = prog.prepare(g)
    faults.set_round(jnp.int32(0))
    if isinstance(prog, AsyncSuperstepProgram):
        state0, handle0 = prog.init(g, *inputs)
    else:
        state0 = prog.init(g, *inputs)
        handle0 = ()
    ok0 = _round_ok(prog, g, state0, state0)
    return state0, handle0, jnp.int32(0), ok0


def run_chunk(prog, g: dict, carry, chunk: int):
    """Advance ``carry`` by up to ``chunk`` guarded rounds.

    Exits early on halt, ``max_rounds``, or the first violated round
    (sticky ``ok``).  Returns ``(carry, halted)``; the caller inspects
    ``carry[3]`` (ok) to decide checkpoint vs rollback and ``halted`` /
    ``carry[2]`` (rounds) to decide whether to keep chunking.
    """
    g = prog.prepare(g)
    is_async = isinstance(prog, AsyncSuperstepProgram)

    def cond(c):
        (state, _, r, ok), i = c
        return ok & jnp.logical_not(prog.halt(state)) \
            & (i < chunk) & (r < prog.max_rounds)

    def body(c):
        (state, handle, r, ok), i = c
        faults.set_round(r + 1 if is_async else r)
        prev = state
        if is_async:
            state, handle = prog.fold(g, prog.local(g, state), handle)
        else:
            state = prog.step(g, state)
        ok = ok & _round_ok(prog, g, prev, state)
        return (state, handle, r + 1, ok), i + 1

    carry, _ = jax.lax.while_loop(cond, body, (carry, jnp.int32(0)))
    faults.set_round(jnp.int32(-1))
    return carry, jnp.asarray(prog.halt(carry[0]), bool)


def carry_outputs(prog, g: dict, carry):
    """Finalize a halted carry into the program's outputs tuple."""
    g = prog.prepare(g)
    faults.set_round(jnp.int32(-1))
    state = carry[0]
    if isinstance(prog, AsyncSuperstepProgram):
        return prog.outputs(g, state)
    return prog.outputs(state)
