"""Superstep programs: the engine's declarative algorithm abstraction.

"The Anatomy of Large-Scale Distributed Graph Algorithms" (Firoz et al.)
decomposes distributed graph algorithms into reusable runtime pieces —
a work bundle (what one superstep does), an ordering/termination policy,
and a synchronization strategy.  This module makes that decomposition
the public API: an algorithm is a :class:`SuperstepProgram` (pure
``init / step / halt / outputs`` callables over per-partition graph
arrays + the ``partitioned.py`` exchange primitives), and ONE shared
driver (:func:`run_program`) supplies the loop machinery every
hand-rolled driver used to duplicate:

  * early-exit ``lax.while_loop`` when termination is data-dependent
    (the production path),
  * fixed-trip ``lax.scan`` when ``static_iters > 0`` (the dry-run /
    roofline path: static trip counts make the cost model exact; steps
    past convergence are natural no-ops by construction), and
  * round accounting (the returned round count is driver state, not
    program state).

Programs never call collectives for loop control themselves — ``halt``
reads a count/error scalar the step already reduced — so swapping the
driver (BSP scan vs early-exit, single- vs multi-source) never touches
algorithm code.  All callables run INSIDE ``shard_map`` over the
1-D "parts" axis; ``core/api.py`` owns the jit/shard_map wrapping and
the compile cache.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SuperstepProgram:
    """A distributed graph algorithm as data.

    The per-shard callables (all traced inside ``shard_map``):

      prepare(g) -> g        optional: derive loop-invariant edge data
                             (e.g. SSSP weights) once, outside the loop
      init(g, *inputs) -> state
                             build the initial state pytree from the
                             per-query inputs (e.g. a root vertex)
      step(g, state) -> state
                             ONE superstep: local compute + exchange;
                             must fold any convergence scalar (frontier
                             count, residual error) into the state
      halt(state) -> bool    True when converged (driver also stops at
                             ``max_rounds``); ignored under static_iters
      outputs(state) -> tuple
                             final per-shard outputs, aligned with
                             ``output_names`` / ``output_is_vertex``
    """

    name: str
    variant: str
    inputs: tuple[str, ...]           # per-query input names, e.g. ("root",)
    init: Callable[..., Any]
    step: Callable[[dict, Any], Any]
    halt: Callable[[Any], Any]
    outputs: Callable[[Any], tuple]
    output_names: tuple[str, ...]
    output_is_vertex: tuple[bool, ...]  # True: (n_local,) field -> sharded
    max_rounds: int = 64
    prepare: Callable[[dict], dict] = field(default=lambda g: g)

    @property
    def key(self) -> str:
        return f"{self.name}/{self.variant}"


# Documented rounds slack for async vs BSP runs of the SAME monotone
# program: fold() relaxes delivered updates before re-shipping, so a
# cross-partition hop still costs one round (BSP parity) and the local
# closure only adds progress — the overhead is pipeline fill plus the
# two-quiescent-rounds halt rule.  tests/test_async.py and the
# benchmarks/compare.py rounds gate both read these.
ASYNC_ROUNDS_SLACK_FACTOR = 1.5
ASYNC_ROUNDS_SLACK_CONST = 4


@dataclass(frozen=True)
class AsyncSuperstepProgram:
    """A stale-tolerant algorithm for the double-buffered driver.

    Where :class:`SuperstepProgram.step` blocks on a full exchange every
    round (the BSP barrier), an async program splits one round into:

      init(g, *inputs) -> (state, handle)
                             seed the state AND issue the first exchange
                             (``partitioned.exchange_*_start``) so round
                             one has an in-flight handle to finish
      local(g, state) -> state
                             the overlap window: compute on already-
                             resident data only — NO collectives here;
                             this work hides the in-flight exchange
      fold(g, state, handle) -> (state, handle)
                             finish the handle (pure local reduction),
                             apply the delivered updates, and start the
                             next exchange
      halt(state) -> bool    must read only globally-uniform values (the
                             piggybacked scalar a finish returned) — all
                             partitions run the same trip count
      outputs(g, state) -> tuple
                             post-loop finalization; unlike the BSP form
                             it receives ``g`` (and MAY use collectives:
                             it runs outside the loop, uniformly)

    The driver calls ``local`` then ``fold`` each round, so the exchange
    started in round k's ``fold`` crosses the loop carry and is consumed
    after round k+1's ``local`` — local compute and wire movement
    overlap, which is the HPX insight the source paper's follow-up names
    as the fix for latency-bound BSP scaling.
    """

    name: str
    variant: str
    inputs: tuple[str, ...]
    init: Callable[..., Any]
    local: Callable[[dict, Any], Any]
    fold: Callable[[dict, Any, Any], Any]
    halt: Callable[[Any], Any]
    outputs: Callable[[dict, Any], tuple]
    output_names: tuple[str, ...]
    output_is_vertex: tuple[bool, ...]
    max_rounds: int = 64
    prepare: Callable[[dict], dict] = field(default=lambda g: g)

    @property
    def key(self) -> str:
        return f"{self.name}/{self.variant}"


def run_program_async(prog: AsyncSuperstepProgram, g: dict, *inputs,
                      static_iters: int = 0):
    """The double-buffered driver: same ``(outputs, rounds)`` contract
    as :func:`run_program`, same while/scan split, but each round is
    ``local`` (overlap window) then ``fold`` (finish + restart the
    exchange), with the in-flight handle carried across iterations."""
    g = prog.prepare(g)
    state0, handle0 = prog.init(g, *inputs)

    if static_iters:
        def sbody(carry, _):
            state, handle, r = carry
            state, handle = prog.fold(g, prog.local(g, state), handle)
            return (state, handle, r + 1), None

        (state, _, rounds), _ = jax.lax.scan(
            sbody, (state0, handle0, jnp.int32(0)), None,
            length=static_iters)
        return prog.outputs(g, state), rounds

    def cond(carry):
        state, _, r = carry
        return jnp.logical_not(prog.halt(state)) & (r < prog.max_rounds)

    def body(carry):
        state, handle, r = carry
        state, handle = prog.fold(g, prog.local(g, state), handle)
        return state, handle, r + 1

    state, _, rounds = jax.lax.while_loop(
        cond, body, (state0, handle0, jnp.int32(0)))
    return prog.outputs(g, state), rounds


@dataclass(frozen=True)
class PhasedProgram:
    """A multi-phase algorithm: a tuple of :class:`SuperstepProgram`s run
    back to back, each phase's ``outputs`` threaded into the next phase's
    ``init`` (after the per-query ``inputs`` of phase 0).

    Brandes betweenness is the motivating case: a forward
    shortest-path-counting BFS, then a dependency-accumulation backward
    sweep seeded with the forward (dist, sigma) fields.  The driver is
    still :func:`run_program` — it dispatches to :func:`run_phases` — so
    every engine layer (compile cache, batching, dry-run static_iters)
    works on phased programs with no extra plumbing.

    ``output_names`` / ``output_is_vertex`` describe the LAST phase's
    outputs, which are the program's outputs.
    """

    name: str
    variant: str
    inputs: tuple[str, ...]
    phases: tuple[SuperstepProgram, ...]
    output_names: tuple[str, ...]
    output_is_vertex: tuple[bool, ...]

    @property
    def key(self) -> str:
        return f"{self.name}/{self.variant}"


def run_phases(prog: PhasedProgram, g: dict, *inputs,
               static_iters: int = 0):
    """Chain the phases of a :class:`PhasedProgram`: phase ``i+1`` is
    initialized with phase ``i``'s outputs.  Returns the last phase's
    outputs and the TOTAL round count (each phase runs ``static_iters``
    supersteps on the scan path, so the total is ``len(phases) *
    static_iters`` there)."""
    chained = inputs
    total = jnp.int32(0)
    for phase in prog.phases:
        chained, rounds = run_program(phase, g, *chained,
                                      static_iters=static_iters)
        total = total + rounds
    return chained, total


def run_program(prog, g: dict, *inputs, static_iters: int = 0):
    """The ONE shared superstep driver (call inside shard_map).

    Returns ``(outputs_tuple, rounds)`` where ``rounds`` is the number of
    supersteps executed (== ``static_iters`` on the scan path).  A
    :class:`PhasedProgram` dispatches to :func:`run_phases`.
    """
    if isinstance(prog, PhasedProgram):
        return run_phases(prog, g, *inputs, static_iters=static_iters)
    if isinstance(prog, AsyncSuperstepProgram):
        return run_program_async(prog, g, *inputs,
                                 static_iters=static_iters)
    g = prog.prepare(g)
    state0 = prog.init(g, *inputs)

    if static_iters:
        def sbody(carry, _):
            state, r = carry
            return (prog.step(g, state), r + 1), None

        (state, rounds), _ = jax.lax.scan(
            sbody, (state0, jnp.int32(0)), None, length=static_iters)
        return prog.outputs(state), rounds

    def cond(carry):
        state, r = carry
        return jnp.logical_not(prog.halt(state)) & (r < prog.max_rounds)

    def body(carry):
        state, r = carry
        return prog.step(g, state), r + 1

    state, rounds = jax.lax.while_loop(cond, body, (state0, jnp.int32(0)))
    return prog.outputs(state), rounds


def run_program_batched(prog, g: dict, *batched_inputs,
                        static_iters: int = 0):
    """Multi-source driver: vmap :func:`run_program` over (B,)-batched
    query inputs (e.g. BFS/SSSP roots), amortizing one graph residency
    across B traversals — the serve-many-queries path.

    Vertex outputs gain a leading (B,) axis; ``rounds`` becomes (B,).
    Works for :class:`PhasedProgram` too (batched betweenness: B forward
    sweeps then B backward sweeps, vmapped as one phased traversal).
    """
    if not isinstance(prog, PhasedProgram):
        # hoist the loop-invariant prepare out of the vmap so per-query
        # traversals share one derived-edge-data computation
        g = prog.prepare(g)
        prog = dataclasses.replace(prog, prepare=lambda garr: garr)

    def one(*ins):
        outs, rounds = run_program(prog, g, *ins,
                                   static_iters=static_iters)
        return (*outs, rounds)

    res = jax.vmap(one)(*batched_inputs)
    return res[:-1], res[-1]
