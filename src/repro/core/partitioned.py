"""PartitionedVector: the ``hpx::partitioned_vector`` analogue.

A global per-vertex array lives as (P, n_local) sharded over the "parts"
mesh axis.  HPX exposes remote element access through AGAS; the SPMD
analogue is bulk exchange, so this module provides the three exchange
primitives the graph algorithms are built from:

  * exchange_sum / exchange_or  -- each partition holds a full-length
      (n,) accumulator of proposed updates; a single fused
      ``psum_scatter`` delivers the combined slice to each owner.  This
      is the TPU-native form of the paper's "remote contributions are
      sent and atomically applied at the owner" (message aggregation
      replaces fine-grained atomics).
  * exchange_min_int -- owner-combining with MIN (parent selection in
      BFS replaces compare_exchange); implemented with all_to_all.
  * broadcast_global -- all-gather a (P, n_local) field into a full (n,)
      replica on every partition (pull-mode reads).

All functions are meant to be called INSIDE shard_map over axis "parts".
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.compat import axis_size

AXIS = "parts"


def local_slice_bounds(n_local: int):
    """[lo, hi) global ids owned by this partition (inside shard_map)."""
    idx = jax.lax.axis_index(AXIS)
    lo = idx * n_local
    return lo, lo + n_local


def exchange_sum(acc_global, axis_name: str = AXIS):
    """acc_global: (n,) proposed updates for ALL vertices (local view).

    Returns (n_local,) combined updates for the vertices THIS partition
    owns.  One reduce-scatter on the wire: (P-1)/P * n elements.
    """
    parts = axis_size(axis_name)
    blocks = acc_global.reshape(parts, -1)
    return jax.lax.psum_scatter(blocks, axis_name, scatter_dimension=0,
                                tiled=False).reshape(-1)


def exchange_or(mask_global, axis_name: str = AXIS):
    """Boolean OR-combine: frontiers. Same wire cost as exchange_sum."""
    summed = exchange_sum(mask_global.astype(jnp.int32), axis_name)
    return summed > 0


def exchange_min_int(val_global, axis_name: str = AXIS, big=None):
    """Element-wise MIN combine of int32 proposals.

    all_to_all moves each partition's (P, n_local) proposal matrix so
    that owners receive P candidate rows; min over the row axis.
    """
    parts = axis_size(axis_name)
    blocks = val_global.reshape(parts, 1, -1)
    rows = jax.lax.all_to_all(blocks, axis_name, split_axis=0,
                              concat_axis=1)          # (1, P, n_local)
    return rows.min(axis=(0, 1))


def broadcast_global(local_vals, axis_name: str = AXIS):
    """(n_local,) -> (n,) full replica (all-gather)."""
    return jax.lax.all_gather(local_vals, axis_name, axis=0,
                              tiled=True)


def psum_scalar(x, axis_name: str = AXIS):
    return jax.lax.psum(x, axis_name)
