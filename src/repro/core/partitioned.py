"""PartitionedVector: the ``hpx::partitioned_vector`` analogue.

A global per-vertex array lives as (P, n_local) sharded over the "parts"
mesh axis.  HPX exposes remote element access through AGAS; the SPMD
analogue is bulk exchange, so this module provides the three exchange
primitives the graph algorithms are built from:

  * exchange_sum -- each partition holds a full-length (n,) accumulator
      of proposed updates; a single fused ``psum_scatter`` delivers the
      combined slice to each owner.  This is the TPU-native form of the
      paper's "remote contributions are sent and atomically applied at
      the owner" (message aggregation replaces fine-grained atomics).
  * exchange_or -- boolean OR-combine over a PACKED uint32 bitmap:
      n/32 words on the wire (the old bool->int32 inflation shipped 4n
      bytes, 32x more).
  * exchange_min_int -- owner-combining with MIN (parent selection in
      BFS replaces compare_exchange); implemented with all_to_all.
  * broadcast_global -- all-gather a (P, n_local) field into a full (n,)
      replica on every partition (pull-mode reads).

The bit-packing helpers (``pack_bits`` / ``unpack_bits`` / ``test_bit``)
live here too - they are exchange-payload machinery shared by the
packed OR exchange, the direction-optimizing BFS frontier bitmap, and
the frontier-pull kernels.

All exchange functions are meant to be called INSIDE shard_map over
axis "parts".

Every primitive routes its OUTGOING payload through ``_tap`` before
the collective — first the telemetry wire tap (``obs/telemetry.py``
byte accounting at trace time), then the deterministic chaos-injection
point (see ``core/faults.py``); both are Python-level no-ops unless
armed.  Ops: ``sum`` / ``min`` / ``or`` / ``bcast``; the blocking and
double-buffered forms share op names so one schedule (or one wire
report) addresses both execution modes.  ``psum_scalar`` is NOT
tapped: the BSP halt scalar is control plane, not payload — async
programs piggyback their halt count on the data exchange, where it IS
faultable (and counted).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import faults
from repro.core.compat import axis_size
from repro.obs import telemetry as obs_telemetry

AXIS = "parts"


def _tap(op: str, payload, axis_name: str):
    """Every exchange routes its outgoing payload through here: the
    telemetry wire tap first (trace-time byte accounting, a no-op
    unless ``obs.telemetry.recording`` is armed), then the chaos-
    injection tap (``faults.tap``, a no-op unless a schedule is armed).
    Both read the payload the collective actually ships, so the byte
    figure telemetry reports is the post-packing wire size."""
    obs_telemetry.tap_wire(op, payload)
    return faults.tap(op, payload, axis_name)


def pack_bits(bits):
    """(m,) bool -> (m/32,) uint32 (m must be a multiple of 32)."""
    m = bits.shape[0]
    w = bits.reshape(m // 32, 32).astype(jnp.uint32)
    return (w << jnp.arange(32, dtype=jnp.uint32)).sum(axis=1,
                                                       dtype=jnp.uint32)


def unpack_bits(packed, m):
    """(m/32,) uint32 -> (m,) bool."""
    idx = jnp.arange(m, dtype=jnp.int32)
    return ((packed[idx >> 5] >> (idx & 31).astype(jnp.uint32)) & 1
            ).astype(bool)


def test_bit(packed, idx):
    """Gather bit idx (any shape int32) from a packed bitmap."""
    word = packed[idx >> 5]
    return (word >> (idx & 31).astype(jnp.uint32)) & 1


def local_slice_bounds(n_local: int):
    """[lo, hi) global ids owned by this partition (inside shard_map)."""
    idx = jax.lax.axis_index(AXIS)
    lo = idx * n_local
    return lo, lo + n_local


def exchange_sum(acc_global, axis_name: str = AXIS):
    """acc_global: (n,) proposed updates for ALL vertices (local view).

    Returns (n_local,) combined updates for the vertices THIS partition
    owns.  One reduce-scatter on the wire: (P-1)/P * n elements.
    """
    parts = axis_size(axis_name)
    blocks = _tap("sum", acc_global.reshape(parts, -1), axis_name)
    return jax.lax.psum_scatter(blocks, axis_name, scatter_dimension=0,
                                tiled=False).reshape(-1)


def exchange_or(mask_global, axis_name: str = AXIS):
    """Boolean OR-combine: frontiers/activation masks.

    The mask is bit-PACKED before it touches the wire: each partition
    ships its (n/32,) uint32 bitmap through one all_to_all and owners
    OR the P candidate rows - n/8 bytes total per partition instead of
    the 4n an int32-inflated psum_scatter pays (32x less wire).
    """
    parts = axis_size(axis_name)
    n_local_words = mask_global.shape[0] // parts // 32
    packed = _tap(
        "or", pack_bits(mask_global).reshape(parts, n_local_words),
        axis_name)
    rows = jax.lax.all_to_all(
        packed.reshape(parts, 1, n_local_words), axis_name,
        split_axis=0, concat_axis=1)                    # (1, P, nl/32)
    acc = jax.lax.reduce(rows[0], jnp.uint32(0), jax.lax.bitwise_or, (0,))
    return unpack_bits(acc, mask_global.shape[0] // parts)


def exchange_min_int(val_global, axis_name: str = AXIS, big=None):
    """Element-wise MIN combine of proposals (any ordered dtype —
    int32 parents/labels, f32 distances).

    all_to_all moves each partition's (P, n_local) proposal matrix so
    that owners receive P candidate rows; min over the row axis.
    """
    parts = axis_size(axis_name)
    blocks = _tap("min", val_global.reshape(parts, -1), axis_name)
    rows = jax.lax.all_to_all(blocks.reshape(parts, 1, -1), axis_name,
                              split_axis=0,
                              concat_axis=1)          # (1, P, n_local)
    return rows.min(axis=(0, 1))


def broadcast_global(local_vals, axis_name: str = AXIS):
    """(n_local,) -> (n,) full replica (all-gather)."""
    return jax.lax.all_gather(_tap("bcast", local_vals, axis_name),
                              axis_name, axis=0, tiled=True)


def psum_scalar(x, axis_name: str = AXIS):
    return jax.lax.psum(x, axis_name)


# --------------------------------------------------------------------------
# Double-buffered exchange: start / finish pairs.
#
# The blocking primitives above fuse "ship the proposals" and "combine at
# the owner" into one call, which is exactly the BSP barrier the source
# paper blames for latency-bound scaling.  The ``*_start`` forms below
# issue ONLY the wire movement (all_to_all / psum_scatter) and return the
# raw received rows as an opaque in-flight handle — a plain array pytree
# that an async driver carries across a ``lax.while_loop`` iteration.  The
# matching ``*_finish`` forms are pure local reductions over the handle.
# Round k's handle is finished AFTER round k+1's local compute, so the
# local work overlaps the in-flight collective (the serve executor's
# device/host overlap, replayed inside the superstep loop).
#
# Every start form also piggybacks one reduction scalar (a halt count or
# residual) as an extra payload column, so convergence detection rides
# the data exchange instead of paying a separate psum collective per
# round.  Each partition stamps its local scalar on all P outgoing rows;
# after the exchange the receiver holds all P stamps, and summing them
# reproduces ``psum_scalar`` bit-for-bit (integer-valued scalars stay
# exact in f32 payloads up to 2**24; the property suite pins this).
# --------------------------------------------------------------------------


def exchange_min_start(val_global, scalar, axis_name: str = AXIS):
    """Issue the MIN-combine exchange of ``(n,)`` proposals without
    reducing.  ``scalar`` (the piggybacked halt count) is appended as a
    trailing payload column in the proposal dtype.  Returns the in-flight
    handle: ``(1, P, n_local + 1)`` received rows."""
    parts = axis_size(axis_name)
    n_local = val_global.shape[0] // parts
    blocks = val_global.reshape(parts, n_local)
    payload = _tap("min", jnp.concatenate(
        [blocks, jnp.full((parts, 1), scalar, blocks.dtype)], axis=1),
        axis_name)
    return jax.lax.all_to_all(payload.reshape(parts, 1, n_local + 1),
                              axis_name, split_axis=0, concat_axis=1)


def exchange_min_finish(handle):
    """Pure-local reduction of an :func:`exchange_min_start` handle:
    ``((n_local,) combined minima, global scalar sum)``."""
    rows = handle[0]                            # (P, n_local + 1)
    return rows[:, :-1].min(axis=0), rows[:, -1].sum()


def exchange_sum_start(acc_global, scalar, axis_name: str = AXIS):
    """Issue the SUM-combine reduce-scatter of ``(n,)`` proposals with a
    piggybacked scalar column.  ``psum_scatter`` combines on the wire, so
    the handle is already reduced data — the split still buys the driver
    a full local-compute window before :func:`exchange_sum_finish` reads
    it.  Returns the ``(n_local + 1,)`` handle."""
    parts = axis_size(axis_name)
    n_local = acc_global.shape[0] // parts
    blocks = acc_global.reshape(parts, n_local)
    payload = _tap("sum", jnp.concatenate(
        [blocks, jnp.full((parts, 1), scalar, blocks.dtype)], axis=1),
        axis_name)
    return jax.lax.psum_scatter(payload, axis_name, scatter_dimension=0,
                                tiled=False)


def exchange_sum_finish(handle):
    """``((n_local,) combined sums, global scalar sum)``."""
    return handle[:-1], handle[-1]


def exchange_or_start(mask_global, scalar, axis_name: str = AXIS):
    """Issue the bit-packed OR exchange of an ``(n,)`` bool mask with a
    piggybacked uint32 count word.  Returns the ``(1, P, n_words + 1)``
    handle; finish with :func:`exchange_or_finish` (which needs the
    static ``n_local`` because the handle itself stays a pure array
    pytree a loop carry can hold)."""
    parts = axis_size(axis_name)
    n_local_words = mask_global.shape[0] // parts // 32
    blocks = pack_bits(mask_global).reshape(parts, n_local_words)
    payload = _tap("or", jnp.concatenate(
        [blocks, jnp.full((parts, 1), scalar, jnp.uint32)], axis=1),
        axis_name)
    return jax.lax.all_to_all(payload.reshape(parts, 1, n_local_words + 1),
                              axis_name, split_axis=0, concat_axis=1)


def exchange_or_finish(handle, n_local: int):
    """``((n_local,) bool OR-combined mask, global int32 scalar sum)``."""
    rows = handle[0]                            # (P, n_words + 1)
    acc = jax.lax.reduce(rows[:, :-1], jnp.uint32(0),
                         jax.lax.bitwise_or, (0,))
    return unpack_bits(acc, n_local), rows[:, -1].sum().astype(jnp.int32)
