"""Distributed PageRank: BSP baseline (BGL-style) and the HPX-adapted
optimized implementation.

Paper mapping (SS4.2) - the three phases per iteration:
  1. Contribution accumulation: contrib[i] = rank[i] / out_degree[i];
     local neighbors applied directly, remote ones shipped to the owner.
  2. Rank update: rank[i] = base + alpha * z.
  3. Error computation: sum |rank_new - rank_old| (convergence).

Both variants are :class:`~repro.core.superstep.SuperstepProgram`
factories; the shared driver in core/superstep.py owns the while/scan
loop.

``pagerank/bsp``  -- pull over in-edges after ALL-GATHERING the full (n,)
    f32 contribution vector every iteration (the ghost-replication
    pattern of distributed BGL), plus a separate error all-reduce.
``pagerank/fast`` -- push-aggregate: each partition segment-sums its
    local edges' contributions into a length-n accumulator and ONE fused
    reduce-scatter delivers owner slices (the paper's "remote
    contribution applied atomically at the owner", batched).  The
    exchange payload is quantized bf16 with an error-feedback residual
    (2x less wire); the error term rides the same collective schedule.

The local segment-sum is the SpMV hot spot; it routes through
``core/localops.py`` (``spmv_pull`` over the blocked-ELL in-neighbor
lists for the pull variant, ``scatter_combine`` over ``ell_dst`` for the
push variant): the Pallas SpMV kernel serves it on TPU, a dense
per-bucket gather + row-sum everywhere else - the serialized COO
scatter survives only as the ``REPRO_LOCALOPS=ref`` debug path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import localops
from repro.core.partitioned import AXIS, broadcast_global, exchange_sum, \
    exchange_sum_finish, exchange_sum_start, psum_scalar
from repro.core.superstep import AsyncSuperstepProgram, SuperstepProgram


ALPHA = 0.85


def _local_contrib(rank, out_degree):
    return jnp.where(out_degree > 0, rank / out_degree.astype(jnp.float32),
                     0.0)


def _rank_mass_ok(rank, n, n_orig, margin):
    """Mass-conservation invariant for the fault guards.

    Rank mass starts at ``n / n_orig`` (padded tail vertices carry an
    initial 1/n_orig in the unseeded variants) and only shrinks toward
    the dangling-adjusted fixed point >= (1 - alpha), so any round's
    global mass must sit in ``((1 - alpha) * 0.9, n/n_orig * margin)``.
    ``margin`` absorbs transient overshoot (bf16 error feedback, stale
    remote snapshots); a dropped/duplicated/corrupted contribution
    block moves mass outside the band, and NaN fails the element-wise
    non-negativity check.
    """
    mass = psum_scalar(rank.sum())
    cap = (1.0 + (n - n_orig) / n_orig) * margin
    return (rank >= 0).all() & (mass > (1.0 - ALPHA) * 0.9) & (mass < cap)


def pagerank_bsp_program(shards, iters: int = 50,
                         tol: float = 1e-6) -> SuperstepProgram:
    """BGL-style pull PageRank (ghost replication via all-gather)."""
    n, n_local, n_orig = shards.n, shards.n_local, shards.n_orig
    ell_in = shards.ell("ell_in")
    base = (1.0 - ALPHA) / n_orig

    def init(g, *_):
        rank0 = jnp.full((n_local,), 1.0 / n_orig, jnp.float32)
        return rank0, jnp.float32(1.0)

    def step(g, state):
        rank, _ = state
        contrib = _local_contrib(rank, g["out_degree"])
        cg = broadcast_global(contrib)              # all-gather (n,) f32
        z = localops.spmv_pull(g, ell_in, cg)       # local SpMV (pull)
        new_rank = base + ALPHA * z
        err = psum_scalar(jnp.abs(new_rank - rank).sum())  # extra barrier
        return new_rank, err

    def guard(g, prev, state):
        rank, err = state
        return _rank_mass_ok(rank, n, n_orig, 1.02) & (err >= 0)

    return SuperstepProgram(
        name="pagerank", variant="bsp", inputs=(),
        init=init, step=step,
        halt=lambda state: state[1] <= tol,
        probe_names=("err",), probe=lambda state: (state[1],),
        outputs=lambda state: (state[0], state[1]),
        output_names=("rank", "err"), output_is_vertex=(True, False),
        max_rounds=iters, guard=guard)


def pagerank_fast_program(shards, iters: int = 50,
                          tol: float = 1e-6, compress=True,
                          switch_factor: float = 1e3,
                          err_every: int = 5,
                          seeded: bool = False) -> SuperstepProgram:
    """Push-aggregate PageRank with fused reduce-scatter exchange and
    ADAPTIVE bf16 error-feedback compression.

    While the iteration error is far from tol, the exchange ships bf16
    (2x less wire, error-feedback residual keeps the average unbiased);
    once err < switch_factor * tol the loop switches to fp32 payloads so
    convergence reaches the exact fixed point.  Runtime adaptivity in the
    spirit of the paper's adaptive_core_chunk_size executor.

    The convergence check (a global barrier) runs every ``err_every``
    iterations instead of every iteration - the BSP baseline's
    per-iteration error all-reduce is exactly the synchronization cost
    the paper calls out; batching it removes 80% of the barriers at the
    cost of up to err_every-1 extra (cheap) iterations.  The iteration
    counter rides in the program state (not the driver) because
    ``err_every`` is an algorithm policy, not loop control.

    With ``seeded=True`` the program becomes the ``pagerank/warm``
    variant: init adopts a per-vertex ``rank0`` input (typically the
    previous snapshot epoch's rank vector).  Power iteration is a
    contraction to ONE fixed point, so any seed is exact at
    convergence — a near-fixed-point seed just reaches tol in far
    fewer rounds (the dynamic-graph warm-restart win).
    """
    n, n_local, n_orig = shards.n, shards.n_local, shards.n_orig
    ell_dst = shards.ell("ell_dst")
    base = (1.0 - ALPHA) / n_orig

    def init(g, *inputs):
        if seeded:
            (rank_in,) = inputs
            lo = jax.lax.axis_index(AXIS) * n_local
            gid = jnp.arange(n_local, dtype=jnp.int32) + lo
            # padded tail vertices are edgeless and never gathered:
            # zero them so the seed's value there is irrelevant
            rank0 = jnp.where(gid < n_orig, rank_in.astype(jnp.float32), 0.0)
        else:
            rank0 = jnp.full((n_local,), 1.0 / n_orig, jnp.float32)
        resid0 = jnp.zeros((n,), jnp.float32)
        return rank0, resid0, jnp.float32(1.0), jnp.int32(0)

    def step(g, state):
        rank, resid, err_prev, it = state
        srcl = g["out_src_local"]                   # (E,) local
        dst = g["out_dst_global"]                   # (E,) sentinel n
        valid = dst < n
        contrib = _local_contrib(rank, g["out_degree"])
        # local segment-sum into a length-n accumulator (SpMV push);
        # localops routes it to the Pallas spmv kernel on TPU and a
        # dense blocked-ELL gather + row-sum elsewhere.
        acc = localops.scatter_combine(
            g, ell_dst, jnp.where(valid, contrib[srcl], 0.0), "add",
            identity=jnp.float32(0.0))

        def compressed(_):
            # error-feedback quantization: ship bf16, keep the residual
            payload = (acc + resid).astype(jnp.bfloat16)
            new_resid = (acc + resid) - payload.astype(jnp.float32)
            return exchange_sum(payload).astype(jnp.float32), new_resid

        def exact(_):
            return exchange_sum(acc + resid), jnp.zeros_like(resid)

        if compress == "always":
            # static variant (dry-run/roofline): no precision switch
            z, new_resid = compressed(None)
        elif compress:
            # switch no later than the bf16 noise floor (sum|delta| ~ 3e-3
            # for rank mass 1), else a tight tol would never leave the
            # compressed regime
            switch_at = jnp.maximum(switch_factor * tol, 3e-3)
            z, new_resid = jax.lax.cond(
                err_prev > switch_at, compressed, exact, operand=None)
        else:
            z, new_resid = exact(None)
        new_rank = base + ALPHA * z
        err = jax.lax.cond(
            (it + 1) % err_every == 0,
            lambda _: psum_scalar(jnp.abs(new_rank - rank).sum()),
            lambda _: err_prev,
            operand=None)
        return new_rank, new_resid, err, it + 1

    def guard(g, prev, state):
        rank, resid, err, it = state
        return _rank_mass_ok(rank, n, n_orig, 1.02) \
            & jnp.isfinite(resid).all() & (err >= 0) & (it >= 0)

    return SuperstepProgram(
        name="pagerank", variant="warm" if seeded else "fast",
        inputs=("rank0",) if seeded else (),
        init=init, step=step,
        halt=lambda state: state[2] <= tol,
        probe_names=("err",), probe=lambda state: (state[2],),
        outputs=lambda state: (state[0], state[2]),
        output_names=("rank", "err"), output_is_vertex=(True, False),
        max_rounds=iters, guard=guard)


def pagerank_async_program(shards, iters: int = 64, tol: float = 1e-6,
                           staleness: int = 1) -> AsyncSuperstepProgram:
    """Bounded-staleness push PageRank on the double-buffered exchange.

    The rank update splits into an own-partition term (always fresh —
    computed in the overlap window every round) and a remote term
    (delivered by the in-flight reduce-scatter): each round runs
    ``rank = base + alpha * (own + remote_snapshot)``, and the remote
    snapshot refreshes only every ``staleness`` rounds — the bounded-
    staleness knob.  Between refreshes NO collective runs at all (wire
    per round drops by the same factor); at a refresh the exchange that
    has been in flight since the previous one is finished and the next
    is started, with the local residual ``sum |delta rank|`` piggybacked
    as the payload's trailing column so convergence detection never pays
    a separate psum barrier.

    Staleness is BOUNDED, not best-effort: the remote term used in any
    round derives from ranks at most ``2 * staleness + 1`` rounds old
    (shipped <= staleness rounds after they were computed, then served
    for <= staleness rounds).  The program tracks the realized maximum
    and reports it as the ``max_age`` output, which the conformance
    lane asserts against that bound.  Power iteration is an alpha-
    contraction with ONE fixed point, so the stale recurrence
    ``e(k+1) <= alpha * max(e(k), ..., e(k - 2*staleness - 1))`` still
    converges to the exact BSP answer — per-round error may oscillate,
    but its max over windows of ``2*staleness + 2`` rounds (delay bound
    + 1) is monotone non-increasing (the property suite pins this on
    the NumPy model of the recurrence).
    """
    n, n_local, n_orig = shards.n, shards.n_local, shards.n_orig
    ell_dst = shards.ell("ell_dst")
    base = (1.0 - ALPHA) / n_orig
    if staleness < 1:
        raise ValueError(f"staleness must be >= 1, got {staleness}")

    def _contrib_acc(g, rank):
        """(n,) push accumulator with the OWN slice zeroed for shipping:
        the exchange must deliver purely-remote contributions."""
        srcl = g["out_src_local"]
        valid = g["out_dst_global"] < n
        contrib = _local_contrib(rank, g["out_degree"])
        acc = localops.scatter_combine(
            g, ell_dst, jnp.where(valid, contrib[srcl], 0.0), "add",
            identity=jnp.float32(0.0))
        lo = jax.lax.axis_index(AXIS) * n_local
        own = jax.lax.dynamic_slice_in_dim(acc, lo, n_local)
        ship = jax.lax.dynamic_update_slice_in_dim(
            acc, jnp.zeros((n_local,), jnp.float32), lo, axis=0)
        return own, ship

    def init(g):
        rank0 = jnp.full((n_local,), 1.0 / n_orig, jnp.float32)
        _, ship0 = _contrib_acc(g, rank0)
        # the err column ships 1.0 per partition so halt can't fire
        # before a real residual arrives
        handle0 = exchange_sum_start(ship0, jnp.float32(1.0))
        state0 = (rank0, jnp.zeros((n_local,), jnp.float32), ship0,
                  jnp.float32(1.0), jnp.float32(1.0), jnp.int32(0),
                  jnp.int32(1), jnp.int32(1), jnp.int32(1))
        return state0, handle0

    def local(g, state):
        rank, remote, _, _, err_g, it, age_cur, age_infl, max_age = state
        own, ship = _contrib_acc(g, rank)
        new_rank = base + ALPHA * (own + remote)
        err_local = jnp.abs(new_rank - rank).sum()
        max_age = jnp.maximum(max_age, age_cur)
        return (new_rank, remote, ship, err_local, err_g, it,
                age_cur, age_infl, max_age)

    def fold(g, state, handle):
        (rank, remote, ship, err_local, err_g, it,
         age_cur, age_infl, max_age) = state

        def refresh(_):
            remote_new, err_glob = exchange_sum_finish(handle)
            new_handle = exchange_sum_start(ship, err_local)
            # delivered snapshot: shipped age_infl rounds of aging ago,
            # +1 for this round; the fresh payload is 1 round old
            return (remote_new, err_glob, new_handle,
                    age_infl + jnp.int32(1), jnp.int32(1))

        def keep(_):
            return (remote, err_g, handle,
                    age_cur + jnp.int32(1), age_infl + jnp.int32(1))

        remote, err_g, handle, age_cur, age_infl = jax.lax.cond(
            it % staleness == 0, refresh, keep, operand=None)
        state = (rank, remote, ship, err_local, err_g, it + 1,
                 age_cur, age_infl, max_age)
        return state, handle

    def guard(g, prev, state):
        # looser mass margin: the remote snapshot lags the local term by
        # up to 2*staleness+1 rounds, so transient overshoot is larger
        rank, remote, ship = state[0], state[1], state[2]
        return _rank_mass_ok(rank, n, n_orig, 1.05) \
            & jnp.isfinite(remote).all() & (remote >= 0).all() \
            & jnp.isfinite(ship).all() & (ship >= 0).all() \
            & (state[3] >= 0) & (state[4] >= 0) \
            & (state[6] >= 0) & (state[7] >= 0) & (state[8] >= 0)

    return AsyncSuperstepProgram(
        name="pagerank", variant="async", inputs=(),
        init=init, local=local, fold=fold,
        halt=lambda state: state[4] <= tol,
        probe_names=("err",), probe=lambda state: (state[4],),
        outputs=lambda g, state: (state[0], state[4], state[8]),
        output_names=("rank", "err", "max_age"),
        output_is_vertex=(True, False, False),
        max_rounds=iters, guard=guard)
