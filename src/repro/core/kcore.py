"""Distributed k-core decomposition: iterative peeling with a
degree-threshold halt scalar.

Semantics: core numbers of the UNDIRECTED MULTIGRAPH underlying the edge
list (parallel edges each contribute a degree unit; self-loops are
dropped) — the NumPy oracle in ``tests/oracle.py`` peels the same
multigraph, so conformance is exact integer equality.

The peeling recurrence (Batagelj-Zaversnik, threshold form): hold a
current threshold ``k``; every superstep removes ALL alive vertices with
induced degree <= k and assigns them ``core = k`` (correct even when
earlier removals at this k dropped their degree below k: surviving the
(k-1)-peel proves membership in the k-core).  Removal decrements are
message-aggregated exactly like PageRank contributions — each killed
endpoint posts one decrement per incident edge into a length-n
accumulator and ONE fused ``exchange_sum`` delivers owner slices.  When
a superstep kills nothing, the threshold advances.  The halt scalar is
the global alive count.

Rounds past convergence only advance ``k`` (core assignments are
frozen), so the program is safe under the driver's fixed-trip
``static_iters`` scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import localops
from repro.core.partitioned import AXIS, exchange_sum, psum_scalar
from repro.core.superstep import SuperstepProgram


def _undirected_degree(g, n, n_local):
    """out_degree + in_degree - 2 * self_loops (multigraph, loops dropped)."""
    lo = jax.lax.axis_index(AXIS) * n_local
    srcl, dst = g["out_src_local"], g["out_dst_global"]
    is_loop = (dst < n) & (dst == srcl + lo)
    loops = jnp.zeros((n_local,), jnp.int32).at[
        jnp.where(is_loop, srcl, 0)].add(is_loop.astype(jnp.int32))
    return g["out_degree"] + g["in_degree"] - 2 * loops


def kcore_program(shards, max_rounds: int = 512) -> SuperstepProgram:
    """Iterative peeling as a superstep program.

    Outputs: per-vertex core numbers (vertex field) and the degeneracy
    (max core number, replicated scalar).
    """
    n, n_local = shards.n, shards.n_local
    ell_dst = shards.ell("ell_dst")
    ell_src = shards.ell("ell_src")

    def prepare(g):
        g = dict(g)
        g["und_degree"] = _undirected_degree(g, n, n_local)
        return g

    def init(g, *_):
        alive0 = jnp.ones((n_local,), bool)
        core0 = jnp.zeros((n_local,), jnp.int32)
        return alive0, core0, g["und_degree"], jnp.int32(0), jnp.int32(1)

    def step(g, state):
        alive, core, deg, k, _ = state
        lo = jax.lax.axis_index(AXIS) * n_local
        kills = alive & (deg <= k)
        n_killed = psum_scalar(kills.sum(dtype=jnp.int32))
        core = jnp.where(kills, k, core)
        alive = alive & ~kills
        # aggregate degree decrements: each removed edge notifies its
        # surviving endpoint (dead receivers are harmless); both
        # per-direction combines are blocked-ELL gather+sums (localops)
        srcl, dst = g["out_src_local"], g["out_dst_global"]
        dec_out = kills[srcl] & (dst < n) & (dst != srcl + lo)
        src, dstl = g["in_src_global"], g["in_dst_local"]
        dec_in = kills[dstl] & (src < n) & (src != dstl + lo)
        acc = localops.scatter_combine(
            g, ell_dst, dec_out.astype(jnp.int32), "add",
            identity=jnp.int32(0))
        acc = acc + localops.scatter_combine(
            g, ell_src, dec_in.astype(jnp.int32), "add",
            identity=jnp.int32(0))
        deg = deg - exchange_sum(acc)
        # no kills at this threshold -> the (k+1)-core remains: advance k
        k = jnp.where(n_killed > 0, k, k + 1)
        n_alive = psum_scalar(alive.sum(dtype=jnp.int32))
        return alive, core, deg, k, n_alive

    def outputs(state):
        _, core, _, _, _ = state
        kmax = jax.lax.pmax(core.max(), AXIS)
        return core, kmax

    def guard(g, prev, state):
        # peeling invariants: live degrees bounded by the static
        # undirected degree (a corrupted decrement moves deg OUT of
        # [0, und_degree] in either direction), core/threshold
        # non-decreasing and non-negative.  Dead vertices' degrees are
        # never read, so they are exempt from the bound.
        alive, core, deg, k, n_alive = state
        live_deg = jnp.where(alive, deg, 0)
        return (live_deg >= 0).all() \
            & (live_deg <= g["und_degree"]).all() \
            & (core >= prev[1]).all() & (core >= 0).all() \
            & (k >= prev[3]) & (k >= 0) & (n_alive >= 0)

    return SuperstepProgram(
        name="kcore", variant="default", inputs=(),
        prepare=prepare, init=init, step=step,
        halt=lambda state: state[4] <= 0,
        outputs=outputs,
        output_names=("core", "kmax"),
        output_is_vertex=(True, False),
        max_rounds=max_rounds, guard=guard)
