"""Serve a small model with batched requests: prefill + step-wise decode
against the segment KV/SSM cache (greedy sampling).

  PYTHONPATH=src python examples/serve_decode.py
  PYTHONPATH=src python examples/serve_decode.py --arch mamba2-1.3b
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    if len(sys.argv) == 1:
        sys.argv += ["--arch", "tinyllama-1.1b", "--smoke", "--batch", "4",
                     "--prompt-len", "32", "--gen", "48"]
    elif "--smoke" not in sys.argv:
        sys.argv.append("--smoke")
    main()
