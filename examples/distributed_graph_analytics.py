"""End-to-end driver (the paper's kind of workload at benchmark scale):
urand20 (1M vertices, 16M edges) partitioned over 8 localities, the full
registered algorithm suite with verification — BFS + PageRank in BSP vs
HPX-adapted modes, SSSP, CC, k-core, Brandes betweenness (the two-phase
program); triangle counting is skipped here because its O(n^2/P)
neighbor-set bitmap exceeds its n_budget at this scale (for the full
nine-program suite run the launcher CLI on a small graph:
``python -m repro.launch.graph_analytics --graph urand12``) — plus
batched multi-source traversal (16 roots per launch), the
serve-many-queries scenario.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/distributed_graph_analytics.py
"""

import jax

from repro.launch.graph_analytics import run

if __name__ == "__main__":
    parts = len(jax.devices())
    graph = "urand18" if parts == 1 else "urand20"
    run(graph, parts=parts, pr_iters=30, multi_source=16)
