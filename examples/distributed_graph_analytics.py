"""End-to-end driver (the paper's kind of workload at benchmark scale):
urand20 (1M vertices, 16M edges) partitioned over 8 localities, the full
registered algorithm suite with verification, BSP vs HPX-adapted
comparison, plus batched multi-source traversal (16 roots per launch) —
the serve-many-queries scenario.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/distributed_graph_analytics.py
"""

import jax

from repro.launch.graph_analytics import run

if __name__ == "__main__":
    parts = len(jax.devices())
    graph = "urand18" if parts == 1 else "urand20"
    run(graph, parts=parts, pr_iters=30, multi_source=16)
