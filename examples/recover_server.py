"""Crash a durable server, then recover it — a runnable drill in ~80
lines.

This script runs twice.  The parent invocation re-launches itself as a
``--victim`` subprocess with a deterministic crash point armed
(``REPRO_CRASH_POINT=after-wal-append:2``): the victim builds a durable
:class:`~repro.serve.server.GraphServer`, replays a seeded mutation
trace, and is killed by ``os._exit`` the instant its SECOND WAL record
hits disk — after the fsync, before the batch applies.  The parent then

  1. checks the victim died at the crash point (exit code 113, not a
     clean exit),
  2. replays the SAME trace on an uninterrupted in-process server to
     get the reference answers per epoch, and
  3. ``GraphServer.recover()``s from the victim's directory and asserts
     the recovered epoch and BFS parents are bit-identical to the
     reference at that epoch — the logged-but-unapplied batch was
     replayed from the WAL, not lost.

  PYTHONPATH=src python examples/recover_server.py

The full per-crash-point acceptance sweep lives in
``tests/test_persist.py`` (``pytest -m durability``); the on-disk
format and ordering contract are documented in ``docs/API.md`` under
"Durability & crash recovery".
"""

import os
import subprocess
import sys
import tempfile

import numpy as np

from repro.core import GraphEngine, partition_graph
from repro.graphs import urand_edges
from repro.launch.mesh import make_graph_mesh
from repro.serve import GraphServer, Persistence, query
from repro.serve.persist import CRASH_EXIT_CODE, ENV_VAR

N, E, ROUNDS, TRACE_SEED = 1024, 8192, 3, 5
CRASH_POINT = "after-wal-append:2"      # 2nd WAL record: logged, unapplied


def build_server(persistence=None) -> GraphServer:
    edges = urand_edges(N, E, seed=1)
    g = partition_graph(edges, N, parts=1)
    eng = GraphEngine(g, make_graph_mesh(1))
    return GraphServer(eng, buckets=(1,), persistence=persistence)


def run_trace(server: GraphServer) -> dict[int, np.ndarray]:
    """The seeded delete/insert/serve trace; BFS parents per epoch."""
    rng = np.random.default_rng(TRACE_SEED)
    answers = {}
    for _ in range(ROUNDS):
        dyn = server.dynamic_graph()
        server.mutate(deletes=dyn.sample_deletable(32, rng))
        server.mutate(
            inserts=server.dynamic_graph().sample_insertable(32, rng))
        (res,) = server.serve([query("bfs", root=3)])
        answers[server.epoch] = np.asarray(res["parents"])
    return answers


if "--victim" in sys.argv:               # the process that gets killed
    run_trace(build_server(
        Persistence(dir=sys.argv[-1], snapshot_every=2)))
    print("VICTIM SURVIVED — crash point never fired", file=sys.stderr)
    sys.exit(1)

pdir = tempfile.mkdtemp(prefix="recover-server-")
print(f"[drill] victim: crash point {CRASH_POINT!r}, state in {pdir}")
proc = subprocess.run(
    [sys.executable, __file__, "--victim", pdir],
    env={**os.environ, ENV_VAR: CRASH_POINT}, timeout=600)
assert proc.returncode == CRASH_EXIT_CODE, \
    f"victim exited {proc.returncode}, wanted {CRASH_EXIT_CODE}"
print(f"[drill] victim killed mid-protocol (exit {proc.returncode}); "
      f"on disk: {sorted(os.listdir(pdir))}")

print("[drill] reference: same trace, never interrupted")
reference = run_trace(build_server())

server = GraphServer.recover(pdir, buckets=(1,))
rep = server.recovery_report
print(f"[drill] recovered to epoch {server.epoch}: snapshot epoch "
      f"{rep.snapshot_epoch} + {rep.replayed} WAL record(s) replayed "
      f"({rep.wal_records} logged, {rep.skipped} already snapshotted)")

(res,) = server.serve([query("bfs", root=3)])
np.testing.assert_array_equal(np.asarray(res["parents"]),
                              reference[server.epoch])
print(f"[drill] OK: recovered BFS parents at epoch {server.epoch} are "
      f"bit-identical to the uninterrupted run — the logged-but-"
      f"unapplied batch came back from the WAL")
