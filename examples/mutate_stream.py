"""Dynamic graphs in ~50 lines: mutate the served graph in place and
recompute incrementally from the previous snapshot epoch.

The server keeps the partitioned graph device-resident; a batched edge
insert/delete patches the blocked-ELL + COO shards' free slots (no
re-partition, no re-upload, nothing re-traces) and opens a new snapshot
epoch.  Seeded programs — ``pagerank/warm``, ``cc/incremental``,
``kcore/incremental`` — then warm-restart from the previous epoch's
served answers wherever that stays exact, instead of recomputing cold.

  PYTHONPATH=src python examples/mutate_stream.py

For mutation batches merged into sustained synthetic traffic see
``python -m repro.launch.graph_serve --mutate-every 1 --mutate-size 64``.
"""

import numpy as np

from repro.core import GraphEngine, partition_graph
from repro.graphs import urand_edges
from repro.launch.mesh import make_graph_mesh
from repro.serve import GraphServer, MutationBatch, query

n, e = 4096, 32900                      # e not a multiple of 128: the
edges = urand_edges(n, e, seed=1)       # COO rounding slack (here 124
g = partition_graph(edges, n, parts=1)  # slots) is the insert headroom
eng = GraphEngine(g, make_graph_mesh(1))
server = GraphServer(eng, buckets=(1, 4))

# -- epoch 0: static answers (also stores the warm seeds) ----------------
res = server.serve([query("pagerank"), query("cc"), query("kcore")])
print(f"epoch 0: pagerank {res[0].rounds} rounds, "
      f"cc {res[1].rounds} rounds, kcore kmax={int(res[2]['kmax'])}")

# -- mutate: delete 64 live edges, insert 64 fresh ones ------------------
dyn = server.dynamic_graph()
rng = np.random.default_rng(0)
deletes = dyn.sample_deletable(64, rng)
inserts = dyn.sample_insertable(64, rng)
stats = server.mutate(inserts=inserts, deletes=deletes)
print(f"epoch {stats.epoch}: patched {stats.slots_patched} slots across "
      f"{stats.arrays_patched} arrays in {stats.apply_s*1e3:.1f} ms "
      f"(rebuild={stats.rebuild})")

# -- epoch 1: recompute incrementally ------------------------------------
warm = server.serve([query("pagerank", "warm")])[0]
cold = server.serve([query("pagerank")])[0]
print(f"pagerank after mutation: warm restart {warm.rounds} rounds vs "
      f"cold {cold.rounds} rounds "
      f"(max |warm-cold| = {np.abs(warm['rank'] - cold['rank']).max():.2e})")

seed, is_warm = server.resolve_seed(query("pagerank", "warm").key)
print(f"pagerank seed resolution: warm={is_warm} "
      f"(any mutation kind keeps the fixed point reachable)")
seed, is_warm = server.resolve_seed(query("cc", "incremental").key)
print(f"cc seed resolution: warm={is_warm} "
      f"(the batch contained deletes, so cc falls back to its cold seed "
      f"— still exact, just full-rate)")

# -- mutation batches inside a timed trace -------------------------------
trace = [(0.00, query("cc")),
         (0.01, MutationBatch(deletes=dyn.sample_deletable(32, rng))),
         (0.02, query("cc"))]
a, b = sorted(server.serve_trace(trace), key=lambda r: r.epoch)
print(f"trace replay: cc answered at epoch {a.epoch} and epoch {b.epoch}; "
      f"labels differ: {bool((a['labels'] != b['labels']).any())}")
