"""Train a small causal LM end to end: deterministic data pipeline,
AdamW, checkpoint every 50 steps, auto-resume, straggler watchdog.

  PYTHONPATH=src python examples/train_lm.py              # ~2M params, CPU
  PYTHONPATH=src python examples/train_lm.py --arch zamba2-7b --smoke

The same driver lowers unchanged against the production mesh (see
repro/launch/dryrun.py); --simulate-failure N demonstrates the
checkpoint/restart + elastic-remesh path.
"""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    if len(sys.argv) == 1:
        sys.argv += ["--arch", "tinyllama-1.1b", "--smoke", "--steps", "200",
                     "--batch", "8", "--seq", "128", "--lr", "1e-3"]
    main()
