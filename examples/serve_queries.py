"""Graph query serving in ~40 lines: submit a mixed query stream to a
resident-engine GraphServer and read back per-query results.

A partitioned graph stays device-resident across queries; BFS/SSSP
source queries coalesce into padded fixed-size batched launches (so
every launch hits an already-compiled program), PageRank/CC refreshes
share one launch per key, and answers are bit-identical to direct
``engine.program()`` calls.

  PYTHONPATH=src python examples/serve_queries.py

For sustained synthetic traffic (Zipfian roots, Poisson arrivals) see
``python -m repro.launch.graph_serve``.
"""

import numpy as np

from repro.core import GraphEngine, partition_graph
from repro.graphs import urand_edges
from repro.launch.mesh import make_graph_mesh
from repro.serve import GraphServer, query, synthetic_trace

n, e = 4096, 32768
edges = urand_edges(n, e, seed=1)
g = partition_graph(edges, n, parts=1)
eng = GraphEngine(g, make_graph_mesh(1))

server = GraphServer(eng, buckets=(1, 4, 16), depth=2)
print("warmup launches:", server.warmup(["bfs", "sssp", "pagerank", "cc"]))

# -- a mixed closed-loop stream ------------------------------------------
results = server.serve([
    query("bfs", root=0),
    query("bfs", root=17),
    query("bfs", root=993),            # three bfs roots -> one batch=4
    query("sssp", root=17),
    query("pagerank"),                 # refresh: no root
    query("cc"),
])
for r in results:
    field = next(iter(r.fields))
    print(f"  q{r.qid} {r.key.label:14s} bucket={r.bucket or 'shared':>6} "
          f"rounds={r.rounds:3d} latency={r.latency_s*1e3:6.1f}ms "
          f"{field}[:4]={np.asarray(r[field])[:4]}")

# served == direct (the conformance gate tests this for every program)
import jax.numpy as jnp  # noqa: E402
parents, _ = eng.program("bfs", "fast")(eng.device_graph(), jnp.int32(17))
np.testing.assert_array_equal(results[1]["parents"],
                              eng.gather_vertex_field(parents))
print("served bfs == direct program() call: OK")

# -- sustained synthetic traffic -----------------------------------------
trace = synthetic_trace(n, "bfs:8,sssp:4,cc:1", rate=300, duration=2.0,
                        seed=7)
server.serve_trace(trace)
print(f"replayed {len(trace)} queries:")
print(server.metrics.table())
