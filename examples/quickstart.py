"""Quickstart: the paper's workload in 50 lines, on the superstep-program
API.

Generates a small Erdos-Renyi graph, runs distributed BFS and PageRank
through ``GraphEngine.program`` (both the BSP baseline and the
HPX-adapted implementation), verifies against a numpy oracle, and
demonstrates a batched multi-source BFS (many roots, one launch).

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import GraphEngine, partition_graph, registry
from repro.graphs import urand_edges
from repro.launch.mesh import make_graph_mesh

n, e = 4096, 32768
edges = urand_edges(n, e, seed=1)
g = partition_graph(edges, n, parts=1)
eng = GraphEngine(g, make_graph_mesh(1))
garr = eng.device_graph()

print("registered programs:", [f"{a}/{v}" for a, v in registry.available()])

# --- BFS (direction-optimizing variant; cached compiled program) ---
bfs = eng.program("bfs", "fast")
parents, levels = bfs(garr, jnp.int32(0))
par = eng.gather_vertex_field(parents)
print(f"BFS: reached {int((par < 2**30).sum())}/{n} vertices "
      f"in {int(levels)} levels")
assert bfs is eng.program("bfs", "fast")  # second lookup: cache hit

# --- PageRank (paper eq. 1) ---
rank, err, iters = eng.program("pagerank", "fast", iters=60, tol=1e-9)(garr)
r = eng.gather_vertex_field(rank)

# numpy oracle (same formulation)
outdeg = np.bincount(edges[:, 0], minlength=n).astype(np.float64)
ref = np.full(n, 1.0 / n)
for _ in range(60):
    contrib = np.where(outdeg > 0, ref / np.maximum(outdeg, 1), 0.0)
    z = np.zeros(n)
    np.add.at(z, edges[:, 1], contrib[edges[:, 0]])
    ref = 0.15 / n + 0.85 * z
rel = np.abs(r - ref).max() / ref.max()
print(f"PageRank: {int(iters)} iters, err={float(err):.2e}, "
      f"max rel diff vs oracle = {rel:.2e}")
assert rel < 5e-3

# --- batched multi-source BFS: 8 roots, one launch ---
B = 8
parents_b, levels_b = eng.program("bfs", "fast", batch=B)(
    garr, jnp.arange(B, dtype=jnp.int32))
per_root = eng.gather_batched_vertex_field(parents_b)   # (B, n)
np.testing.assert_array_equal(per_root[0], par)         # root 0 == above
print(f"multi-source BFS: {B} roots, levels per root = "
      f"{np.asarray(levels_b).tolist()}")
print("OK")
