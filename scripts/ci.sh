#!/usr/bin/env bash
# CI gate: tier-1 test suite + fast benchmark smoke.
#
#   bash scripts/ci.sh             # full suite (tier-1 + slow) + bench
#   bash scripts/ci.sh --markers   # tiered: fast lane first, then slow
#
# The tier split uses the pytest marker `slow` (subprocess / multi-device
# tests).  The oracle-conformance suite is deliberately NOT marked slow:
# it is the correctness gate every registered program must pass, so it
# runs in tier-1 in both modes.
#
# The fast bench writes BENCH_graph.json at the repo root so the perf
# trajectory (algo, graph, parts, ms) is tracked across PRs.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--markers" ]]; then
    echo "== tier-1: pytest -m 'not slow' (fast lane, incl. oracle conformance) =="
    python -m pytest -x -q -m "not slow"
    echo "== tier-2: pytest -m slow (subprocess / multi-device) =="
    python -m pytest -q -m "slow"
else
    echo "== tier-1: pytest =="
    python -m pytest -x -q
fi

echo "== bench smoke: benchmarks.run --fast =="
python -m benchmarks.run --fast

test -f BENCH_graph.json || { echo "BENCH_graph.json missing" >&2; exit 1; }
echo "== CI OK =="
