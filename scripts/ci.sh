#!/usr/bin/env bash
# CI gate: tier-1 test suite + fast benchmark smoke.
#
#   bash scripts/ci.sh
#
# The fast bench writes BENCH_graph.json at the repo root so the perf
# trajectory (algo, parts, ms) is tracked across PRs.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== bench smoke: benchmarks.run --fast =="
python -m benchmarks.run --fast

test -f BENCH_graph.json || { echo "BENCH_graph.json missing" >&2; exit 1; }
echo "== CI OK =="
