#!/usr/bin/env bash
# CI gate: tier-1 test suite + fast benchmark smoke.
#
#   bash scripts/ci.sh             # full suite (tier-1 + slow) + bench
#   bash scripts/ci.sh --markers   # tiered: fast lane first, then slow
#
# The tier split uses the pytest marker `slow` (subprocess / multi-device
# tests).  The oracle-conformance suite is deliberately NOT marked slow:
# it is the correctness gate every registered program must pass, so it
# runs in tier-1 in both modes.  That includes the ASYNC lane — the
# */async variants are registered programs, so they sweep parts
# {1, 2, 4} x three families against the same oracles in tier-1, and
# tests/test_async.py (rounds-accounting + exec_mode plumbing) rides
# the fast lane with them.  The `tier1` marker PINS a suite to the
# fast lane (selected as "tier1 or not slow", so tier1 wins even if a
# suite someday also gets marked slow): the kernel-interpret parity
# suites (tests/test_kernels_{spmv,frontier}.py) carry it because the
# localops dispatch layer routes production hot loops through those
# kernels.
#
# The fast benches write BENCH_graph.json (direct launches — the bfs
# and pagerank figures emit bsp-vs-async row pairs, each row carrying
# rounds_to_converge + wire_mb_per_part, both gated deterministically),
# BENCH_serve.json (the query-serving path: queries/sec + latency per
# (algo, bucket) cell) and BENCH_mutate.json (the dynamic-graph path:
# in-place mutation apply + warm-vs-cold recompute rounds) at the repo
# root so all three perf trajectories are tracked across PRs, and
# benchmarks/compare.py gates the fresh rows against the committed ones
# (>1.25x wall-time growth or queries/sec drop on any cell fails CI).
# bench_mutate additionally fails outright when the PageRank warm
# restart stops beating the cold start on rounds-to-converge.
#
# The `chaos` marker is the seeded fault-injection acceptance sweep
# (tests/test_chaos.py): every registered (algo, variant) pair at
# parts {2, 4} under a drop+corrupt+stall schedule must detect via its
# guard, recover from the last checkpoint, and match the NumPy oracle
# exactly.  It runs as its own lane in BOTH modes (multi-device
# subprocesses — isolating it keeps the tier-1 signal fast and clean).
#
# The `durability` marker is the crash-recovery acceptance drill
# (tests/test_persist.py): for each named crash point in the
# WAL/snapshot protocol, a subprocess server is killed at that exact
# instruction mid-mutation-trace, recovered in a fresh process, and
# must land on the exact epoch + edge multiset with probe answers
# bit-identical to an uninterrupted reference run.  Like chaos, it is
# its own lane in both modes.
#
# The `obs` marker is the observability acceptance drill
# (tests/test_obs.py): a multi-device subprocess runs a short TRACED
# serve session and schema-validates its exported Chrome trace
# (matched async pairs, ordered tracks, proper nesting), asserts
# telemetry-OFF builds stay bit-identical to the seed path, and runs
# telemetry-ON programs through the NumPy-oracle gate at parts
# {1, 2, 4}.  Its own lane in both modes; the in-process obs unit
# tests (series parsing, span rings, exporter schema, registry drift)
# ride tier-1.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--markers" ]]; then
    echo "== tier-1: pytest -m 'tier1 or not slow' (fast lane: conformance + kernel parity) =="
    python -m pytest -x -q -m "(tier1 or not slow) and not chaos and not durability and not obs"
    echo "== tier-2: pytest -m 'slow and not tier1' (subprocess / multi-device) =="
    python -m pytest -q -m "slow and not tier1 and not chaos and not durability and not obs"
else
    echo "== tier-1: pytest =="
    python -m pytest -x -q -m "not chaos and not durability and not obs"
fi

echo "== chaos lane: pytest -m chaos (seeded fault-injection sweep, parts {2,4}) =="
python -m pytest -q -m chaos

echo "== durability lane: pytest -m durability (crash-point kill + recovery drills) =="
python -m pytest -q -m durability

echo "== obs lane: pytest -m obs (traced serve + schema-valid trace export + telemetry bit-identity/conformance) =="
python -m pytest -q -m obs

echo "== bench smoke: benchmarks.run --fast =="
python -m benchmarks.run --fast

test -f BENCH_graph.json || { echo "BENCH_graph.json missing" >&2; exit 1; }

echo "== serve bench: benchmarks.bench_serve --fast =="
python -m benchmarks.bench_serve --fast

test -f BENCH_serve.json || { echo "BENCH_serve.json missing" >&2; exit 1; }

echo "== mutate bench: benchmarks.bench_mutate --fast =="
python -m benchmarks.bench_mutate --fast

test -f BENCH_mutate.json || { echo "BENCH_mutate.json missing" >&2; exit 1; }

echo "== bench regression gate: benchmarks.compare (vs committed rows) =="
python -m benchmarks.compare --threshold 1.25

echo "== CI OK =="
